"""Pallas matmul kernel vs pure-jnp oracle (the core L1 correctness signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, matmul
from compile.kernels.matmul import matmul_ad
from compile.kernels import ref as kref

SETTINGS = dict(max_examples=15, deadline=None)


def _rand(rs, *shape):
    return jnp.asarray(rs.standard_normal(shape), jnp.float32)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 300),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_shape_sweep(m, k, n, seed):
    rs = np.random.default_rng(seed)
    x, w = _rand(rs, m, k), _rand(rs, k, n)
    got = matmul(x, w)
    want = kref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([8, 16, 128, 160]),
    k=st.sampled_from([8, 128, 256]),
    n=st.sampled_from([8, 128]),
    activation=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_aligned_fused_activation(m, k, n, activation, seed):
    """Tile-aligned shapes take the fused epilogue path inside the kernel."""
    rs = np.random.default_rng(seed)
    x, w = _rand(rs, m, k), _rand(rs, k, n)
    got = matmul(x, w, activation=activation)
    want = kref.matmul_ref(x, w, activation=activation)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 200),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_bias_relu(m, k, n, seed):
    rs = np.random.default_rng(seed)
    x, w, b = _rand(rs, m, k), _rand(rs, k, n), _rand(rs, n)
    got = dense(x, w, b, activation="relu")
    want = kref.matmul_ref(x, w, b, activation="relu")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
    assert (np.asarray(got) >= 0).all()


def test_multi_tile_grid():
    """Shapes spanning several 128-tiles in every grid dimension."""
    rs = np.random.default_rng(0)
    x, w = _rand(rs, 300, 384), _rand(rs, 384, 200)
    np.testing.assert_allclose(
        matmul(x, w), kref.matmul_ref(x, w), rtol=1e-5, atol=1e-3
    )


def test_custom_block_sizes():
    rs = np.random.default_rng(1)
    x, w = _rand(rs, 64, 96), _rand(rs, 96, 32)
    got = matmul(x, w, block_m=32, block_n=16, block_k=24)
    np.testing.assert_allclose(got, kref.matmul_ref(x, w), rtol=1e-5, atol=1e-4)


def test_gradients_match_ref():
    """custom_vjp backward (also Pallas) == jnp autodiff of the oracle."""
    rs = np.random.default_rng(2)
    x, w = _rand(rs, 8, 48), _rand(rs, 48, 10)

    def f_pallas(x, w):
        return jnp.sum(jnp.tanh(matmul_ad(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.tanh(jnp.dot(x, w)))

    gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-5, atol=1e-4)


def test_contraction_mismatch_raises():
    rs = np.random.default_rng(3)
    with pytest.raises(AssertionError):
        matmul(_rand(rs, 4, 5), _rand(rs, 6, 7))


def test_zero_input_gives_zero():
    w = jnp.zeros((16, 8), jnp.float32)
    x = jnp.ones((4, 16), jnp.float32)
    assert np.asarray(matmul(x, w)).sum() == 0.0
