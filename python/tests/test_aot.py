"""AOT pipeline tests: manifest consistency and HLO text well-formedness.

These lower small modules in-process (fast) and, when ``artifacts/`` exists,
validate the shipped manifest against the model definitions — the same
contract the Rust runtime trusts.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip_numerics():
    """Lower a tiny jitted fn; the HLO text must contain an ENTRY module."""

    def fn(x, y):
        return (jnp.dot(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "ENTRY" in text and "HloModule" in text
    assert "f32[4,4]" in text


def test_lower_model_writes_all_entries(tmp_path):
    mdef = M.get_model("cnn", image=8)
    meta = aot.lower_model(mdef, str(tmp_path), 4, 8, 4)
    assert set(meta["entries"]) == {"train", "eval", "agg", "sparsify"}
    for e in meta["entries"].values():
        path = tmp_path / e["file"]
        assert path.exists() and path.stat().st_size > 100
    assert meta["param_count"] == mdef.param_count


def test_train_entry_arg_specs(tmp_path):
    mdef = M.get_model("mlp", image=8)
    meta = aot.lower_model(mdef, str(tmp_path), 4, 8, 4)
    args = meta["entries"]["train"]["args"]
    assert [a["name"] for a in args] == ["params", "x", "y", "lr"]
    assert args[0]["shape"] == [mdef.param_count]
    assert args[1]["shape"] == [4, 8, 8, 3]
    assert args[2]["dtype"] == "i32"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_shipped_manifest_matches_models():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["format"] == 1
    for name, meta in man["models"].items():
        mdef = M.get_model(name, image=man["image"])
        assert meta["param_count"] == mdef.param_count, name
        assert meta["input_shape"] == list(mdef.input_shape)
        for e in meta["entries"].values():
            path = os.path.join(ART, e["file"])
            assert os.path.exists(path), path
            with open(path) as f:
                head = f.read(4096)
            assert "HloModule" in head


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_shipped_train_hlo_shapes_mentioned():
    """The lowered train module mentions the exact parameter-vector shape."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, meta in man["models"].items():
        p = meta["param_count"]
        with open(os.path.join(ART, meta["entries"]["train"]["file"])) as f:
            text = f.read()
        assert f"f32[{p}]" in text, name
