"""Pallas aggregation kernel vs oracle + D-PSGD aggregation invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate
from compile.kernels import ref as kref

SETTINGS = dict(max_examples=15, deadline=None)


@settings(**SETTINGS)
@given(
    k=st.integers(1, 24),
    p=st.integers(1, 9000),
    seed=st.integers(0, 2**31 - 1),
)
def test_aggregate_matches_ref(k, p, seed):
    rs = np.random.default_rng(seed)
    stack = jnp.asarray(rs.standard_normal((k, p)), jnp.float32)
    w = jnp.asarray(rs.random(k), jnp.float32)
    got = aggregate(stack, w)
    want = kref.aggregate_ref(stack, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(**SETTINGS)
@given(
    k=st.integers(2, 16),
    kz=st.integers(1, 8),
    p=st.integers(10, 3000),
    seed=st.integers(0, 2**31 - 1),
)
def test_zero_weight_rows_are_inert(k, kz, p, seed):
    """Rows with weight 0 (padding for absent neighbors) change nothing."""
    rs = np.random.default_rng(seed)
    stack = jnp.asarray(rs.standard_normal((k, p)), jnp.float32)
    w = jnp.asarray(rs.random(k), jnp.float32)
    padded = jnp.concatenate(
        [stack, jnp.asarray(rs.standard_normal((kz, p)) * 1e6, jnp.float32)]
    )
    wpad = jnp.concatenate([w, jnp.zeros((kz,), jnp.float32)])
    np.testing.assert_allclose(
        aggregate(padded, wpad), aggregate(stack, w), rtol=1e-6, atol=1e-6
    )


def test_convex_combination_stays_in_hull():
    """With weights summing to 1, each coordinate stays within min/max."""
    rs = np.random.default_rng(7)
    stack = jnp.asarray(rs.standard_normal((6, 500)), jnp.float32)
    w = jnp.asarray([0.3, 0.2, 0.1, 0.15, 0.15, 0.1], jnp.float32)
    out = np.asarray(aggregate(stack, w))
    s = np.asarray(stack)
    assert (out <= s.max(axis=0) + 1e-5).all()
    assert (out >= s.min(axis=0) - 1e-5).all()


def test_identity_weight_selects_row():
    rs = np.random.default_rng(8)
    stack = jnp.asarray(rs.standard_normal((4, 100)), jnp.float32)
    w = jnp.asarray([0.0, 1.0, 0.0, 0.0], jnp.float32)
    np.testing.assert_allclose(aggregate(stack, w), stack[1], atol=1e-6)


def test_block_boundary_sizes():
    """P exactly at / one off the kernel tile boundary."""
    for p in (4095, 4096, 4097, 8192):
        rs = np.random.default_rng(p)
        stack = jnp.asarray(rs.standard_normal((3, p)), jnp.float32)
        w = jnp.asarray([0.5, 0.25, 0.25], jnp.float32)
        np.testing.assert_allclose(
            aggregate(stack, w),
            kref.aggregate_ref(stack, w),
            rtol=1e-5,
            atol=1e-4,
        )
