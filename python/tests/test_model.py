"""L2 model tests: layout round-trips, learning signal, kernel-vs-ref parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

MODELS = ["mlp", "cnn", "celeba"]


def _batch(mdef, n, seed=0):
    rs = np.random.default_rng(seed)
    h, w, c = mdef.input_shape
    x = jnp.asarray(rs.standard_normal((n, h, w, c)), jnp.float32)
    y = jnp.asarray(rs.integers(0, mdef.num_classes, n), jnp.int32)
    return x, y


@pytest.mark.parametrize("name", MODELS)
def test_flatten_unflatten_roundtrip(name):
    mdef = M.get_model(name)
    flat = M.init_params(mdef.spec, seed=3)
    tree = M.unflatten(mdef.spec, flat)
    again = M.flatten(mdef.spec, tree)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(again))
    assert flat.shape == (mdef.param_count,)


@pytest.mark.parametrize("name", MODELS)
def test_init_deterministic(name):
    mdef = M.get_model(name)
    a = M.init_params(mdef.spec, seed=1)
    b = M.init_params(mdef.spec, seed=1)
    c = M.init_params(mdef.spec, seed=2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


@pytest.mark.parametrize("name", MODELS)
def test_forward_pallas_matches_ref(name):
    """Model forward with Pallas dense == model forward with jnp dense."""
    mdef = M.get_model(name)
    flat = M.init_params(mdef.spec, seed=0)
    p = M.unflatten(mdef.spec, flat)
    x, _ = _batch(mdef, 4)
    got = mdef.forward(p, x, False)
    want = mdef.forward(p, x, True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("name", MODELS)
def test_train_step_reduces_loss(name):
    mdef = M.get_model(name)
    flat = M.init_params(mdef.spec, seed=0)
    step = jax.jit(M.make_train_step(mdef))
    x, y = _batch(mdef, 8)
    first = None
    for _ in range(25):
        flat, loss = step(flat, x, y, jnp.float32(0.05))
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.7, (first, float(loss))


@pytest.mark.parametrize("name", MODELS)
def test_train_grad_matches_ref_model(name):
    """One SGD step through the Pallas model == step through the jnp model."""
    mdef = M.get_model(name)
    flat = M.init_params(mdef.spec, seed=0)
    x, y = _batch(mdef, 4)
    p1, l1 = M.make_train_step(mdef, use_ref=False)(flat, x, y, 0.1)
    p2, l2 = M.make_train_step(mdef, use_ref=True)(flat, x, y, 0.1)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-4)


def test_eval_batch_counts():
    mdef = M.get_model("mlp")
    flat = M.init_params(mdef.spec, seed=0)
    x, y = _batch(mdef, 16)
    sum_loss, correct = M.make_eval_batch(mdef)(flat, x, y)
    # Manual check against the forward pass.
    p = M.unflatten(mdef.spec, flat)
    logits = mdef.forward(p, x, True)
    pred = jnp.argmax(logits, -1)
    assert int(correct) == int((pred == y).sum())
    assert 0 <= int(correct) <= 16
    assert np.isfinite(float(sum_loss))


def test_cross_entropy_uniform_logits():
    logits = jnp.zeros((5, 10), jnp.float32)
    y = jnp.asarray([0, 1, 2, 3, 4], jnp.int32)
    np.testing.assert_allclose(
        float(M.cross_entropy(logits, y)), np.log(10.0), rtol=1e-6
    )


def test_get_model_unknown_raises():
    with pytest.raises(KeyError):
        M.get_model("resnet152")


def test_image_rescaling_changes_param_count():
    small = M.get_model("mlp", image=8)
    big = M.get_model("mlp", image=32)
    assert small.param_count < big.param_count
