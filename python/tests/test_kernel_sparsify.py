"""Pallas sparsification kernel vs oracle + error-feedback invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import sparsify
from compile.kernels import ref as kref

SETTINGS = dict(max_examples=15, deadline=None)


def _case(seed, p):
    rs = np.random.default_rng(seed)
    v = jnp.asarray(rs.standard_normal(p), jnp.float32)
    r = jnp.asarray(rs.standard_normal(p) * 0.3, jnp.float32)
    return v, r


@settings(**SETTINGS)
@given(
    p=st.integers(1, 9000),
    t=st.floats(0.0, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparsify_matches_ref(p, t, seed):
    v, r = _case(seed, p)
    th = jnp.asarray([t], jnp.float32)
    s, nr = sparsify(v, r, th)
    s2, nr2 = kref.sparsify_ref(v, r, th)
    np.testing.assert_allclose(s, s2, atol=1e-6)
    np.testing.assert_allclose(nr, nr2, atol=1e-6)


@settings(**SETTINGS)
@given(
    p=st.integers(1, 5000),
    t=st.floats(0.0, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_error_feedback_conserves_mass(p, t, seed):
    """sent + residual' == values + residual exactly (no information lost)."""
    v, r = _case(seed, p)
    s, nr = sparsify(v, r, jnp.asarray([t], jnp.float32))
    np.testing.assert_allclose(
        np.asarray(s) + np.asarray(nr), np.asarray(v) + np.asarray(r),
        atol=1e-6,
    )


@settings(**SETTINGS)
@given(
    p=st.integers(1, 5000),
    t=st.floats(0.01, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_disjoint_support(p, t, seed):
    """Each coordinate is either sent or kept as residual, never both."""
    v, r = _case(seed, p)
    s, nr = sparsify(v, r, jnp.asarray([t], jnp.float32))
    assert (np.asarray(s) * np.asarray(nr) == 0.0).all()


def test_threshold_zero_sends_everything():
    v, r = _case(0, 1000)
    s, nr = sparsify(v, r, jnp.asarray([0.0], jnp.float32))
    np.testing.assert_allclose(s, np.asarray(v) + np.asarray(r), atol=1e-6)
    assert np.abs(np.asarray(nr)).max() == 0.0


def test_threshold_monotone_density():
    """Higher thresholds send fewer coordinates."""
    v, r = _case(1, 4000)
    prev = None
    for t in (0.0, 0.5, 1.0, 2.0, 4.0):
        s, _ = sparsify(v, r, jnp.asarray([t], jnp.float32))
        nz = int((np.asarray(s) != 0).sum())
        if prev is not None:
            assert nz <= prev
        prev = nz
