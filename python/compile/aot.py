"""AOT lowering: JAX/Pallas entry points -> HLO text artifacts + manifest.

Run once at build time (``make artifacts``); the Rust coordinator loads the
artifacts through PJRT and Python never appears on the training path again.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (what
the published ``xla`` 0.1.6 crate links) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Artifacts per model (mlp / cnn / celeba):
  {model}_train.hlo.txt     (params[P], x[B,H,W,C], y[B]i32, lr[1]) -> (params', loss)
  {model}_eval.hlo.txt      (params[P], x[E,H,W,C], y[E]i32) -> (sum_loss, correct i32)
  {model}_agg.hlo.txt       (stack[K,P], weights[K]) -> params[P]
  {model}_sparsify.hlo.txt  (values[P], residual[P], threshold[1]) -> (sent, residual')
plus ``manifest.json`` describing every argument/output shape so the Rust
runtime is fully manifest-driven.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _arg(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def lower_model(mdef, out_dir, train_batch, eval_batch, agg_k):
    """Lower all four entry points for one model; returns manifest entries."""
    p = mdef.param_count
    h, w, c = mdef.input_shape
    entries = {}

    def emit(tag, fn, specs, args_meta, outs_meta):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{mdef.name}_{tag}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries[tag] = {"file": fname, "args": args_meta, "outs": outs_meta}
        print(f"  {fname}: {len(text)} chars")

    # train: lr enters as a [1] array (a rank-0 scalar is awkward to build
    # from the rust Literal API).
    raw_train = M.make_train_step(mdef)

    def train(params, x, y, lr):
        return raw_train(params, x, y, lr[0])

    emit(
        "train",
        train,
        [
            _spec((p,)),
            _spec((train_batch, h, w, c)),
            _spec((train_batch,), jnp.int32),
            _spec((1,)),
        ],
        [
            _arg("params", (p,)),
            _arg("x", (train_batch, h, w, c)),
            _arg("y", (train_batch,), "i32"),
            _arg("lr", (1,)),
        ],
        [_arg("params", (p,)), _arg("loss", ())],
    )

    emit(
        "eval",
        M.make_eval_batch(mdef),
        [
            _spec((p,)),
            _spec((eval_batch, h, w, c)),
            _spec((eval_batch,), jnp.int32),
        ],
        [
            _arg("params", (p,)),
            _arg("x", (eval_batch, h, w, c)),
            _arg("y", (eval_batch,), "i32"),
        ],
        [_arg("sum_loss", ()), _arg("correct", (), "i32")],
    )

    emit(
        "agg",
        M.make_aggregate(agg_k),
        [_spec((agg_k, p)), _spec((agg_k,))],
        [_arg("stack", (agg_k, p)), _arg("weights", (agg_k,))],
        [_arg("params", (p,))],
    )

    emit(
        "sparsify",
        M.make_sparsify(),
        [_spec((p,)), _spec((p,)), _spec((1,))],
        [
            _arg("values", (p,)),
            _arg("residual", (p,)),
            _arg("threshold", (1,)),
        ],
        [_arg("sent", (p,)), _arg("residual", (p,))],
    )

    # Initial parameters (He-uniform, seed 0): every node starts from the
    # same point in D-PSGD, and the Rust side must not re-implement the
    # init scheme. Raw little-endian f32.
    init = M.init_params(mdef.spec, seed=0)
    init_file = f"{mdef.name}_init.f32"
    import numpy as np

    np.asarray(init, dtype="<f4").tofile(os.path.join(out_dir, init_file))
    print(f"  {init_file}: {p} params")

    return {
        "param_count": p,
        "input_shape": [h, w, c],
        "num_classes": mdef.num_classes,
        "train_batch": train_batch,
        "eval_batch": eval_batch,
        "agg_k": agg_k,
        "init_file": init_file,
        "entries": entries,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="mlp,cnn,celeba")
    ap.add_argument("--image", type=int, default=16,
                    help="input image resolution (square)")
    ap.add_argument("--train-batch", type=int, default=8)
    ap.add_argument("--eval-batch", type=int, default=32)
    ap.add_argument("--agg-k", type=int, default=16,
                    help="max models per aggregation call (degree+1 <= K)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": 1, "image": args.image, "models": {}}
    for name in args.models.split(","):
        name = name.strip()
        mdef = M.get_model(name, image=args.image)
        print(f"lowering {name} (P={mdef.param_count}) ...")
        manifest["models"][name] = lower_model(
            mdef, args.out_dir, args.train_batch, args.eval_batch, args.agg_k
        )
    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
