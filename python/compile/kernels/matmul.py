"""L1 Pallas kernel: blocked matmul with fused bias and activation.

This is the compute hot-spot of every dense layer in the L2 models
(`python/compile/model.py`).  The kernel is written TPU-idiomatically —
tiles sized for VMEM feeding an MXU-shaped ``jnp.dot`` — but is lowered with
``interpret=True`` on this image so it inlines into plain HLO that the CPU
PJRT client can execute (real-TPU lowering emits a Mosaic custom-call the
CPU plugin cannot run; see DESIGN.md §Hardware-Adaptation).

Correctness oracle: :func:`kernels.ref.matmul_ref` (pure jnp), exercised by
``python/tests/test_kernel_matmul.py`` with hypothesis shape sweeps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default MXU-friendly tile sizes.  On TPU the MXU is a 128x128 systolic
# array; feeding it (128, 128) f32 blocks from VMEM keeps it saturated.  On
# small problems we shrink blocks to the (padded) problem size instead of
# wasting VMEM on padding.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, activation, nsteps_k):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (sequential) axis.

    The f32 accumulator lives in a VMEM scratch buffer so the MXU output is
    accumulated at full precision regardless of the input dtype.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nsteps_k - 1)
    def _done():
        out = acc_ref[...]
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def _pick_block(dim: int, preferred: int) -> int:
    """Largest tile <= preferred; tiny dims round up to a sublane multiple."""
    if dim >= preferred:
        return preferred
    # Round tiny dims up to a multiple of 8 (f32 sublane) so the tile is
    # layout-friendly; interpret mode does not care, real TPU does.
    return max(8, -(-dim // 8) * 8)


def _pad_to(x, target, axis):
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k")
)
def matmul(
    x,
    w,
    b=None,
    *,
    activation: str = "none",
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
):
    """``activation(x @ w + b)`` as a blocked Pallas kernel.

    Arbitrary ``(M, K) @ (K, N)`` shapes are supported by padding up to the
    tile grid and slicing back.  Zero padding is exact for matmul; when the
    output needed padding (or a bias is given) the bias/activation epilogue
    runs on the sliced result instead of inside the kernel, so the fused
    path is kept for aligned no-bias shapes and numerics are identical
    everywhere.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {w.shape}"

    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk

    xp = _pad_to(_pad_to(x, mp, 0), kp, 1)
    wp = _pad_to(_pad_to(w, kp, 0), np_, 1)
    nsteps_k = kp // bk

    fuse = b is None and mp == m and np_ == n
    out = pl.pallas_call(
        functools.partial(
            _matmul_kernel,
            activation=activation if fuse else "none",
            nsteps_k=nsteps_k,
        ),
        grid=(mp // bm, np_ // bn, nsteps_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(xp, wp)

    out = out[:m, :n]
    if not fuse:
        if b is not None:
            out = out + b
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
    return out


@jax.custom_vjp
def matmul_ad(x, w):
    """Differentiable blocked-Pallas matmul.

    ``pallas_call`` has no JVP rule, so the backward pass is supplied
    explicitly — and itself runs through the same Pallas kernel:
    ``dx = g @ w.T`` and ``dw = x.T @ g``.
    """
    return matmul(x, w)


def _matmul_ad_fwd(x, w):
    return matmul(x, w), (x, w)


def _matmul_ad_bwd(res, g):
    x, w = res
    return matmul(g, w.T), matmul(x.T, g)


matmul_ad.defvjp(_matmul_ad_fwd, _matmul_ad_bwd)


def dense(x, w, b, activation: str = "none"):
    """Dense layer used by the L2 models (differentiable).

    The matmul runs in the Pallas kernel (fwd and bwd); the bias add and
    activation form a trivially-differentiable jnp epilogue that XLA fuses
    into the surrounding HLO.
    """
    out = matmul_ad(x, w) + b
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out
