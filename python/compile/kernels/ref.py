"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has an oracle here with identical semantics;
``python/tests/`` asserts allclose between kernel and oracle across
hypothesis-generated shapes.  The oracles are also what the L2 model uses
in its reference mode so kernel bugs cannot hide behind model bugs.
"""

import jax.numpy as jnp


def matmul_ref(x, w, b=None, *, activation: str = "none"):
    out = jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
    if b is not None:
        out = out + b
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def aggregate_ref(stack, weights):
    return jnp.einsum("k,kp->p", weights, stack)


def sparsify_ref(values, residual, threshold):
    corrected = values + residual
    keep = jnp.abs(corrected) >= threshold[0]
    sent = jnp.where(keep, corrected, 0.0)
    return sent, corrected - sent
