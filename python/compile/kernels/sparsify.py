"""L1 Pallas kernel: threshold sparsification with error feedback.

Sparsifying sharers (TopK, Choco-SGD) send only parameters whose magnitude
clears a threshold; the un-sent remainder is kept locally as an error
residual and re-added next round (error feedback).  The top-k *selection*
(finding the threshold) is done host-side by the Rust coordinator — an
order-statistics problem that does not vectorize — while this kernel does
the bandwidth-bound part: fused residual-add, mask, and residual update in
one pass over the parameter vector (pure VPU elementwise work, one VMEM
block of P at a time).

Oracle: :func:`kernels.ref.sparsify_ref`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 4096


def _sparsify_kernel(v_ref, r_ref, t_ref, out_ref, new_r_ref):
    corrected = v_ref[...] + r_ref[...]
    keep = jnp.abs(corrected) >= t_ref[0]
    sent = jnp.where(keep, corrected, 0.0)
    out_ref[...] = sent
    new_r_ref[...] = corrected - sent


@functools.partial(jax.jit, static_argnames=("block_p",))
def sparsify(values, residual, threshold, *, block_p: int = BLOCK_P):
    """Error-compensated threshold sparsification.

    Returns ``(sent, new_residual)`` where
    ``sent = (v + r) * [|v + r| >= t]`` and ``new_residual = (v + r) - sent``.

    ``values``/``residual``: f32[P]; ``threshold``: f32[1] (runtime scalar —
    kept as a rank-1 input so it lands in SMEM on real TPU).
    """
    p = values.shape[0]
    bp = min(block_p, p)
    pp = -(-p // bp) * bp
    if pp != p:
        values = jnp.pad(values, (0, pp - p))
        residual = jnp.pad(residual, (0, pp - p))
    sent, new_r = pl.pallas_call(
        _sparsify_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pp,), jnp.float32),
            jax.ShapeDtypeStruct((pp,), jnp.float32),
        ],
        interpret=True,
    )(values, residual, threshold)
    return sent[:p], new_r[:p]
