"""L1: Pallas kernels for the compute hot-spots (build-time only).

All kernels are lowered with ``interpret=True`` so they inline into plain
HLO executable by the CPU PJRT client; see DESIGN.md §Hardware-Adaptation.
"""

from .aggregate import aggregate  # noqa: F401
from .matmul import dense, matmul  # noqa: F401
from .sparsify import sparsify  # noqa: F401
