"""L1 Pallas kernel: weighted neighbor-model aggregation.

The D-PSGD aggregation step is ``theta' = sum_k w_k * theta_k`` over the
node's own model and its neighbors' models (Metropolis-Hastings weights).
The kernel streams the ``[K, P]`` stacked-model matrix through VMEM one
``P``-block at a time and reduces over ``K`` on the VPU — this is the L3
coordinator's per-round hot path when executed via the exported HLO
artifact (`artifacts/<model>_aggregate.hlo.txt`).

Oracle: :func:`kernels.ref.aggregate_ref`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# P-axis tile: 8 * 128 lanes of f32 per row of the VREG layout; 4096 keeps
# the [K, 4096] working set comfortably inside VMEM for K <= 64.
BLOCK_P = 4096


def _aggregate_kernel(stack_ref, w_ref, o_ref):
    # stack_ref: [K, bp] block, w_ref: [K] weights, o_ref: [bp].
    # Weighted reduction over K expressed as a (1, K) @ (K, bp) contraction
    # so a real-TPU lowering maps it onto the MXU; in interpret mode it is a
    # plain dot.
    w = w_ref[...].reshape(1, -1)
    o_ref[...] = jnp.dot(
        w, stack_ref[...], preferred_element_type=jnp.float32
    ).reshape(-1)


@functools.partial(jax.jit, static_argnames=("block_p",))
def aggregate(stack, weights, *, block_p: int = BLOCK_P):
    """``sum_k weights[k] * stack[k, :]`` as a Pallas kernel.

    ``stack``: f32[K, P] — row 0 is conventionally the node's own model.
    ``weights``: f32[K] — Metropolis-Hastings (or arbitrary) mixing weights;
    rows a node did not receive carry weight 0, so padding is exact.
    """
    k, p = stack.shape
    bp = min(block_p, p)
    # Pad P up to a tile multiple; zero tail contributes nothing.
    pp = -(-p // bp) * bp
    if pp != p:
        stack = jnp.pad(stack, ((0, 0), (0, pp - p)))
    out = pl.pallas_call(
        _aggregate_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((k, bp), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), jnp.float32),
        interpret=True,
    )(stack, weights)
    return out[:p]
