"""L2: JAX models for DecentralizeRs (build-time only).

Defines the training-path compute graphs that the Rust coordinator executes
through PJRT: per-model ``train_step`` (forward + backward + SGD, matching
the paper's plain-SGD-no-momentum setup) and ``eval_batch``.  Dense layers
call the L1 Pallas matmul kernel so the kernel lowers into the same HLO
module (see ``kernels/matmul.py``).

Parameters cross the Rust<->HLO boundary as ONE flat f32 vector — the same
representation the DL sharing/aggregation path uses — so the coordinator
never needs to know the pytree structure.  ``ParamSpec`` records the
(name, shape) layout; ``flatten``/``unflatten`` are exact inverses.

Models (sized for 1-core CPU emulation; see DESIGN.md substitution table):
  * ``mlp``    — CIFAR10-S:  flatten -> dense(h, relu) -> dense(10)
  * ``cnn``    — CIFAR10-S:  2x [conv3x3 + relu + avgpool2] -> dense(10)
                 (a GN-LeNet-flavored small CNN, convs via lax.conv)
  * ``celeba`` — CelebA-S:   same CNN shape, 2 classes
"""

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import kernels
from .kernels import ref as kref


# --------------------------------------------------------------------------
# Parameter layout: ordered (name, shape) list <-> flat f32 vector.
# --------------------------------------------------------------------------

ParamSpec = List[Tuple[str, Tuple[int, ...]]]


def param_count(spec: ParamSpec) -> int:
    total = 0
    for _, shape in spec:
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


def unflatten(spec: ParamSpec, flat) -> Dict[str, jnp.ndarray]:
    params = {}
    off = 0
    for name, shape in spec:
        n = 1
        for d in shape:
            n *= d
        params[name] = flat[off : off + n].reshape(shape)
        off += n
    return params


def flatten(spec: ParamSpec, params: Dict[str, jnp.ndarray]):
    return jnp.concatenate([params[name].reshape(-1) for name, _ in spec])


def init_params(spec: ParamSpec, seed: int = 0):
    """He-uniform init for weight matrices/filters, zeros for biases."""
    key = jax.random.PRNGKey(seed)
    leaves = []
    for name, shape in spec:
        key, sub = jax.random.split(key)
        if len(shape) == 1:  # bias
            leaves.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            bound = (6.0 / fan_in) ** 0.5
            leaves.append(
                jax.random.uniform(
                    sub, shape, jnp.float32, minval=-bound, maxval=bound
                )
            )
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    return flat


# --------------------------------------------------------------------------
# Model definitions.
# --------------------------------------------------------------------------


class ModelDef:
    """A model: its ParamSpec, input shape, and forward function."""

    def __init__(self, name, spec, input_shape, num_classes, forward):
        self.name = name
        self.spec = spec
        self.input_shape = input_shape  # per-example, e.g. (16, 16, 3)
        self.num_classes = num_classes
        self.forward = forward  # (params_dict, x, use_ref) -> logits

    @property
    def param_count(self) -> int:
        return param_count(self.spec)


def _dense(x, w, b, activation, use_ref):
    if use_ref:
        return kref.matmul_ref(x, w, b, activation=activation)
    return kernels.dense(x, w, b, activation=activation)


def _mlp_def(image: int = 16, channels: int = 3, hidden: int = 64,
             classes: int = 10, name: str = "mlp") -> ModelDef:
    d = image * image * channels
    spec: ParamSpec = [
        ("w1", (d, hidden)),
        ("b1", (hidden,)),
        ("w2", (hidden, classes)),
        ("b2", (classes,)),
    ]

    def forward(p, x, use_ref=False):
        b = x.shape[0]
        h = _dense(x.reshape(b, -1), p["w1"], p["b1"], "relu", use_ref)
        return _dense(h, p["w2"], p["b2"], "none", use_ref)

    return ModelDef(name, spec, (image, image, channels), classes, forward)


def _conv(x, w, b):
    """NHWC conv3x3, SAME padding, stride 1, + bias."""
    out = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def _avgpool2(x):
    return lax.reduce_window(
        x, 0.0, lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ) / 4.0


def _cnn_def(image: int = 16, channels: int = 3, classes: int = 10,
             c1: int = 8, c2: int = 16, name: str = "cnn") -> ModelDef:
    feat = (image // 4) * (image // 4) * c2
    spec: ParamSpec = [
        ("k1", (3, 3, channels, c1)),
        ("c1b", (c1,)),
        ("k2", (3, 3, c1, c2)),
        ("c2b", (c2,)),
        ("w", (feat, classes)),
        ("b", (classes,)),
    ]

    def forward(p, x, use_ref=False):
        b = x.shape[0]
        h = jnp.maximum(_conv(x, p["k1"], p["c1b"]), 0.0)
        h = _avgpool2(h)
        h = jnp.maximum(_conv(h, p["k2"], p["c2b"]), 0.0)
        h = _avgpool2(h)
        return _dense(h.reshape(b, -1), p["w"], p["b"], "none", use_ref)

    return ModelDef(name, spec, (image, image, channels), classes, forward)


MODELS: Dict[str, ModelDef] = {
    "mlp": _mlp_def(),
    "cnn": _cnn_def(),
    "celeba": _cnn_def(classes=2, name="celeba"),
}


def get_model(name: str, image: int = 16) -> ModelDef:
    """Construct a ModelDef; ``image`` rescales the input resolution."""
    if name == "mlp":
        return _mlp_def(image=image)
    if name == "cnn":
        return _cnn_def(image=image)
    if name == "celeba":
        return _cnn_def(image=image, classes=2, name="celeba")
    raise KeyError(f"unknown model {name!r}; have {sorted(MODELS)}")


# --------------------------------------------------------------------------
# Training / evaluation entry points (what aot.py lowers).
# --------------------------------------------------------------------------


def cross_entropy(logits, y):
    """Mean softmax cross-entropy; y is int32 class ids."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def make_train_step(mdef: ModelDef, use_ref: bool = False):
    """(flat_params, x, y, lr) -> (flat_params', loss). Plain SGD."""

    def loss_fn(flat, x, y):
        p = unflatten(mdef.spec, flat)
        logits = mdef.forward(p, x, use_ref)
        return cross_entropy(logits, y)

    def train_step(flat, x, y, lr):
        loss, grad = jax.value_and_grad(loss_fn)(flat, x, y)
        return flat - lr * grad, loss

    return train_step


def make_eval_batch(mdef: ModelDef, use_ref: bool = False):
    """(flat_params, x, y) -> (sum_loss, correct_count).

    Returns *sums* (not means) so the Rust side can accumulate exact
    test-set metrics across batches of any size.
    """

    def eval_batch(flat, x, y):
        p = unflatten(mdef.spec, flat)
        logits = mdef.forward(p, x, use_ref)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        sum_loss = jnp.sum(logz - gold)
        correct = jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.int32))
        return sum_loss, correct

    return eval_batch


def make_aggregate(k: int):
    """(stack[K,P], weights[K]) -> [P] via the L1 aggregation kernel."""

    def agg(stack, weights):
        return kernels.aggregate(stack, weights)

    return agg


def make_sparsify():
    """(values[P], residual[P], threshold[1]) -> (sent[P], residual'[P])."""

    def sp(values, residual, threshold):
        return kernels.sparsify(values, residual, threshold)

    return sp
