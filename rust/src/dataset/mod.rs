//! Datasets, partitioning, and loading (the paper's *Dataset* module).
//!
//! The paper trains on CIFAR-10 (2-shard non-IID) and CelebA. Real
//! downloads are unavailable in this offline environment, so we generate
//! **synthetic class-conditional datasets** with the same tensor layout and
//! the exact same partitioners (see DESIGN.md's substitution table): the
//! systems claims under reproduction — topology orderings, byte costs,
//! sparsification degradation under non-IID — depend on having a real
//! learnable task with controlled label skew, not on the photographs.

mod loader;
mod partition;
mod synthetic;

pub use loader::*;
pub use partition::*;
pub use synthetic::*;

/// An in-memory labeled image dataset (row-major f32 features).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `len * dim` feature matrix, row per example, NHWC within a row.
    pub features: Vec<f32>,
    /// Class id per example.
    pub labels: Vec<u8>,
    /// (height, width, channels).
    pub shape: (usize, usize, usize),
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Flattened per-example feature dimension.
    pub fn dim(&self) -> usize {
        let (h, w, c) = self.shape;
        h * w * c
    }

    pub fn example(&self, i: usize) -> (&[f32], u8) {
        let d = self.dim();
        (&self.features[i * d..(i + 1) * d], self.labels[i])
    }

    /// Materialize a subset by indices (used to build per-node shards).
    pub fn subset(&self, indices: &[usize]) -> Dataset {
        let d = self.dim();
        let mut features = Vec::with_capacity(indices.len() * d);
        let mut labels = Vec::with_capacity(indices.len());
        for &i in indices {
            features.extend_from_slice(&self.features[i * d..(i + 1) * d]);
            labels.push(self.labels[i]);
        }
        Dataset { features, labels, shape: self.shape, num_classes: self.num_classes }
    }

    /// Count of examples per class.
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }

    /// Number of distinct classes present.
    pub fn distinct_classes(&self) -> usize {
        self.class_histogram().iter().filter(|&&c| c > 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            features: (0..12).map(|x| x as f32).collect(),
            labels: vec![0, 1, 1],
            shape: (2, 2, 1),
            num_classes: 2,
        }
    }

    #[test]
    fn example_access() {
        let d = tiny();
        assert_eq!(d.len(), 3);
        assert_eq!(d.dim(), 4);
        let (f, l) = d.example(1);
        assert_eq!(f, &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(l, 1);
    }

    #[test]
    fn subset_materializes() {
        let d = tiny();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.labels, vec![1, 0]);
        assert_eq!(s.example(0).0, &[8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    fn histogram() {
        let d = tiny();
        assert_eq!(d.class_histogram(), vec![1, 2]);
        assert_eq!(d.distinct_classes(), 2);
    }
}
