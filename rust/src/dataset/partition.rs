//! Data partitioners: how the global training set is split across nodes.
//!
//! * [`Partition::Iid`] — shuffle and split evenly.
//! * [`Partition::Shards`] — McMahan-style sharding: sort by label, cut
//!   into `nodes * shards_per_node` contiguous shards, deal each node
//!   `shards_per_node` of them. The paper uses "2-sharding non-IID ...
//!   which limits the number of classes per node" (§3.1).
//! * [`Partition::Dirichlet`] — label-distribution skew with
//!   concentration `alpha` (common in the non-IID literature).
//!
//! All partitioners return disjoint index sets covering (almost) the whole
//! dataset, and are deterministic given the experiment seed.

use crate::rng::Xoshiro256pp;

/// Partition strategy.
#[derive(Debug, Clone, PartialEq)]
pub enum Partition {
    Iid,
    Shards { per_node: usize },
    Dirichlet { alpha: f64 },
}

impl Partition {
    /// Parse from a config string: `iid`, `shards:<k>`, `dirichlet:<alpha>`.
    pub fn from_spec(spec: &str) -> anyhow::Result<Partition> {
        let parts: Vec<&str> = spec.split(':').collect();
        Ok(match parts.as_slice() {
            ["iid"] => Partition::Iid,
            ["shards", k] => Partition::Shards { per_node: k.parse()? },
            ["dirichlet", a] => Partition::Dirichlet { alpha: a.parse()? },
            _ => anyhow::bail!("unknown partition spec {spec:?}"),
        })
    }

    /// Compute per-node example indices.
    pub fn split(
        &self,
        labels: &[u8],
        nodes: usize,
        rng: &mut Xoshiro256pp,
    ) -> Vec<Vec<usize>> {
        assert!(nodes > 0, "no nodes");
        match self {
            Partition::Iid => iid(labels.len(), nodes, rng),
            Partition::Shards { per_node } => shards(labels, nodes, *per_node, rng),
            Partition::Dirichlet { alpha } => dirichlet(labels, nodes, *alpha, rng),
        }
    }
}

fn iid(n: usize, nodes: usize, rng: &mut Xoshiro256pp) -> Vec<Vec<usize>> {
    let mut idx = rng.permutation(n);
    let per = n / nodes;
    let mut out = Vec::with_capacity(nodes);
    for _ in 0..nodes {
        let rest = idx.split_off(per.min(idx.len()));
        out.push(idx);
        idx = rest;
    }
    // Leftover examples (n % nodes) are dropped, matching equal-shard
    // experimental setups.
    out
}

fn shards(
    labels: &[u8],
    nodes: usize,
    per_node: usize,
    rng: &mut Xoshiro256pp,
) -> Vec<Vec<usize>> {
    let n = labels.len();
    let total_shards = nodes * per_node;
    assert!(total_shards <= n, "more shards than examples");
    // Sort indices by label (stable: ties keep dataset order).
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by_key(|&i| labels[i]);
    // Cut into contiguous shards and deal them randomly.
    let shard_size = n / total_shards;
    let mut shard_ids: Vec<usize> = (0..total_shards).collect();
    rng.shuffle(&mut shard_ids);
    let mut out = vec![Vec::with_capacity(per_node * shard_size); nodes];
    for (pos, &sid) in shard_ids.iter().enumerate() {
        let node = pos % nodes;
        let start = sid * shard_size;
        let end = if sid == total_shards - 1 { start + shard_size } else { start + shard_size };
        out[node].extend_from_slice(&idx[start..end]);
    }
    out
}

fn dirichlet(
    labels: &[u8],
    nodes: usize,
    alpha: f64,
    rng: &mut Xoshiro256pp,
) -> Vec<Vec<usize>> {
    let num_classes = labels.iter().map(|&l| l as usize + 1).max().unwrap_or(1);
    // Indices per class, shuffled.
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
    for (i, &l) in labels.iter().enumerate() {
        per_class[l as usize].push(i);
    }
    for c in per_class.iter_mut() {
        rng.shuffle(c);
    }
    let mut out = vec![Vec::new(); nodes];
    for class_idx in per_class {
        if class_idx.is_empty() {
            continue;
        }
        let props = rng.dirichlet(alpha, nodes);
        // Convert proportions to cut points.
        let mut start = 0usize;
        let mut acc = 0.0f64;
        for (node, &p) in props.iter().enumerate() {
            acc += p;
            let end = if node == nodes - 1 {
                class_idx.len()
            } else {
                ((acc * class_idx.len() as f64).round() as usize).min(class_idx.len())
            };
            out[node].extend_from_slice(&class_idx[start..end]);
            start = end;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize, classes: usize) -> Vec<u8> {
        (0..n).map(|i| (i % classes) as u8).collect()
    }

    fn assert_disjoint_cover(parts: &[Vec<usize>], n: usize, min_cover: usize) {
        let mut seen = std::collections::HashSet::new();
        for p in parts {
            for &i in p {
                assert!(i < n);
                assert!(seen.insert(i), "index {i} assigned twice");
            }
        }
        assert!(seen.len() >= min_cover, "covered {} < {min_cover}", seen.len());
    }

    #[test]
    fn iid_split_even_and_disjoint() {
        let mut rng = Xoshiro256pp::new(0);
        let l = labels(1000, 10);
        let parts = Partition::Iid.split(&l, 8, &mut rng);
        assert_eq!(parts.len(), 8);
        assert!(parts.iter().all(|p| p.len() == 125));
        assert_disjoint_cover(&parts, 1000, 1000);
    }

    #[test]
    fn iid_is_label_balanced() {
        let mut rng = Xoshiro256pp::new(1);
        let l = labels(2000, 10);
        let parts = Partition::Iid.split(&l, 4, &mut rng);
        for p in &parts {
            let mut h = [0usize; 10];
            for &i in p {
                h[l[i] as usize] += 1;
            }
            // Each class ~50 per node out of 500.
            assert!(h.iter().all(|&c| (30..=70).contains(&c)), "{h:?}");
        }
    }

    #[test]
    fn two_sharding_limits_classes_per_node() {
        let mut rng = Xoshiro256pp::new(2);
        let l = labels(2000, 10);
        let parts = Partition::Shards { per_node: 2 }.split(&l, 20, &mut rng);
        assert_disjoint_cover(&parts, 2000, 1900);
        for p in &parts {
            let classes: std::collections::HashSet<u8> =
                p.iter().map(|&i| l[i]).collect();
            // 2 shards -> at most 3 classes (a shard can straddle one
            // label boundary), typically <= 2.
            assert!(classes.len() <= 3, "{} classes", classes.len());
            assert!(!p.is_empty());
        }
    }

    #[test]
    fn sharding_deterministic() {
        let l = labels(500, 10);
        let a = Partition::Shards { per_node: 2 }.split(&l, 10, &mut Xoshiro256pp::new(9));
        let b = Partition::Shards { per_node: 2 }.split(&l, 10, &mut Xoshiro256pp::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn dirichlet_skew_increases_as_alpha_drops() {
        let l = labels(4000, 10);
        let skew = |alpha: f64| -> f64 {
            let mut rng = Xoshiro256pp::new(5);
            let parts = Partition::Dirichlet { alpha }.split(&l, 8, &mut rng);
            // Mean (max class share) per node.
            parts
                .iter()
                .map(|p| {
                    let mut h = [0f64; 10];
                    for &i in p {
                        h[l[i] as usize] += 1.0;
                    }
                    let total: f64 = h.iter().sum();
                    h.iter().cloned().fold(0.0, f64::max) / total.max(1.0)
                })
                .sum::<f64>()
                / parts.len() as f64
        };
        let spiky = skew(0.1);
        let flat = skew(100.0);
        assert!(spiky > flat + 0.1, "spiky {spiky} flat {flat}");
    }

    #[test]
    fn dirichlet_disjoint() {
        let mut rng = Xoshiro256pp::new(6);
        let l = labels(1000, 10);
        let parts = Partition::Dirichlet { alpha: 0.5 }.split(&l, 6, &mut rng);
        assert_disjoint_cover(&parts, 1000, 1000);
    }

    #[test]
    fn scaling_nodes_shrinks_shards() {
        // Fig 6 setup: fixed dataset, 4x nodes -> 4x fewer samples each.
        let l = labels(4096, 10);
        let small = Partition::Iid.split(&l, 16, &mut Xoshiro256pp::new(7));
        let large = Partition::Iid.split(&l, 64, &mut Xoshiro256pp::new(7));
        assert_eq!(small[0].len(), 256);
        assert_eq!(large[0].len(), 64);
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(Partition::from_spec("iid").unwrap(), Partition::Iid);
        assert_eq!(
            Partition::from_spec("shards:2").unwrap(),
            Partition::Shards { per_node: 2 }
        );
        assert_eq!(
            Partition::from_spec("dirichlet:0.3").unwrap(),
            Partition::Dirichlet { alpha: 0.3 }
        );
        assert!(Partition::from_spec("nope").is_err());
    }
}
