//! Synthetic class-conditional image generators (CIFAR10-S, CelebA-S).
//!
//! Each class has a fixed smooth "prototype" image — a sum of class-seeded
//! 2-D sinusoids with a class color bias — and each example is the
//! prototype plus i.i.d. Gaussian pixel noise and a small random global
//! shift. This yields a genuinely learnable multi-class task (linear
//! probes get part of it, small CNN/MLPs do much better) whose difficulty
//! is tunable via `noise`, while staying fully deterministic per seed.

use crate::rng::{mix_seed, Xoshiro256pp};

use super::Dataset;

/// Specification of a synthetic dataset.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    /// Dataset family name, e.g. "cifar10s" or "celebas".
    pub name: String,
    pub num_classes: usize,
    /// Square image resolution.
    pub image: usize,
    pub channels: usize,
    pub train: usize,
    pub test: usize,
    /// Per-pixel Gaussian noise sigma (task difficulty knob).
    pub noise: f32,
    pub seed: u64,
}

impl SyntheticSpec {
    /// CIFAR-10 stand-in: 10 classes, 3 channels.
    pub fn cifar10s(image: usize, train: usize, test: usize, seed: u64) -> Self {
        SyntheticSpec {
            name: "cifar10s".into(),
            num_classes: 10,
            image,
            channels: 3,
            train,
            test,
            noise: 0.8,
            seed,
        }
    }

    /// CelebA stand-in: binary attribute classification, 3 channels.
    pub fn celebas(image: usize, train: usize, test: usize, seed: u64) -> Self {
        SyntheticSpec {
            name: "celebas".into(),
            num_classes: 2,
            image,
            channels: 3,
            train,
            test,
            noise: 0.9,
            seed,
        }
    }
}

/// Per-class prototype parameters.
struct Prototype {
    /// (freq_y, freq_x, phase, amplitude) per sinusoid component.
    waves: Vec<(f32, f32, f32, f32)>,
    /// Per-channel DC bias.
    bias: Vec<f32>,
}

fn make_prototype(spec: &SyntheticSpec, class: usize) -> Prototype {
    let mut rng = Xoshiro256pp::new(mix_seed(&[spec.seed, 0xC1A5, class as u64]));
    let waves = (0..4)
        .map(|_| {
            (
                0.5 + 3.0 * rng.next_f32(),
                0.5 + 3.0 * rng.next_f32(),
                std::f32::consts::TAU * rng.next_f32(),
                0.4 + 0.6 * rng.next_f32(),
            )
        })
        .collect();
    let bias = (0..spec.channels)
        .map(|_| 0.6 * (rng.next_f32() - 0.5))
        .collect();
    Prototype { waves, bias }
}

fn render(proto: &Prototype, spec: &SyntheticSpec, dy: f32, dx: f32, out: &mut [f32]) {
    let n = spec.image;
    let c = spec.channels;
    for y in 0..n {
        for x in 0..n {
            let fy = y as f32 / n as f32 + dy;
            let fx = x as f32 / n as f32 + dx;
            let mut v = 0.0f32;
            for &(wy, wx, ph, amp) in &proto.waves {
                v += amp
                    * (std::f32::consts::TAU * (wy * fy + wx * fx) + ph).sin();
            }
            for ch in 0..c {
                // Channel modulation keeps channels correlated but distinct.
                let scale = 1.0 - 0.25 * ch as f32;
                out[(y * n + x) * c + ch] = v * scale + proto.bias[ch];
            }
        }
    }
}

/// Generate `(train, test)` datasets from a spec.
///
/// Train and test draw from the same class-conditional distribution but
/// from disjoint RNG streams, mirroring a real train/test split.
pub fn generate(spec: &SyntheticSpec) -> (Dataset, Dataset) {
    let protos: Vec<Prototype> =
        (0..spec.num_classes).map(|k| make_prototype(spec, k)).collect();
    let train = generate_split(spec, &protos, spec.train, 1);
    let test = generate_split(spec, &protos, spec.test, 2);
    (train, test)
}

fn generate_split(
    spec: &SyntheticSpec,
    protos: &[Prototype],
    count: usize,
    split_tag: u64,
) -> Dataset {
    let dim = spec.image * spec.image * spec.channels;
    let mut features = vec![0.0f32; count * dim];
    let mut labels = vec![0u8; count];
    let mut rng = Xoshiro256pp::new(mix_seed(&[spec.seed, 0xDA7A, split_tag]));
    let mut scratch = vec![0.0f32; dim];
    for i in 0..count {
        // Balanced labels with a shuffled tail to avoid count % classes bias.
        let class = if i < count - (count % spec.num_classes) {
            i % spec.num_classes
        } else {
            rng.range(0, spec.num_classes)
        };
        labels[i] = class as u8;
        let dy = 0.08 * (rng.next_f32() - 0.5);
        let dx = 0.08 * (rng.next_f32() - 0.5);
        render(&protos[class], spec, dy, dx, &mut scratch);
        let row = &mut features[i * dim..(i + 1) * dim];
        for (o, &s) in row.iter_mut().zip(scratch.iter()) {
            *o = s + rng.normal_f32(0.0, spec.noise);
        }
    }
    Dataset {
        features,
        labels,
        shape: (spec.image, spec.image, spec.channels),
        num_classes: spec.num_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SyntheticSpec {
        SyntheticSpec::cifar10s(8, 200, 80, 42)
    }

    #[test]
    fn shapes_and_counts() {
        let (train, test) = generate(&spec());
        assert_eq!(train.len(), 200);
        assert_eq!(test.len(), 80);
        assert_eq!(train.dim(), 8 * 8 * 3);
        assert_eq!(train.num_classes, 10);
        assert_eq!(train.features.len(), 200 * 192);
    }

    #[test]
    fn deterministic_per_seed() {
        let (a, _) = generate(&spec());
        let (b, _) = generate(&spec());
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
        let mut s2 = spec();
        s2.seed = 43;
        let (c, _) = generate(&s2);
        assert_ne!(a.features, c.features);
    }

    #[test]
    fn labels_roughly_balanced() {
        let (train, _) = generate(&spec());
        let h = train.class_histogram();
        assert!(h.iter().all(|&c| c >= 15), "{h:?}");
    }

    #[test]
    fn classes_are_separable_by_prototype_distance() {
        // Nearest-prototype classification on noiseless prototypes must
        // beat chance by a wide margin — the signal the models learn.
        let mut s = spec();
        s.noise = 0.5;
        let (train, _) = generate(&s);
        let protos: Vec<Prototype> =
            (0..s.num_classes).map(|k| make_prototype(&s, k)).collect();
        let dim = train.dim();
        let mut clean = vec![vec![0.0f32; dim]; s.num_classes];
        for (k, c) in clean.iter_mut().enumerate() {
            render(&protos[k], &s, 0.0, 0.0, c);
        }
        let mut correct = 0;
        for i in 0..train.len() {
            let (f, l) = train.example(i);
            let mut best = (f32::INFINITY, 0usize);
            for (k, c) in clean.iter().enumerate() {
                let d: f32 = f.iter().zip(c).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.0 {
                    best = (d, k);
                }
            }
            if best.1 == l as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / train.len() as f64;
        assert!(acc > 0.5, "nearest-prototype accuracy only {acc}");
    }

    #[test]
    fn celebas_is_binary() {
        let (train, test) = generate(&SyntheticSpec::celebas(8, 100, 40, 7));
        assert_eq!(train.num_classes, 2);
        assert!(train.labels.iter().all(|&l| l < 2));
        assert!(test.labels.iter().all(|&l| l < 2));
    }

    #[test]
    fn train_test_disjoint_streams() {
        let (train, test) = generate(&spec());
        // First examples of each split must differ (different RNG streams).
        assert_ne!(train.features[..192], test.features[..192]);
    }
}
