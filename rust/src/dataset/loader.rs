//! Mini-batch loading with deterministic per-epoch shuffling.
//!
//! Mirrors the role of a `DataLoader`: each node owns one loader over its
//! shard; every epoch reshuffles with a seed derived from (node seed,
//! epoch), so runs are bit-reproducible and independent across nodes.

use crate::rng::{mix_seed, Xoshiro256pp};

use super::Dataset;

/// Batch view: features are copied into a contiguous `[batch, dim]` buffer
/// (the layout the PJRT literals expect), labels as i32.
#[derive(Debug, Clone)]
pub struct Batch {
    pub features: Vec<f32>,
    pub labels: Vec<i32>,
    pub batch: usize,
}

/// Deterministic shuffling batch loader over a (node-local) dataset.
#[derive(Debug)]
pub struct DataLoader {
    data: Dataset,
    batch: usize,
    seed: u64,
    epoch: u64,
    order: Vec<usize>,
    cursor: usize,
}

impl DataLoader {
    /// `batch` must be non-zero; datasets smaller than one batch are
    /// up-sampled with wraparound so fixed-shape executables always get a
    /// full batch.
    pub fn new(data: Dataset, batch: usize, seed: u64) -> DataLoader {
        assert!(batch > 0, "batch must be > 0");
        let mut dl = DataLoader {
            data,
            batch,
            seed,
            epoch: 0,
            order: Vec::new(),
            cursor: 0,
        };
        dl.reshuffle();
        dl
    }

    pub fn dataset(&self) -> &Dataset {
        &self.data
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    fn reshuffle(&mut self) {
        let n = self.data.len();
        let mut rng = Xoshiro256pp::new(mix_seed(&[self.seed, 0xE90C, self.epoch]));
        self.order = rng.permutation(n.max(1));
        self.cursor = 0;
    }

    /// Next batch; advances the epoch (and reshuffles) on wraparound.
    pub fn next_batch(&mut self) -> Batch {
        let n = self.data.len();
        assert!(n > 0, "empty dataset");
        let d = self.data.dim();
        let mut features = Vec::with_capacity(self.batch * d);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.epoch += 1;
                self.reshuffle();
            }
            let i = self.order[self.cursor] % n;
            self.cursor += 1;
            let (f, l) = self.data.example(i);
            features.extend_from_slice(f);
            labels.push(l as i32);
        }
        Batch { features, labels, batch: self.batch }
    }

    /// Iterate the dataset once in order as fixed-size batches for
    /// evaluation, padding the final batch by wrapping to index 0..  The
    /// returned `valid` count per batch says how many rows are real.
    pub fn eval_batches(data: &Dataset, batch: usize) -> Vec<(Batch, usize)> {
        assert!(batch > 0);
        let n = data.len();
        let d = data.dim();
        let mut out = Vec::new();
        let mut i = 0usize;
        while i < n {
            let valid = batch.min(n - i);
            let mut features = Vec::with_capacity(batch * d);
            let mut labels = Vec::with_capacity(batch);
            for j in 0..batch {
                let idx = if j < valid { i + j } else { j % n.max(1) };
                let (f, l) = data.example(idx);
                features.extend_from_slice(f);
                labels.push(l as i32);
            }
            out.push((Batch { features, labels, batch }, valid));
            i += valid;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::SyntheticSpec;

    fn data(n: usize) -> Dataset {
        let (train, _) = crate::dataset::generate(&SyntheticSpec::cifar10s(4, n, 8, 1));
        train
    }

    #[test]
    fn batches_have_fixed_shape() {
        let mut dl = DataLoader::new(data(20), 8, 3);
        for _ in 0..10 {
            let b = dl.next_batch();
            assert_eq!(b.features.len(), 8 * 4 * 4 * 3);
            assert_eq!(b.labels.len(), 8);
        }
    }

    #[test]
    fn epoch_covers_every_example() {
        let d = data(24);
        let mut dl = DataLoader::new(d, 8, 3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            let b = dl.next_batch();
            for chunk in b.features.chunks(4 * 4 * 3) {
                // Identify examples by bit pattern of their first pixel.
                seen.insert(chunk[0].to_bits());
            }
        }
        // 24 distinct examples (noise makes collisions implausible).
        assert_eq!(seen.len(), 24);
        assert_eq!(dl.epoch(), 0);
        dl.next_batch();
        assert_eq!(dl.epoch(), 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let d = data(16);
        let mut a = DataLoader::new(d.clone(), 4, 7);
        let mut b = DataLoader::new(d, 4, 7);
        for _ in 0..6 {
            let (x, y) = (a.next_batch(), b.next_batch());
            assert_eq!(x.features, y.features);
            assert_eq!(x.labels, y.labels);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let d = data(16);
        let mut a = DataLoader::new(d.clone(), 4, 7);
        let mut b = DataLoader::new(d, 4, 8);
        let (x, y) = (a.next_batch(), b.next_batch());
        assert_ne!(x.labels, y.labels); // overwhelmingly likely with n=16
    }

    #[test]
    fn tiny_dataset_wraps() {
        let mut dl = DataLoader::new(data(3), 8, 1);
        let b = dl.next_batch();
        assert_eq!(b.labels.len(), 8);
    }

    #[test]
    fn eval_batches_cover_and_pad() {
        let d = data(21);
        let batches = DataLoader::eval_batches(&d, 8);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].1, 8);
        assert_eq!(batches[2].1, 5);
        assert!(batches.iter().all(|(b, _)| b.labels.len() == 8));
        let total: usize = batches.iter().map(|(_, v)| v).sum();
        assert_eq!(total, 21);
    }
}
