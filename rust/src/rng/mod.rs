//! Deterministic random number generation.
//!
//! The `rand` crate is unavailable offline, so the framework ships its own
//! generators:
//!
//! * [`SplitMix64`] — seed expansion / hashing (Steele et al.).
//! * [`Xoshiro256pp`] — general-purpose PRNG for data generation,
//!   partitioning, topology sampling (Blackman & Vigna's xoshiro256++).
//! * AES-CTR mask expansion (in [`crate::secure`]) builds on the cached
//!   `aes` crate for cryptographic mask streams.
//!
//! Every experiment seeds its generators from `(experiment_seed, node_id,
//! round)` via [`SplitMix64`], which makes all runs bit-reproducible — the
//! property the paper's 5-seed × 95%-CI methodology depends on.

/// SplitMix64: tiny, full-period seed expander.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Mix arbitrary stream labels into one 64-bit seed (order-sensitive).
pub fn mix_seed(parts: &[u64]) -> u64 {
    let mut sm = SplitMix64::new(0xDEC0_DE00_5EED_0001);
    let mut acc = 0u64;
    for &p in parts {
        sm.state ^= p.rotate_left(17);
        acc = acc.rotate_left(29) ^ sm.next_u64();
    }
    acc
}

/// xoshiro256++ 1.0 — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // All-zero state is invalid (never happens from SplitMix64, but be
        // defensive for direct construction).
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Xoshiro256pp { s }
    }

    /// Derive a child generator for a labeled substream.
    pub fn fork(&mut self, label: u64) -> Xoshiro256pp {
        Xoshiro256pp::new(mix_seed(&[self.next_u64(), label]))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in [0, bound) (Lemire rejection).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_wide(x, bound);
            if lo >= bound || lo >= x.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform in [lo, hi) for usize ranges.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Normal with mean/std as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.next_normal() as f32
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample k distinct indices from 0..n (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample larger than population");
        if k * 3 >= n {
            let mut perm = self.permutation(n);
            perm.truncate(k);
            return perm;
        }
        // Sparse rejection sampling for k << n.
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.below(n as u64) as usize;
            if seen.insert(x) {
                out.push(x);
            }
        }
        out
    }

    /// Dirichlet(alpha * 1) sample of dimension k (for non-IID partitions).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        // Gamma(alpha) via Marsaglia-Tsang (with boost for alpha < 1).
        let mut out: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = out.iter().sum();
        if sum <= 0.0 {
            // Degenerate draw; fall back to uniform.
            return vec![1.0 / k as f64; k];
        }
        for v in out.iter_mut() {
            *v /= sum;
        }
        out
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a).
            let u = self.next_f64().max(f64::MIN_POSITIVE);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }
}

#[inline]
fn mul_wide(a: u64, b: u64) -> (u64, u64) {
    let r = (a as u128) * (b as u128);
    ((r >> 64) as u64, r as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 0 (from the public-domain reference C).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a: Vec<u64> = {
            let mut r = Xoshiro256pp::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Xoshiro256pp::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = Xoshiro256pp::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_below_is_in_range_and_roughly_uniform() {
        let mut r = Xoshiro256pp::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..20_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((1700..2300).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256pp::new(9);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::new(11);
        let n = 40_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256pp::new(5);
        for (n, k) in [(100, 5), (100, 60), (10, 10), (1000, 3)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Xoshiro256pp::new(13);
        for alpha in [0.1, 0.5, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 10);
            let sum: f64 = d.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_effect() {
        // Small alpha -> spiky; large alpha -> near-uniform.
        let mut r = Xoshiro256pp::new(17);
        let spiky: f64 = (0..50)
            .map(|_| {
                r.dirichlet(0.05, 10)
                    .iter()
                    .cloned()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 50.0;
        let flat: f64 = (0..50)
            .map(|_| {
                r.dirichlet(100.0, 10)
                    .iter()
                    .cloned()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 50.0;
        assert!(spiky > 0.6, "spiky {spiky}");
        assert!(flat < 0.2, "flat {flat}");
    }

    #[test]
    fn mix_seed_order_sensitive() {
        assert_ne!(mix_seed(&[1, 2]), mix_seed(&[2, 1]));
        assert_eq!(mix_seed(&[1, 2, 3]), mix_seed(&[1, 2, 3]));
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Xoshiro256pp::new(1);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
