//! # DecentralizeRs
//!
//! A decentralized-learning (DL) framework — a from-scratch reproduction
//! of *"Decentralized Learning Made Easy with DecentralizePy"*
//! (EuroMLSys '23) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the DL middleware: overlay graphs, peer
//!   sampling, sharing/aggregation algorithms, secure aggregation,
//!   transports, datasets, metrics, and the experiment coordinator.
//! * **Layer 2** — JAX model graphs (`python/compile/model.py`), AOT-
//!   lowered once to HLO text artifacts.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the
//!   compute hot-spots, inlined into the same artifacts.
//!
//! At run time the Rust binary executes artifacts through PJRT
//! ([`runtime`]); Python is never on the training path.
//!
//! Large fleets run on the virtual-time [`scheduler`]; the [`scenario`]
//! subsystem layers compute heterogeneity, per-link WAN delays, and
//! availability churn on top of it, and the shared parameter [`store`]
//! (copy-on-write model shards + zero-copy broadcast payloads) keeps
//! memory O(active divergence) so one process reaches 4096+ nodes.
//!
//! See the repository `README.md` for the quickstart,
//! `docs/ARCHITECTURE.md` for the scheduler/scenario walk-through, and
//! `examples/quickstart.rs` for the API tour.

#![deny(rustdoc::broken_intra_doc_links)]

pub mod bench;
pub mod communication;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod graph;
pub mod kernels;
pub mod mapping;
pub mod metrics;
pub mod node;
pub mod model;
pub mod rng;
pub mod runtime;
pub mod scenario;
pub mod scheduler;
pub mod secure;
pub mod serve;
pub mod sharing;
pub mod store;
pub mod trace;
pub mod training;
pub mod util;
