//! Shared parameter-store subsystem: copy-on-write model shards and
//! zero-copy payload broadcast.
//!
//! The paper's headline capability is emulating 1000+ nodes in one
//! process; what caps that number in practice is parameter memory, not
//! CPU. Before this subsystem every emulated node owned a private
//! `Vec<f32>` clone of the common initialization (O(nodes × params)
//! allocated before round 0 even starts) and every broadcast cloned the
//! serialized model once per neighbor (O(nodes × degree × params) of
//! in-flight payload bytes). The store breaks both terms:
//!
//! * **Copy-on-write shards** — [`ParamStore`] owns one shared base
//!   snapshot (`Arc<[f32]>`, the artifact's common init). Nodes hold
//!   [`ParamsRef`] handles and read through to the base until their
//!   first write ([`ParamsRef::take_for_write`]), which materializes a
//!   private shard. Resident parameter memory is therefore O(active
//!   divergence): nodes that never train (offline churn sessions,
//!   late-joining cohorts) cost nothing, and a departing node releases
//!   its shard back ([`ParamsRef::release`]).
//! * **Zero-copy broadcast** — [`Payload`] (an `Arc<[u8]>` buffer) lets
//!   a node serialize its outgoing model once per round and share the
//!   allocation across every recipient's queue.
//! * **Accounting** — the store counts live shards, shared bytes, and
//!   peak resident parameter bytes ([`StoreStats`]); runs export a
//!   [`StoreReport`] into the results directory (`store.jsonl`) and the
//!   `fig6` bench writes a `BENCH_fig6.json` trajectory from it.
//!
//! Node code is store-agnostic: a [`ParamSlot`] either owns a plain
//! vector (`param_store = "owned"`, the back-compat default) or holds a
//! [`ParamsRef`] (`param_store = "shared"`). Both variants hand out the
//! exact same `Vec<f32>` values in the same order, so a run is
//! bit-identical across the two modes and across worker counts —
//! enforced by `shared_param_store_bit_identical_to_owned_across_workers`
//! in `rust/tests/dl_integration.rs` and the CoW property tests in
//! `rust/tests/proptests.rs`.
//!
//! # Shard lifecycle
//!
//! ```text
//! register()      take_for_write()      put()            release()/Drop
//! ────────────▶ Shared ──────────────▶ InFlight ───────▶ Owned ──────────▶ Released
//!               (reads hit the base)   (vec is out       (private shard;   (bytes returned;
//!                                       with a compute    reads/writes      handle dead)
//!                                       job; 1 copy       hit the shard)
//!                                       charged here)        │    ▲
//!                                                            └────┘
//!                                                       take_for_write/put
//! ```
//!
//! Materialization happens exactly once, at the first
//! `take_for_write` — for DL nodes that is the start of their first
//! training round. `InFlight` means the vector is temporarily outside
//! the store (owned by a worker-pool compute job); its bytes stay
//! charged to the store until `release`.

mod payload;

pub use payload::Payload;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// One node's shard state inside the store.
enum Slot {
    /// Never written: reads resolve to the shared base snapshot.
    Shared,
    /// Materialized private shard.
    Owned(Vec<f32>),
    /// Taken for write; the vector is out with a compute job.
    InFlight,
    /// Handle released (node departed / dropped); bytes returned.
    Released,
}

struct StoreInner {
    base: Arc<[f32]>,
    /// Registered handles (shards are locked per-node, not globally —
    /// one node's materialization or eval snapshot never serializes
    /// another node's store access).
    nodes: AtomicU64,
    live_shards: AtomicU64,
    materialized_total: AtomicU64,
    resident_bytes: AtomicU64,
    peak_resident_bytes: AtomicU64,
}

impl StoreInner {
    fn shard_bytes(&self) -> u64 {
        (self.base.len() * std::mem::size_of::<f32>()) as u64
    }

    /// Charge one newly materialized shard.
    fn on_materialize(&self) {
        self.live_shards.fetch_add(1, Ordering::Relaxed);
        self.materialized_total.fetch_add(1, Ordering::Relaxed);
        let bytes = self.shard_bytes();
        let now = self.resident_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak_resident_bytes.fetch_max(now, Ordering::Relaxed);
    }

    /// Return one shard's bytes (release of a materialized shard).
    fn on_release(&self) {
        self.live_shards.fetch_sub(1, Ordering::Relaxed);
        self.resident_bytes.fetch_sub(self.shard_bytes(), Ordering::Relaxed);
    }
}

/// Point-in-time accounting snapshot of a [`ParamStore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StoreStats {
    /// Registered handles (== emulated nodes backed by the store).
    pub nodes: u64,
    /// Bytes of the shared base snapshot (counted once, ever).
    pub shared_bytes: u64,
    /// Currently materialized shards (owned or in flight).
    pub live_shards: u64,
    /// Shards ever materialized (monotone; release does not undo it).
    pub materialized_total: u64,
    /// Bytes of materialized shards currently charged to the store.
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes`.
    pub peak_resident_bytes: u64,
}

impl StoreStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("shared_bytes", Json::num(self.shared_bytes as f64)),
            ("live_shards", Json::num(self.live_shards as f64)),
            ("materialized_total", Json::num(self.materialized_total as f64)),
            ("resident_bytes", Json::num(self.resident_bytes as f64)),
            ("peak_resident_bytes", Json::num(self.peak_resident_bytes as f64)),
        ])
    }
}

/// Store accounting exported by a finished run: one snapshot taken after
/// every node registered (before round 0) and one at quiescence. The gap
/// between the two is the run's actual divergence; `at_start` is what
/// stays O(1) in node count and breaks the per-node-buffer scale ceiling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreReport {
    pub at_start: StoreStats,
    pub at_end: StoreStats,
}

impl StoreReport {
    /// Two JSONL lines (`phase: start | end`), written as `store.jsonl`
    /// next to the per-node metric logs.
    pub fn to_jsonl(&self) -> String {
        let line = |phase: &str, s: &StoreStats| {
            let mut j = s.to_json();
            if let Json::Obj(ref mut obj) = j {
                obj.insert("phase".into(), Json::str(phase));
            }
            let mut out = j.dump();
            out.push('\n');
            out
        };
        let mut out = line("start", &self.at_start);
        out.push_str(&line("end", &self.at_end));
        out
    }
}

/// Process-wide owner of all model parameter state for one run
/// (`param_store = "shared"`). Cheap to clone (handle).
#[derive(Clone)]
pub struct ParamStore {
    inner: Arc<StoreInner>,
}

impl ParamStore {
    /// Build a store over a shared base snapshot (the common model init).
    pub fn with_base(base: Arc<[f32]>) -> ParamStore {
        ParamStore {
            inner: Arc::new(StoreInner {
                base,
                nodes: AtomicU64::new(0),
                live_shards: AtomicU64::new(0),
                materialized_total: AtomicU64::new(0),
                resident_bytes: AtomicU64::new(0),
                peak_resident_bytes: AtomicU64::new(0),
            }),
        }
    }

    /// Convenience for tests: wrap a plain vector as the base.
    pub fn from_vec(base: Vec<f32>) -> ParamStore {
        ParamStore::with_base(base.into())
    }

    /// Parameter-vector dimension (every shard has it).
    pub fn dim(&self) -> usize {
        self.inner.base.len()
    }

    /// Register one node; the returned handle reads through to the base
    /// until its first write.
    pub fn register(&self) -> ParamsRef {
        let id = self.inner.nodes.fetch_add(1, Ordering::Relaxed) as usize;
        ParamsRef {
            store: Arc::clone(&self.inner),
            slot: Mutex::new(Slot::Shared),
            id,
        }
    }

    pub fn stats(&self) -> StoreStats {
        StoreStats {
            nodes: self.inner.nodes.load(Ordering::Relaxed),
            shared_bytes: self.inner.shard_bytes(),
            live_shards: self.inner.live_shards.load(Ordering::Relaxed),
            materialized_total: self.inner.materialized_total.load(Ordering::Relaxed),
            resident_bytes: self.inner.resident_bytes.load(Ordering::Relaxed),
            peak_resident_bytes: self.inner.peak_resident_bytes.load(Ordering::Relaxed),
        }
    }
}

/// One node's handle onto the [`ParamStore`]. The shard is locked
/// per-node (the handle owns its slot's mutex), so one node's
/// materialization, eval snapshot, or release never contends with
/// another node's — the store-wide state is all atomics. Dropping the
/// handle releases the shard (its bytes stop counting as resident).
pub struct ParamsRef {
    store: Arc<StoreInner>,
    /// This node's shard, guarded by its own lock (interior mutability
    /// lets `take`/`put` run from `&self` node code and compute jobs).
    slot: Mutex<Slot>,
    /// Registration index, for diagnostics only.
    id: usize,
}

impl ParamsRef {
    pub fn dim(&self) -> usize {
        self.store.base.len()
    }

    /// True once this node has materialized a private shard.
    pub fn materialized(&self) -> bool {
        matches!(*self.slot.lock().unwrap(), Slot::Owned(_) | Slot::InFlight)
    }

    /// Take the parameters out for mutation (training). The first call
    /// copies the shared base — that copy *is* the CoW materialization —
    /// and later calls hand back the private shard. The caller must
    /// [`put`](ParamsRef::put) the vector back; taking twice without a
    /// put is a node-logic bug and panics (mirrors the one-compute-per-
    /// wake assertion in the scheduler).
    pub fn take_for_write(&self) -> Vec<f32> {
        let prior = {
            let mut slot = self.slot.lock().unwrap();
            std::mem::replace(&mut *slot, Slot::InFlight)
        };
        match prior {
            Slot::Shared => {
                // The O(params) materialization copy happens outside
                // even the per-node lock.
                self.store.on_materialize();
                self.store.base.to_vec()
            }
            Slot::Owned(v) => v,
            Slot::InFlight => panic!("shard {} already taken for write", self.id),
            Slot::Released => panic!("shard {} used after release", self.id),
        }
    }

    /// Return the (possibly mutated) parameters taken with
    /// [`take_for_write`](ParamsRef::take_for_write).
    pub fn put(&self, params: Vec<f32>) {
        assert_eq!(params.len(), self.store.base.len(), "shard dimension changed");
        let mut slot = self.slot.lock().unwrap();
        assert!(
            matches!(*slot, Slot::InFlight),
            "put without a matching take_for_write on shard {}",
            self.id
        );
        *slot = Slot::Owned(params);
    }

    /// Run `f` over the current view without copying (base until the
    /// first write, the private shard after). Holds only this node's
    /// shard lock for the duration.
    pub fn with<R>(&self, f: impl FnOnce(&[f32]) -> R) -> R {
        let slot = self.slot.lock().unwrap();
        match &*slot {
            Slot::Shared => f(&self.store.base),
            Slot::Owned(v) => f(v),
            Slot::InFlight => panic!("shard {} is taken for write", self.id),
            Slot::Released => panic!("shard {} used after release", self.id),
        }
    }

    /// Copy the current view out (evaluation jobs need owned buffers).
    /// An unmaterialized shard clones the base `Arc` first and copies
    /// outside the per-node lock.
    pub fn to_vec(&self) -> Vec<f32> {
        {
            let slot = self.slot.lock().unwrap();
            match &*slot {
                Slot::Shared => {} // fall through: copy base lock-free
                Slot::Owned(v) => return v.clone(),
                Slot::InFlight => panic!("shard {} is taken for write", self.id),
                Slot::Released => panic!("shard {} used after release", self.id),
            }
        }
        self.store.base.to_vec()
    }

    /// Give the shard back for good (churn-trace departure): resident
    /// bytes drop, the handle is dead. Idempotent; `Drop` calls it too.
    pub fn release(&self) {
        let prior = {
            let mut slot = self.slot.lock().unwrap();
            std::mem::replace(&mut *slot, Slot::Released)
        };
        match prior {
            // An in-flight vector is out with a compute job that will
            // never put it back; its charge is returned here either way.
            Slot::Owned(_) | Slot::InFlight => self.store.on_release(),
            Slot::Shared | Slot::Released => {}
        }
    }
}

impl Drop for ParamsRef {
    fn drop(&mut self) {
        self.release();
    }
}

/// A node's parameter slot: either a plain owned vector
/// (`param_store = "owned"`, the historical behavior) or a handle into
/// the shared [`ParamStore`]. Both variants move identical `Vec<f32>`
/// values through `take`/`put`, which is what keeps the two modes
/// bit-identical.
pub struct ParamSlot {
    dim: usize,
    kind: SlotKind,
}

enum SlotKind {
    Owned(Option<Vec<f32>>),
    Stored(ParamsRef),
}

impl ParamSlot {
    /// Private per-node buffer (legacy mode).
    pub fn owned(params: Vec<f32>) -> ParamSlot {
        ParamSlot { dim: params.len(), kind: SlotKind::Owned(Some(params)) }
    }

    /// Copy-on-write handle into a shared store.
    pub fn stored(handle: ParamsRef) -> ParamSlot {
        ParamSlot { dim: handle.dim(), kind: SlotKind::Stored(handle) }
    }

    /// Parameter dimension (stable across take/put).
    pub fn len(&self) -> usize {
        self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.dim == 0
    }

    /// Take the parameters out for mutation; pair with
    /// [`put`](ParamSlot::put).
    pub fn take(&mut self) -> Vec<f32> {
        match &mut self.kind {
            SlotKind::Owned(v) => v.take().expect("params already taken"),
            SlotKind::Stored(r) => r.take_for_write(),
        }
    }

    /// Return the parameters taken with [`take`](ParamSlot::take).
    pub fn put(&mut self, params: Vec<f32>) {
        match &mut self.kind {
            SlotKind::Owned(v) => {
                debug_assert!(v.is_none(), "put without a matching take");
                *v = Some(params);
            }
            SlotKind::Stored(r) => r.put(params),
        }
    }

    /// Copy the current parameters out (evaluation snapshot).
    pub fn to_vec(&self) -> Vec<f32> {
        match &self.kind {
            SlotKind::Owned(v) => v.as_ref().expect("params are taken").clone(),
            SlotKind::Stored(r) => r.to_vec(),
        }
    }

    /// Drop the parameters for good (departure): frees the owned buffer
    /// or releases the store shard.
    pub fn release(&mut self) {
        match &mut self.kind {
            SlotKind::Owned(v) => {
                v.take();
            }
            SlotKind::Stored(r) => r.release(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_free_until_first_write() {
        let store = ParamStore::from_vec(vec![1.0; 100]);
        let refs: Vec<ParamsRef> = (0..64).map(|_| store.register()).collect();
        let s = store.stats();
        assert_eq!(s.nodes, 64);
        assert_eq!(s.shared_bytes, 400);
        assert_eq!(s.live_shards, 0);
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.peak_resident_bytes, 0);
        // Reads hit the base without materializing.
        refs[7].with(|v| assert_eq!(v, &[1.0f32; 100][..]));
        assert_eq!(store.stats().live_shards, 0);
    }

    #[test]
    fn first_write_materializes_and_isolates() {
        let store = ParamStore::from_vec(vec![0.5; 8]);
        let a = store.register();
        let b = store.register();
        let mut v = a.take_for_write();
        assert_eq!(v, vec![0.5; 8]);
        v[0] = 9.0;
        a.put(v);
        assert!(a.materialized());
        assert!(!b.materialized());
        // Read-your-writes for a, base view for b.
        assert_eq!(a.to_vec()[0], 9.0);
        assert_eq!(b.to_vec()[0], 0.5);
        let s = store.stats();
        assert_eq!(s.live_shards, 1);
        assert_eq!(s.materialized_total, 1);
        assert_eq!(s.resident_bytes, 32);
        assert_eq!(s.peak_resident_bytes, 32);
    }

    #[test]
    fn release_returns_bytes_but_keeps_peak() {
        let store = ParamStore::from_vec(vec![0.0; 16]);
        let a = store.register();
        let b = store.register();
        a.put({
            let mut v = a.take_for_write();
            v[1] = 1.0;
            v
        });
        b.put({
            let mut v = b.take_for_write();
            v[2] = 2.0;
            v
        });
        assert_eq!(store.stats().resident_bytes, 128);
        a.release();
        let s = store.stats();
        assert_eq!(s.live_shards, 1);
        assert_eq!(s.resident_bytes, 64);
        assert_eq!(s.peak_resident_bytes, 128);
        assert_eq!(s.materialized_total, 2);
        // Idempotent, and Drop releases too.
        a.release();
        drop(b);
        assert_eq!(store.stats().live_shards, 0);
        assert_eq!(store.stats().resident_bytes, 0);
    }

    #[test]
    #[should_panic(expected = "already taken")]
    fn double_take_panics() {
        let store = ParamStore::from_vec(vec![0.0; 4]);
        let a = store.register();
        let _v = a.take_for_write();
        let _w = a.take_for_write();
    }

    #[test]
    fn slot_owned_and_stored_move_identical_values() {
        let base = vec![1.0f32, 2.0, 3.0];
        let store = ParamStore::from_vec(base.clone());
        let mut owned = ParamSlot::owned(base.clone());
        let mut stored = ParamSlot::stored(store.register());
        assert_eq!(owned.len(), 3);
        assert_eq!(stored.len(), 3);
        let (mut a, mut b) = (owned.take(), stored.take());
        assert_eq!(a, b);
        a[1] = 7.0;
        b[1] = 7.0;
        owned.put(a);
        stored.put(b);
        assert_eq!(owned.to_vec(), stored.to_vec());
        // len is stable even while the params are taken.
        let _t = owned.take();
        assert_eq!(owned.len(), 3);
        owned.put(_t);
        owned.release();
        stored.release();
        assert_eq!(store.stats().live_shards, 0);
    }

    #[test]
    fn report_serializes_as_jsonl() {
        let store = ParamStore::from_vec(vec![0.0; 4]);
        let at_start = store.stats();
        let a = store.register();
        a.put(a.take_for_write());
        let report = StoreReport { at_start, at_end: store.stats() };
        let text = report.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let start = crate::util::json::parse(lines[0]).unwrap();
        let end = crate::util::json::parse(lines[1]).unwrap();
        assert_eq!(start.get("phase").as_str(), Some("start"));
        assert_eq!(end.get("phase").as_str(), Some("end"));
        assert_eq!(end.get("live_shards").as_usize(), Some(1));
        assert_eq!(end.get("shared_bytes").as_usize(), Some(16));
    }
}
