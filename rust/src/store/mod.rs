//! Shared parameter-store subsystem: copy-on-write model shards and
//! zero-copy payload broadcast.
//!
//! The paper's headline capability is emulating 1000+ nodes in one
//! process; what caps that number in practice is parameter memory, not
//! CPU. Before this subsystem every emulated node owned a private
//! `Vec<f32>` clone of the common initialization (O(nodes × params)
//! allocated before round 0 even starts) and every broadcast cloned the
//! serialized model once per neighbor (O(nodes × degree × params) of
//! in-flight payload bytes). The store breaks both terms:
//!
//! * **Copy-on-write shards** — [`ParamStore`] owns one shared base
//!   snapshot (`Arc<[f32]>`, the artifact's common init). Nodes hold
//!   [`ParamsRef`] handles and read through to the base until their
//!   first write ([`ParamsRef::take_for_write`]), which materializes a
//!   private shard. Resident parameter memory is therefore O(active
//!   divergence): nodes that never train (offline churn sessions,
//!   late-joining cohorts) cost nothing, and a departing node releases
//!   its shard back ([`ParamsRef::release`]).
//! * **Paged shards + interning** (`param_store = "paged"`) — the base
//!   is split into fixed-size pages (`page_size` f32 elements); a write
//!   only materializes the pages whose bytes actually differ from the
//!   base, and every divergent page is *interned*: hashed on
//!   [`ParamsRef::put`] and deduplicated store-wide, so two nodes whose
//!   aggregation converged onto the same page content share one copy,
//!   and a page that reconverges to the base bit-for-bit is folded back
//!   and its bytes reclaimed. Resident memory is O(unique divergent
//!   pages), the term that makes the 100k-node tier fit in RAM.
//! * **Zero-copy broadcast** — [`Payload`] (a shared byte buffer) lets
//!   a node serialize its outgoing model once per round and share the
//!   allocation across every recipient's queue; unique buffers can be
//!   pooled and refilled in place (see `Scratch::checkout_payload`).
//! * **Accounting** — the store counts live shards, shared bytes, and
//!   peak resident parameter bytes ([`StoreStats`]); runs export a
//!   [`StoreReport`] into the results directory (`store.jsonl`) and the
//!   `fig6` bench writes a `BENCH_fig6.json` trajectory from it.
//!
//! Node code is store-agnostic: a [`ParamSlot`] either owns a plain
//! vector (`param_store = "owned"`, the back-compat default) or holds a
//! [`ParamsRef`] (`param_store = "shared"`). Both variants hand out the
//! exact same `Vec<f32>` values in the same order, so a run is
//! bit-identical across the two modes and across worker counts —
//! enforced by `shared_param_store_bit_identical_to_owned_across_workers`
//! in `rust/tests/dl_integration.rs` and the CoW property tests in
//! `rust/tests/proptests.rs`.
//!
//! # Shard lifecycle
//!
//! ```text
//! register()      take_for_write()      put()            release()/Drop
//! ────────────▶ Shared ──────────────▶ InFlight ───────▶ Owned ──────────▶ Released
//!               (reads hit the base)   (vec is out       (private shard;   (bytes returned;
//!                                       with a compute    reads/writes      handle dead)
//!                                       job; 1 copy       hit the shard)
//!                                       charged here)        │    ▲
//!                                                            └────┘
//!                                                       take_for_write/put
//! ```
//!
//! Materialization happens exactly once, at the first
//! `take_for_write` — for DL nodes that is the start of their first
//! training round. `InFlight` means the vector is temporarily outside
//! the store (owned by a worker-pool compute job); its bytes stay
//! charged to the store until `release`.
//!
//! In paged mode the lifecycle is per *page*: `take_for_write` always
//! assembles (and transiently charges) one full working vector, but
//! `put` diffs it page-by-page against the base and only the divergent
//! pages stay resident — interned, refcounted, and reclaimed the moment
//! the last holder reconverges or departs.

mod payload;

pub use payload::Payload;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// FNV-1a over the page's f32 bit patterns — the intern table's content
/// hash. Bit-exact on purpose: `-0.0` vs `0.0` (and NaN payloads) must
/// not be conflated, or paged runs would stop being bit-identical to
/// owned ones.
fn page_hash(vals: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in vals {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Bit-exact page equality (the comparison backing both interning and
/// the fold-back-to-base check).
fn pages_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// One node's shard state inside the store.
enum Slot {
    /// Never written: reads resolve to the shared base snapshot.
    Shared,
    /// Materialized private shard (unpaged stores).
    Owned(Vec<f32>),
    /// Taken for write; the vector is out with a compute job.
    InFlight,
    /// Paged store: per-page view. `None` reads through to the base
    /// page, `Some` is an interned divergent page.
    Paged(Vec<Option<Arc<[f32]>>>),
    /// Paged store, taken for write: the assembled vector is out with
    /// the writer; the old pages stay charged until `put` diffs the
    /// returned vector against them.
    PagedInFlight(Vec<Option<Arc<[f32]>>>),
    /// Handle released (node departed / dropped); bytes returned.
    Released,
}

struct StoreInner {
    base: Arc<[f32]>,
    /// Page size in f32 elements; 0 = unpaged (whole-shard CoW).
    page_size: usize,
    /// Content-addressed divergent pages, keyed by [`page_hash`] with a
    /// bucket per hash for collisions. The table holds one reference to
    /// each page; slots hold the rest. All intern/unintern transitions
    /// happen under this lock, so refcount checks are race-free.
    intern: Mutex<HashMap<u64, Vec<Arc<[f32]>>>>,
    /// Registered handles (shards are locked per-node, not globally —
    /// one node's materialization or eval snapshot never serializes
    /// another node's store access).
    nodes: AtomicU64,
    live_shards: AtomicU64,
    materialized_total: AtomicU64,
    resident_bytes: AtomicU64,
    peak_resident_bytes: AtomicU64,
    live_pages: AtomicU64,
    page_bytes: AtomicU64,
}

impl StoreInner {
    fn shard_bytes(&self) -> u64 {
        (self.base.len() * std::mem::size_of::<f32>()) as u64
    }

    fn paged(&self) -> bool {
        self.page_size > 0
    }

    fn page_count(&self) -> usize {
        (self.base.len() + self.page_size - 1) / self.page_size
    }

    /// Element range of page `p` (the last page may be short).
    fn page_range(&self, p: usize) -> std::ops::Range<usize> {
        let start = p * self.page_size;
        start..(start + self.page_size).min(self.base.len())
    }

    /// Charge `bytes` of resident parameter memory, updating the peak.
    fn charge(&self, bytes: u64) {
        let now = self.resident_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak_resident_bytes.fetch_max(now, Ordering::Relaxed);
    }

    /// Return `bytes` of resident parameter memory.
    fn discharge(&self, bytes: u64) {
        self.resident_bytes.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Charge one newly materialized shard (unpaged stores).
    fn on_materialize(&self) {
        self.live_shards.fetch_add(1, Ordering::Relaxed);
        self.materialized_total.fetch_add(1, Ordering::Relaxed);
        self.charge(self.shard_bytes());
    }

    /// Return one shard's bytes (release of a materialized shard).
    fn on_release(&self) {
        self.live_shards.fetch_sub(1, Ordering::Relaxed);
        self.discharge(self.shard_bytes());
    }

    /// Copy a paged view out into one contiguous vector.
    fn assemble(&self, pages: &[Option<Arc<[f32]>>]) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.base.len());
        for (p, pg) in pages.iter().enumerate() {
            match pg {
                None => out.extend_from_slice(&self.base[self.page_range(p)]),
                Some(pg) => out.extend_from_slice(pg),
            }
        }
        out
    }

    /// Look the page content up in the intern table, inserting a fresh
    /// copy on miss. The returned handle is the slot's reference; the
    /// table keeps one of its own, so a freshly interned page has a
    /// strong count of 2.
    fn intern_page(&self, vals: &[f32]) -> Arc<[f32]> {
        let mut table = self.intern.lock().unwrap();
        let bucket = table.entry(page_hash(vals)).or_default();
        for pg in bucket.iter() {
            if pages_equal(pg, vals) {
                return Arc::clone(pg);
            }
        }
        let pg: Arc<[f32]> = Arc::from(vals);
        bucket.push(Arc::clone(&pg));
        self.live_pages.fetch_add(1, Ordering::Relaxed);
        let bytes = (vals.len() * std::mem::size_of::<f32>()) as u64;
        self.page_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.charge(bytes);
        pg
    }

    /// Drop one slot's reference to an interned page. When the table's
    /// own copy is the only other holder, the page is reclaimed and its
    /// bytes returned.
    fn unintern_page(&self, pg: Arc<[f32]>) {
        let mut table = self.intern.lock().unwrap();
        let hash = page_hash(&pg);
        let Some(bucket) = table.get_mut(&hash) else { return };
        let Some(i) = bucket.iter().position(|q| Arc::ptr_eq(q, &pg)) else { return };
        // `pg` (the slot's handle) + the table entry are two counts;
        // anything above means other slots still share this page. New
        // references are only minted under the table lock we hold.
        if Arc::strong_count(&pg) == 2 {
            bucket.swap_remove(i);
            if bucket.is_empty() {
                table.remove(&hash);
            }
            self.live_pages.fetch_sub(1, Ordering::Relaxed);
            let bytes = (pg.len() * std::mem::size_of::<f32>()) as u64;
            self.page_bytes.fetch_sub(bytes, Ordering::Relaxed);
            self.discharge(bytes);
        }
    }

    /// Release a paged slot's pages (departure path).
    fn release_pages(&self, pages: Vec<Option<Arc<[f32]>>>, in_flight: bool) {
        let diverged = pages.iter().any(Option::is_some);
        for pg in pages.into_iter().flatten() {
            self.unintern_page(pg);
        }
        if diverged {
            self.live_shards.fetch_sub(1, Ordering::Relaxed);
        }
        if in_flight {
            // The assembled vector is out with a job that will never
            // put it back; its transient charge is returned here.
            self.discharge(self.shard_bytes());
        }
    }
}

/// Point-in-time accounting snapshot of a [`ParamStore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StoreStats {
    /// Registered handles (== emulated nodes backed by the store).
    pub nodes: u64,
    /// Bytes of the shared base snapshot (counted once, ever).
    pub shared_bytes: u64,
    /// Currently materialized shards (owned or in flight).
    pub live_shards: u64,
    /// Shards ever materialized (monotone; release does not undo it).
    pub materialized_total: u64,
    /// Bytes of materialized shards currently charged to the store.
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes`.
    pub peak_resident_bytes: u64,
    /// CoW page size in f32 elements (0 = unpaged store).
    pub page_size: u64,
    /// Unique divergent pages currently interned (paged stores only).
    pub live_pages: u64,
    /// Bytes of interned divergent pages (subset of `resident_bytes`).
    pub page_bytes: u64,
}

impl StoreStats {
    /// Store kind this snapshot came from: `"paged"` when CoW paging is
    /// on (`page_size > 0`), `"shared"` for the unpaged shard store.
    /// Labels `store.jsonl` rows and telemetry store events.
    pub fn kind(&self) -> &'static str {
        if self.page_size > 0 {
            "paged"
        } else {
            "shared"
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("shared_bytes", Json::num(self.shared_bytes as f64)),
            ("live_shards", Json::num(self.live_shards as f64)),
            ("materialized_total", Json::num(self.materialized_total as f64)),
            ("resident_bytes", Json::num(self.resident_bytes as f64)),
            ("peak_resident_bytes", Json::num(self.peak_resident_bytes as f64)),
            ("page_size", Json::num(self.page_size as f64)),
            ("live_pages", Json::num(self.live_pages as f64)),
            ("page_bytes", Json::num(self.page_bytes as f64)),
        ])
    }
}

/// Store accounting exported by a finished run: one snapshot taken after
/// every node registered (before round 0) and one at quiescence. The gap
/// between the two is the run's actual divergence; `at_start` is what
/// stays O(1) in node count and breaks the per-node-buffer scale ceiling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreReport {
    pub at_start: StoreStats,
    pub at_end: StoreStats,
}

impl StoreReport {
    /// Two JSONL lines (`phase: start | end`), written as `store.jsonl`
    /// next to the per-node metric logs. Each row carries the store
    /// `kind` (`shared` | `paged`) so consumers can label it.
    pub fn to_jsonl(&self) -> String {
        let line = |phase: &str, s: &StoreStats| {
            let mut j = s.to_json();
            if let Json::Obj(ref mut obj) = j {
                obj.insert("phase".into(), Json::str(phase));
                obj.insert("kind".into(), Json::str(s.kind()));
            }
            let mut out = j.dump();
            out.push('\n');
            out
        };
        let mut out = line("start", &self.at_start);
        out.push_str(&line("end", &self.at_end));
        out
    }
}

/// Process-wide owner of all model parameter state for one run
/// (`param_store = "shared"`). Cheap to clone (handle).
#[derive(Clone)]
pub struct ParamStore {
    inner: Arc<StoreInner>,
}

impl ParamStore {
    fn build(base: Arc<[f32]>, page_size: usize) -> ParamStore {
        ParamStore {
            inner: Arc::new(StoreInner {
                base,
                page_size,
                intern: Mutex::new(HashMap::new()),
                nodes: AtomicU64::new(0),
                live_shards: AtomicU64::new(0),
                materialized_total: AtomicU64::new(0),
                resident_bytes: AtomicU64::new(0),
                peak_resident_bytes: AtomicU64::new(0),
                live_pages: AtomicU64::new(0),
                page_bytes: AtomicU64::new(0),
            }),
        }
    }

    /// Build a store over a shared base snapshot (the common model init).
    pub fn with_base(base: Arc<[f32]>) -> ParamStore {
        ParamStore::build(base, 0)
    }

    /// Build a *paged* store: writes materialize only the `page_size`-
    /// element pages that differ from the base, and divergent pages are
    /// interned store-wide (`param_store = "paged"`).
    pub fn with_base_paged(base: Arc<[f32]>, page_size: usize) -> ParamStore {
        assert!(page_size > 0, "page_size must be >= 1 (f32 elements per page)");
        ParamStore::build(base, page_size)
    }

    /// Convenience for tests: wrap a plain vector as the base.
    pub fn from_vec(base: Vec<f32>) -> ParamStore {
        ParamStore::with_base(base.into())
    }

    /// Convenience for tests: paged variant of [`from_vec`](ParamStore::from_vec).
    pub fn from_vec_paged(base: Vec<f32>, page_size: usize) -> ParamStore {
        ParamStore::with_base_paged(base.into(), page_size)
    }

    /// Parameter-vector dimension (every shard has it).
    pub fn dim(&self) -> usize {
        self.inner.base.len()
    }

    /// Register one node; the returned handle reads through to the base
    /// until its first write.
    pub fn register(&self) -> ParamsRef {
        let id = self.inner.nodes.fetch_add(1, Ordering::Relaxed) as usize;
        ParamsRef {
            store: Arc::clone(&self.inner),
            slot: Mutex::new(Slot::Shared),
            id,
        }
    }

    pub fn stats(&self) -> StoreStats {
        StoreStats {
            nodes: self.inner.nodes.load(Ordering::Relaxed),
            shared_bytes: self.inner.shard_bytes(),
            live_shards: self.inner.live_shards.load(Ordering::Relaxed),
            materialized_total: self.inner.materialized_total.load(Ordering::Relaxed),
            resident_bytes: self.inner.resident_bytes.load(Ordering::Relaxed),
            peak_resident_bytes: self.inner.peak_resident_bytes.load(Ordering::Relaxed),
            page_size: self.inner.page_size as u64,
            live_pages: self.inner.live_pages.load(Ordering::Relaxed),
            page_bytes: self.inner.page_bytes.load(Ordering::Relaxed),
        }
    }
}

/// One node's handle onto the [`ParamStore`]. The shard is locked
/// per-node (the handle owns its slot's mutex), so one node's
/// materialization, eval snapshot, or release never contends with
/// another node's — the store-wide state is all atomics. Dropping the
/// handle releases the shard (its bytes stop counting as resident).
pub struct ParamsRef {
    store: Arc<StoreInner>,
    /// This node's shard, guarded by its own lock (interior mutability
    /// lets `take`/`put` run from `&self` node code and compute jobs).
    slot: Mutex<Slot>,
    /// Registration index, for diagnostics only.
    id: usize,
}

impl ParamsRef {
    pub fn dim(&self) -> usize {
        self.store.base.len()
    }

    /// True once this node has materialized private state: a whole
    /// shard (unpaged) or at least one divergent page (paged).
    pub fn materialized(&self) -> bool {
        match &*self.slot.lock().unwrap() {
            Slot::Owned(_) | Slot::InFlight | Slot::PagedInFlight(_) => true,
            Slot::Paged(pages) => pages.iter().any(Option::is_some),
            Slot::Shared | Slot::Released => false,
        }
    }

    /// Take the parameters out for mutation (training). The first call
    /// copies the shared base — that copy *is* the CoW materialization —
    /// and later calls hand back the private shard. The caller must
    /// [`put`](ParamsRef::put) the vector back; taking twice without a
    /// put is a node-logic bug and panics (mirrors the one-compute-per-
    /// wake assertion in the scheduler).
    pub fn take_for_write(&self) -> Vec<f32> {
        if self.store.paged() {
            return self.take_for_write_paged();
        }
        let prior = {
            let mut slot = self.slot.lock().unwrap();
            std::mem::replace(&mut *slot, Slot::InFlight)
        };
        match prior {
            Slot::Shared => {
                // The O(params) materialization copy happens outside
                // even the per-node lock.
                self.store.on_materialize();
                self.store.base.to_vec()
            }
            Slot::Owned(v) => v,
            Slot::InFlight => panic!("shard {} already taken for write", self.id),
            Slot::Released => panic!("shard {} used after release", self.id),
            Slot::Paged(_) | Slot::PagedInFlight(_) => {
                unreachable!("paged slot in an unpaged store")
            }
        }
    }

    /// Paged stores always hand out a freshly assembled full vector and
    /// charge it transiently; `put` diffs it page-by-page and only the
    /// divergent pages stay resident.
    fn take_for_write_paged(&self) -> Vec<f32> {
        let mut slot = self.slot.lock().unwrap();
        let out = match std::mem::replace(&mut *slot, Slot::InFlight) {
            Slot::Shared => {
                *slot = Slot::PagedInFlight(vec![None; self.store.page_count()]);
                self.store.base.to_vec()
            }
            Slot::Paged(pages) => {
                let v = self.store.assemble(&pages);
                *slot = Slot::PagedInFlight(pages);
                v
            }
            Slot::InFlight | Slot::PagedInFlight(_) => {
                panic!("shard {} already taken for write", self.id)
            }
            Slot::Released => panic!("shard {} used after release", self.id),
            Slot::Owned(_) => unreachable!("owned slot in a paged store"),
        };
        drop(slot);
        self.store.charge(self.store.shard_bytes());
        out
    }

    /// Return the (possibly mutated) parameters taken with
    /// [`take_for_write`](ParamsRef::take_for_write).
    pub fn put(&self, params: Vec<f32>) {
        assert_eq!(params.len(), self.store.base.len(), "shard dimension changed");
        if self.store.paged() {
            return self.put_paged(&params);
        }
        let mut slot = self.slot.lock().unwrap();
        assert!(
            matches!(*slot, Slot::InFlight),
            "put without a matching take_for_write on shard {}",
            self.id
        );
        *slot = Slot::Owned(params);
    }

    /// Diff the returned vector against the base page-by-page: pages
    /// that match the base bit-for-bit fold back (reconvergence reclaims
    /// their bytes), the rest are interned so identical divergent pages
    /// are stored once fleet-wide. Stale pages are released *before*
    /// their replacements are interned, so the steady-state peak tracks
    /// live pages plus one in-flight vector, not a transient double
    /// copy.
    fn put_paged(&self, params: &[f32]) {
        let mut slot = self.slot.lock().unwrap();
        let old_pages = match std::mem::replace(&mut *slot, Slot::InFlight) {
            Slot::PagedInFlight(pages) => pages,
            _ => panic!("put without a matching take_for_write on shard {}", self.id),
        };
        let was_diverged = old_pages.iter().any(Option::is_some);
        let mut new_pages: Vec<Option<Arc<[f32]>>> = Vec::with_capacity(old_pages.len());
        for (p, old) in old_pages.into_iter().enumerate() {
            let range = self.store.page_range(p);
            let vals = &params[range.clone()];
            if pages_equal(vals, &self.store.base[range]) {
                if let Some(pg) = old {
                    self.store.unintern_page(pg);
                }
                new_pages.push(None);
            } else if let Some(pg) = old {
                if pages_equal(vals, &pg) {
                    new_pages.push(Some(pg));
                } else {
                    self.store.unintern_page(pg);
                    new_pages.push(Some(self.store.intern_page(vals)));
                }
            } else {
                new_pages.push(Some(self.store.intern_page(vals)));
            }
        }
        let now_diverged = new_pages.iter().any(Option::is_some);
        match (was_diverged, now_diverged) {
            (false, true) => {
                self.store.live_shards.fetch_add(1, Ordering::Relaxed);
                self.store.materialized_total.fetch_add(1, Ordering::Relaxed);
            }
            (true, false) => {
                self.store.live_shards.fetch_sub(1, Ordering::Relaxed);
            }
            _ => {}
        }
        *slot = Slot::Paged(new_pages);
        drop(slot);
        // The in-flight full-vector copy returns with this put.
        self.store.discharge(self.store.shard_bytes());
    }

    /// Run `f` over the current view (base until the first write, the
    /// private shard after). Copy-free except for paged slots with
    /// divergent pages, which assemble a temporary contiguous vector.
    /// Holds only this node's shard lock for the duration.
    pub fn with<R>(&self, f: impl FnOnce(&[f32]) -> R) -> R {
        let slot = self.slot.lock().unwrap();
        match &*slot {
            Slot::Shared => f(&self.store.base),
            Slot::Owned(v) => f(v),
            Slot::Paged(pages) => {
                if pages.iter().all(Option::is_none) {
                    f(&self.store.base)
                } else {
                    f(&self.store.assemble(pages))
                }
            }
            Slot::InFlight | Slot::PagedInFlight(_) => {
                panic!("shard {} is taken for write", self.id)
            }
            Slot::Released => panic!("shard {} used after release", self.id),
        }
    }

    /// Copy the current view out (evaluation jobs need owned buffers).
    /// An unmaterialized shard clones the base `Arc` first and copies
    /// outside the per-node lock.
    pub fn to_vec(&self) -> Vec<f32> {
        {
            let slot = self.slot.lock().unwrap();
            match &*slot {
                Slot::Shared => {} // fall through: copy base lock-free
                Slot::Owned(v) => return v.clone(),
                Slot::Paged(pages) => return self.store.assemble(pages),
                Slot::InFlight | Slot::PagedInFlight(_) => {
                    panic!("shard {} is taken for write", self.id)
                }
                Slot::Released => panic!("shard {} used after release", self.id),
            }
        }
        self.store.base.to_vec()
    }

    /// Give the shard back for good (churn-trace departure): resident
    /// bytes drop, the handle is dead. Idempotent; `Drop` calls it too.
    pub fn release(&self) {
        let prior = {
            let mut slot = self.slot.lock().unwrap();
            std::mem::replace(&mut *slot, Slot::Released)
        };
        match prior {
            // An in-flight vector is out with a compute job that will
            // never put it back; its charge is returned here either way.
            Slot::Owned(_) | Slot::InFlight => self.store.on_release(),
            Slot::Paged(pages) => self.store.release_pages(pages, false),
            Slot::PagedInFlight(pages) => self.store.release_pages(pages, true),
            Slot::Shared | Slot::Released => {}
        }
    }
}

impl Drop for ParamsRef {
    fn drop(&mut self) {
        self.release();
    }
}

/// A node's parameter slot: either a plain owned vector
/// (`param_store = "owned"`, the historical behavior) or a handle into
/// the shared [`ParamStore`]. Both variants move identical `Vec<f32>`
/// values through `take`/`put`, which is what keeps the two modes
/// bit-identical.
pub struct ParamSlot {
    dim: usize,
    kind: SlotKind,
}

enum SlotKind {
    Owned(Option<Vec<f32>>),
    Stored(ParamsRef),
}

impl ParamSlot {
    /// Private per-node buffer (legacy mode).
    pub fn owned(params: Vec<f32>) -> ParamSlot {
        ParamSlot { dim: params.len(), kind: SlotKind::Owned(Some(params)) }
    }

    /// Copy-on-write handle into a shared store.
    pub fn stored(handle: ParamsRef) -> ParamSlot {
        ParamSlot { dim: handle.dim(), kind: SlotKind::Stored(handle) }
    }

    /// Parameter dimension (stable across take/put).
    pub fn len(&self) -> usize {
        self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.dim == 0
    }

    /// Take the parameters out for mutation; pair with
    /// [`put`](ParamSlot::put).
    pub fn take(&mut self) -> Vec<f32> {
        match &mut self.kind {
            SlotKind::Owned(v) => v.take().expect("params already taken"),
            SlotKind::Stored(r) => r.take_for_write(),
        }
    }

    /// Return the parameters taken with [`take`](ParamSlot::take).
    pub fn put(&mut self, params: Vec<f32>) {
        match &mut self.kind {
            SlotKind::Owned(v) => {
                debug_assert!(v.is_none(), "put without a matching take");
                *v = Some(params);
            }
            SlotKind::Stored(r) => r.put(params),
        }
    }

    /// Copy the current parameters out (evaluation snapshot).
    pub fn to_vec(&self) -> Vec<f32> {
        match &self.kind {
            SlotKind::Owned(v) => v.as_ref().expect("params are taken").clone(),
            SlotKind::Stored(r) => r.to_vec(),
        }
    }

    /// Drop the parameters for good (departure): frees the owned buffer
    /// or releases the store shard.
    pub fn release(&mut self) {
        match &mut self.kind {
            SlotKind::Owned(v) => {
                v.take();
            }
            SlotKind::Stored(r) => r.release(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_free_until_first_write() {
        let store = ParamStore::from_vec(vec![1.0; 100]);
        let refs: Vec<ParamsRef> = (0..64).map(|_| store.register()).collect();
        let s = store.stats();
        assert_eq!(s.nodes, 64);
        assert_eq!(s.shared_bytes, 400);
        assert_eq!(s.live_shards, 0);
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.peak_resident_bytes, 0);
        // Reads hit the base without materializing.
        refs[7].with(|v| assert_eq!(v, &[1.0f32; 100][..]));
        assert_eq!(store.stats().live_shards, 0);
    }

    #[test]
    fn first_write_materializes_and_isolates() {
        let store = ParamStore::from_vec(vec![0.5; 8]);
        let a = store.register();
        let b = store.register();
        let mut v = a.take_for_write();
        assert_eq!(v, vec![0.5; 8]);
        v[0] = 9.0;
        a.put(v);
        assert!(a.materialized());
        assert!(!b.materialized());
        // Read-your-writes for a, base view for b.
        assert_eq!(a.to_vec()[0], 9.0);
        assert_eq!(b.to_vec()[0], 0.5);
        let s = store.stats();
        assert_eq!(s.live_shards, 1);
        assert_eq!(s.materialized_total, 1);
        assert_eq!(s.resident_bytes, 32);
        assert_eq!(s.peak_resident_bytes, 32);
    }

    #[test]
    fn release_returns_bytes_but_keeps_peak() {
        let store = ParamStore::from_vec(vec![0.0; 16]);
        let a = store.register();
        let b = store.register();
        a.put({
            let mut v = a.take_for_write();
            v[1] = 1.0;
            v
        });
        b.put({
            let mut v = b.take_for_write();
            v[2] = 2.0;
            v
        });
        assert_eq!(store.stats().resident_bytes, 128);
        a.release();
        let s = store.stats();
        assert_eq!(s.live_shards, 1);
        assert_eq!(s.resident_bytes, 64);
        assert_eq!(s.peak_resident_bytes, 128);
        assert_eq!(s.materialized_total, 2);
        // Idempotent, and Drop releases too.
        a.release();
        drop(b);
        assert_eq!(store.stats().live_shards, 0);
        assert_eq!(store.stats().resident_bytes, 0);
    }

    #[test]
    #[should_panic(expected = "already taken")]
    fn double_take_panics() {
        let store = ParamStore::from_vec(vec![0.0; 4]);
        let a = store.register();
        let _v = a.take_for_write();
        let _w = a.take_for_write();
    }

    #[test]
    fn slot_owned_and_stored_move_identical_values() {
        let base = vec![1.0f32, 2.0, 3.0];
        let store = ParamStore::from_vec(base.clone());
        let mut owned = ParamSlot::owned(base.clone());
        let mut stored = ParamSlot::stored(store.register());
        assert_eq!(owned.len(), 3);
        assert_eq!(stored.len(), 3);
        let (mut a, mut b) = (owned.take(), stored.take());
        assert_eq!(a, b);
        a[1] = 7.0;
        b[1] = 7.0;
        owned.put(a);
        stored.put(b);
        assert_eq!(owned.to_vec(), stored.to_vec());
        // len is stable even while the params are taken.
        let _t = owned.take();
        assert_eq!(owned.len(), 3);
        owned.put(_t);
        owned.release();
        stored.release();
        assert_eq!(store.stats().live_shards, 0);
    }

    #[test]
    fn paged_first_write_materializes_only_written_pages() {
        let store = ParamStore::from_vec_paged(vec![0.5; 8], 2); // 4 pages of 2 f32
        let a = store.register();
        let mut v = a.take_for_write();
        assert_eq!(v, vec![0.5; 8]);
        v[3] = 9.0; // dirties page 1 only
        a.put(v);
        assert!(a.materialized());
        assert_eq!(a.to_vec()[3], 9.0);
        a.with(|v| assert_eq!(v[2], 0.5));
        let s = store.stats();
        assert_eq!(s.page_size, 2);
        assert_eq!(s.live_shards, 1);
        assert_eq!(s.materialized_total, 1);
        assert_eq!(s.live_pages, 1);
        assert_eq!(s.page_bytes, 8);
        // One 8-byte page resident, not the 32-byte shard; the peak saw
        // the page plus the transient in-flight copy.
        assert_eq!(s.resident_bytes, 8);
        assert_eq!(s.peak_resident_bytes, 32 + 8);
        a.release();
        let s = store.stats();
        assert_eq!(s.live_pages, 0);
        assert_eq!(s.live_shards, 0);
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.peak_resident_bytes, 40);
    }

    #[test]
    fn paged_identical_pages_intern_to_one_copy() {
        let store = ParamStore::from_vec_paged(vec![0.0; 8], 4); // 2 pages
        let a = store.register();
        let b = store.register();
        for r in [&a, &b] {
            let mut v = r.take_for_write();
            v[1] = 5.0; // identical page-0 content on both nodes
            r.put(v);
        }
        let s = store.stats();
        assert_eq!(s.live_shards, 2);
        assert_eq!(s.live_pages, 1); // deduplicated: one interned page serves both
        assert_eq!(s.page_bytes, 16);
        assert_eq!(s.resident_bytes, 16);
        assert_eq!(a.to_vec(), b.to_vec());
        // The first release keeps the shared page; the last reclaims it.
        a.release();
        assert_eq!(store.stats().live_pages, 1);
        b.release();
        let s = store.stats();
        assert_eq!(s.live_pages, 0);
        assert_eq!(s.resident_bytes, 0);
    }

    #[test]
    fn paged_reconvergence_returns_resident_bytes_to_baseline() {
        let store = ParamStore::from_vec_paged(vec![1.0; 6], 4); // pages: 4 + short tail of 2
        let a = store.register();
        let mut v = a.take_for_write();
        v[5] = 3.0; // tail page, charged by its real 2-f32 size
        a.put(v);
        let s = store.stats();
        assert_eq!(s.live_pages, 1);
        assert_eq!(s.page_bytes, 8);
        assert_eq!(s.resident_bytes, 8);
        // Aggregation drives the node back onto the base bit-for-bit:
        // interning folds the page back and every byte is reclaimed.
        let mut v = a.take_for_write();
        assert_eq!(v, vec![1.0, 1.0, 1.0, 1.0, 1.0, 3.0]);
        v[5] = 1.0;
        a.put(v);
        let s = store.stats();
        assert_eq!(s.live_pages, 0);
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.live_shards, 0);
        assert!(!a.materialized());
        assert_eq!(s.peak_resident_bytes, 24 + 8);
        // The handle keeps working after reconverging.
        assert_eq!(a.to_vec(), vec![1.0; 6]);
        let mut v = a.take_for_write();
        v[0] = 2.0;
        a.put(v);
        assert_eq!(store.stats().live_pages, 1);
    }

    #[test]
    #[should_panic(expected = "already taken")]
    fn paged_double_take_panics() {
        let store = ParamStore::from_vec_paged(vec![0.0; 4], 2);
        let a = store.register();
        let _v = a.take_for_write();
        let _w = a.take_for_write();
    }

    #[test]
    fn report_serializes_as_jsonl() {
        let store = ParamStore::from_vec(vec![0.0; 4]);
        let at_start = store.stats();
        let a = store.register();
        a.put(a.take_for_write());
        let report = StoreReport { at_start, at_end: store.stats() };
        let text = report.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let start = crate::util::json::parse(lines[0]).unwrap();
        let end = crate::util::json::parse(lines[1]).unwrap();
        assert_eq!(start.get("phase").as_str(), Some("start"));
        assert_eq!(end.get("phase").as_str(), Some("end"));
        assert_eq!(end.get("live_shards").as_usize(), Some(1));
        assert_eq!(end.get("shared_bytes").as_usize(), Some(16));
        // Accounting rows are labeled with the store kind.
        assert_eq!(start.get("kind").as_str(), Some("shared"));
        assert_eq!(end.get("kind").as_str(), Some("shared"));
        let paged = ParamStore::with_base_paged(vec![0.0f32; 8].into(), 4);
        assert_eq!(paged.stats().kind(), "paged");
    }
}
