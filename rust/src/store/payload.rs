//! Zero-copy message payloads: one serialization, many recipients.
//!
//! A [`Payload`] is an immutable, reference-counted byte buffer
//! (`Arc<[u8]>` underneath). Cloning one is a pointer bump, so a node
//! that broadcasts its model to `k` neighbors serializes **once** and
//! every envelope — and every receive queue the envelope sits in —
//! shares the same allocation. Before this type, every
//! `payload.clone()` at a broadcast site duplicated the full serialized
//! model per recipient, which at 4096 nodes × degree 6 made in-flight
//! payload copies the dominant term of the emulator's memory footprint.
//!
//! Payloads are deliberately immutable: a receiver that needs to mutate
//! bytes copies them out explicitly (none of the current protocols do —
//! aggregation decodes into fresh `f32` buffers).

use std::fmt;
use std::ops::Deref;
use std::sync::Arc;

/// Immutable shared byte buffer used as the payload of every
/// [`crate::communication::Envelope`]. `Clone` is O(1).
#[derive(Clone, PartialEq, Eq)]
pub struct Payload(Arc<[u8]>);

impl Payload {
    /// The empty payload (control frames, tests).
    pub fn empty() -> Payload {
        Payload(Arc::from(Vec::new()))
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.0
    }

    /// True when both handles share one allocation (zero-copy check).
    pub fn ptr_eq(a: &Payload, b: &Payload) -> bool {
        Arc::ptr_eq(&a.0, &b.0)
    }
}

impl Deref for Payload {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        &self.0
    }
}

impl Default for Payload {
    fn default() -> Payload {
        Payload::empty()
    }
}

impl From<Vec<u8>> for Payload {
    fn from(bytes: Vec<u8>) -> Payload {
        Payload(Arc::from(bytes))
    }
}

impl From<&[u8]> for Payload {
    fn from(bytes: &[u8]) -> Payload {
        Payload(Arc::from(bytes))
    }
}

impl fmt::Debug for Payload {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Envelope debug output stays readable for multi-MB models.
        write!(f, "Payload({} bytes)", self.0.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_and_slice_roundtrip() {
        let p: Payload = vec![1u8, 2, 3].into();
        assert_eq!(&p[..], &[1, 2, 3]);
        assert_eq!(p.len(), 3);
        assert!(!p.is_empty());
        let q = Payload::from(&[1u8, 2, 3][..]);
        assert_eq!(p, q);
        assert!(!Payload::ptr_eq(&p, &q)); // equal bytes, distinct buffers
    }

    #[test]
    fn clone_is_zero_copy() {
        let p: Payload = vec![7u8; 1024].into();
        let q = p.clone();
        assert!(Payload::ptr_eq(&p, &q));
        assert_eq!(p, q);
    }

    #[test]
    fn empty_default() {
        assert!(Payload::empty().is_empty());
        assert_eq!(Payload::default(), Payload::empty());
        assert_eq!(Payload::empty().len(), 0);
    }
}
