//! Zero-copy message payloads: one serialization, many recipients.
//!
//! A [`Payload`] is a reference-counted byte buffer (`Arc<Vec<u8>>`
//! underneath). Cloning one is a pointer bump, so a node that
//! broadcasts its model to `k` neighbors serializes **once** and every
//! envelope — and every receive queue the envelope sits in — shares the
//! same allocation. Before this type, every `payload.clone()` at a
//! broadcast site duplicated the full serialized model per recipient,
//! which at 4096 nodes × degree 6 made in-flight payload copies the
//! dominant term of the emulator's memory footprint.
//!
//! Payloads are immutable while shared: a handle only exposes its bytes
//! mutably through [`buf_mut`](Payload::buf_mut), which succeeds solely
//! when the handle is the buffer's *unique* holder. That is the hook
//! the hot path's payload pool builds on (`Scratch::checkout_payload`):
//! once every recipient of last round's broadcast has dropped its
//! handle, the sender reclaims the buffer and refills it in place —
//! zero allocations per round at steady state. The extra pointer hop of
//! `Arc<Vec<u8>>` over `Arc<[u8]>` is what buys that reusability.

use std::fmt;
use std::ops::Deref;
use std::sync::Arc;

/// Shared byte buffer used as the payload of every
/// [`crate::communication::Envelope`]. `Clone` is O(1).
#[derive(Clone, PartialEq, Eq)]
pub struct Payload(Arc<Vec<u8>>);

impl Payload {
    /// The empty payload (control frames, tests, pool bootstrap).
    pub fn empty() -> Payload {
        Payload(Arc::new(Vec::new()))
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        self.0.as_slice()
    }

    /// Capacity of the backing buffer (pool accounting).
    pub fn capacity(&self) -> usize {
        self.0.capacity()
    }

    /// True when this handle is the only holder of the buffer — i.e.
    /// every recipient of the broadcast has dropped its clone and the
    /// buffer may be reused.
    pub fn is_unique(&self) -> bool {
        Arc::strong_count(&self.0) == 1
    }

    /// Mutable access to the backing buffer, granted only to a unique
    /// holder (`None` while any clone is still in flight).
    pub fn buf_mut(&mut self) -> Option<&mut Vec<u8>> {
        Arc::get_mut(&mut self.0)
    }

    /// True when both handles share one allocation (zero-copy check).
    pub fn ptr_eq(a: &Payload, b: &Payload) -> bool {
        Arc::ptr_eq(&a.0, &b.0)
    }
}

impl Deref for Payload {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.0.as_slice()
    }
}

impl Default for Payload {
    fn default() -> Payload {
        Payload::empty()
    }
}

impl From<Vec<u8>> for Payload {
    fn from(bytes: Vec<u8>) -> Payload {
        // Moves the buffer: one control-block allocation, no byte copy.
        Payload(Arc::new(bytes))
    }
}

impl From<&[u8]> for Payload {
    fn from(bytes: &[u8]) -> Payload {
        Payload(Arc::new(bytes.to_vec()))
    }
}

impl fmt::Debug for Payload {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Envelope debug output stays readable for multi-MB models.
        write!(f, "Payload({} bytes)", self.0.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_and_slice_roundtrip() {
        let p: Payload = vec![1u8, 2, 3].into();
        assert_eq!(&p[..], &[1, 2, 3]);
        assert_eq!(p.len(), 3);
        assert!(!p.is_empty());
        let q = Payload::from(&[1u8, 2, 3][..]);
        assert_eq!(p, q);
        assert!(!Payload::ptr_eq(&p, &q)); // equal bytes, distinct buffers
    }

    #[test]
    fn clone_is_zero_copy() {
        let p: Payload = vec![7u8; 1024].into();
        let q = p.clone();
        assert!(Payload::ptr_eq(&p, &q));
        assert_eq!(p, q);
    }

    #[test]
    fn empty_default() {
        assert!(Payload::empty().is_empty());
        assert_eq!(Payload::default(), Payload::empty());
        assert_eq!(Payload::empty().len(), 0);
    }

    #[test]
    fn unique_holder_can_refill_in_place() {
        let mut p: Payload = vec![1u8, 2, 3].into();
        assert!(p.is_unique());
        let q = p.clone();
        assert!(!p.is_unique());
        assert!(p.buf_mut().is_none()); // shared: bytes stay frozen
        drop(q);
        assert!(p.is_unique());
        let before = p.as_slice().as_ptr();
        let buf = p.buf_mut().unwrap();
        buf.clear();
        buf.extend_from_slice(&[9, 9]);
        assert_eq!(&p[..], &[9, 9]);
        assert_eq!(p.as_slice().as_ptr(), before); // same backing buffer
    }
}
