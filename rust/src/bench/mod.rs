//! Minimal benchmarking framework (criterion is unavailable offline).
//!
//! Used by every `cargo bench` target (`harness = false`): warmup, timed
//! iterations, robust summary (mean / σ / median / min), and an optional
//! throughput line. Results print in a stable, greppable format:
//!
//! ```text
//! bench <name>  mean 12.34µs  median 12.10µs  sd 0.40µs  min 11.9µs  iters 1000
//! ```

use std::time::{Duration, Instant};

use crate::util::stats::{median, Running};

/// One benchmark's summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub median_s: f64,
    pub sd_s: f64,
    pub min_s: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<44} mean {:>10}  median {:>10}  sd {:>10}  min {:>10}  iters {}",
            self.name,
            fmt_s(self.mean_s),
            fmt_s(self.median_s),
            fmt_s(self.sd_s),
            fmt_s(self.min_s),
            self.iters
        );
    }

    /// Print with an ops/sec or items/sec throughput annotation.
    pub fn print_throughput(&self, items_per_iter: f64, unit: &str) {
        self.print();
        let per_sec = items_per_iter / self.mean_s;
        println!("      {:<44} {:.3e} {unit}/s", "", per_sec);
    }
}

fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Benchmark `f`, auto-scaling iteration count to fill `budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration: run until 10% of budget or 3 iterations.
    let warm_budget = budget / 10;
    let warm_start = Instant::now();
    let mut warm_iters = 0usize;
    while warm_iters < 3 || warm_start.elapsed() < warm_budget {
        f();
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
    let target_iters = ((budget.as_secs_f64() * 0.9) / per_iter.max(1e-9)) as usize;
    let iters = target_iters.clamp(5, 1_000_000);

    let mut r = Running::new();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        r.push(dt);
        samples.push(dt);
    }
    BenchResult {
        name: name.to_string(),
        mean_s: r.mean(),
        median_s: median(&samples),
        sd_s: r.std(),
        min_s: r.min(),
        iters,
    }
}

/// Convenience: bench and print in one call; returns the result for
/// comparisons.
pub fn run<F: FnMut()>(name: &str, budget_ms: u64, f: F) -> BenchResult {
    let res = bench(name, Duration::from_millis(budget_ms), f);
    res.print();
    res
}

/// Prevent the optimizer from discarding a value (stable-Rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleeps() {
        let r = bench("sleepy", Duration::from_millis(60), || {
            std::thread::sleep(Duration::from_millis(2))
        });
        assert!(r.mean_s >= 1.5e-3 && r.mean_s < 20e-3, "{}", r.mean_s);
        assert!(r.iters >= 5);
        assert!(r.median_s > 0.0 && r.min_s > 0.0);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_s(2.0).ends_with('s'));
        assert!(fmt_s(2e-3).ends_with("ms"));
        assert!(fmt_s(2e-6).ends_with("µs"));
        assert!(fmt_s(2e-9).ends_with("ns"));
    }
}
