//! Portable chunk-unrolled kernel bodies (the default lane path).
//!
//! These are the fixed 8-lane `chunks_exact` bodies the `kernels`
//! wrappers dispatch to when the `simd` feature is off (or the target
//! is not x86_64); with the feature on, `kernels::lanes` provides the
//! explicit SSE2 twins and this module remains compiled — and public —
//! so tests can pin the two paths bit-identical against each other.
//!
//! Validation (length checks, error reporting) lives in the `kernels`
//! wrappers; bodies here only `debug_assert`, which is what lets the
//! two lane paths share one validation story.

/// Unroll width: 8 f32 lanes (one AVX2 register, two NEON registers).
const LANES: usize = 8;

/// `x[i] *= alpha`
pub fn scale(x: &mut [f32], alpha: f32) {
    let mut chunks = x.chunks_exact_mut(LANES);
    for c in &mut chunks {
        for v in c.iter_mut() {
            *v *= alpha;
        }
    }
    for v in chunks.into_remainder() {
        *v *= alpha;
    }
}

/// `acc[i] += alpha * x[i]`
pub fn axpy(acc: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let mut a = acc.chunks_exact_mut(LANES);
    let mut b = x.chunks_exact(LANES);
    for (ca, cb) in (&mut a).zip(&mut b) {
        for i in 0..LANES {
            ca[i] += alpha * cb[i];
        }
    }
    for (va, vb) in a.into_remainder().iter_mut().zip(b.remainder()) {
        *va += alpha * vb;
    }
}

/// `acc[i] += alpha * (x[i] - y[i])`
pub fn diff_axpy(acc: &mut [f32], alpha: f32, x: &[f32], y: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    debug_assert_eq!(acc.len(), y.len());
    let mut a = acc.chunks_exact_mut(LANES);
    let mut bx = x.chunks_exact(LANES);
    let mut by = y.chunks_exact(LANES);
    for ((ca, cx), cy) in (&mut a).zip(&mut bx).zip(&mut by) {
        for i in 0..LANES {
            ca[i] += alpha * (cx[i] - cy[i]);
        }
    }
    for ((va, vx), vy) in a
        .into_remainder()
        .iter_mut()
        .zip(bx.remainder())
        .zip(by.remainder())
    {
        *va += alpha * (vx - vy);
    }
}

/// `acc[i] += alpha * f32_le(bytes[4i..])` — length pre-validated.
pub fn decode_le_axpy(acc: &mut [f32], alpha: f32, bytes: &[u8]) {
    debug_assert_eq!(bytes.len(), acc.len() * 4);
    let mut a = acc.chunks_exact_mut(LANES);
    let mut b = bytes.chunks_exact(4 * LANES);
    for (ca, cb) in (&mut a).zip(&mut b) {
        for i in 0..LANES {
            let v = f32::from_le_bytes([cb[4 * i], cb[4 * i + 1], cb[4 * i + 2], cb[4 * i + 3]]);
            ca[i] += alpha * v;
        }
    }
    for (va, cb) in a.into_remainder().iter_mut().zip(b.remainder().chunks_exact(4)) {
        *va += alpha * f32::from_le_bytes([cb[0], cb[1], cb[2], cb[3]]);
    }
}

/// `acc[i] = (acc[i] + a1·v1[i]) + a2·v2[i]` — both payloads
/// pre-validated; two sequential adds per element, one accumulator pass.
pub fn decode_le_axpy2(acc: &mut [f32], a1: f32, b1: &[u8], a2: f32, b2: &[u8]) {
    debug_assert_eq!(b1.len(), acc.len() * 4);
    debug_assert_eq!(b2.len(), acc.len() * 4);
    let mut a = acc.chunks_exact_mut(LANES);
    let mut c1 = b1.chunks_exact(4 * LANES);
    let mut c2 = b2.chunks_exact(4 * LANES);
    for ((ca, p1), p2) in (&mut a).zip(&mut c1).zip(&mut c2) {
        for i in 0..LANES {
            let v1 = f32::from_le_bytes([p1[4 * i], p1[4 * i + 1], p1[4 * i + 2], p1[4 * i + 3]]);
            let v2 = f32::from_le_bytes([p2[4 * i], p2[4 * i + 1], p2[4 * i + 2], p2[4 * i + 3]]);
            ca[i] = (ca[i] + a1 * v1) + a2 * v2;
        }
    }
    for ((va, p1), p2) in a
        .into_remainder()
        .iter_mut()
        .zip(c1.remainder().chunks_exact(4))
        .zip(c2.remainder().chunks_exact(4))
    {
        let v1 = f32::from_le_bytes([p1[0], p1[1], p1[2], p1[3]]);
        let v2 = f32::from_le_bytes([p2[0], p2[1], p2[2], p2[3]]);
        *va = (*va + a1 * v1) + a2 * v2;
    }
}

/// `acc[i] += w * (f32_le(bytes) as f64)` — length pre-validated.
pub fn decode_le_axpy_widen(acc: &mut [f64], w: f64, bytes: &[u8]) {
    debug_assert_eq!(bytes.len(), acc.len() * 4);
    let mut a = acc.chunks_exact_mut(LANES);
    let mut b = bytes.chunks_exact(4 * LANES);
    for (ca, cb) in (&mut a).zip(&mut b) {
        for i in 0..LANES {
            let v = f32::from_le_bytes([cb[4 * i], cb[4 * i + 1], cb[4 * i + 2], cb[4 * i + 3]]);
            ca[i] += w * v as f64;
        }
    }
    for (va, cb) in a.into_remainder().iter_mut().zip(b.remainder().chunks_exact(4)) {
        *va += w * f32::from_le_bytes([cb[0], cb[1], cb[2], cb[3]]) as f64;
    }
}

/// `acc[idx[j]] += alpha * vals[j]`
pub fn scatter_axpy(acc: &mut [f32], alpha: f32, indices: &[u32], vals: &[f32]) {
    debug_assert_eq!(indices.len(), vals.len());
    for (&i, &v) in indices.iter().zip(vals.iter()) {
        acc[i as usize] += alpha * v;
    }
}

/// `acc[idx[j]] += alpha * (vals[j] - own[idx[j]])`
pub fn scatter_blend(acc: &mut [f32], alpha: f32, indices: &[u32], vals: &[f32], own: &[f32]) {
    debug_assert_eq!(indices.len(), vals.len());
    debug_assert_eq!(acc.len(), own.len());
    for (&i, &v) in indices.iter().zip(vals.iter()) {
        let i = i as usize;
        acc[i] += alpha * (v - own[i]);
    }
}

/// Coordinate-wise trimmed mean; see the `kernels` wrapper for the
/// contract. Only the first `rows` slots of `gather` are used here (the
/// `2 * rows` capacity contract exists for the SSE2 twin, which stages
/// an unsorted column copy alongside the sorted one).
pub fn trimmed_mean(
    out: &mut [f32],
    vals: &[f32],
    rows: usize,
    trim: usize,
    gather: &mut [f32],
    admitted: &mut [f64],
) {
    debug_assert_eq!(vals.len(), rows * out.len());
    debug_assert!(gather.len() >= rows && admitted.len() >= rows);
    debug_assert!(2 * trim < rows);
    let dim = out.len();
    let kept = (rows - 2 * trim) as f64;
    for c in 0..dim {
        let g = &mut gather[..rows];
        for (r, slot) in g.iter_mut().enumerate() {
            *slot = vals[r * dim + c];
        }
        g.sort_unstable_by(f32::total_cmp);
        let (lo, hi) = (g[trim], g[rows - 1 - trim]);
        let mut sum = 0.0f64;
        for &v in &g[trim..rows - trim] {
            sum += v as f64;
        }
        out[c] = (sum / kept) as f32;
        for (r, a) in admitted.iter_mut().enumerate().take(rows) {
            let v = vals[r * dim + c];
            if v.total_cmp(&lo).is_ge() && v.total_cmp(&hi).is_le() {
                *a += 1.0;
            }
        }
    }
}

/// Coordinate-wise median; same staging discipline as [`trimmed_mean`].
pub fn coord_median(
    out: &mut [f32],
    vals: &[f32],
    rows: usize,
    gather: &mut [f32],
    admitted: &mut [f64],
) {
    debug_assert_eq!(vals.len(), rows * out.len());
    debug_assert!(gather.len() >= rows && admitted.len() >= rows);
    debug_assert!(rows > 0);
    let dim = out.len();
    for c in 0..dim {
        let g = &mut gather[..rows];
        for (r, slot) in g.iter_mut().enumerate() {
            *slot = vals[r * dim + c];
        }
        g.sort_unstable_by(f32::total_cmp);
        let (lo, hi, med) = if rows % 2 == 1 {
            let m = g[rows / 2];
            (m, m, m as f64)
        } else {
            let (a, b) = (g[rows / 2 - 1], g[rows / 2]);
            (a, b, (a as f64 + b as f64) / 2.0)
        };
        out[c] = med as f32;
        for (r, a) in admitted.iter_mut().enumerate().take(rows) {
            let v = vals[r * dim + c];
            if v.total_cmp(&lo).is_ge() && v.total_cmp(&hi).is_le() {
                *a += 1.0;
            }
        }
    }
}

/// Pairwise squared L2 distances into a symmetric `rows × rows` matrix
/// with a zero diagonal (upper triangle computed, mirrored).
pub fn pairwise_sq_dist(vals: &[f32], rows: usize, dim: usize, dist: &mut [f64]) {
    debug_assert_eq!(vals.len(), rows * dim);
    debug_assert!(dist.len() >= rows * rows);
    for i in 0..rows {
        dist[i * rows + i] = 0.0;
        for j in (i + 1)..rows {
            let a = &vals[i * dim..(i + 1) * dim];
            let b = &vals[j * dim..(j + 1) * dim];
            let mut s = 0.0f64;
            for k in 0..dim {
                let d = (a[k] - b[k]) as f64;
                s += d * d;
            }
            dist[i * rows + j] = s;
            dist[j * rows + i] = s;
        }
    }
}
