//! Explicit SSE2 lane implementations of the dense kernels (the `simd`
//! feature's hot half; `portable` is the auto-vectorized fallback).
//!
//! SSE2 is part of the x86_64 baseline ABI, so these intrinsics are
//! always available on this architecture — no runtime dispatch, no
//! `#[target_feature]` shims, and the module is compiled only under
//! `cfg(all(feature = "simd", target_arch = "x86_64"))`.
//!
//! **Bit-identity discipline** (same hard contract as `portable`):
//!
//! * one vector op = four independent IEEE-754 scalar ops — never a
//!   fused multiply-add (`_mm_mul_ps` + `_mm_add_ps` round twice,
//!   exactly like the scalar `acc += a * v`), never a reassociation of
//!   one element's arithmetic;
//! * byte decodes use unaligned vector loads, which on little-endian
//!   x86 are exactly `f32::from_le_bytes` four at a time;
//! * f32→f64 widening (`_mm_cvtps_pd`) is exact, and every f64
//!   reduction (`pairwise_sq_dist`) extracts the vector-computed squares
//!   and adds them **sequentially in element order**, matching the
//!   scalar sum bit for bit;
//! * order statistics (`trimmed_mean` / `coord_median`) keep the sort
//!   and the ascending kept-range sum scalar (order-pinned); what
//!   vectorizes is the admitted-range counting, via the integer
//!   transform that makes signed i32 comparison agree with
//!   [`f32::total_cmp`] — including NaN totals, which is what the
//!   proptests pin.
//!
//! Inputs are pre-validated by the `kernels` wrappers (lengths checked,
//! errors raised there), so bodies here only `debug_assert`.

use std::arch::x86_64::{
    __m128i, _mm_add_pd, _mm_add_ps, _mm_castps_si128, _mm_castsi128_ps, _mm_cmpgt_epi32,
    _mm_cmplt_epi32, _mm_cvtps_pd, _mm_loadu_pd, _mm_loadu_ps, _mm_movehl_ps, _mm_movemask_ps,
    _mm_mul_pd, _mm_mul_ps, _mm_or_si128, _mm_set1_epi32, _mm_set1_pd, _mm_set1_ps,
    _mm_srai_epi32, _mm_srli_epi32, _mm_storeu_pd, _mm_storeu_ps, _mm_sub_ps, _mm_xor_si128,
};

/// `x[i] *= alpha`
pub fn scale(x: &mut [f32], alpha: f32) {
    unsafe {
        let va = _mm_set1_ps(alpha);
        let n = x.len();
        let p = x.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            _mm_storeu_ps(p.add(i), _mm_mul_ps(_mm_loadu_ps(p.add(i)), va));
            i += 4;
        }
        while i < n {
            *p.add(i) *= alpha;
            i += 1;
        }
    }
}

/// `acc[i] += alpha * x[i]`
pub fn axpy(acc: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    unsafe {
        let va = _mm_set1_ps(alpha);
        let n = acc.len();
        let p = acc.as_mut_ptr();
        let q = x.as_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let prod = _mm_mul_ps(va, _mm_loadu_ps(q.add(i)));
            _mm_storeu_ps(p.add(i), _mm_add_ps(_mm_loadu_ps(p.add(i)), prod));
            i += 4;
        }
        while i < n {
            *p.add(i) += alpha * *q.add(i);
            i += 1;
        }
    }
}

/// `acc[i] += alpha * (x[i] - y[i])`
pub fn diff_axpy(acc: &mut [f32], alpha: f32, x: &[f32], y: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    debug_assert_eq!(acc.len(), y.len());
    unsafe {
        let va = _mm_set1_ps(alpha);
        let n = acc.len();
        let p = acc.as_mut_ptr();
        let (qx, qy) = (x.as_ptr(), y.as_ptr());
        let mut i = 0;
        while i + 4 <= n {
            let d = _mm_sub_ps(_mm_loadu_ps(qx.add(i)), _mm_loadu_ps(qy.add(i)));
            let prod = _mm_mul_ps(va, d);
            _mm_storeu_ps(p.add(i), _mm_add_ps(_mm_loadu_ps(p.add(i)), prod));
            i += 4;
        }
        while i < n {
            *p.add(i) += alpha * (*qx.add(i) - *qy.add(i));
            i += 1;
        }
    }
}

/// `acc[i] += alpha * f32_le(bytes[4i..])` — length pre-validated.
pub fn decode_le_axpy(acc: &mut [f32], alpha: f32, bytes: &[u8]) {
    debug_assert_eq!(bytes.len(), acc.len() * 4);
    unsafe {
        let va = _mm_set1_ps(alpha);
        let n = acc.len();
        let p = acc.as_mut_ptr();
        let q = bytes.as_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm_loadu_ps(q.add(4 * i).cast());
            _mm_storeu_ps(p.add(i), _mm_add_ps(_mm_loadu_ps(p.add(i)), _mm_mul_ps(va, v)));
            i += 4;
        }
        while i < n {
            *p.add(i) += alpha * q.add(4 * i).cast::<f32>().read_unaligned();
            i += 1;
        }
    }
}

/// `acc[i] = (acc[i] + a1·v1[i]) + a2·v2[i]` — both payloads
/// pre-validated; two sequential adds per element, one accumulator pass.
pub fn decode_le_axpy2(acc: &mut [f32], a1: f32, b1: &[u8], a2: f32, b2: &[u8]) {
    debug_assert_eq!(b1.len(), acc.len() * 4);
    debug_assert_eq!(b2.len(), acc.len() * 4);
    unsafe {
        let va1 = _mm_set1_ps(a1);
        let va2 = _mm_set1_ps(a2);
        let n = acc.len();
        let p = acc.as_mut_ptr();
        let (q1, q2) = (b1.as_ptr(), b2.as_ptr());
        let mut i = 0;
        while i + 4 <= n {
            let v1 = _mm_loadu_ps(q1.add(4 * i).cast());
            let v2 = _mm_loadu_ps(q2.add(4 * i).cast());
            let mut a = _mm_loadu_ps(p.add(i));
            a = _mm_add_ps(a, _mm_mul_ps(va1, v1));
            a = _mm_add_ps(a, _mm_mul_ps(va2, v2));
            _mm_storeu_ps(p.add(i), a);
            i += 4;
        }
        while i < n {
            let v1 = q1.add(4 * i).cast::<f32>().read_unaligned();
            let v2 = q2.add(4 * i).cast::<f32>().read_unaligned();
            *p.add(i) = (*p.add(i) + a1 * v1) + a2 * v2;
            i += 1;
        }
    }
}

/// `acc[i] += w * (f32_le(bytes) as f64)` — length pre-validated;
/// `_mm_cvtps_pd` widening is exact, so each lane is the scalar op.
pub fn decode_le_axpy_widen(acc: &mut [f64], w: f64, bytes: &[u8]) {
    debug_assert_eq!(bytes.len(), acc.len() * 4);
    unsafe {
        let vw = _mm_set1_pd(w);
        let n = acc.len();
        let p = acc.as_mut_ptr();
        let q = bytes.as_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm_loadu_ps(q.add(4 * i).cast());
            let lo = _mm_cvtps_pd(v);
            let hi = _mm_cvtps_pd(_mm_movehl_ps(v, v));
            let a_lo = _mm_add_pd(_mm_loadu_pd(p.add(i)), _mm_mul_pd(vw, lo));
            let a_hi = _mm_add_pd(_mm_loadu_pd(p.add(i + 2)), _mm_mul_pd(vw, hi));
            _mm_storeu_pd(p.add(i), a_lo);
            _mm_storeu_pd(p.add(i + 2), a_hi);
            i += 4;
        }
        while i < n {
            *p.add(i) += w * q.add(4 * i).cast::<f32>().read_unaligned() as f64;
            i += 1;
        }
    }
}

/// `acc[idx[j]] += alpha * vals[j]` — the products vectorize (they are
/// independent of the accumulator), the indexed adds stay in `j` order,
/// so duplicate indices fold exactly as the scalar loop does.
pub fn scatter_axpy(acc: &mut [f32], alpha: f32, indices: &[u32], vals: &[f32]) {
    debug_assert_eq!(indices.len(), vals.len());
    unsafe {
        let va = _mm_set1_ps(alpha);
        let n = indices.len();
        let mut prod = [0.0f32; 4];
        let mut j = 0;
        while j + 4 <= n {
            _mm_storeu_ps(
                prod.as_mut_ptr(),
                _mm_mul_ps(va, _mm_loadu_ps(vals.as_ptr().add(j))),
            );
            for (t, &p) in prod.iter().enumerate() {
                acc[*indices.get_unchecked(j + t) as usize] += p;
            }
            j += 4;
        }
        while j < n {
            acc[indices[j] as usize] += alpha * vals[j];
            j += 1;
        }
    }
}

/// `acc[idx[j]] += alpha * (vals[j] - own[idx[j]])` — `own` is a
/// snapshot disjoint from `acc`, so gathering four of its values up
/// front is exact even under duplicate indices; adds stay in `j` order.
pub fn scatter_blend(acc: &mut [f32], alpha: f32, indices: &[u32], vals: &[f32], own: &[f32]) {
    debug_assert_eq!(indices.len(), vals.len());
    debug_assert_eq!(acc.len(), own.len());
    unsafe {
        let va = _mm_set1_ps(alpha);
        let n = indices.len();
        let mut gathered = [0.0f32; 4];
        let mut prod = [0.0f32; 4];
        let mut j = 0;
        while j + 4 <= n {
            for (t, g) in gathered.iter_mut().enumerate() {
                *g = own[*indices.get_unchecked(j + t) as usize];
            }
            let d = _mm_sub_ps(
                _mm_loadu_ps(vals.as_ptr().add(j)),
                _mm_loadu_ps(gathered.as_ptr()),
            );
            _mm_storeu_ps(prod.as_mut_ptr(), _mm_mul_ps(va, d));
            for (t, &p) in prod.iter().enumerate() {
                acc[*indices.get_unchecked(j + t) as usize] += p;
            }
            j += 4;
        }
        while j < n {
            let i = indices[j] as usize;
            acc[i] += alpha * (vals[j] - own[i]);
            j += 1;
        }
    }
}

/// The [`f32::total_cmp`] integer transform, four lanes at a time:
/// signed comparison of `b ^ ((b >>a 31) >>l 1)` orders exactly like the
/// total order on floats (sign-magnitude → two's complement).
#[inline]
unsafe fn total_cmp_keys(bits: __m128i) -> __m128i {
    _mm_xor_si128(bits, _mm_srli_epi32(_mm_srai_epi32(bits, 31), 1))
}

/// `admitted[r] += 1.0` for every `col[r]` inside `[lo, hi]` under the
/// total order — the vectorized half of the robust order-statistic
/// kernels. Bit-for-bit the scalar `total_cmp` range test (NaNs
/// included): the key transform makes signed i32 compares agree with
/// `f32::total_cmp` exactly.
fn admitted_in_range(col: &[f32], lo: f32, hi: f32, admitted: &mut [f64]) {
    debug_assert!(admitted.len() >= col.len());
    unsafe {
        let klo = total_cmp_keys(_mm_set1_epi32(lo.to_bits() as i32));
        let khi = total_cmp_keys(_mm_set1_epi32(hi.to_bits() as i32));
        let n = col.len();
        let mut r = 0;
        while r + 4 <= n {
            let k = total_cmp_keys(_mm_castps_si128(_mm_loadu_ps(col.as_ptr().add(r))));
            let outside = _mm_or_si128(_mm_cmplt_epi32(k, klo), _mm_cmpgt_epi32(k, khi));
            let mask = _mm_movemask_ps(_mm_castsi128_ps(outside));
            for t in 0..4 {
                if mask & (1 << t) == 0 {
                    *admitted.get_unchecked_mut(r + t) += 1.0;
                }
            }
            r += 4;
        }
        while r < n {
            let v = col[r];
            if v.total_cmp(&lo).is_ge() && v.total_cmp(&hi).is_le() {
                admitted[r] += 1.0;
            }
            r += 1;
        }
    }
}

/// Coordinate-wise trimmed mean; see the `kernels` wrapper for the
/// contract. `gather` holds the unsorted column copy in its first
/// `rows` slots and the sorted copy in the next `rows` (hence the
/// `2 * rows` capacity contract); sort and ascending f64 sum stay
/// scalar (order-pinned), the admitted counting vectorizes.
pub fn trimmed_mean(
    out: &mut [f32],
    vals: &[f32],
    rows: usize,
    trim: usize,
    gather: &mut [f32],
    admitted: &mut [f64],
) {
    debug_assert_eq!(vals.len(), rows * out.len());
    debug_assert!(gather.len() >= 2 * rows && admitted.len() >= rows);
    debug_assert!(2 * trim < rows);
    let dim = out.len();
    let kept = (rows - 2 * trim) as f64;
    let (unsorted, rest) = gather.split_at_mut(rows);
    let sorted = &mut rest[..rows];
    for c in 0..dim {
        for (r, slot) in unsorted.iter_mut().enumerate() {
            *slot = vals[r * dim + c];
        }
        sorted.copy_from_slice(unsorted);
        sorted.sort_unstable_by(f32::total_cmp);
        let (lo, hi) = (sorted[trim], sorted[rows - 1 - trim]);
        let mut sum = 0.0f64;
        for &v in &sorted[trim..rows - trim] {
            sum += v as f64;
        }
        out[c] = (sum / kept) as f32;
        admitted_in_range(unsorted, lo, hi, admitted);
    }
}

/// Coordinate-wise median; same staging discipline as [`trimmed_mean`].
pub fn coord_median(
    out: &mut [f32],
    vals: &[f32],
    rows: usize,
    gather: &mut [f32],
    admitted: &mut [f64],
) {
    debug_assert_eq!(vals.len(), rows * out.len());
    debug_assert!(gather.len() >= 2 * rows && admitted.len() >= rows);
    debug_assert!(rows > 0);
    let dim = out.len();
    let (unsorted, rest) = gather.split_at_mut(rows);
    let sorted = &mut rest[..rows];
    for c in 0..dim {
        for (r, slot) in unsorted.iter_mut().enumerate() {
            *slot = vals[r * dim + c];
        }
        sorted.copy_from_slice(unsorted);
        sorted.sort_unstable_by(f32::total_cmp);
        let (lo, hi, med) = if rows % 2 == 1 {
            let m = sorted[rows / 2];
            (m, m, m as f64)
        } else {
            let (a, b) = (sorted[rows / 2 - 1], sorted[rows / 2]);
            (a, b, (a as f64 + b as f64) / 2.0)
        };
        out[c] = med as f32;
        admitted_in_range(unsorted, lo, hi, admitted);
    }
}

/// One pair's squared L2 distance: vector subtract, exact f32→f64
/// widen, vector square, then a **sequential** in-order sum of the
/// extracted squares — the f64 accumulation order is the scalar one.
fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    unsafe {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut s = 0.0f64;
        let mut sq = [0.0f64; 4];
        let mut k = 0;
        while k + 4 <= n {
            let d = _mm_sub_ps(_mm_loadu_ps(pa.add(k)), _mm_loadu_ps(pb.add(k)));
            let lo = _mm_cvtps_pd(d);
            let hi = _mm_cvtps_pd(_mm_movehl_ps(d, d));
            _mm_storeu_pd(sq.as_mut_ptr(), _mm_mul_pd(lo, lo));
            _mm_storeu_pd(sq.as_mut_ptr().add(2), _mm_mul_pd(hi, hi));
            s += sq[0];
            s += sq[1];
            s += sq[2];
            s += sq[3];
            k += 4;
        }
        while k < n {
            let d = (*pa.add(k) - *pb.add(k)) as f64;
            s += d * d;
            k += 1;
        }
        s
    }
}

/// Pairwise squared L2 distances into a symmetric `rows × rows` matrix
/// with a zero diagonal (upper triangle computed, mirrored).
pub fn pairwise_sq_dist(vals: &[f32], rows: usize, dim: usize, dist: &mut [f64]) {
    debug_assert_eq!(vals.len(), rows * dim);
    debug_assert!(dist.len() >= rows * rows);
    for i in 0..rows {
        dist[i * rows + i] = 0.0;
        for j in (i + 1)..rows {
            let s = sq_dist(&vals[i * dim..(i + 1) * dim], &vals[j * dim..(j + 1) * dim]);
            dist[i * rows + j] = s;
            dist[j * rows + i] = s;
        }
    }
}
