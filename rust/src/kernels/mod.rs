//! Fused, allocation-free primitives for the round hot path.
//!
//! Every aggregation in the sharing layer reduces to a handful of dense
//! vector operations: scale the local model by its self-weight, fold in
//! each neighbor's payload with its mixing weight, scatter sparse
//! updates. Before this module each strategy carried its own scalar
//! loop, and the dense paths decoded every neighbor payload into a
//! fresh `Vec<f32>` first — one 4·P-byte allocation plus an extra
//! memory pass per neighbor per round. The kernels here go straight
//! from wire bytes to the weighted accumulator with no intermediate
//! vector ([`decode_le_axpy`]), and each has **two lane paths** behind
//! one validating wrapper:
//!
//! * [`portable`] — fixed 8-lane bodies over `chunks_exact` (scalar
//!   tail) that the compiler auto-vectorizes; the default, and always
//!   compiled.
//! * `lanes` — explicit SSE2 intrinsics, selected by the `simd` cargo
//!   feature on x86_64 ([`simd_active`] reports which path is live).
//!
//! **Bit-identity is a hard contract, on both paths.** Each kernel
//! performs exactly the per-element operation of the scalar loop it
//! replaced, in the same element order, with the same rounding —
//! lanes only split *independent* elements, never reassociate one
//! element's arithmetic, and never contract into FMA. The scalar
//! originals are retained in [`reference`] and proptests pin every
//! kernel bit-identical to them across odd tail lengths, chunk
//! boundaries, and NaN totals (`rust/tests/proptests.rs`), which is
//! what keeps the shared-vs-owned and worker-count equivalence tests
//! green under either feature set.
//!
//! The [`Scratch`] arena supplies the reusable buffers (decode floats,
//! sparse index/value staging, f64 accumulator, payload bytes, and the
//! [`FoldPartial`] set backing the parallel neighbor fold in [`fold`])
//! that make steady-state rounds allocation-free; every node owns one
//! and threads it through [`crate::sharing::Sharing::aggregate_with`] /
//! [`outgoing_with`](crate::sharing::Sharing::outgoing_with). See
//! `docs/PERFORMANCE.md` for the hot-path map and the per-round
//! allocation budget, and `benches/hotpath.rs` for the regression
//! harness that tracks kernel-vs-reference throughput in
//! `BENCH_hotpath.json`.

use anyhow::{bail, Result};

pub mod fold;
pub mod portable;

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod lanes;

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use lanes as hot;
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
use portable as hot;

/// Whether the explicit SSE2 lane path is compiled in (the `simd`
/// feature on x86_64). Purely informational — results are bit-identical
/// either way — but the bench rows and the CI job summary key on it.
pub fn simd_active() -> bool {
    cfg!(all(feature = "simd", target_arch = "x86_64"))
}

/// `x[i] *= alpha`
pub fn scale(x: &mut [f32], alpha: f32) {
    hot::scale(x, alpha)
}

/// `acc[i] += alpha * x[i]`
pub fn axpy(acc: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    hot::axpy(acc, alpha, x)
}

/// `acc[i] += alpha * (x[i] - y[i])` — the Choco-SGD gossip step on a
/// pair of public estimates.
pub fn diff_axpy(acc: &mut [f32], alpha: f32, x: &[f32], y: &[f32]) {
    assert_eq!(acc.len(), x.len());
    assert_eq!(acc.len(), y.len());
    hot::diff_axpy(acc, alpha, x, y)
}

/// Fused little-endian f32 decode + weighted accumulate:
/// `acc[i] += alpha * f32::from_le_bytes(bytes[4i..4i+4])`, with no
/// intermediate vector. This is the dense-aggregation workhorse — one
/// pass over the payload instead of decode-then-fold.
pub fn decode_le_axpy(acc: &mut [f32], alpha: f32, bytes: &[u8]) -> Result<()> {
    if bytes.len() != acc.len() * 4 {
        bail!("raw_f32: expected {} bytes, got {}", acc.len() * 4, bytes.len());
    }
    hot::decode_le_axpy(acc, alpha, bytes);
    Ok(())
}

/// Fused decode + weighted accumulate of **two** payloads in one
/// accumulator pass:
/// `acc[i] = (acc[i] + a1·v1[i]) + a2·v2[i]` — per element exactly the
/// sequence [`decode_le_axpy`] twice (two sequential f32 additions, no
/// reassociation, no FMA contraction), but a single traversal of `acc`,
/// which halves the dominant accumulator read/write traffic for dense
/// aggregation at degree ≥ 2. Both payload lengths are validated before
/// anything folds (the sequential pair folds the first payload before
/// seeing the second's length; the difference is unobservable because
/// an aggregation error aborts the run).
pub fn decode_le_axpy2(acc: &mut [f32], a1: f32, b1: &[u8], a2: f32, b2: &[u8]) -> Result<()> {
    if b1.len() != acc.len() * 4 {
        bail!("raw_f32: expected {} bytes, got {}", acc.len() * 4, b1.len());
    }
    if b2.len() != acc.len() * 4 {
        bail!("raw_f32: expected {} bytes, got {}", acc.len() * 4, b2.len());
    }
    hot::decode_le_axpy2(acc, a1, b1, a2, b2);
    Ok(())
}

/// Little-endian f32 decode into a reusable buffer (cleared + refilled;
/// no allocation once `out` has capacity).
pub fn decode_le_into(out: &mut Vec<f32>, bytes: &[u8]) {
    debug_assert_eq!(bytes.len() % 4, 0);
    out.clear();
    out.reserve(bytes.len() / 4);
    out.extend(
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
    );
}

/// Fused decode + widening accumulate for the secure-aggregation path:
/// `acc[i] += w * (decoded f32 as f64)`. Accumulation stays in f64, in
/// element order, exactly as the scalar loop it replaced (the SSE2 path
/// widens with `cvtps2pd`, which is exact).
pub fn decode_le_axpy_widen(acc: &mut [f64], w: f64, bytes: &[u8]) -> Result<()> {
    if bytes.len() != acc.len() * 4 {
        bail!("raw_f32: expected {} bytes, got {}", acc.len() * 4, bytes.len());
    }
    hot::decode_le_axpy_widen(acc, w, bytes);
    Ok(())
}

/// `out = src[i] as f64 * w` into a reusable f64 buffer (the secure
/// path's accumulator initialization: self-weighted own parameters).
pub fn widen_scale(out: &mut Vec<f64>, src: &[f32], w: f64) {
    out.clear();
    out.reserve(src.len());
    out.extend(src.iter().map(|&v| v as f64 * w));
}

/// `dst[i] = src[i] as f32` — narrow the f64 accumulator back into the
/// parameter vector.
pub fn narrow(dst: &mut [f32], src: &[f64]) {
    assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        *d = *s as f32;
    }
}

/// Sparse weighted accumulate: `acc[idx[j]] += alpha * vals[j]`.
/// Indices must be in-bounds (the sparse decoders guarantee it for
/// well-formed payloads; out-of-bounds panics, as the scalar loop did).
pub fn scatter_axpy(acc: &mut [f32], alpha: f32, indices: &[u32], vals: &[f32]) {
    assert_eq!(indices.len(), vals.len());
    hot::scatter_axpy(acc, alpha, indices, vals)
}

/// Sparse absolute-value blend: `acc[idx[j]] += alpha * (vals[j] -
/// own[idx[j]])` — the missing-coordinate-preserving aggregation rule
/// shared by the subsample and top-k sparsifiers, against a snapshot of
/// the receiver's pre-aggregation values.
pub fn scatter_blend(acc: &mut [f32], alpha: f32, indices: &[u32], vals: &[f32], own: &[f32]) {
    assert_eq!(indices.len(), vals.len());
    assert_eq!(acc.len(), own.len());
    hot::scatter_blend(acc, alpha, indices, vals, own)
}

/// Little-endian f32 decode into an exact-length slice (a row of a
/// staged candidate matrix; no allocation, unlike [`decode_le_into`]).
pub fn decode_le(out: &mut [f32], bytes: &[u8]) -> Result<()> {
    if bytes.len() != out.len() * 4 {
        bail!("raw_f32: expected {} bytes, got {}", out.len() * 4, bytes.len());
    }
    for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}

/// Coordinate-wise trimmed mean over `rows` stacked vectors.
///
/// `vals` is row-major `rows × out.len()`. Per coordinate, the `trim`
/// lowest and `trim` highest values are dropped and the survivors are
/// averaged in f64, summed in ascending sorted order (deterministic and
/// shared with the scalar twin). `gather` stages the coordinate's
/// column — `len >= 2 * rows`, because the SSE2 lane path keeps an
/// unsorted copy alongside the sorted one for vectorized admitted
/// counting; `admitted[r]` accumulates, per row, the number of
/// coordinates whose value fell inside the kept range — boundary
/// duplicates count as admitted, which over-credits ties but never
/// under-reports an honest row.
pub fn trimmed_mean(
    out: &mut [f32],
    vals: &[f32],
    rows: usize,
    trim: usize,
    gather: &mut [f32],
    admitted: &mut [f64],
) {
    assert_eq!(vals.len(), rows * out.len());
    assert!(gather.len() >= 2 * rows && admitted.len() >= rows);
    assert!(2 * trim < rows, "trim {trim} leaves no survivors of {rows} rows");
    hot::trimmed_mean(out, vals, rows, trim, gather, admitted)
}

/// Coordinate-wise median over `rows` stacked vectors (row-major, as
/// [`trimmed_mean`], including the `2 * rows` gather contract). Even
/// row counts average the two middle values in f64. `admitted[r]`
/// counts coordinates where the row's value lies within the median
/// bracket (the one or two middle order statistics).
pub fn coord_median(
    out: &mut [f32],
    vals: &[f32],
    rows: usize,
    gather: &mut [f32],
    admitted: &mut [f64],
) {
    assert_eq!(vals.len(), rows * out.len());
    assert!(gather.len() >= 2 * rows && admitted.len() >= rows);
    assert!(rows > 0);
    hot::coord_median(out, vals, rows, gather, admitted)
}

/// Pairwise squared L2 distances between `rows` stacked vectors
/// (row-major `rows × dim`) into a row-major `rows × rows` matrix.
/// Accumulation is f64 in coordinate order; the matrix is symmetric
/// with a zero diagonal.
pub fn pairwise_sq_dist(vals: &[f32], rows: usize, dim: usize, dist: &mut [f64]) {
    assert_eq!(vals.len(), rows * dim);
    assert!(dist.len() >= rows * rows);
    hot::pairwise_sq_dist(vals, rows, dim, dist)
}

/// Krum selection: each candidate's score is the sum of its `closest`
/// smallest squared distances to the *other* candidates (ascending
/// order, f64), and the lowest score wins, ties broken by lowest row
/// index. `dist` is the [`pairwise_sq_dist`] matrix; `row_buf` stages
/// one row per candidate (`len >= rows`). Sorting the copied row puts
/// the zero self-distance first, so skipping one leading entry excludes
/// self even when other distances are exactly zero (identical
/// colluders) — the skipped value is equal either way. Sort-dominated,
/// so there is no SIMD lane variant.
pub fn krum_select(dist: &[f64], rows: usize, closest: usize, row_buf: &mut [f64]) -> usize {
    assert!(rows > 0 && dist.len() >= rows * rows && row_buf.len() >= rows);
    assert!(closest < rows);
    let mut best = 0usize;
    let mut best_score = f64::INFINITY;
    for i in 0..rows {
        let b = &mut row_buf[..rows];
        b.copy_from_slice(&dist[i * rows..i * rows + rows]);
        b.sort_unstable_by(f64::total_cmp);
        let mut score = 0.0f64;
        for &d in &b[1..1 + closest] {
            score += d;
        }
        if score < best_score {
            best_score = score;
            best = i;
        }
    }
    best
}

pub mod reference {
    //! Retained scalar originals of every kernel, kept for two jobs:
    //! the bit-identity proptests pin each kernel to its reference
    //! across odd tails and chunk boundaries, and `benches/hotpath.rs`
    //! measures the kernel-vs-reference speedup that
    //! `BENCH_hotpath.json` tracks per PR. Not called on any hot path —
    //! but the order-statistic twins use the same out-param signatures
    //! as the fast path, so reference-vs-fast comparisons exercise
    //! identical buffer reuse instead of hiding allocations.

    /// Scalar `x[i] *= alpha`.
    pub fn scale(x: &mut [f32], alpha: f32) {
        for v in x.iter_mut() {
            *v *= alpha;
        }
    }

    /// Scalar `acc[i] += alpha * x[i]`.
    pub fn axpy(acc: &mut [f32], alpha: f32, x: &[f32]) {
        assert_eq!(acc.len(), x.len());
        for (a, b) in acc.iter_mut().zip(x.iter()) {
            *a += alpha * b;
        }
    }

    /// Scalar `acc[i] += alpha * (x[i] - y[i])`.
    pub fn diff_axpy(acc: &mut [f32], alpha: f32, x: &[f32], y: &[f32]) {
        assert_eq!(acc.len(), x.len());
        assert_eq!(acc.len(), y.len());
        for i in 0..acc.len() {
            acc[i] += alpha * (x[i] - y[i]);
        }
    }

    /// The pre-kernel dense fold: decode the payload into a **fresh**
    /// vector, then accumulate — one allocation and one extra pass per
    /// neighbor per round. This is the baseline the hotpath bench's
    /// `speedup_vs_scalar` compares against.
    pub fn decode_le_axpy(acc: &mut [f32], alpha: f32, bytes: &[u8]) {
        assert_eq!(bytes.len(), acc.len() * 4);
        let vals: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        for (a, v) in acc.iter_mut().zip(vals.iter()) {
            *a += alpha * v;
        }
    }

    /// Scalar widening fold of a raw-f32 payload into an f64 accumulator.
    pub fn decode_le_axpy_widen(acc: &mut [f64], w: f64, bytes: &[u8]) {
        assert_eq!(bytes.len(), acc.len() * 4);
        let vals: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        for (a, v) in acc.iter_mut().zip(vals.iter()) {
            *a += w * *v as f64;
        }
    }

    /// Scalar `acc[idx[j]] += alpha * vals[j]`.
    pub fn scatter_axpy(acc: &mut [f32], alpha: f32, indices: &[u32], vals: &[f32]) {
        for (&i, &v) in indices.iter().zip(vals.iter()) {
            acc[i as usize] += alpha * v;
        }
    }

    /// Scalar sparse absolute-value blend against an own-value snapshot.
    pub fn scatter_blend(acc: &mut [f32], alpha: f32, indices: &[u32], vals: &[f32], own: &[f32]) {
        for (&i, &v) in indices.iter().zip(vals.iter()) {
            let i = i as usize;
            acc[i] += alpha * (v - own[i]);
        }
    }

    /// Allocating scalar twin of [`super::trimmed_mean`]: fresh column
    /// vector per coordinate, stable `sort_by`, same ascending f64 sum
    /// and boundary-inclusive admitted counting — bit-identical output.
    pub fn trimmed_mean(out: &mut [f32], vals: &[f32], rows: usize, trim: usize, admitted: &mut [f64]) {
        assert_eq!(vals.len(), rows * out.len());
        assert!(2 * trim < rows);
        let dim = out.len();
        let kept = (rows - 2 * trim) as f64;
        for c in 0..dim {
            let mut col: Vec<f32> = (0..rows).map(|r| vals[r * dim + c]).collect();
            col.sort_by(f32::total_cmp);
            let (lo, hi) = (col[trim], col[rows - 1 - trim]);
            let mut sum = 0.0f64;
            for &v in &col[trim..rows - trim] {
                sum += v as f64;
            }
            out[c] = (sum / kept) as f32;
            for (r, a) in admitted.iter_mut().enumerate().take(rows) {
                let v = vals[r * dim + c];
                if v.total_cmp(&lo).is_ge() && v.total_cmp(&hi).is_le() {
                    *a += 1.0;
                }
            }
        }
    }

    /// Allocating scalar twin of [`super::coord_median`].
    pub fn coord_median(out: &mut [f32], vals: &[f32], rows: usize, admitted: &mut [f64]) {
        assert_eq!(vals.len(), rows * out.len());
        assert!(rows > 0);
        let dim = out.len();
        for c in 0..dim {
            let mut col: Vec<f32> = (0..rows).map(|r| vals[r * dim + c]).collect();
            col.sort_by(f32::total_cmp);
            let (lo, hi, med) = if rows % 2 == 1 {
                let m = col[rows / 2];
                (m, m, m as f64)
            } else {
                let (a, b) = (col[rows / 2 - 1], col[rows / 2]);
                (a, b, (a as f64 + b as f64) / 2.0)
            };
            out[c] = med as f32;
            for (r, a) in admitted.iter_mut().enumerate().take(rows) {
                let v = vals[r * dim + c];
                if v.total_cmp(&lo).is_ge() && v.total_cmp(&hi).is_le() {
                    *a += 1.0;
                }
            }
        }
    }

    /// Scalar twin of [`super::pairwise_sq_dist`], same out-param
    /// signature (both triangles computed independently, unlike the fast
    /// path's mirrored upper triangle — the arithmetic per pair is
    /// identical, so the outputs match bitwise).
    pub fn pairwise_sq_dist(vals: &[f32], rows: usize, dim: usize, dist: &mut [f64]) {
        assert_eq!(vals.len(), rows * dim);
        assert!(dist.len() >= rows * rows);
        for i in 0..rows {
            for j in 0..rows {
                let mut s = 0.0f64;
                for k in 0..dim {
                    let d = (vals[i * dim + k] - vals[j * dim + k]) as f64;
                    s += d * d;
                }
                dist[i * rows + j] = s;
            }
        }
    }

    /// Scalar twin of [`super::krum_select`], same out-param `row_buf`
    /// signature and the same skip-one-leading-zero self exclusion and
    /// index tie-break. (`sort_unstable_by` under a total order yields
    /// the same sorted array a stable sort would — equal keys are
    /// bit-identical — without the stable sort's temp allocation.)
    pub fn krum_select(dist: &[f64], rows: usize, closest: usize, row_buf: &mut [f64]) -> usize {
        assert!(rows > 0 && dist.len() >= rows * rows && row_buf.len() >= rows);
        assert!(closest < rows);
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for i in 0..rows {
            let row = &mut row_buf[..rows];
            row.copy_from_slice(&dist[i * rows..i * rows + rows]);
            row.sort_unstable_by(f64::total_cmp);
            let score: f64 = row[1..1 + closest].iter().sum();
            if score < best_score {
                best_score = score;
                best = i;
            }
        }
        best
    }
}

/// One tree-fold leaf group's private staging: a partial dense
/// accumulator plus the decode/sparse scratch that group's fold needs,
/// so concurrent groups never share a buffer (see [`fold`]). Lives in
/// [`Scratch::partials`]; buffers warm up once and are reused every
/// round, exactly like the flat arena fields.
#[derive(Default)]
pub struct FoldPartial {
    /// The group's partial accumulator (one model-dim vector).
    pub acc: Vec<f32>,
    /// Dense decode staging (per-group codec scratch).
    pub stage: Vec<f32>,
    /// Sparse coordinate staging (per-group).
    pub indices: Vec<u32>,
    /// Sparse value staging (per-group).
    pub values: Vec<f32>,
}

/// Per-node scratch arena: every reusable hot-path buffer in one place.
///
/// A node allocates one `Scratch` at construction and threads it
/// through every `outgoing_with` / `aggregate_with` call; after the
/// first round warms the buffers up to the model dimension, steady-state
/// rounds reallocate nothing (pinned by the capacity-signature test in
/// `rust/tests/hotpath_alloc.rs`). Buffers are plain public fields —
/// borrow them individually so disjoint field borrows coexist.
///
/// Even the outgoing payload buffer is pooled here: broadcast handles
/// park in `payloads` after each round and are refilled in place once
/// every recipient has dropped theirs
/// ([`checkout_payload`](Scratch::checkout_payload)), leaving only O(k)
/// sparse-selection output as per-round allocation;
/// `docs/PERFORMANCE.md` lists the full budget.
#[derive(Default)]
pub struct Scratch {
    /// Dense decode buffer (float codecs, staged neighbor values).
    pub dense: Vec<f32>,
    /// Second dense buffer: diff vectors (Choco/TopK change metric),
    /// own-value snapshots (sparse absolute aggregation).
    pub dense2: Vec<f32>,
    /// Top-k selection buffer (coordinate magnitudes).
    pub mags: Vec<f32>,
    /// Sparse message coordinate staging.
    pub indices: Vec<u32>,
    /// Sparse message value staging.
    pub values: Vec<f32>,
    /// f64 accumulator for the secure-aggregation fold.
    pub doubles: Vec<f64>,
    /// Byte staging (index-codec blocks inside sparse payload builds).
    pub bytes: Vec<u8>,
    /// Pooled broadcast payload handles: one parks here per round and is
    /// reused once every recipient of that broadcast dropped its clone.
    pub payloads: Vec<crate::store::Payload>,
    /// Tree-fold partials: one per leaf group beyond group 0 (which
    /// folds straight into the model). Empty under the serial plan.
    pub partials: Vec<FoldPartial>,
}

/// Bound on parked payload handles: with the scheduler's one-broadcast-
/// per-round cadence one slot cycles, so anything past a few means
/// recipients are holding on (slow consumers) and pooling them is a
/// leak, not a win.
const PAYLOAD_POOL_CAP: usize = 4;

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Pop a reusable broadcast payload out of the pool: the first
    /// parked handle whose recipients have all dropped their clones.
    /// `None` when every pooled payload is still in flight (the caller
    /// falls back to a fresh buffer). Moving the handle *out* keeps the
    /// borrow of its buffer disjoint from the rest of the arena.
    pub fn checkout_payload(&mut self) -> Option<crate::store::Payload> {
        let i = self.payloads.iter().position(crate::store::Payload::is_unique)?;
        Some(self.payloads.swap_remove(i))
    }

    /// Park a broadcast payload handle for reuse next round. Bounded:
    /// when the pool overflows, a still-shared handle is evicted first
    /// (its buffer can never be reclaimed by us anyway).
    pub fn retain_payload(&mut self, payload: crate::store::Payload) {
        self.payloads.push(payload);
        if self.payloads.len() > PAYLOAD_POOL_CAP {
            let i = self
                .payloads
                .iter()
                .position(|p| !p.is_unique())
                .unwrap_or(0);
            self.payloads.swap_remove(i);
        }
    }

    /// Ensure `n` fold partials exist, each with a zeroed `dim`-length
    /// accumulator. Never shrinks: once a round warms the partial set to
    /// its group count, later rounds reuse the buffers in place (the
    /// zero-fill is a write into retained capacity, not an allocation).
    /// After this call, field-split borrows of `partials` alongside the
    /// flat arena buffers are the intended usage.
    pub fn prepare_partials(&mut self, n: usize, dim: usize) {
        if self.partials.len() < n {
            self.partials.resize_with(n, FoldPartial::default);
        }
        for p in &mut self.partials[..n] {
            p.acc.clear();
            p.acc.resize(dim, 0.0);
        }
    }

    /// Capacities of every buffer, in declaration order (the last two
    /// entries sum the pooled payload buffers and the fold partials'
    /// four staging buffers respectively). The allocation-freeze test
    /// records this after a warm-up round and asserts it never changes
    /// again: a stable signature means no hot-path buffer reallocated.
    pub fn capacity_signature(&self) -> [usize; 9] {
        [
            self.dense.capacity(),
            self.dense2.capacity(),
            self.mags.capacity(),
            self.indices.capacity(),
            self.values.capacity(),
            self.doubles.capacity(),
            self.bytes.capacity(),
            self.payloads.iter().map(|p| p.capacity()).sum(),
            self.partials
                .iter()
                .map(|p| {
                    p.acc.capacity() + p.stage.capacity() + p.values.capacity() + p.indices.capacity()
                })
                .sum(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn vals(rng: &mut Xoshiro256pp, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    /// Lengths that straddle the unroll width: empty, sub-chunk, exact
    /// chunks, and every off-by-one around the boundary.
    const EDGE_LENS: [usize; 10] = [0, 1, 3, 7, 8, 9, 15, 16, 17, 100];

    #[test]
    fn scale_axpy_match_reference_on_edge_lengths() {
        for (case, &n) in EDGE_LENS.iter().enumerate() {
            let mut rng = Xoshiro256pp::new(100 + case as u64);
            let base = vals(&mut rng, n);
            let x = vals(&mut rng, n);
            let (mut a, mut b) = (base.clone(), base.clone());
            scale(&mut a, 0.37);
            reference::scale(&mut b, 0.37);
            assert_eq!(a, b, "scale n={n}");
            axpy(&mut a, -1.25, &x);
            reference::axpy(&mut b, -1.25, &x);
            assert_eq!(a, b, "axpy n={n}");
        }
    }

    /// Pin the dispatched lane path bit-identical to the portable
    /// bodies. With `--features simd` this compares SSE2 against the
    /// chunked code on every edge length; without it the two sides are
    /// the same code and the test is a tautology — cheap either way.
    #[test]
    fn dispatched_lanes_match_portable_on_edge_lengths() {
        for (case, &n) in EDGE_LENS.iter().enumerate() {
            let mut rng = Xoshiro256pp::new(900 + case as u64);
            let base = vals(&mut rng, n);
            let x = vals(&mut rng, n);
            let y = vals(&mut rng, n);
            let p1: Vec<u8> = vals(&mut rng, n).iter().flat_map(|v| v.to_le_bytes()).collect();
            let p2: Vec<u8> = vals(&mut rng, n).iter().flat_map(|v| v.to_le_bytes()).collect();

            let (mut a, mut b) = (base.clone(), base.clone());
            scale(&mut a, -0.83);
            portable::scale(&mut b, -0.83);
            assert_eq!(a, b, "scale n={n}");
            axpy(&mut a, 0.41, &x);
            portable::axpy(&mut b, 0.41, &x);
            assert_eq!(a, b, "axpy n={n}");
            diff_axpy(&mut a, 1.7, &x, &y);
            portable::diff_axpy(&mut b, 1.7, &x, &y);
            assert_eq!(a, b, "diff_axpy n={n}");
            decode_le_axpy(&mut a, 0.29, &p1).unwrap();
            portable::decode_le_axpy(&mut b, 0.29, &p1);
            assert_eq!(a, b, "decode_le_axpy n={n}");
            decode_le_axpy2(&mut a, 0.5, &p1, -0.25, &p2).unwrap();
            portable::decode_le_axpy2(&mut b, 0.5, &p1, -0.25, &p2);
            assert_eq!(a, b, "decode_le_axpy2 n={n}");

            let mut wa: Vec<f64> = base.iter().map(|&v| v as f64).collect();
            let mut wb = wa.clone();
            decode_le_axpy_widen(&mut wa, 0.77, &p1).unwrap();
            portable::decode_le_axpy_widen(&mut wb, 0.77, &p1);
            assert_eq!(wa, wb, "decode_le_axpy_widen n={n}");
        }
    }

    /// NaN totals: the robust kernels order and bracket with
    /// `total_cmp`, so a NaN-poisoned column must produce identical
    /// output (and admitted counts) on the dispatched, portable, and
    /// reference paths.
    #[test]
    fn robust_lanes_handle_nan_totals_like_reference() {
        let (rows, dim) = (5usize, 9usize);
        let mut rng = Xoshiro256pp::new(4242);
        let mut stacked = vals(&mut rng, rows * dim);
        stacked[3] = f32::NAN;
        stacked[dim + 3] = -f32::NAN;
        stacked[2 * dim + 7] = f32::NAN;
        stacked[4 * dim] = -0.0;
        stacked[4 * dim + 1] = 0.0;

        let mut gather = vec![0.0f32; 2 * rows];
        let (mut out, mut out_p, mut out_r) =
            (vec![0.0f32; dim], vec![0.0f32; dim], vec![0.0f32; dim]);
        let (mut adm, mut adm_p, mut adm_r) =
            (vec![0.0f64; rows], vec![0.0f64; rows], vec![0.0f64; rows]);

        trimmed_mean(&mut out, &stacked, rows, 1, &mut gather, &mut adm);
        portable::trimmed_mean(&mut out_p, &stacked, rows, 1, &mut gather, &mut adm_p);
        reference::trimmed_mean(&mut out_r, &stacked, rows, 1, &mut adm_r);
        assert_eq!(bits32(&out), bits32(&out_p), "trimmed_mean vs portable");
        assert_eq!(bits32(&out), bits32(&out_r), "trimmed_mean vs reference");
        assert_eq!(adm, adm_p);
        assert_eq!(adm, adm_r);

        adm.iter_mut().for_each(|a| *a = 0.0);
        adm_p.iter_mut().for_each(|a| *a = 0.0);
        adm_r.iter_mut().for_each(|a| *a = 0.0);
        coord_median(&mut out, &stacked, rows, &mut gather, &mut adm);
        portable::coord_median(&mut out_p, &stacked, rows, &mut gather, &mut adm_p);
        reference::coord_median(&mut out_r, &stacked, rows, &mut adm_r);
        assert_eq!(bits32(&out), bits32(&out_p), "coord_median vs portable");
        assert_eq!(bits32(&out), bits32(&out_r), "coord_median vs reference");
        assert_eq!(adm, adm_p);
        assert_eq!(adm, adm_r);

        let mut dist = vec![0.0f64; rows * rows];
        let mut dist_p = vec![0.0f64; rows * rows];
        pairwise_sq_dist(&stacked, rows, dim, &mut dist);
        portable::pairwise_sq_dist(&stacked, rows, dim, &mut dist_p);
        assert_eq!(bits64(&dist), bits64(&dist_p), "pairwise with NaN rows");
    }

    fn bits32(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn bits64(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn decode_le_axpy_matches_reference_and_checks_length() {
        for (case, &n) in EDGE_LENS.iter().enumerate() {
            let mut rng = Xoshiro256pp::new(200 + case as u64);
            let base = vals(&mut rng, n);
            let payload: Vec<u8> = vals(&mut rng, n)
                .iter()
                .flat_map(|v| v.to_le_bytes())
                .collect();
            let (mut a, mut b) = (base.clone(), base.clone());
            decode_le_axpy(&mut a, 0.61, &payload).unwrap();
            reference::decode_le_axpy(&mut b, 0.61, &payload);
            assert_eq!(a, b, "n={n}");
        }
        let mut acc = vec![0.0f32; 4];
        assert!(decode_le_axpy(&mut acc, 1.0, &[0u8; 15]).is_err());
    }

    #[test]
    fn decode_le_axpy2_equals_sequential_pair() {
        for (case, &n) in EDGE_LENS.iter().enumerate() {
            let mut rng = Xoshiro256pp::new(300 + case as u64);
            let base = vals(&mut rng, n);
            let p1: Vec<u8> = vals(&mut rng, n).iter().flat_map(|v| v.to_le_bytes()).collect();
            let p2: Vec<u8> = vals(&mut rng, n).iter().flat_map(|v| v.to_le_bytes()).collect();
            let (mut a, mut b) = (base.clone(), base);
            decode_le_axpy2(&mut a, 0.3, &p1, -0.7, &p2).unwrap();
            decode_le_axpy(&mut b, 0.3, &p1).unwrap();
            decode_le_axpy(&mut b, -0.7, &p2).unwrap();
            assert_eq!(a, b, "n={n}");
        }
        let mut acc = vec![0.0f32; 2];
        assert!(decode_le_axpy2(&mut acc, 1.0, &[0u8; 8], 1.0, &[0u8; 7]).is_err());
        assert!(decode_le_axpy2(&mut acc, 1.0, &[0u8; 7], 1.0, &[0u8; 8]).is_err());
    }

    #[test]
    fn widen_narrow_roundtrip_matches_scalar() {
        let mut rng = Xoshiro256pp::new(7);
        for &n in &EDGE_LENS {
            let src = vals(&mut rng, n);
            let payload: Vec<u8> = vals(&mut rng, n)
                .iter()
                .flat_map(|v| v.to_le_bytes())
                .collect();
            let mut acc = Vec::new();
            widen_scale(&mut acc, &src, 0.4);
            let mut acc_ref: Vec<f64> = src.iter().map(|&v| v as f64 * 0.4).collect();
            assert_eq!(acc, acc_ref, "widen n={n}");
            decode_le_axpy_widen(&mut acc, 0.3, &payload).unwrap();
            reference::decode_le_axpy_widen(&mut acc_ref, 0.3, &payload);
            assert_eq!(acc, acc_ref, "fold n={n}");
            let mut out = vec![0.0f32; n];
            narrow(&mut out, &acc);
            let want: Vec<f32> = acc.iter().map(|&a| a as f32).collect();
            assert_eq!(out, want, "narrow n={n}");
        }
    }

    #[test]
    fn scatter_kernels_match_reference() {
        let mut rng = Xoshiro256pp::new(11);
        let n = 50;
        let base = vals(&mut rng, n);
        let own = vals(&mut rng, n);
        // Duplicate indices exercise the lane path's gather-then-add
        // ordering (adds must stay in j order for exact duplication).
        let indices: Vec<u32> = vec![0, 3, 17, 17, 31, 49, 3];
        let v = vals(&mut rng, indices.len());
        let (mut a, mut b) = (base.clone(), base.clone());
        scatter_axpy(&mut a, 0.8, &indices, &v);
        reference::scatter_axpy(&mut b, 0.8, &indices, &v);
        assert_eq!(a, b);
        scatter_blend(&mut a, 0.5, &indices, &v, &own);
        reference::scatter_blend(&mut b, 0.5, &indices, &v, &own);
        assert_eq!(a, b);
    }

    #[test]
    fn diff_axpy_matches_reference() {
        let mut rng = Xoshiro256pp::new(13);
        for &n in &EDGE_LENS {
            let base = vals(&mut rng, n);
            let x = vals(&mut rng, n);
            let y = vals(&mut rng, n);
            let (mut a, mut b) = (base.clone(), base.clone());
            diff_axpy(&mut a, 0.21, &x, &y);
            reference::diff_axpy(&mut b, 0.21, &x, &y);
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn decode_le_into_reuses_capacity() {
        let payload: Vec<u8> = (0..64u32).flat_map(|i| (i as f32).to_le_bytes()).collect();
        let mut out = Vec::new();
        decode_le_into(&mut out, &payload);
        assert_eq!(out.len(), 64);
        assert_eq!(out[5], 5.0);
        let cap = out.capacity();
        decode_le_into(&mut out, &payload);
        assert_eq!(out.capacity(), cap, "steady-state decode must not grow");
    }

    #[test]
    fn decode_le_matches_decode_le_into_and_checks_length() {
        let payload: Vec<u8> = (0..37u32).flat_map(|i| (i as f32).to_le_bytes()).collect();
        let mut out = vec![0.0f32; 37];
        decode_le(&mut out, &payload).unwrap();
        let mut want = Vec::new();
        decode_le_into(&mut want, &payload);
        assert_eq!(out, want);
        let mut short = vec![0.0f32; 4];
        assert!(decode_le(&mut short, &payload[..15]).is_err());
    }

    #[test]
    fn robust_kernels_match_reference_on_edge_shapes() {
        for (case, &dim) in EDGE_LENS.iter().enumerate() {
            for rows in [1usize, 2, 3, 5, 8] {
                let mut rng = Xoshiro256pp::new(400 + 100 * case as u64 + rows as u64);
                let stacked = vals(&mut rng, rows * dim);
                let mut gather = vec![0.0f32; 2 * rows];
                let trim = if rows > 2 { 1 } else { 0 };

                let (mut out, mut out_ref) = (vec![0.0f32; dim], vec![0.0f32; dim]);
                let (mut adm, mut adm_ref) = (vec![0.0f64; rows], vec![0.0f64; rows]);
                trimmed_mean(&mut out, &stacked, rows, trim, &mut gather, &mut adm);
                reference::trimmed_mean(&mut out_ref, &stacked, rows, trim, &mut adm_ref);
                assert_eq!(out, out_ref, "trimmed_mean dim={dim} rows={rows}");
                assert_eq!(adm, adm_ref, "trimmed_mean admitted dim={dim} rows={rows}");

                adm.iter_mut().for_each(|a| *a = 0.0);
                adm_ref.iter_mut().for_each(|a| *a = 0.0);
                coord_median(&mut out, &stacked, rows, &mut gather, &mut adm);
                reference::coord_median(&mut out_ref, &stacked, rows, &mut adm_ref);
                assert_eq!(out, out_ref, "coord_median dim={dim} rows={rows}");
                assert_eq!(adm, adm_ref, "coord_median admitted dim={dim} rows={rows}");

                let mut dist = vec![0.0f64; rows * rows];
                let mut dist_ref = vec![0.0f64; rows * rows];
                pairwise_sq_dist(&stacked, rows, dim, &mut dist);
                reference::pairwise_sq_dist(&stacked, rows, dim, &mut dist_ref);
                assert_eq!(dist, dist_ref, "pairwise dim={dim} rows={rows}");
                let mut row_buf = vec![0.0f64; rows];
                let mut row_ref = vec![0.0f64; rows];
                for closest in 0..rows {
                    assert_eq!(
                        krum_select(&dist, rows, closest, &mut row_buf),
                        reference::krum_select(&dist_ref, rows, closest, &mut row_ref),
                        "krum dim={dim} rows={rows} closest={closest}"
                    );
                }
            }
        }
    }

    #[test]
    fn trimmed_mean_discards_an_outlier_row() {
        // Three honest rows near 1.0, one poisoned row at -100: with
        // trim=1 the aggregate sits with the honest mass and the
        // poisoned row's admitted count stays at zero.
        let dim = 8;
        let honest = [0.9f32, 1.0, 1.1];
        let mut vals = Vec::new();
        for &h in &honest {
            vals.extend(std::iter::repeat(h).take(dim));
        }
        vals.extend(std::iter::repeat(-100.0f32).take(dim));
        let mut out = vec![0.0f32; dim];
        let mut gather = vec![0.0f32; 8];
        let mut admitted = vec![0.0f64; 4];
        trimmed_mean(&mut out, &vals, 4, 1, &mut gather, &mut admitted);
        assert!(out.iter().all(|&v| (v - 0.95).abs() < 1e-6), "{out:?}");
        assert_eq!(admitted[3], 0.0, "poisoned row must not be admitted");
        assert!(admitted[0] > 0.0 && admitted[1] > 0.0);
    }

    #[test]
    fn krum_prefers_the_honest_cluster() {
        // Rows 0..3 clustered, row 3 far away: krum with closest=2 must
        // pick a cluster member, never the outlier.
        let dim = 4;
        let mut vals = vec![0.0f32; 4 * dim];
        for r in 0..3 {
            for c in 0..dim {
                vals[r * dim + c] = 1.0 + 0.01 * r as f32;
            }
        }
        for c in 0..dim {
            vals[3 * dim + c] = 50.0;
        }
        let mut dist = vec![0.0f64; 16];
        pairwise_sq_dist(&vals, 4, dim, &mut dist);
        let mut row_buf = vec![0.0f64; 4];
        let pick = krum_select(&dist, 4, 2, &mut row_buf);
        assert!(pick < 3, "krum picked the outlier (row {pick})");
    }

    #[test]
    fn scratch_signature_tracks_growth() {
        let mut s = Scratch::new();
        let sig0 = s.capacity_signature();
        assert_eq!(sig0, [0; 9]);
        s.dense.extend_from_slice(&[1.0; 16]);
        assert_ne!(s.capacity_signature(), sig0);
        let warm = s.capacity_signature();
        s.dense.clear();
        s.dense.extend_from_slice(&[2.0; 16]);
        assert_eq!(s.capacity_signature(), warm);

        // Fold partials register in the signature and re-preparing the
        // same shape is allocation-stable (zero-fill reuses capacity).
        s.prepare_partials(3, 32);
        let warm2 = s.capacity_signature();
        assert_ne!(warm2, warm);
        s.partials[1].acc[0] = 9.0; // dirty a partial
        s.prepare_partials(3, 32);
        assert_eq!(s.capacity_signature(), warm2);
        assert_eq!(s.partials[1].acc[0], 0.0, "re-prepare must zero partials");
        // Fewer groups next round never shrinks the warm set.
        s.prepare_partials(1, 32);
        assert_eq!(s.capacity_signature(), warm2);
        assert_eq!(s.partials.len(), 3);
    }

    #[test]
    fn payload_pool_checks_out_unique_handles_only() {
        use crate::store::Payload;
        let mut s = Scratch::new();
        assert!(s.checkout_payload().is_none());
        let p: Payload = vec![1u8, 2, 3].into();
        let in_flight = p.clone(); // a recipient still holds the buffer
        s.retain_payload(p);
        assert!(s.checkout_payload().is_none());
        drop(in_flight);
        let mut reused = s.checkout_payload().expect("recipients gone, buffer reusable");
        assert_eq!(&reused[..], &[1, 2, 3]);
        assert!(reused.buf_mut().is_some());
        assert!(s.checkout_payload().is_none()); // pool is empty again

        // The pool stays bounded, evicting still-shared handles first.
        let keep: Payload = vec![9u8; 8].into();
        let held = keep.clone();
        s.retain_payload(keep);
        for _ in 0..6 {
            s.retain_payload(vec![0u8; 4].into());
        }
        assert!(s.payloads.len() <= 4);
        assert!(s.payloads.iter().all(Payload::is_unique));
        drop(held);
    }
}
