//! Parallel per-neighbor fold plans for high-degree aggregation.
//!
//! At degree ≫ 8 the per-round neighbor fold dominates round rate, and
//! it is embarrassingly parallel *if* the reduction keeps a fixed
//! shape. This module supplies that shape: a [`FoldSpec`] splits the
//! received messages into contiguous **leaf groups** of `width`
//! messages, each group folds into its own partial accumulator, and the
//! partials are combined into the model **sequentially in group order**.
//!
//! **The determinism contract.** The reduction tree's shape is a pure
//! function of `(degree, width)` — it never depends on the worker
//! count, thread scheduling, or arrival order. Groups are data-disjoint
//! (each owns its accumulator and staging buffers), so any number of
//! workers produces bit-identical results; `workers = 1` runs the exact
//! same plan inline. This is the same discipline as the sharded event
//! heaps: parallelism changes *when* work happens, never *what* is
//! computed.
//!
//! Two special cases pin the semantics:
//! * `serial` (the default) is one group folded straight into the
//!   model — the pre-fold behavior, bit for bit.
//! * `tree:<width>` with `width >= degree` is also one group, so it is
//!   bit-identical to `serial` at any worker count. With
//!   `width < degree` the partial combine re-associates the weighted
//!   sum — a *different but deterministic* f32 rounding trajectory,
//!   reproducible at any worker count (floating-point addition is not
//!   associative, so no grouped reduction can match the serial chain
//!   bitwise in general; the tree trades that for scalability and pins
//!   its own result instead).
//!
//! Execution uses `std::thread::scope` so borrows of the model, the
//! arena partials, and the received payload slices need no `Arc`
//! plumbing. The `workers <= 1` (or single-group) path never spawns and
//! performs **zero heap allocations** — it is the path the
//! `hotpath_alloc` freeze pins; multi-worker scopes pay O(workers)
//! executor scaffolding per call, which is outside the buffer-reuse
//! contract (documented in `docs/PERFORMANCE.md`).

use anyhow::{anyhow, bail, Result};

/// How to fold per-neighbor contributions: one serial chain, or a
/// fixed-shape grouped tree. Parsed from the `fold` config key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FoldSpec {
    /// Fold every message into the model in order (the default).
    Serial,
    /// Split messages into contiguous groups of `width`; fold each group
    /// into a private partial, then combine partials in group order.
    Tree {
        /// Messages per leaf group (≥ 2).
        width: usize,
    },
}

impl FoldSpec {
    /// Parse `"serial"` | `"tree:<width>"` (width ≥ 2).
    pub fn parse(spec: &str) -> Result<FoldSpec> {
        if spec == "serial" {
            return Ok(FoldSpec::Serial);
        }
        if let Some(w) = spec.strip_prefix("tree:") {
            let width: usize = w
                .parse()
                .map_err(|_| anyhow!("fold: bad tree width {w:?} (expected an integer)"))?;
            if width < 2 {
                bail!("fold: tree width must be >= 2, got {width}");
            }
            return Ok(FoldSpec::Tree { width });
        }
        bail!("unknown fold spec {spec:?} (expected \"serial\" | \"tree:<width>\")")
    }
}

/// A fold plan bound to an executor width: the spec that fixes the
/// reduction shape plus the worker budget that only affects wall-clock.
/// Strategies receive one via [`crate::sharing::Sharing::set_fold`].
#[derive(Debug, Clone, Copy)]
pub struct FoldCtx {
    pub spec: FoldSpec,
    /// Worker threads the fold may use (≥ 1). Purely an executor knob:
    /// results are bit-identical at any value by construction.
    pub workers: usize,
}

impl Default for FoldCtx {
    fn default() -> FoldCtx {
        FoldCtx { spec: FoldSpec::Serial, workers: 1 }
    }
}

impl FoldCtx {
    /// The serial single-chain plan (what every strategy starts with).
    pub fn serial() -> FoldCtx {
        FoldCtx::default()
    }

    /// A grouped tree plan of `width` messages per leaf.
    pub fn tree(width: usize, workers: usize) -> FoldCtx {
        FoldCtx { spec: FoldSpec::Tree { width }, workers: workers.max(1) }
    }

    /// Leaf-group count for `degree` messages. Depends only on
    /// `(degree, spec)`, never on `workers` — the determinism contract.
    pub fn groups(&self, degree: usize) -> usize {
        match self.spec {
            FoldSpec::Serial => 1,
            FoldSpec::Tree { width } => {
                if degree == 0 {
                    1
                } else {
                    degree.div_ceil(width)
                }
            }
        }
    }

    /// Half-open message range of leaf group `g` (contiguous slices in
    /// canonical received order, so the plan is arrival-order free once
    /// the caller canonicalizes).
    pub fn group_range(&self, degree: usize, g: usize) -> std::ops::Range<usize> {
        match self.spec {
            FoldSpec::Serial => 0..degree,
            FoldSpec::Tree { width } => (g * width)..((g + 1) * width).min(degree),
        }
    }
}

/// Run `own()` on the calling thread while `f(i, &mut items[i])` runs
/// once per item across up to `workers` scoped threads. This is the
/// tree-fold executor: `own` folds leaf group 0 into the model while
/// item `i` stages leaf group `i + 1` into its arena partial.
///
/// `workers <= 1` (or an empty item slice) degrades to `own()` followed
/// by a sequential loop — no spawn, no allocation, same results: item
/// order never carries meaning because items are data-disjoint.
/// Worker errors and panics surface as `Err` after every job finished.
pub fn run_fold_jobs<T, F, G>(workers: usize, items: &mut [T], f: F, own: G) -> Result<()>
where
    T: Send,
    F: Fn(usize, &mut T) -> Result<()> + Sync,
    G: FnOnce() -> Result<()>,
{
    let jobs = items.len();
    if workers <= 1 || jobs == 0 {
        own()?;
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item)?;
        }
        return Ok(());
    }
    let chunk = jobs.div_ceil(workers.min(jobs));
    let mut worker_results: Vec<Result<()>> = Vec::new();
    let own_result = std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks_mut(chunk)
            .enumerate()
            .map(|(ci, chunk_items)| {
                let f = &f;
                s.spawn(move || -> Result<()> {
                    for (i, item) in chunk_items.iter_mut().enumerate() {
                        f(ci * chunk + i, item)?;
                    }
                    Ok(())
                })
            })
            .collect();
        let own_result = own();
        worker_results = handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("fold worker panicked")))
            })
            .collect();
        own_result
    });
    own_result?;
    for r in worker_results {
        r?;
    }
    Ok(())
}

/// Row-parallel variant for staged candidate matrices: split `buf` into
/// `buf.len() / per` rows of `per` elements and run `f(row, slice)` on
/// each, spreading contiguous row slabs over up to `workers` scoped
/// threads. `workers <= 1` loops inline with zero allocations. Used by
/// the robust strategies to decode neighbor payloads into
/// `Scratch::values` concurrently (pure per-row byte decode, so results
/// are trivially bit-identical at any worker count).
pub fn run_row_jobs<F>(workers: usize, buf: &mut [f32], per: usize, f: F) -> Result<()>
where
    F: Fn(usize, &mut [f32]) -> Result<()> + Sync,
{
    assert!(per > 0 && buf.len() % per == 0);
    let rows = buf.len() / per;
    if workers <= 1 || rows <= 1 {
        for (r, row) in buf.chunks_exact_mut(per).enumerate() {
            f(r, row)?;
        }
        return Ok(());
    }
    let slab = rows.div_ceil(workers.min(rows));
    let results: Vec<Result<()>> = std::thread::scope(|s| {
        let handles: Vec<_> = buf
            .chunks_mut(per * slab)
            .enumerate()
            .map(|(ci, slab_buf)| {
                let f = &f;
                s.spawn(move || -> Result<()> {
                    for (r, row) in slab_buf.chunks_exact_mut(per).enumerate() {
                        f(ci * slab + r, row)?;
                    }
                    Ok(())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("fold worker panicked")))
            })
            .collect()
    });
    for r in results {
        r?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_serial_and_tree() {
        assert_eq!(FoldSpec::parse("serial").unwrap(), FoldSpec::Serial);
        assert_eq!(FoldSpec::parse("tree:8").unwrap(), FoldSpec::Tree { width: 8 });
        assert_eq!(FoldSpec::parse("tree:2").unwrap(), FoldSpec::Tree { width: 2 });
        assert!(FoldSpec::parse("tree:1").is_err());
        assert!(FoldSpec::parse("tree:0").is_err());
        assert!(FoldSpec::parse("tree:").is_err());
        assert!(FoldSpec::parse("tree:x").is_err());
        assert!(FoldSpec::parse("parallel").is_err());
    }

    #[test]
    fn group_shape_depends_only_on_degree_and_width() {
        let t = FoldCtx::tree(8, 4);
        assert_eq!(t.groups(0), 1);
        assert_eq!(t.groups(8), 1);
        assert_eq!(t.groups(9), 2);
        assert_eq!(t.groups(33), 5);
        assert_eq!(t.group_range(33, 0), 0..8);
        assert_eq!(t.group_range(33, 4), 32..33);
        // Worker count never changes the shape.
        for w in [1, 2, 7, 64] {
            let t2 = FoldCtx::tree(8, w);
            assert_eq!(t2.groups(33), 5);
            assert_eq!(t2.group_range(33, 2), t.group_range(33, 2));
        }
        // width >= degree is a single group == the serial chain.
        assert_eq!(FoldCtx::tree(64, 4).groups(33), 1);
        assert_eq!(FoldCtx::tree(64, 4).group_range(33, 0), 0..33);
        assert_eq!(FoldCtx::serial().groups(33), 1);
        assert_eq!(FoldCtx::serial().group_range(33, 0), 0..33);
    }

    #[test]
    fn fold_jobs_cover_every_item_once_at_any_worker_count() {
        for workers in [1usize, 2, 3, 8, 16] {
            let mut items = vec![0u64; 13];
            run_fold_jobs(workers, &mut items, |i, slot| {
                *slot += 1 + i as u64;
                Ok(())
            }, || Ok(()))
            .unwrap();
            let want: Vec<u64> = (0..13).map(|i| 1 + i as u64).collect();
            assert_eq!(items, want, "workers={workers}");
        }
    }

    #[test]
    fn fold_jobs_propagate_errors_from_workers_and_own() {
        let mut items = vec![0u8; 6];
        let err = run_fold_jobs(4, &mut items, |i, _| {
            if i == 3 {
                bail!("group 3 failed")
            }
            Ok(())
        }, || Ok(()));
        assert!(err.is_err());
        let err = run_fold_jobs(4, &mut items, |_, _| Ok(()), || bail!("own failed"));
        assert!(err.is_err());
    }

    #[test]
    fn row_jobs_decode_every_row_once() {
        for workers in [1usize, 3, 8] {
            let mut buf = vec![0.0f32; 7 * 5];
            run_row_jobs(workers, &mut buf, 5, |r, row| {
                for (i, v) in row.iter_mut().enumerate() {
                    *v = (r * 5 + i) as f32;
                }
                Ok(())
            })
            .unwrap();
            let want: Vec<f32> = (0..35).map(|i| i as f32).collect();
            assert_eq!(buf, want, "workers={workers}");
        }
    }
}
