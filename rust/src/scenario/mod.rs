//! Heterogeneity & WAN scenarios: who is slow, which links are far,
//! who is online.
//!
//! The paper's claim is that the emulation captures "practical and
//! crucial behaviors … associated to parallelism, data transfer,
//! network delays, and wall-clock time". PR 1's virtual-time scheduler
//! made per-message timing faithful but still modeled every node as
//! equally fast, every link as one `(latency, bandwidth)` pair, and
//! availability as i.i.d. coin flips. A [`Scenario`] layers three
//! orthogonal, independently-specified axes on top of a base config:
//!
//! * **Compute heterogeneity** ([`ComputePlan`]) — a per-node step-time
//!   multiplier (seeded distribution or FedScale-style trace file), so
//!   stragglers delay their neighbors' `AwaitModels` completion in
//!   virtual time.
//! * **Per-link delays** ([`crate::communication::shaper::LinkMatrix`])
//!   — a dense `(src, dst)` latency/bandwidth lookup (geo-clustered WAN
//!   preset or matrix file) applied at delivery timestamping in the
//!   scheduler.
//! * **Availability churn** ([`ChurnTrace`] / [`Availability`]) —
//!   replayable per-node online intervals replacing the Bernoulli draw;
//!   nodes can sit out rounds, return, or depart for good, in which
//!   case the scheduler drops their in-flight deliveries.
//! * **Byzantine adversaries** ([`ByzantineRoster`]) — a deterministic
//!   per-node attack assignment (`byzantine:<frac>:<attack>` with
//!   `flood`, `poison:<scale>`, `collude:<k>`); malicious nodes corrupt
//!   their *outgoing* broadcasts at the round loop's send step while
//!   robust `Sharing` strategies (`trimmed_mean`, `coord_median`,
//!   `krum`) defend on the receive side.
//!
//! Every axis has a *degenerate* spec (`uniform` / `uniform` / empty /
//! empty) under which runs stay **bit-identical** to the plain PR-1
//! scheduler path — scenarios are pure extensions, never silent
//! behavior changes. Specs enter through the config keys `step_time`,
//! `link_model`, `churn_trace`, and `byzantine`, or the CLI flags
//! `--step-time-trace`, `--link-model`, `--churn-trace`, `--byzantine`,
//! and `--scenario` (a JSON overlay file). See `docs/ARCHITECTURE.md`
//! for the subsystem walk-through and `docs/CLI.md` for the full spec
//! grammars.

mod byzantine;
mod churn;
mod compute;

pub use byzantine::{ByzantineRoster, NodeAttack};
pub use churn::{is_crash_spec, Availability, ChurnTrace, FOREVER};
pub use compute::ComputePlan;

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::communication::shaper::{LinkMatrix, LinkModel, NetworkModel};
use crate::rng::mix_seed;

/// Check a `link_model` spec's syntax (no filesystem access).
pub fn validate_link_spec(spec: &str) -> Result<()> {
    parse_link_spec(spec).map(|_| ())
}

enum LinkSpec {
    Uniform,
    Geo { clusters: usize },
    Matrix { path: String },
}

fn parse_link_spec(spec: &str) -> Result<LinkSpec> {
    if spec.is_empty() || spec == "uniform" {
        return Ok(LinkSpec::Uniform);
    }
    if let Some(rest) = spec.strip_prefix("geo:") {
        let clusters: usize = rest.parse().with_context(|| format!("bad cluster count {rest:?}"))?;
        if clusters == 0 {
            bail!("geo spec needs >= 1 cluster");
        }
        return Ok(LinkSpec::Geo { clusters });
    }
    if let Some(path) = spec.strip_prefix("matrix:") {
        if path.is_empty() {
            bail!("matrix spec is matrix:<path>");
        }
        return Ok(LinkSpec::Matrix { path: path.to_string() });
    }
    bail!("unknown link-model spec {spec:?} (expected uniform | geo:<clusters> | matrix:<path>)")
}

/// Resolve a `link_model` spec into what the scheduler consumes.
/// `base` is the config's uniform network model (`None` = untimed);
/// `uniform` defers to it, matrix specs override it.
pub fn link_model_from_spec(
    spec: &str,
    nodes: usize,
    seed: u64,
    base: Option<NetworkModel>,
) -> Result<Option<LinkModel>> {
    Ok(match parse_link_spec(spec)? {
        LinkSpec::Uniform => base.map(LinkModel::Uniform),
        LinkSpec::Geo { clusters } => Some(LinkModel::Matrix(Arc::new(
            LinkMatrix::geo_clustered(nodes, clusters, seed),
        ))),
        LinkSpec::Matrix { path } => {
            let default = base.unwrap_or_else(NetworkModel::lan);
            Some(LinkModel::Matrix(Arc::new(LinkMatrix::from_file(&path, nodes, default)?)))
        }
    })
}

/// One fully-resolved scenario: everything the runners need beyond the
/// base config. Built once per experiment by `coordinator::prepare()`.
pub struct Scenario {
    /// Per-node step-time multipliers.
    pub compute: ComputePlan,
    /// Delivery-timestamping model for the scheduler (`None` = untimed).
    pub links: Option<LinkModel>,
    /// Replayable availability (`None` = the config's Bernoulli churn).
    pub churn: Option<Arc<ChurnTrace>>,
    /// Per-node attack assignment (`None` = every node is honest).
    pub byzantine: Option<Arc<ByzantineRoster>>,
}

impl Scenario {
    /// The all-degenerate scenario (PR-1 behavior) over `base`.
    pub fn degenerate(nodes: usize, base: Option<NetworkModel>) -> Scenario {
        Scenario {
            compute: ComputePlan::uniform(nodes),
            links: base.map(LinkModel::Uniform),
            churn: None,
            byzantine: None,
        }
    }

    /// Materialize the four axes from their config specs. Seeds for
    /// each axis derive from the experiment seed with distinct labels,
    /// so e.g. changing the churn spec never reshuffles stragglers.
    pub fn from_specs(
        step_time: &str,
        link_model: &str,
        churn_trace: &str,
        byzantine: &str,
        base: Option<NetworkModel>,
        nodes: usize,
        rounds: u64,
        seed: u64,
    ) -> Result<Scenario> {
        Ok(Scenario {
            compute: ComputePlan::from_spec(step_time, nodes, mix_seed(&[seed, 0x5CE0]))?,
            links: link_model_from_spec(link_model, nodes, mix_seed(&[seed, 0x11EF]), base)?,
            churn: ChurnTrace::from_spec(churn_trace, nodes, rounds, mix_seed(&[seed, 0xC0A1]))?,
            byzantine: ByzantineRoster::from_spec(byzantine, nodes, seed)?.map(Arc::new),
        })
    }

    /// The availability model the peer sampler should use in dynamic
    /// mode (`bernoulli` is the config's churn probability).
    pub fn availability(&self, bernoulli: f64) -> Availability {
        match &self.churn {
            Some(t) => Availability::Trace(Arc::clone(t)),
            None => Availability::Bernoulli(bernoulli),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_spec_validation() {
        for good in ["uniform", "", "geo:4", "matrix:/tmp/links.txt"] {
            assert!(validate_link_spec(good).is_ok(), "{good}");
        }
        for bad in ["geo:0", "geo:x", "matrix:", "mesh:3"] {
            assert!(validate_link_spec(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn uniform_link_spec_defers_to_base() {
        let base = NetworkModel::lan();
        match link_model_from_spec("uniform", 8, 1, Some(base)).unwrap() {
            Some(LinkModel::Uniform(m)) => assert_eq!(m, base),
            other => panic!("expected uniform, got {other:?}"),
        }
        assert!(link_model_from_spec("uniform", 8, 1, None).unwrap().is_none());
        // A matrix spec produces timing even with an untimed base.
        assert!(link_model_from_spec("geo:2", 8, 1, None).unwrap().is_some());
    }

    #[test]
    fn degenerate_scenario_axes() {
        let s = Scenario::degenerate(16, Some(NetworkModel::wan()));
        assert!(s.compute.is_uniform());
        assert!(s.churn.is_none());
        assert!(matches!(s.links, Some(LinkModel::Uniform(_))));
        assert!(matches!(s.availability(0.3), Availability::Bernoulli(p) if p == 0.3));
    }

    #[test]
    fn from_specs_builds_all_axes() {
        let s = Scenario::from_specs(
            "stragglers:0.25:4",
            "geo:4",
            "departures:0.25",
            "byzantine:0.25:poison:2",
            Some(NetworkModel::lan()),
            64,
            20,
            7,
        )
        .unwrap();
        assert!(!s.compute.is_uniform());
        assert!(matches!(s.links, Some(LinkModel::Matrix(_))));
        assert!(s.churn.is_some());
        assert!(matches!(s.availability(0.0), Availability::Trace(_)));
        let roster = s.byzantine.as_ref().expect("byzantine axis resolved");
        assert!(roster.count() > 0);
        // Deterministic in the seed.
        let t = Scenario::from_specs(
            "stragglers:0.25:4",
            "geo:4",
            "departures:0.25",
            "byzantine:0.25:poison:2",
            Some(NetworkModel::lan()),
            64,
            20,
            7,
        )
        .unwrap();
        assert_eq!(s.compute, t.compute);
        let other = t.byzantine.as_ref().unwrap();
        assert_eq!(
            (0..64).map(|i| roster.is_byzantine(i)).collect::<Vec<_>>(),
            (0..64).map(|i| other.is_byzantine(i)).collect::<Vec<_>>(),
        );
    }
}
