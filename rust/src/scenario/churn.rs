//! Replayable availability churn: who is online at which round.
//!
//! The PR-1 peer sampler modeled availability as i.i.d. Bernoulli coin
//! flips per round. A [`ChurnTrace`] replaces that with explicit,
//! replayable per-node online intervals — arrival/departure traces in
//! the FedScale style — so runs with churn are exactly reproducible and
//! can express *sessions* (nodes that leave and come back) and
//! *departures* (nodes that leave for good). [`Availability`] is the
//! bridge type the peer sampler consumes: either the legacy Bernoulli
//! draw or a trace.
//!
//! Spec grammar (the config's `churn_trace` key / `--churn-trace` flag):
//!
//! * empty — no trace; the `churn` config key's Bernoulli draw applies
//!   (PR-1 behavior).
//! * `trace:<path>` — interval file: one `node start end` triple per
//!   line, `end` exclusive, `-` meaning "never leaves"; nodes with no
//!   line are always online; `#` comments allowed.
//! * `sessions:<mean_on>:<mean_off>` — every node alternates online /
//!   offline sessions whose lengths are uniform in `[1, 2*mean - 1]`
//!   rounds (mean `mean`), starting online at round 0. Seeded.
//! * `departures:<frac>` — each node independently departs for good
//!   with probability `frac`, at a seeded round in `[1, rounds)`.
//! * `crashes:<frac>:<horizon_s>` — **time-indexed** fail-stop crashes:
//!   each node independently crashes with probability `frac` at a
//!   seeded *virtual instant* uniform in `(0, horizon_s)` seconds. A
//!   crash is not round-aligned: the scheduler kills the node mid-round
//!   (dropping its queued events) and its neighbors discover the
//!   silence only through their own timeouts — which is why `crashes:`
//!   requires the asynchronous gossip mode (`mode = "async_dl"`); a
//!   synchronous fleet would deadlock waiting for the dead node.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::rng::{mix_seed, Xoshiro256pp};

/// Sentinel round meaning "never" (an interval that does not end).
pub const FOREVER: u64 = u64::MAX;

/// Per-round node availability for the peer sampler and the scheduler's
/// DL state machines.
#[derive(Debug, Clone)]
pub enum Availability {
    /// Each node is independently unavailable with probability `p` each
    /// round (the PR-1 i.i.d. model; `0.0` = everyone always on).
    Bernoulli(f64),
    /// Replayable arrival/departure trace.
    Trace(Arc<ChurnTrace>),
}

impl Availability {
    /// Everyone online every round.
    pub fn always() -> Availability {
        Availability::Bernoulli(0.0)
    }
}

/// Per-node online intervals, half-open `[start, end)` in rounds, plus
/// optional *time-indexed* crash instants (virtual seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnTrace {
    /// Sorted, disjoint intervals per node.
    intervals: Vec<Vec<(u64, u64)>>,
    /// Virtual instant at which each node fail-stops (`None` = never).
    /// Orthogonal to the round-indexed intervals: a `crashes:` trace
    /// keeps every node round-active until its crash instant.
    crash_time_s: Vec<Option<f64>>,
}

impl ChurnTrace {
    fn from_intervals(intervals: Vec<Vec<(u64, u64)>>) -> ChurnTrace {
        let nodes = intervals.len();
        ChurnTrace { intervals, crash_time_s: vec![None; nodes] }
    }

    /// Everyone online forever (degenerate trace).
    pub fn always_on(nodes: usize) -> ChurnTrace {
        ChurnTrace::from_intervals(vec![vec![(0, FOREVER)]; nodes])
    }

    pub fn nodes(&self) -> usize {
        self.intervals.len()
    }

    /// Is `node` online at `round`? Ranks beyond the trace (e.g. the
    /// peer sampler's service rank) are always online.
    pub fn active(&self, node: usize, round: u64) -> bool {
        match self.intervals.get(node) {
            None => true,
            Some(iv) => iv.iter().any(|&(s, e)| s <= round && round < e),
        }
    }

    /// The last round `node` is online: `None` if it is never online,
    /// `Some(FOREVER)` if it never leaves for good. A node whose last
    /// online round is `r` has *departed* once its clock passes `r` —
    /// the scheduler then drops deliveries still in flight to it.
    pub fn last_online_round(&self, node: usize) -> Option<u64> {
        let iv = match self.intervals.get(node) {
            None => return Some(FOREVER),
            Some(iv) => iv,
        };
        iv.last().map(|&(_, e)| if e == FOREVER { FOREVER } else { e - 1 })
    }

    /// Check spec syntax without touching the filesystem.
    pub fn validate_spec(spec: &str) -> Result<()> {
        parse_spec(spec).map(|_| ())
    }

    /// Materialize a trace for `nodes` nodes and `rounds` rounds;
    /// `Ok(None)` for the empty spec (Bernoulli churn applies).
    pub fn from_spec(
        spec: &str,
        nodes: usize,
        rounds: u64,
        seed: u64,
    ) -> Result<Option<ChurnTrace>> {
        Ok(match parse_spec(spec)? {
            Spec::None => None,
            Spec::File { path } => Some(ChurnTrace::from_file(&path, nodes)?),
            Spec::Sessions { mean_on, mean_off } => {
                Some(ChurnTrace::sessions(nodes, rounds, mean_on, mean_off, seed))
            }
            Spec::Departures { frac } => Some(ChurnTrace::departures(nodes, rounds, frac, seed)),
            Spec::Crashes { frac, horizon_s } => {
                Some(ChurnTrace::crashes(nodes, frac, horizon_s, seed))
            }
        })
    }

    /// Parse an interval file (`node start end`, `end` exclusive or `-`).
    pub fn from_file(path: &str, nodes: usize) -> Result<ChurnTrace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading churn trace {path}"))?;
        let mut intervals: Vec<Vec<(u64, u64)>> = vec![Vec::new(); nodes];
        let mut mentioned = vec![false; nodes];
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let bad = || format!("{path}:{}: expected `node start end` (end = round or -)", i + 1);
            let mut parts = line.split_whitespace();
            let node: usize = parts.next().with_context(bad)?.parse().with_context(bad)?;
            let start: u64 = parts.next().with_context(bad)?.parse().with_context(bad)?;
            let end_tok = parts.next().with_context(bad)?;
            let end = if end_tok == "-" || end_tok == "inf" {
                FOREVER
            } else {
                end_tok.parse().with_context(bad)?
            };
            if node >= nodes {
                bail!("{path}:{}: node {node} out of range (fleet has {nodes})", i + 1);
            }
            if end <= start {
                bail!("{path}:{}: empty interval [{start}, {end})", i + 1);
            }
            intervals[node].push((start, end));
            mentioned[node] = true;
        }
        for (node, m) in mentioned.iter().enumerate() {
            if !m {
                intervals[node].push((0, FOREVER));
            }
        }
        for (node, iv) in intervals.iter_mut().enumerate() {
            iv.sort_unstable();
            for w in iv.windows(2) {
                if w[1].0 < w[0].1 {
                    bail!("churn trace {path}: node {node} has overlapping intervals");
                }
            }
        }
        Ok(ChurnTrace::from_intervals(intervals))
    }

    /// Alternating online/offline sessions per node, starting online at
    /// round 0; session lengths are uniform in `[1, 2*mean - 1]`.
    pub fn sessions(nodes: usize, rounds: u64, mean_on: u64, mean_off: u64, seed: u64) -> ChurnTrace {
        let draw = |rng: &mut Xoshiro256pp, mean: u64| -> u64 {
            1 + rng.below(2 * mean.max(1) - 1)
        };
        let intervals = (0..nodes)
            .map(|node| {
                let mut rng = Xoshiro256pp::new(mix_seed(&[seed, 0xC4_9A, node as u64]));
                let mut iv = Vec::new();
                let mut t = 0u64;
                let mut online = true;
                while t < rounds {
                    let len = draw(&mut rng, if online { mean_on } else { mean_off });
                    if online {
                        iv.push((t, t + len));
                    }
                    t += len;
                    online = !online;
                }
                iv
            })
            .collect();
        ChurnTrace::from_intervals(intervals)
    }

    /// Each node independently departs for good with probability `frac`,
    /// at a seeded round in `[1, rounds)`; the rest never leave.
    pub fn departures(nodes: usize, rounds: u64, frac: f64, seed: u64) -> ChurnTrace {
        let mut rng = Xoshiro256pp::new(mix_seed(&[seed, 0xDE_9A]));
        let intervals = (0..nodes)
            .map(|_| {
                if rounds >= 2 && rng.next_f64() < frac {
                    let d = 1 + rng.below(rounds - 1);
                    vec![(0, d)]
                } else {
                    vec![(0, FOREVER)]
                }
            })
            .collect();
        ChurnTrace::from_intervals(intervals)
    }

    /// Time-indexed fail-stop crashes: each node independently crashes
    /// with probability `frac` at a seeded virtual instant uniform in
    /// `(0, horizon_s)`. Everyone stays round-active until their crash —
    /// the scheduler enforces the instant itself, mid-round.
    pub fn crashes(nodes: usize, frac: f64, horizon_s: f64, seed: u64) -> ChurnTrace {
        let mut rng = Xoshiro256pp::new(mix_seed(&[seed, 0xC7_A5]));
        let crash_time_s = (0..nodes)
            .map(|_| {
                // Consume both draws unconditionally so each node's
                // crash instant is independent of earlier outcomes.
                let hit = rng.next_f64() < frac;
                let at = rng.next_f64() * horizon_s;
                if hit && at > 0.0 {
                    Some(at)
                } else {
                    None
                }
            })
            .collect();
        ChurnTrace {
            intervals: vec![vec![(0, FOREVER)]; nodes],
            crash_time_s,
        }
    }

    /// The virtual instant `node` fail-stops, if any. Ranks beyond the
    /// trace never crash.
    pub fn crash_time(&self, node: usize) -> Option<f64> {
        self.crash_time_s.get(node).copied().flatten()
    }

    /// True when any node has a time-indexed crash scheduled.
    pub fn has_crashes(&self) -> bool {
        self.crash_time_s.iter().any(|c| c.is_some())
    }
}

enum Spec {
    None,
    File { path: String },
    Sessions { mean_on: u64, mean_off: u64 },
    Departures { frac: f64 },
    Crashes { frac: f64, horizon_s: f64 },
}

/// True when `spec` is a time-indexed `crashes:` trace (they need the
/// async scheduler; config validation gates on this).
pub fn is_crash_spec(spec: &str) -> bool {
    spec.starts_with("crashes:")
}

fn parse_spec(spec: &str) -> Result<Spec> {
    if spec.is_empty() {
        return Ok(Spec::None);
    }
    if let Some(path) = spec.strip_prefix("trace:") {
        if path.is_empty() {
            bail!("churn trace spec is trace:<path>");
        }
        return Ok(Spec::File { path: path.to_string() });
    }
    if let Some(rest) = spec.strip_prefix("sessions:") {
        let (a, b) = rest
            .split_once(':')
            .context("sessions spec is sessions:<mean_on>:<mean_off>")?;
        let mean_on: u64 = a.parse().with_context(|| format!("bad mean_on {a:?}"))?;
        let mean_off: u64 = b.parse().with_context(|| format!("bad mean_off {b:?}"))?;
        if mean_on == 0 || mean_off == 0 {
            bail!("session means must be >= 1 round");
        }
        return Ok(Spec::Sessions { mean_on, mean_off });
    }
    if let Some(rest) = spec.strip_prefix("departures:") {
        let frac: f64 = rest.parse().with_context(|| format!("bad departure fraction {rest:?}"))?;
        if !(0.0..=1.0).contains(&frac) {
            bail!("departure fraction must be in [0, 1] (got {frac})");
        }
        return Ok(Spec::Departures { frac });
    }
    if let Some(rest) = spec.strip_prefix("crashes:") {
        let (f, h) = rest
            .split_once(':')
            .context("crash spec is crashes:<frac>:<horizon_s>")?;
        let frac: f64 = f.parse().with_context(|| format!("bad crash fraction {f:?}"))?;
        if !(0.0..=1.0).contains(&frac) {
            bail!("crash fraction must be in [0, 1] (got {frac})");
        }
        let horizon_s: f64 = h.parse().with_context(|| format!("bad crash horizon {h:?}"))?;
        if !(horizon_s > 0.0) {
            bail!("crash horizon must be > 0 virtual seconds (got {horizon_s})");
        }
        return Ok(Spec::Crashes { frac, horizon_s });
    }
    bail!(
        "unknown churn spec {spec:?} \
         (expected trace:<path> | sessions:<mean_on>:<mean_off> | departures:<frac> \
          | crashes:<frac>:<horizon_s>)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_on_never_departs() {
        let t = ChurnTrace::always_on(4);
        assert!(t.active(2, 0) && t.active(2, 1_000_000));
        assert_eq!(t.last_online_round(2), Some(FOREVER));
        assert!(t.active(99, 5)); // out-of-range rank fallback
        assert_eq!(t.last_online_round(99), Some(FOREVER));
    }

    #[test]
    fn file_roundtrip_intervals() {
        let dir = std::env::temp_dir().join("decentra_churn_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("churn.txt");
        std::fs::write(&path, "# availability\n0 0 -\n1 0 5\n1 8 -\n2 0 3\n").unwrap();
        let t = ChurnTrace::from_file(path.to_str().unwrap(), 4).unwrap();
        // Node 0: always on.
        assert!(t.active(0, 100));
        // Node 1: on [0,5), off [5,8), on from 8.
        assert!(t.active(1, 4) && !t.active(1, 5) && !t.active(1, 7) && t.active(1, 8));
        assert_eq!(t.last_online_round(1), Some(FOREVER));
        // Node 2: departs after round 2.
        assert!(t.active(2, 2) && !t.active(2, 3));
        assert_eq!(t.last_online_round(2), Some(2));
        // Node 3: not mentioned -> always on.
        assert!(t.active(3, 42));
    }

    #[test]
    fn file_rejects_bad_lines() {
        let dir = std::env::temp_dir().join("decentra_churn_trace_bad");
        std::fs::create_dir_all(&dir).unwrap();
        for (name, body) in [
            ("overlap.txt", "0 0 5\n0 3 8\n"),
            ("empty_iv.txt", "0 5 5\n"),
            ("range.txt", "9 0 -\n"),
            ("garbage.txt", "zero one two\n"),
        ] {
            let path = dir.join(name);
            std::fs::write(&path, body).unwrap();
            assert!(ChurnTrace::from_file(path.to_str().unwrap(), 4).is_err(), "{name}");
        }
    }

    #[test]
    fn sessions_deterministic_start_online_and_mix() {
        let a = ChurnTrace::sessions(32, 40, 6, 3, 11);
        let b = ChurnTrace::sessions(32, 40, 6, 3, 11);
        assert_eq!(a, b);
        // Everyone starts online.
        assert!((0..32).all(|i| a.active(i, 0)));
        // Some node is offline at some round (3-round mean gaps in 40
        // rounds make an all-online draw astronomically unlikely).
        let some_off =
            (0..32).any(|i| (0..40).any(|r| !a.active(i, r)));
        assert!(some_off);
    }

    #[test]
    fn departures_split_fleet() {
        let t = ChurnTrace::departures(64, 20, 0.5, 5);
        let gone = (0..64)
            .filter(|&i| t.last_online_round(i) != Some(FOREVER))
            .count();
        assert!((16..=48).contains(&gone), "{gone} departures");
        for i in 0..64 {
            match t.last_online_round(i) {
                Some(FOREVER) => assert!(t.active(i, 1_000)),
                Some(last) => {
                    assert!((1..20).contains(&(last + 1)), "depart round {}", last + 1);
                    assert!(t.active(i, last) && !t.active(i, last + 1));
                }
                None => panic!("node {i} never online"),
            }
        }
    }

    #[test]
    fn spec_validation() {
        for good in ["", "trace:/tmp/x", "sessions:6:3", "departures:0.25", "crashes:0.2:5.0"] {
            assert!(ChurnTrace::validate_spec(good).is_ok(), "{good}");
        }
        for bad in [
            "trace:",
            "sessions:0:3",
            "sessions:6",
            "departures:1.5",
            "bernoulli:0.2",
            "crashes:0.2",
            "crashes:1.5:5",
            "crashes:0.2:0",
            "crashes:0.2:-3",
        ] {
            assert!(ChurnTrace::validate_spec(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn crash_spec_detection() {
        assert!(crate::scenario::is_crash_spec("crashes:0.2:5"));
        assert!(!crate::scenario::is_crash_spec("departures:0.2"));
        assert!(!crate::scenario::is_crash_spec(""));
    }

    #[test]
    fn crashes_are_time_indexed_and_deterministic() {
        let a = ChurnTrace::crashes(64, 0.5, 10.0, 9);
        let b = ChurnTrace::crashes(64, 0.5, 10.0, 9);
        assert_eq!(a, b);
        assert!(a.has_crashes());
        let crashed = (0..64).filter(|&i| a.crash_time(i).is_some()).count();
        assert!((16..=48).contains(&crashed), "{crashed} crashes");
        for i in 0..64 {
            // Round-indexed availability is untouched: everyone is
            // active every round until the scheduler kills them.
            assert!(a.active(i, 1_000));
            assert_eq!(a.last_online_round(i), Some(FOREVER));
            if let Some(t) = a.crash_time(i) {
                assert!((0.0..10.0).contains(&t), "crash at {t}");
            }
        }
        // Ranks beyond the trace never crash.
        assert_eq!(a.crash_time(500), None);
        // Other trace kinds schedule no crashes.
        assert!(!ChurnTrace::departures(16, 10, 0.5, 1).has_crashes());
        assert!(!ChurnTrace::always_on(4).has_crashes());
    }

    #[test]
    fn from_spec_empty_is_none() {
        assert!(ChurnTrace::from_spec("", 8, 10, 1).unwrap().is_none());
        assert!(ChurnTrace::from_spec("departures:0.2", 8, 10, 1).unwrap().is_some());
    }
}
