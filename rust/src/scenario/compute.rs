//! Per-node compute heterogeneity: a step-time multiplier for every node.
//!
//! The coordinator calibrates one `step_time_s` for the whole fleet; a
//! [`ComputePlan`] scales it per node so slow devices (stragglers) take
//! proportionally longer in virtual time. Spec grammar (the config's
//! `step_time` key / the `--step-time-trace` flag):
//!
//! * `uniform` — every node runs at the calibrated speed (the default,
//!   bit-identical to not having a plan at all).
//! * `stragglers:<frac>:<factor>` — each node is independently a
//!   straggler with probability `frac`; stragglers are `factor`× slower.
//! * `lognormal:<sigma>` — multiplier `exp(sigma * z)` with `z` standard
//!   normal, a FedScale-style heavy-tailed device distribution.
//! * `trace:<path>` — one positive multiplier per line (`#` comments
//!   allowed), FedScale-device-trace style; entries are cycled when the
//!   file has fewer lines than the fleet has nodes.
//!
//! All seeded draws are deterministic in `(seed, spec)`.

use anyhow::{bail, Context, Result};

use crate::rng::Xoshiro256pp;

/// Parsed spec, before any file IO or random draws.
enum Spec {
    Uniform,
    Stragglers { frac: f64, factor: f64 },
    LogNormal { sigma: f64 },
    Trace { path: String },
}

fn parse_spec(spec: &str) -> Result<Spec> {
    if spec.is_empty() || spec == "uniform" {
        return Ok(Spec::Uniform);
    }
    if let Some(rest) = spec.strip_prefix("stragglers:") {
        let (a, b) = rest
            .split_once(':')
            .context("stragglers spec is stragglers:<frac>:<factor>")?;
        let frac: f64 = a.parse().with_context(|| format!("bad straggler fraction {a:?}"))?;
        let factor: f64 = b.parse().with_context(|| format!("bad straggler factor {b:?}"))?;
        if !(0.0..=1.0).contains(&frac) {
            bail!("straggler fraction must be in [0, 1] (got {frac})");
        }
        if !(factor > 0.0) {
            bail!("straggler factor must be positive (got {factor})");
        }
        return Ok(Spec::Stragglers { frac, factor });
    }
    if let Some(rest) = spec.strip_prefix("lognormal:") {
        let sigma: f64 = rest.parse().with_context(|| format!("bad lognormal sigma {rest:?}"))?;
        if !(sigma >= 0.0) {
            bail!("lognormal sigma must be >= 0 (got {sigma})");
        }
        return Ok(Spec::LogNormal { sigma });
    }
    if let Some(path) = spec.strip_prefix("trace:") {
        if path.is_empty() {
            bail!("trace spec is trace:<path>");
        }
        return Ok(Spec::Trace { path: path.to_string() });
    }
    bail!(
        "unknown step-time spec {spec:?} \
         (expected uniform | stragglers:<frac>:<factor> | lognormal:<sigma> | trace:<path>)"
    )
}

/// One step-time multiplier per node (1.0 = the calibrated speed).
#[derive(Debug, Clone, PartialEq)]
pub struct ComputePlan {
    multipliers: Vec<f64>,
}

impl ComputePlan {
    /// Every node at the calibrated speed.
    pub fn uniform(nodes: usize) -> ComputePlan {
        ComputePlan { multipliers: vec![1.0; nodes] }
    }

    /// Check spec syntax without touching the filesystem (config
    /// validation runs this; `trace:` files are read only at prepare).
    pub fn validate_spec(spec: &str) -> Result<()> {
        parse_spec(spec).map(|_| ())
    }

    /// Materialize a plan for `nodes` nodes. Deterministic in `seed`.
    pub fn from_spec(spec: &str, nodes: usize, seed: u64) -> Result<ComputePlan> {
        let multipliers = match parse_spec(spec)? {
            Spec::Uniform => vec![1.0; nodes],
            Spec::Stragglers { frac, factor } => {
                let mut rng = Xoshiro256pp::new(seed);
                (0..nodes)
                    .map(|_| if rng.next_f64() < frac { factor } else { 1.0 })
                    .collect()
            }
            Spec::LogNormal { sigma } => {
                let mut rng = Xoshiro256pp::new(seed);
                (0..nodes).map(|_| (sigma * rng.next_normal()).exp()).collect()
            }
            Spec::Trace { path } => {
                let entries = read_trace(&path)?;
                (0..nodes).map(|i| entries[i % entries.len()]).collect()
            }
        };
        Ok(ComputePlan { multipliers })
    }

    /// The step-time multiplier for `node`. Ranks beyond the plan (e.g.
    /// the peer sampler's service rank) run at the calibrated speed.
    pub fn multiplier(&self, node: usize) -> f64 {
        self.multipliers.get(node).copied().unwrap_or(1.0)
    }

    /// True when every node runs at exactly the calibrated speed (the
    /// degenerate scenario; runs are bit-identical to having no plan).
    pub fn is_uniform(&self) -> bool {
        self.multipliers.iter().all(|&m| m == 1.0)
    }

    pub fn nodes(&self) -> usize {
        self.multipliers.len()
    }
}

/// Read a multiplier-per-line trace file.
fn read_trace(path: &str) -> Result<Vec<f64>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading step-time trace {path}"))?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let m: f64 = line
            .parse()
            .with_context(|| format!("{path}:{}: bad multiplier {line:?}", i + 1))?;
        if !(m > 0.0) {
            bail!("{path}:{}: multiplier must be positive (got {m})", i + 1);
        }
        out.push(m);
    }
    if out.is_empty() {
        bail!("step-time trace {path} has no entries");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_is_all_ones() {
        let p = ComputePlan::from_spec("uniform", 8, 1).unwrap();
        assert!(p.is_uniform());
        assert_eq!(p.multiplier(3), 1.0);
        assert_eq!(p.multiplier(100), 1.0); // out-of-range rank fallback
    }

    #[test]
    fn stragglers_deterministic_and_fractional() {
        let a = ComputePlan::from_spec("stragglers:0.25:4", 64, 9).unwrap();
        let b = ComputePlan::from_spec("stragglers:0.25:4", 64, 9).unwrap();
        assert_eq!(a, b);
        let slow = (0..64).filter(|&i| a.multiplier(i) == 4.0).count();
        let fast = (0..64).filter(|&i| a.multiplier(i) == 1.0).count();
        assert_eq!(slow + fast, 64);
        assert!((4..=32).contains(&slow), "{slow} stragglers");
        assert!(!a.is_uniform());
    }

    #[test]
    fn straggler_factor_one_is_degenerate() {
        let p = ComputePlan::from_spec("stragglers:0.5:1", 32, 7).unwrap();
        assert!(p.is_uniform());
    }

    #[test]
    fn lognormal_positive_and_spread() {
        let p = ComputePlan::from_spec("lognormal:0.5", 128, 3).unwrap();
        assert!((0..128).all(|i| p.multiplier(i) > 0.0));
        assert!(!p.is_uniform());
        // sigma 0 degenerates to uniform.
        let z = ComputePlan::from_spec("lognormal:0", 16, 3).unwrap();
        assert!(z.is_uniform());
    }

    #[test]
    fn trace_file_cycles() {
        let dir = std::env::temp_dir().join("decentra_compute_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("devices.txt");
        std::fs::write(&path, "# device speeds\n1.0\n2.5\n0.5\n").unwrap();
        let spec = format!("trace:{}", path.display());
        let p = ComputePlan::from_spec(&spec, 5, 0).unwrap();
        assert_eq!(p.multiplier(0), 1.0);
        assert_eq!(p.multiplier(1), 2.5);
        assert_eq!(p.multiplier(2), 0.5);
        assert_eq!(p.multiplier(3), 1.0); // cycled
        assert_eq!(p.multiplier(4), 2.5);
    }

    #[test]
    fn bad_specs_rejected() {
        for bad in [
            "stragglers:2:4",
            "stragglers:0.5:0",
            "stragglers:0.5",
            "lognormal:-1",
            "trace:",
            "warp:9",
        ] {
            assert!(ComputePlan::validate_spec(bad).is_err(), "{bad}");
        }
        for good in ["uniform", "", "stragglers:0.1:8", "lognormal:0.3", "trace:/tmp/x"] {
            assert!(ComputePlan::validate_spec(good).is_ok(), "{good}");
        }
    }

    #[test]
    fn missing_trace_file_errors_at_materialize_not_validate() {
        let spec = "trace:/nonexistent/decentra/devices.txt";
        assert!(ComputePlan::validate_spec(spec).is_ok());
        assert!(ComputePlan::from_spec(spec, 4, 0).is_err());
    }
}
