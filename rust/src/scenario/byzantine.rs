//! Byzantine adversaries as a first-class scenario axis.
//!
//! A [`ByzantineRoster`] resolves a `byzantine:<frac>:<attack>` spec
//! into a deterministic per-node attack assignment: each node is drawn
//! Byzantine with probability `frac` (one RNG draw per node, consumed
//! unconditionally, so a node's fate depends only on the experiment
//! seed — never on how many peers were drawn before it). Attacks:
//!
//! * `flood[:<factor>]` — the node broadcasts a fresh noise model every
//!   round and sends `factor` duplicate copies to every neighbor
//!   (message amplification; duplicates overwrite in receivers'
//!   per-(round, sender) buffers, so the damage is junk content plus
//!   `factor`× wire bytes).
//! * `poison:<scale>` — the node trains honestly, then broadcasts
//!   `-scale ×` its model (scaled sign-flip poisoning).
//! * `collude:<k>` — Byzantine nodes are partitioned into groups of `k`
//!   (in node-id order) and every member of a group broadcasts the
//!   *same* poisoned model each round, deterministically derived from
//!   `(seed, group, round)` — mutually close candidates that stress
//!   distance-based defenses like Krum.
//!
//! Injection happens at the broadcast step of the node round loop
//! (sync + async state machines and the threaded `DlNode`): the node's
//! *own* parameters keep the honest training result so the attack is
//! sustained round after round, only the outgoing payload is corrupted.
//! All attack payloads derive from `(roster seed, node-or-group,
//! round)` — never from arrival order or wall clock — which is what
//! keeps adversarial runs bit-identical across scheduler worker counts.

use anyhow::{bail, Context, Result};

use crate::rng::{mix_seed, Xoshiro256pp};

/// Domain-separation label for everything Byzantine (roster membership
/// and per-round attack payload derivation).
const BYZ_LABEL: u64 = 0xB12A;

/// Copies per neighbor for a bare `flood` spec.
const DEFAULT_FLOOD_FACTOR: u32 = 3;

/// Noise scale of flood-attack payloads (junk models, far outside the
/// honest parameter distribution).
const FLOOD_NOISE_STD: f32 = 5.0;

/// Noise scale of the colluders' common poisoned model.
const COLLUDE_STD: f32 = 5.0;

/// The attack a single Byzantine node mounts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeAttack {
    /// Broadcast `factor` copies of a fresh noise model per neighbor.
    Flood { factor: u32 },
    /// Broadcast `-scale ×` the honestly-trained model. (The scale is
    /// carried as bits so the attack enum stays `Eq`; it is always a
    /// finite positive f32 by construction.)
    Poison { scale_bits: u32 },
    /// Broadcast the colluding group's common poisoned model.
    Collude { group: u64 },
}

/// Deterministic per-node attack assignment for one experiment.
pub struct ByzantineRoster {
    seed: u64,
    attacks: Vec<Option<NodeAttack>>,
    count: usize,
}

enum AttackKind {
    Flood { factor: u32 },
    Poison { scale: f32 },
    Collude { k: usize },
}

fn parse_attack(s: &str) -> Result<AttackKind> {
    let parts: Vec<&str> = s.split(':').collect();
    Ok(match parts.as_slice() {
        ["flood"] => AttackKind::Flood { factor: DEFAULT_FLOOD_FACTOR },
        ["flood", f] => {
            let factor: u32 = f.parse().with_context(|| format!("bad flood factor {f:?}"))?;
            if !(1..=64).contains(&factor) {
                bail!("flood factor must be in [1, 64], got {factor}");
            }
            AttackKind::Flood { factor }
        }
        ["poison"] => AttackKind::Poison { scale: 1.0 },
        ["poison", sc] => {
            let scale: f32 = sc.parse().with_context(|| format!("bad poison scale {sc:?}"))?;
            if !scale.is_finite() || scale <= 0.0 {
                bail!("poison scale must be positive and finite, got {scale}");
            }
            AttackKind::Poison { scale }
        }
        ["collude", k] => {
            let k: usize = k.parse().with_context(|| format!("bad collude group size {k:?}"))?;
            if k < 2 {
                bail!("collude group size must be >= 2, got {k}");
            }
            AttackKind::Collude { k }
        }
        _ => bail!(
            "unknown byzantine attack {s:?} (expected flood[:<factor>] | poison[:<scale>] | collude:<k>)"
        ),
    })
}

impl ByzantineRoster {
    /// Resolve a spec for an `nodes`-node fleet. Empty spec = no
    /// adversaries (`None`); everything else must match
    /// `byzantine:<frac>:<attack>`.
    pub fn from_spec(spec: &str, nodes: usize, seed: u64) -> Result<Option<ByzantineRoster>> {
        if spec.is_empty() {
            return Ok(None);
        }
        let Some(rest) = spec.strip_prefix("byzantine:") else {
            bail!("unknown byzantine spec {spec:?} (expected byzantine:<frac>:<attack>)");
        };
        let Some((frac_s, attack_s)) = rest.split_once(':') else {
            bail!("byzantine spec {spec:?} is missing an attack (byzantine:<frac>:<attack>)");
        };
        let frac: f64 = frac_s
            .parse()
            .with_context(|| format!("bad byzantine fraction {frac_s:?}"))?;
        if !(0.0..=1.0).contains(&frac) {
            bail!("byzantine fraction must be in [0, 1], got {frac}");
        }
        let kind = parse_attack(attack_s)?;
        let roster_seed = mix_seed(&[seed, BYZ_LABEL]);
        let mut rng = Xoshiro256pp::new(roster_seed);
        let mut attacks: Vec<Option<NodeAttack>> = Vec::with_capacity(nodes);
        let mut byz_index = 0usize;
        for _ in 0..nodes {
            // One draw per node, consumed unconditionally.
            let hit = rng.next_f64() < frac;
            attacks.push(if hit {
                let a = match kind {
                    AttackKind::Flood { factor } => NodeAttack::Flood { factor },
                    AttackKind::Poison { scale } => {
                        NodeAttack::Poison { scale_bits: scale.to_bits() }
                    }
                    AttackKind::Collude { k } => {
                        NodeAttack::Collude { group: (byz_index / k) as u64 }
                    }
                };
                byz_index += 1;
                Some(a)
            } else {
                None
            });
        }
        Ok(Some(ByzantineRoster { seed: roster_seed, attacks, count: byz_index }))
    }

    /// Check a spec's syntax without needing the fleet size.
    pub fn validate_spec(spec: &str) -> Result<()> {
        ByzantineRoster::from_spec(spec, 8, 0).map(|_| ())
    }

    /// The attack node `id` mounts, if any.
    pub fn attack(&self, id: usize) -> Option<NodeAttack> {
        self.attacks.get(id).copied().flatten()
    }

    /// Ground truth for the defense metrics.
    pub fn is_byzantine(&self, id: usize) -> bool {
        self.attack(id).is_some()
    }

    /// How many nodes the roster drew Byzantine.
    pub fn count(&self) -> usize {
        self.count
    }

    /// The model node `id` broadcasts in `round` *instead of* its
    /// honestly-trained `model`, plus the number of copies each
    /// neighbor receives (flood amplification; 1 otherwise). `None`
    /// for honest nodes. Deterministic in `(seed, id-or-group, round)`
    /// only, so adversarial runs stay bit-identical across worker
    /// counts.
    pub fn payload_model(&self, id: usize, round: u64, model: &[f32]) -> Option<(Vec<f32>, u32)> {
        Some(match self.attack(id)? {
            NodeAttack::Poison { scale_bits } => {
                let scale = f32::from_bits(scale_bits);
                (model.iter().map(|&v| -scale * v).collect(), 1)
            }
            NodeAttack::Flood { factor } => {
                let mut rng =
                    Xoshiro256pp::new(mix_seed(&[self.seed, 0xF100D, id as u64, round]));
                let junk = (0..model.len())
                    .map(|_| rng.normal_f32(0.0, FLOOD_NOISE_STD))
                    .collect();
                (junk, factor)
            }
            NodeAttack::Collude { group } => {
                let mut rng = Xoshiro256pp::new(mix_seed(&[self.seed, 0xC0_11DE, group, round]));
                let common = (0..model.len())
                    .map(|_| rng.normal_f32(0.0, COLLUDE_STD))
                    .collect();
                (common, 1)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_validation_accepts_the_grammar() {
        for good in [
            "",
            "byzantine:0.1:flood",
            "byzantine:0.1:flood:5",
            "byzantine:0.2:poison",
            "byzantine:0.2:poison:2.5",
            "byzantine:0.25:collude:3",
            "byzantine:0:poison:1",
            "byzantine:1:flood",
        ] {
            assert!(ByzantineRoster::validate_spec(good).is_ok(), "{good}");
        }
    }

    #[test]
    fn spec_validation_rejects_malformed_specs() {
        for bad in [
            "byzantine:1.5:flood",     // fraction out of range
            "byzantine:-0.2:poison:2", // negative fraction
            "byzantine:0.1:ddos",      // unknown attack name
            "byzantine:0.1",           // missing attack
            "byzantine:x:flood",       // unparsable fraction
            "byzantine:0.1:flood:0",   // zero-copy flood
            "byzantine:0.1:flood:999", // absurd flood factor
            "byzantine:0.1:poison:0",  // non-positive scale
            "byzantine:0.1:poison:-3", // negative scale
            "byzantine:0.1:poison:inf",
            "byzantine:0.1:collude:1", // group of one cannot collude
            "byzantine:0.1:collude:x",
            "adversary:0.1:flood", // wrong prefix
        ] {
            assert!(ByzantineRoster::validate_spec(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn roster_is_deterministic_and_fraction_shaped() {
        let a = ByzantineRoster::from_spec("byzantine:0.25:poison:2", 400, 42)
            .unwrap()
            .unwrap();
        let b = ByzantineRoster::from_spec("byzantine:0.25:poison:2", 400, 42)
            .unwrap()
            .unwrap();
        for id in 0..400 {
            assert_eq!(a.attack(id), b.attack(id), "node {id}");
        }
        // Law of large numbers, loose: 25% of 400 within ±10 points.
        assert!((60..=140).contains(&a.count()), "count = {}", a.count());
        // A different seed redraws membership.
        let c = ByzantineRoster::from_spec("byzantine:0.25:poison:2", 400, 43)
            .unwrap()
            .unwrap();
        assert!((0..400).any(|id| a.is_byzantine(id) != c.is_byzantine(id)));
        // Empty spec: no roster at all.
        assert!(ByzantineRoster::from_spec("", 400, 42).unwrap().is_none());
        // Fraction 0 never draws anyone.
        let z = ByzantineRoster::from_spec("byzantine:0:flood", 400, 42).unwrap().unwrap();
        assert_eq!(z.count(), 0);
    }

    #[test]
    fn poison_negates_and_scales_the_model() {
        let r = ByzantineRoster::from_spec("byzantine:1:poison:2", 4, 1).unwrap().unwrap();
        assert_eq!(r.count(), 4);
        let (sent, copies) = r.payload_model(2, 5, &[1.0, -0.5, 0.25]).unwrap();
        assert_eq!(copies, 1);
        assert_eq!(sent, vec![-2.0, 1.0, -0.5]);
    }

    #[test]
    fn flood_sends_junk_copies_independent_of_the_model() {
        let r = ByzantineRoster::from_spec("byzantine:1:flood:4", 4, 1).unwrap().unwrap();
        let (j1, copies) = r.payload_model(0, 3, &[1.0; 8]).unwrap();
        assert_eq!(copies, 4);
        let (j2, _) = r.payload_model(0, 3, &[9.0; 8]).unwrap();
        assert_eq!(j1, j2, "flood payload must not depend on the trained model");
        let (j3, _) = r.payload_model(0, 4, &[1.0; 8]).unwrap();
        assert_ne!(j1, j3, "flood payload must vary per round");
        let (j4, _) = r.payload_model(1, 3, &[1.0; 8]).unwrap();
        assert_ne!(j1, j4, "flood payload must vary per node");
    }

    #[test]
    fn colluders_share_one_payload_per_group_and_round() {
        let r = ByzantineRoster::from_spec("byzantine:1:collude:2", 6, 9).unwrap().unwrap();
        assert_eq!(r.count(), 6);
        // Groups of 2 in id order: {0,1}, {2,3}, {4,5}.
        let (p0, _) = r.payload_model(0, 2, &[0.0; 16]).unwrap();
        let (p1, _) = r.payload_model(1, 2, &[7.0; 16]).unwrap();
        assert_eq!(p0, p1, "group members must broadcast the same model");
        let (p2, _) = r.payload_model(2, 2, &[0.0; 16]).unwrap();
        assert_ne!(p0, p2, "different groups must differ");
        let (p0_next, _) = r.payload_model(0, 3, &[0.0; 16]).unwrap();
        assert_ne!(p0, p0_next, "the common model must vary per round");
    }

    #[test]
    fn honest_nodes_get_no_payload_override() {
        let r = ByzantineRoster::from_spec("byzantine:0:poison:1", 8, 3).unwrap().unwrap();
        for id in 0..8 {
            assert!(r.payload_model(id, 0, &[1.0]).is_none());
            assert!(!r.is_byzantine(id));
        }
    }
}
