//! Float codecs: raw f32, fp16, and QSGD-style stochastic quantization.

use anyhow::{bail, Result};

use crate::kernels::{decode_le_axpy, decode_le_axpy2, decode_le_into};
use crate::rng::{mix_seed, Xoshiro256pp};
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};

use super::FloatCodec;

/// Identity codec: little-endian f32 (full sharing's value encoding).
pub struct RawF32;

impl FloatCodec for RawF32 {
    fn name(&self) -> &'static str {
        "raw_f32"
    }

    fn encode(&self, values: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(values.len() * 4);
        self.encode_into(values, &mut out);
        out
    }

    fn encode_into(&self, values: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(values.len() * 4);
        for v in values {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(n);
        self.decode_into(bytes, n, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], n: usize, out: &mut Vec<f32>) -> Result<()> {
        if bytes.len() != n * 4 {
            bail!("raw_f32: expected {} bytes, got {}", n * 4, bytes.len());
        }
        decode_le_into(out, bytes);
        Ok(())
    }

    fn decode_axpy(
        &self,
        bytes: &[u8],
        alpha: f32,
        acc: &mut [f32],
        _scratch: &mut Vec<f32>,
    ) -> Result<()> {
        // Fully fused: wire bytes -> weighted accumulate, no staging.
        decode_le_axpy(acc, alpha, bytes)
    }

    fn decode_axpy2(
        &self,
        b1: &[u8],
        a1: f32,
        b2: &[u8],
        a2: f32,
        acc: &mut [f32],
        _scratch: &mut Vec<f32>,
    ) -> Result<()> {
        // Pairwise fused: one accumulator pass for two payloads.
        decode_le_axpy2(acc, a1, b1, a2, b2)
    }

    fn bytes_per_element(&self) -> f64 {
        4.0
    }
}

/// Half-precision codec (2 bytes/element, ~1e-3 relative error).
pub struct Fp16;

impl FloatCodec for Fp16 {
    fn name(&self) -> &'static str {
        "fp16"
    }

    fn encode(&self, values: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(values.len() * 2);
        self.encode_into(values, &mut out);
        out
    }

    fn encode_into(&self, values: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(values.len() * 2);
        for &v in values {
            out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
        }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(n);
        self.decode_into(bytes, n, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], n: usize, out: &mut Vec<f32>) -> Result<()> {
        if bytes.len() != n * 2 {
            bail!("fp16: expected {} bytes, got {}", n * 2, bytes.len());
        }
        out.clear();
        out.reserve(n);
        out.extend(
            bytes
                .chunks_exact(2)
                .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]))),
        );
        Ok(())
    }

    fn bytes_per_element(&self) -> f64 {
        2.0
    }
}

/// QSGD-style stochastic uniform quantizer (Alistarh et al. 2017).
///
/// Encodes `v` as `linf * sign * (level / (levels-1))` with stochastic
/// rounding to the nearest levels, making the decode **unbiased**:
/// `E[decode] = v`. One byte per element for `levels <= 256`, plus a
/// 4-byte scale header. The rounding RNG is seeded from the codec seed so
/// encode is deterministic per (seed, content) pair.
pub struct Qsgd {
    levels: u32,
    seed: u64,
}

impl Qsgd {
    pub fn new(levels: u32, seed: u64) -> Qsgd {
        assert!((2..=256).contains(&levels), "levels must be in 2..=256");
        Qsgd { levels, seed }
    }
}

impl FloatCodec for Qsgd {
    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn encode(&self, values: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + values.len());
        self.encode_into(values, &mut out);
        out
    }

    fn encode_into(&self, values: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(4 + values.len());
        let linf = values.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        out.extend_from_slice(&linf.to_le_bytes());
        if linf == 0.0 {
            out.resize(4 + values.len(), 0x80); // all zeros, sign +
            return;
        }
        let s = (self.levels - 1) as f32;
        let mut rng = Xoshiro256pp::new(mix_seed(&[self.seed, values.len() as u64]));
        for &v in values {
            let x = v.abs() / linf * s; // in [0, s]
            let lo = x.floor();
            let p = x - lo;
            let level = if rng.next_f32() < p { lo + 1.0 } else { lo };
            let level = (level as u32).min(self.levels - 1) as u8;
            // Bit 7 = sign, bits 0..7 = level (levels <= 256 fits since
            // level <= 255 and sign is separate only when levels <= 128;
            // for levels up to 256 we store sign in a parallel trick:
            // encode signed magnitude as level with sign bit folded when
            // possible). To stay simple and exact: 1 byte level + sign bit
            // packed into the top bit requires levels <= 128.
            let byte = if self.levels <= 128 {
                (if v < 0.0 { 0x80 } else { 0x00 }) | level
            } else {
                // levels in 129..=256: use the full byte for the level of
                // the *signed* value mapped to [0, levels-1] around the
                // midpoint. Reconstruction is symmetric.
                let sx = (v / linf + 1.0) * 0.5 * s; // [0, s]
                let lo = sx.floor();
                let p = sx - lo;
                let lv = if rng.next_f32() < p { lo + 1.0 } else { lo };
                (lv as u32).min(self.levels - 1) as u8
            };
            out.push(byte);
        }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(n);
        self.decode_into(bytes, n, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], n: usize, out: &mut Vec<f32>) -> Result<()> {
        if bytes.len() != 4 + n {
            bail!("qsgd: expected {} bytes, got {}", 4 + n, bytes.len());
        }
        let linf = f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
        let s = (self.levels - 1) as f32;
        let body = &bytes[4..];
        out.clear();
        out.reserve(n);
        if linf == 0.0 {
            out.extend(std::iter::repeat(0.0f32).take(n));
        } else if self.levels <= 128 {
            out.extend(body.iter().map(|&b| {
                let sign = if b & 0x80 != 0 { -1.0 } else { 1.0 };
                let level = (b & 0x7F) as f32;
                sign * linf * level / s
            }));
        } else {
            out.extend(body.iter().map(|&b| {
                let level = b as f32;
                (level / s * 2.0 - 1.0) * linf
            }));
        }
        Ok(())
    }

    fn bytes_per_element(&self) -> f64 {
        1.0
    }
}

/// Look up a float codec by config name.
pub fn float_codec_from_spec(spec: &str, seed: u64) -> Result<Box<dyn FloatCodec>> {
    let parts: Vec<&str> = spec.split(':').collect();
    Ok(match parts.as_slice() {
        ["raw"] | ["raw_f32"] => Box::new(RawF32),
        ["fp16"] => Box::new(Fp16),
        ["qsgd"] => Box::new(Qsgd::new(128, seed)),
        ["qsgd", levels] => Box::new(Qsgd::new(levels.parse()?, seed)),
        _ => bail!("unknown float codec {spec:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_lookup() {
        assert_eq!(float_codec_from_spec("raw", 0).unwrap().name(), "raw_f32");
        assert_eq!(float_codec_from_spec("fp16", 0).unwrap().name(), "fp16");
        assert_eq!(float_codec_from_spec("qsgd:64", 0).unwrap().name(), "qsgd");
        assert!(float_codec_from_spec("lzma", 0).is_err());
    }

    #[test]
    fn qsgd_zero_vector_is_exact() {
        let c = Qsgd::new(64, 0);
        let v = vec![0.0f32; 32];
        assert_eq!(c.decode(&c.encode(&v), 32).unwrap(), v);
    }

    #[test]
    fn qsgd_extremes_are_exact() {
        // ±linf always map to the outermost level exactly.
        let c = Qsgd::new(128, 3);
        let v = vec![2.0f32, -2.0, 2.0, -2.0];
        let dec = c.decode(&c.encode(&v), 4).unwrap();
        assert_eq!(dec, v);
    }

    #[test]
    #[should_panic]
    fn qsgd_levels_validated() {
        Qsgd::new(1, 0);
    }
}
