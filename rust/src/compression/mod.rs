//! Compression (the paper's *Compression* module): "general-purpose
//! compression algorithms for floating-point and integer lists".
//!
//! Float codecs ([`FloatCodec`]) compress parameter values; index codecs
//! ([`IndexCodec`]) compress the sorted coordinate lists of sparse
//! messages. The sharing layer composes them and accounts for every wire
//! byte, which is what Figures 3c/4/5 measure.

mod float;
mod index;

pub use float::*;
pub use index::*;

/// Lossy-or-lossless codec for f32 slices.
pub trait FloatCodec: Send + Sync {
    fn name(&self) -> &'static str;
    fn encode(&self, values: &[f32]) -> Vec<u8>;
    /// Encode into a reusable buffer (cleared + refilled); bytes are
    /// identical to [`encode`](FloatCodec::encode). Every in-crate codec
    /// overrides the allocating default, which is what lets the outgoing
    /// path run allocation-free against a pooled payload buffer.
    fn encode_into(&self, values: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&self.encode(values));
    }
    /// Decode; `n` is the expected element count (codecs may or may not
    /// need it, but the caller always knows it).
    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>>;
    /// Decode into a reusable buffer (cleared + refilled) so the hot
    /// path allocates nothing once the buffer has capacity. Values are
    /// bit-identical to [`decode`](FloatCodec::decode); every in-crate
    /// codec overrides the allocating default.
    fn decode_into(&self, bytes: &[u8], n: usize, out: &mut Vec<f32>) -> anyhow::Result<()> {
        *out = self.decode(bytes, n)?;
        Ok(())
    }
    /// Fused decode + weighted accumulate:
    /// `acc[i] += alpha * decode(bytes)[i]`. The default stages through
    /// `scratch` (one reusable buffer, no fresh allocation); [`RawF32`]
    /// overrides with the fully fused [`crate::kernels::decode_le_axpy`]
    /// that never touches `scratch` at all. This is the single dense
    /// aggregation entry point — it replaces the per-strategy
    /// decode-then-fold loops *and* the `codec.name() == "raw_f32"`
    /// string-compare dispatch full sharing used to carry.
    fn decode_axpy(
        &self,
        bytes: &[u8],
        alpha: f32,
        acc: &mut [f32],
        scratch: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        self.decode_into(bytes, acc.len(), scratch)?;
        crate::kernels::axpy(acc, alpha, scratch);
        Ok(())
    }
    /// Fold **two** payloads in one call:
    /// `decode_axpy(b1, a1)` then `decode_axpy(b2, a2)` per element. The
    /// default is literally that sequential pair, so every codec stays
    /// bit-identical; [`RawF32`] overrides with the pairwise-fused
    /// [`crate::kernels::decode_le_axpy2`], which makes one accumulator
    /// pass instead of two — the dominant traffic saving for dense
    /// aggregation at degree ≥ 2. (RawF32 validates both lengths before
    /// folding either; an aggregation error aborts the run, so the
    /// partial-fold difference on malformed input is unobservable.)
    fn decode_axpy2(
        &self,
        b1: &[u8],
        a1: f32,
        b2: &[u8],
        a2: f32,
        acc: &mut [f32],
        scratch: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        self.decode_axpy(b1, a1, acc, scratch)?;
        self.decode_axpy(b2, a2, acc, scratch)
    }
    /// Wire bytes per element (fractional allowed), for cost estimation.
    fn bytes_per_element(&self) -> f64;
}

/// Codec for strictly-increasing u32 index lists.
pub trait IndexCodec: Send + Sync {
    fn name(&self) -> &'static str;
    fn encode(&self, indices: &[u32]) -> Vec<u8>;
    /// Append the encoding to `out` (no fresh allocation); the default
    /// delegates to [`encode`](IndexCodec::encode).
    fn encode_into(&self, indices: &[u32], out: &mut Vec<u8>) {
        out.extend_from_slice(&self.encode(indices));
    }
    fn decode(&self, bytes: &[u8]) -> anyhow::Result<Vec<u32>>;
    /// Decode into a reusable buffer (cleared + refilled); the default
    /// delegates to [`decode`](IndexCodec::decode).
    fn decode_into(&self, bytes: &[u8], out: &mut Vec<u32>) -> anyhow::Result<()> {
        *out = self.decode(bytes)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn sample_values(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn raw_roundtrip_exact() {
        let v = sample_values(1000, 1);
        let c = RawF32;
        let dec = c.decode(&c.encode(&v), v.len()).unwrap();
        assert_eq!(dec, v);
        assert_eq!(c.encode(&v).len(), 4000);
    }

    #[test]
    fn fp16_roundtrip_bounded_error() {
        let v = sample_values(1000, 2);
        let c = Fp16;
        let enc = c.encode(&v);
        assert_eq!(enc.len(), 2000);
        let dec = c.decode(&enc, v.len()).unwrap();
        for (a, b) in v.iter().zip(dec.iter()) {
            assert!((a - b).abs() <= a.abs() * 1e-3 + 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn qsgd_unbiased_and_compact() {
        let v = sample_values(4096, 3);
        let c = Qsgd::new(256, 7);
        let enc = c.encode(&v);
        // 1 byte/level + 4-byte norm header.
        assert!(enc.len() <= v.len() + 16, "{}", enc.len());
        let dec = c.decode(&enc, v.len()).unwrap();
        // Stochastic quantization is unbiased: mean error ~0, bounded max.
        let me: f64 =
            v.iter().zip(&dec).map(|(a, b)| (a - b) as f64).sum::<f64>() / v.len() as f64;
        assert!(me.abs() < 5e-3, "mean err {me}");
        let linf = v.iter().cloned().fold(0.0f32, |m, x| m.max(x.abs()));
        for (a, b) in v.iter().zip(dec.iter()) {
            assert!((a - b).abs() <= 2.0 * linf / 255.0 + 1e-5);
        }
    }

    #[test]
    fn qsgd_empty_and_zero_vectors() {
        let c = Qsgd::new(16, 1);
        assert_eq!(c.decode(&c.encode(&[]), 0).unwrap(), Vec::<f32>::new());
        let z = vec![0.0f32; 64];
        assert_eq!(c.decode(&c.encode(&z), 64).unwrap(), z);
    }

    #[test]
    fn varint_delta_roundtrip() {
        let idx: Vec<u32> = vec![0, 1, 5, 100, 101, 70000, 1 << 30];
        let c = VarintDelta;
        let dec = c.decode(&c.encode(&idx)).unwrap();
        assert_eq!(dec, idx);
    }

    #[test]
    fn varint_delta_compresses_dense_runs() {
        let idx: Vec<u32> = (1000..2000).collect();
        let enc = VarintDelta.encode(&idx);
        // Consecutive deltas are 1 -> 1 byte each (plus the first index).
        assert!(enc.len() < 1010, "{}", enc.len());
    }

    #[test]
    fn bitmask_roundtrip_and_size() {
        let dim = 1000;
        let idx: Vec<u32> = (0..dim).filter(|i| i % 7 == 0).collect();
        let c = Bitmask { dim: dim as usize };
        let enc = c.encode(&idx);
        assert_eq!(enc.len(), (dim as usize + 7) / 8);
        assert_eq!(c.decode(&enc).unwrap(), idx);
    }

    #[test]
    fn best_index_codec_picks_smaller() {
        let dim = 10_000;
        let sparse: Vec<u32> = vec![5, 600, 9000];
        let dense: Vec<u32> = (0..9000).collect();
        assert!(encode_indices_best(&sparse, dim).len() < Bitmask { dim }.encode(&sparse).len() + 2);
        let d = encode_indices_best(&dense, dim);
        let roundtrip = decode_indices_best(&d, dim).unwrap();
        assert_eq!(roundtrip, dense);
        let s = encode_indices_best(&sparse, dim);
        assert_eq!(decode_indices_best(&s, dim).unwrap(), sparse);
    }

    #[test]
    fn decode_into_matches_decode_and_reuses_capacity() {
        let v = sample_values(1000, 9);
        let codecs: [Box<dyn FloatCodec>; 3] =
            [Box::new(RawF32), Box::new(Fp16), Box::new(Qsgd::new(64, 5))];
        for c in &codecs {
            let enc = c.encode(&v);
            let fresh = c.decode(&enc, v.len()).unwrap();
            let mut buf = vec![0.0f32; 7]; // dirty, wrong-sized
            c.decode_into(&enc, v.len(), &mut buf).unwrap();
            assert_eq!(buf, fresh, "{}", c.name());
            let cap = buf.capacity();
            c.decode_into(&enc, v.len(), &mut buf).unwrap();
            assert_eq!(buf.capacity(), cap, "{}: steady-state decode grew", c.name());
        }
    }

    #[test]
    fn decode_axpy_matches_decode_then_fold() {
        let v = sample_values(333, 10); // odd length crosses chunk tails
        let base = sample_values(333, 11);
        let codecs: [Box<dyn FloatCodec>; 3] =
            [Box::new(RawF32), Box::new(Fp16), Box::new(Qsgd::new(128, 6))];
        for c in &codecs {
            let enc = c.encode(&v);
            let mut fused = base.clone();
            let mut scratch = Vec::new();
            c.decode_axpy(&enc, 0.25, &mut fused, &mut scratch).unwrap();
            let mut folded = base.clone();
            let dec = c.decode(&enc, v.len()).unwrap();
            for (a, b) in folded.iter_mut().zip(dec.iter()) {
                *a += 0.25 * b;
            }
            assert_eq!(fused, folded, "{}", c.name());
            // Wrong-length payloads surface as errors, not panics.
            assert!(c.decode_axpy(&enc[..enc.len() - 1], 0.25, &mut fused, &mut scratch).is_err());
        }
    }

    #[test]
    fn index_into_variants_match() {
        let dim = 10_000;
        for idx in [vec![5u32, 600, 9000], (0..9000u32).collect::<Vec<_>>()] {
            let mut enc = vec![0xFFu8; 3]; // dirty buffer
            encode_indices_best_into(&idx, dim, &mut enc);
            assert_eq!(enc, encode_indices_best(&idx, dim));
            let mut dec = vec![7u32];
            decode_indices_best_into(&enc, dim, &mut dec).unwrap();
            assert_eq!(dec, idx);
        }
    }

    #[test]
    fn encode_into_matches_encode_and_reuses_capacity() {
        let v = sample_values(1000, 12);
        let codecs: [Box<dyn FloatCodec>; 3] =
            [Box::new(RawF32), Box::new(Fp16), Box::new(Qsgd::new(64, 5))];
        for c in &codecs {
            let fresh = c.encode(&v);
            let mut buf = vec![0xAAu8; 3]; // dirty, wrong-sized
            c.encode_into(&v, &mut buf);
            assert_eq!(buf, fresh, "{}", c.name());
            let cap = buf.capacity();
            c.encode_into(&v, &mut buf);
            assert_eq!(buf, fresh, "{}", c.name());
            assert_eq!(buf.capacity(), cap, "{}: steady-state encode grew", c.name());
        }
    }

    #[test]
    fn decode_rejects_truncated() {
        let v = sample_values(10, 4);
        let enc = RawF32.encode(&v);
        assert!(RawF32.decode(&enc[..enc.len() - 1], 10).is_err());
        let q = Qsgd::new(16, 1).encode(&v);
        assert!(Qsgd::new(16, 1).decode(&q[..2], 10).is_err());
        assert!(VarintDelta.decode(&[0x80]).is_err());
    }
}
