//! Compression (the paper's *Compression* module): "general-purpose
//! compression algorithms for floating-point and integer lists".
//!
//! Float codecs ([`FloatCodec`]) compress parameter values; index codecs
//! ([`IndexCodec`]) compress the sorted coordinate lists of sparse
//! messages. The sharing layer composes them and accounts for every wire
//! byte, which is what Figures 3c/4/5 measure.

mod float;
mod index;

pub use float::*;
pub use index::*;

/// Lossy-or-lossless codec for f32 slices.
pub trait FloatCodec: Send + Sync {
    fn name(&self) -> &'static str;
    fn encode(&self, values: &[f32]) -> Vec<u8>;
    /// Decode; `n` is the expected element count (codecs may or may not
    /// need it, but the caller always knows it).
    fn decode(&self, bytes: &[u8], n: usize) -> anyhow::Result<Vec<f32>>;
    /// Wire bytes per element (fractional allowed), for cost estimation.
    fn bytes_per_element(&self) -> f64;
}

/// Codec for strictly-increasing u32 index lists.
pub trait IndexCodec: Send + Sync {
    fn name(&self) -> &'static str;
    fn encode(&self, indices: &[u32]) -> Vec<u8>;
    fn decode(&self, bytes: &[u8]) -> anyhow::Result<Vec<u32>>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn sample_values(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn raw_roundtrip_exact() {
        let v = sample_values(1000, 1);
        let c = RawF32;
        let dec = c.decode(&c.encode(&v), v.len()).unwrap();
        assert_eq!(dec, v);
        assert_eq!(c.encode(&v).len(), 4000);
    }

    #[test]
    fn fp16_roundtrip_bounded_error() {
        let v = sample_values(1000, 2);
        let c = Fp16;
        let enc = c.encode(&v);
        assert_eq!(enc.len(), 2000);
        let dec = c.decode(&enc, v.len()).unwrap();
        for (a, b) in v.iter().zip(dec.iter()) {
            assert!((a - b).abs() <= a.abs() * 1e-3 + 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn qsgd_unbiased_and_compact() {
        let v = sample_values(4096, 3);
        let c = Qsgd::new(256, 7);
        let enc = c.encode(&v);
        // 1 byte/level + 4-byte norm header.
        assert!(enc.len() <= v.len() + 16, "{}", enc.len());
        let dec = c.decode(&enc, v.len()).unwrap();
        // Stochastic quantization is unbiased: mean error ~0, bounded max.
        let me: f64 =
            v.iter().zip(&dec).map(|(a, b)| (a - b) as f64).sum::<f64>() / v.len() as f64;
        assert!(me.abs() < 5e-3, "mean err {me}");
        let linf = v.iter().cloned().fold(0.0f32, |m, x| m.max(x.abs()));
        for (a, b) in v.iter().zip(dec.iter()) {
            assert!((a - b).abs() <= 2.0 * linf / 255.0 + 1e-5);
        }
    }

    #[test]
    fn qsgd_empty_and_zero_vectors() {
        let c = Qsgd::new(16, 1);
        assert_eq!(c.decode(&c.encode(&[]), 0).unwrap(), Vec::<f32>::new());
        let z = vec![0.0f32; 64];
        assert_eq!(c.decode(&c.encode(&z), 64).unwrap(), z);
    }

    #[test]
    fn varint_delta_roundtrip() {
        let idx: Vec<u32> = vec![0, 1, 5, 100, 101, 70000, 1 << 30];
        let c = VarintDelta;
        let dec = c.decode(&c.encode(&idx)).unwrap();
        assert_eq!(dec, idx);
    }

    #[test]
    fn varint_delta_compresses_dense_runs() {
        let idx: Vec<u32> = (1000..2000).collect();
        let enc = VarintDelta.encode(&idx);
        // Consecutive deltas are 1 -> 1 byte each (plus the first index).
        assert!(enc.len() < 1010, "{}", enc.len());
    }

    #[test]
    fn bitmask_roundtrip_and_size() {
        let dim = 1000;
        let idx: Vec<u32> = (0..dim).filter(|i| i % 7 == 0).collect();
        let c = Bitmask { dim: dim as usize };
        let enc = c.encode(&idx);
        assert_eq!(enc.len(), (dim as usize + 7) / 8);
        assert_eq!(c.decode(&enc).unwrap(), idx);
    }

    #[test]
    fn best_index_codec_picks_smaller() {
        let dim = 10_000;
        let sparse: Vec<u32> = vec![5, 600, 9000];
        let dense: Vec<u32> = (0..9000).collect();
        assert!(encode_indices_best(&sparse, dim).len() < Bitmask { dim }.encode(&sparse).len() + 2);
        let d = encode_indices_best(&dense, dim);
        let roundtrip = decode_indices_best(&d, dim).unwrap();
        assert_eq!(roundtrip, dense);
        let s = encode_indices_best(&sparse, dim);
        assert_eq!(decode_indices_best(&s, dim).unwrap(), sparse);
    }

    #[test]
    fn decode_rejects_truncated() {
        let v = sample_values(10, 4);
        let enc = RawF32.encode(&v);
        assert!(RawF32.decode(&enc[..enc.len() - 1], 10).is_err());
        let q = Qsgd::new(16, 1).encode(&v);
        assert!(Qsgd::new(16, 1).decode(&q[..2], 10).is_err());
        assert!(VarintDelta.decode(&[0x80]).is_err());
    }
}
