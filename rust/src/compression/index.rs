//! Index codecs for sorted u32 coordinate lists (sparse messages).

use anyhow::{bail, Result};

use super::IndexCodec;

/// LEB128 varint over first-order deltas — compact when indices cluster.
pub struct VarintDelta;

impl IndexCodec for VarintDelta {
    fn name(&self) -> &'static str {
        "varint_delta"
    }

    fn encode(&self, indices: &[u32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(indices.len() * 2 + 5);
        self.encode_into(indices, &mut out);
        out
    }

    fn encode_into(&self, indices: &[u32], out: &mut Vec<u8>) {
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "unsorted indices");
        write_varint(indices.len() as u64, out);
        let mut prev = 0u32;
        for (i, &x) in indices.iter().enumerate() {
            let delta = if i == 0 { x } else { x - prev - 1 };
            write_varint(delta as u64, out);
            prev = x;
        }
    }

    fn decode(&self, bytes: &[u8]) -> Result<Vec<u32>> {
        let mut out = Vec::new();
        self.decode_into(bytes, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], out: &mut Vec<u32>) -> Result<()> {
        let mut pos = 0usize;
        let count = read_varint(bytes, &mut pos)? as usize;
        out.clear();
        out.reserve(count.min(bytes.len().saturating_sub(pos) + 1));
        let mut prev = 0u32;
        for i in 0..count {
            let delta = read_varint(bytes, &mut pos)? as u32;
            let x = if i == 0 { delta } else { prev + delta + 1 };
            out.push(x);
            prev = x;
        }
        if pos != bytes.len() {
            bail!("varint_delta: {} trailing bytes", bytes.len() - pos);
        }
        Ok(())
    }
}

/// Dense bitmap over `dim` coordinates — compact when density > ~1/8.
pub struct Bitmask {
    pub dim: usize,
}

impl IndexCodec for Bitmask {
    fn name(&self) -> &'static str {
        "bitmask"
    }

    fn encode(&self, indices: &[u32]) -> Vec<u8> {
        let mut out = Vec::with_capacity((self.dim + 7) / 8);
        self.encode_into(indices, &mut out);
        out
    }

    fn encode_into(&self, indices: &[u32], out: &mut Vec<u8>) {
        let base = out.len();
        out.resize(base + (self.dim + 7) / 8, 0);
        for &i in indices {
            debug_assert!((i as usize) < self.dim);
            out[base + i as usize / 8] |= 1 << (i % 8);
        }
    }

    fn decode(&self, bytes: &[u8]) -> Result<Vec<u32>> {
        let mut out = Vec::new();
        self.decode_into(bytes, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], out: &mut Vec<u32>) -> Result<()> {
        if bytes.len() != (self.dim + 7) / 8 {
            bail!(
                "bitmask: expected {} bytes for dim {}, got {}",
                (self.dim + 7) / 8,
                self.dim,
                bytes.len()
            );
        }
        out.clear();
        for (byte_i, &b) in bytes.iter().enumerate() {
            let mut rem = b;
            while rem != 0 {
                let bit = rem.trailing_zeros();
                let idx = byte_i as u32 * 8 + bit;
                if (idx as usize) < self.dim {
                    out.push(idx);
                }
                rem &= rem - 1;
            }
        }
        Ok(())
    }
}

/// Adaptive index encoding: pick varint-delta or bitmask, whichever is
/// smaller, with a 1-byte tag. This is what the sparse sharers use.
pub fn encode_indices_best(indices: &[u32], dim: usize) -> Vec<u8> {
    let mut out = Vec::new();
    encode_indices_best_into(indices, dim, &mut out);
    out
}

/// [`encode_indices_best`] into a reusable buffer (cleared + refilled):
/// encodes varint-delta first, and replaces it with the bitmask when
/// that is smaller — same tag and byte output, no fresh allocation once
/// the buffer has capacity.
pub fn encode_indices_best_into(indices: &[u32], dim: usize, out: &mut Vec<u8>) {
    out.clear();
    // Worst-case varint size (tag + count + 5 B/index): reserving it up
    // front pins the buffer's capacity after the first call, so a
    // reused scratch buffer never regrows on later rounds whose varint
    // block happens to be a few bytes longer.
    out.reserve(6 + 5 * indices.len());
    out.push(0u8);
    VarintDelta.encode_into(indices, out);
    let mask_len = (dim + 7) / 8;
    if out.len() - 1 > mask_len {
        out.clear();
        out.push(1u8);
        Bitmask { dim }.encode_into(indices, out);
    }
}

/// Inverse of [`encode_indices_best`].
pub fn decode_indices_best(bytes: &[u8], dim: usize) -> Result<Vec<u32>> {
    let mut out = Vec::new();
    decode_indices_best_into(bytes, dim, &mut out)?;
    Ok(out)
}

/// [`decode_indices_best`] into a reusable buffer (cleared + refilled).
pub fn decode_indices_best_into(bytes: &[u8], dim: usize, out: &mut Vec<u32>) -> Result<()> {
    let Some((&tag, body)) = bytes.split_first() else {
        bail!("empty index payload");
    };
    match tag {
        0 => VarintDelta.decode_into(body, out),
        1 => Bitmask { dim }.decode_into(body, out),
        t => bail!("unknown index codec tag {t}"),
    }
}

pub(crate) fn write_varint(mut v: u64, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

pub(crate) fn read_varint(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&b) = bytes.get(*pos) else {
            bail!("varint: truncated input");
        };
        *pos += 1;
        if shift >= 64 {
            bail!("varint: overflow");
        }
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_scalar_roundtrip() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            write_varint(v, &mut buf);
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn empty_index_lists() {
        assert_eq!(VarintDelta.decode(&VarintDelta.encode(&[])).unwrap(), Vec::<u32>::new());
        let bm = Bitmask { dim: 10 };
        assert_eq!(bm.decode(&bm.encode(&[])).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn bitmask_edge_bits() {
        let bm = Bitmask { dim: 17 };
        let idx = vec![0u32, 7, 8, 15, 16];
        assert_eq!(bm.decode(&bm.encode(&idx)).unwrap(), idx);
    }

    #[test]
    fn adaptive_tag_roundtrip_extremes() {
        let dim = 80_000;
        for idx in [
            vec![0u32],
            (0..dim as u32).step_by(2).collect::<Vec<_>>(),
            (0..100u32).collect::<Vec<_>>(),
        ] {
            let enc = encode_indices_best(&idx, dim);
            assert_eq!(decode_indices_best(&enc, dim).unwrap(), idx);
        }
    }

    #[test]
    fn varint_rejects_truncation_and_trailing() {
        let enc = VarintDelta.encode(&[1, 5, 9]);
        assert!(VarintDelta.decode(&enc[..enc.len() - 1]).is_err());
        let mut extra = enc.clone();
        extra.push(0);
        assert!(VarintDelta.decode(&extra).is_err());
    }
}
