//! Full sharing: the D-PSGD baseline — every parameter, every round.

use anyhow::{bail, Result};

use crate::compression::{FloatCodec, Fp16, RawF32};
use crate::model::ParamVec;

use super::{Received, Sharing};

/// Serialize the whole parameter vector; aggregate by MH-weighted
/// averaging: `x <- w_self * x + Σ w_i * x_i`.
pub struct FullSharing {
    codec: Box<dyn FloatCodec>,
}

impl FullSharing {
    pub fn new() -> FullSharing {
        FullSharing { codec: Box::new(RawF32) }
    }

    /// Full support but fp16 values (2 bytes/param) — a cheap ablation on
    /// the value precision axis.
    pub fn fp16() -> FullSharing {
        FullSharing { codec: Box::new(Fp16) }
    }
}

impl Default for FullSharing {
    fn default() -> Self {
        Self::new()
    }
}

impl Sharing for FullSharing {
    fn name(&self) -> &'static str {
        "full"
    }

    fn outgoing(&mut self, model: &ParamVec, _round: u64) -> Result<Vec<u8>> {
        Ok(self.codec.encode(model.as_slice()))
    }

    fn aggregate(
        &mut self,
        model: &mut ParamVec,
        self_weight: f64,
        received: &[Received<'_>],
    ) -> Result<()> {
        let dim = model.len();
        let total: f64 = self_weight + received.iter().map(|r| r.weight).sum::<f64>();
        if (total - 1.0).abs() > 1e-6 {
            bail!("mixing weights sum to {total}, expected 1");
        }
        model.scale(self_weight as f32);
        for r in received {
            let w = r.weight as f32;
            // Hot path: decode raw f32 payloads straight into the
            // accumulator without the intermediate Vec (saves one 4*P-byte
            // allocation + pass per neighbor per round; see §Perf).
            if self.codec.name() == "raw_f32" {
                if r.payload.len() != dim * 4 {
                    bail!("raw_f32: expected {} bytes, got {}", dim * 4, r.payload.len());
                }
                let m = model.as_mut_slice();
                for (a, c) in m.iter_mut().zip(r.payload.chunks_exact(4)) {
                    *a += w * f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
            } else {
                let vals = self.codec.decode(r.payload, dim)?;
                let m = model.as_mut_slice();
                for (a, v) in m.iter_mut().zip(vals.iter()) {
                    *a += w * v;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_of_two_models() {
        let mut a = FullSharing::new();
        let own = ParamVec::from_vec(vec![1.0, 2.0]);
        let other = ParamVec::from_vec(vec![3.0, 6.0]);
        let payload = a.outgoing(&other, 0).unwrap();
        let mut model = own.clone();
        a.aggregate(
            &mut model,
            0.5,
            &[Received { src: 1, weight: 0.5, payload: &payload }],
        )
        .unwrap();
        assert_eq!(model.as_slice(), &[2.0, 4.0]);
    }

    #[test]
    fn payload_is_4_bytes_per_param() {
        let mut s = FullSharing::new();
        let m = ParamVec::zeros(100);
        assert_eq!(s.outgoing(&m, 0).unwrap().len(), 400);
        let mut h = FullSharing::fp16();
        assert_eq!(h.outgoing(&m, 0).unwrap().len(), 200);
    }

    #[test]
    fn weight_sum_checked() {
        let mut s = FullSharing::new();
        let payload = s.outgoing(&ParamVec::zeros(2), 0).unwrap();
        let mut model = ParamVec::zeros(2);
        let r = [Received { src: 0, weight: 0.9, payload: &payload }];
        assert!(s.aggregate(&mut model, 0.5, &r).is_err());
    }

    #[test]
    fn three_way_metropolis_average() {
        let mut s = FullSharing::new();
        let p1 = s.outgoing(&ParamVec::from_vec(vec![3.0]), 0).unwrap();
        let p2 = s.outgoing(&ParamVec::from_vec(vec![9.0]), 0).unwrap();
        let mut model = ParamVec::from_vec(vec![0.0]);
        s.aggregate(
            &mut model,
            1.0 / 3.0,
            &[
                Received { src: 1, weight: 1.0 / 3.0, payload: &p1 },
                Received { src: 2, weight: 1.0 / 3.0, payload: &p2 },
            ],
        )
        .unwrap();
        assert!((model.as_slice()[0] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn fp16_aggregation_close_to_exact() {
        let mut s = FullSharing::fp16();
        let other = ParamVec::from_vec(vec![0.123456, -4.5678]);
        let payload = s.outgoing(&other, 0).unwrap();
        let mut model = ParamVec::zeros(2);
        s.aggregate(
            &mut model,
            0.5,
            &[Received { src: 1, weight: 0.5, payload: &payload }],
        )
        .unwrap();
        assert!((model.as_slice()[0] - 0.0617).abs() < 1e-3);
        assert!((model.as_slice()[1] + 2.2839).abs() < 2e-3);
    }
}
