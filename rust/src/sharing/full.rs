//! Full sharing: the D-PSGD baseline — every parameter, every round.

use anyhow::{bail, Result};

use crate::compression::{FloatCodec, Fp16, RawF32};
use crate::kernels::fold::FoldCtx;
use crate::kernels::{self, FoldPartial, Scratch};
use crate::model::ParamVec;

use super::{Received, Sharing};

/// Serialize the whole parameter vector; aggregate by MH-weighted
/// averaging: `x <- w_self * x + Σ w_i * x_i`.
pub struct FullSharing {
    codec: Box<dyn FloatCodec>,
    fold: FoldCtx,
}

impl FullSharing {
    pub fn new() -> FullSharing {
        FullSharing { codec: Box::new(RawF32), fold: FoldCtx::serial() }
    }

    /// Full support but fp16 values (2 bytes/param) — a cheap ablation on
    /// the value precision axis.
    pub fn fp16() -> FullSharing {
        FullSharing { codec: Box::new(Fp16), fold: FoldCtx::serial() }
    }
}

/// Fold one leaf group of dense messages into `acc`: pairs share one
/// accumulator pass through the codec's fused `decode_axpy2`, the odd
/// remainder folds alone — exactly the serial aggregation loop applied
/// to the group's slice, so a single-group plan is the serial fold.
fn fold_group(
    codec: &dyn FloatCodec,
    group: &[Received<'_>],
    acc: &mut [f32],
    stage: &mut Vec<f32>,
) -> Result<()> {
    let mut pairs = group.chunks_exact(2);
    for pair in &mut pairs {
        codec.decode_axpy2(
            pair[0].payload,
            pair[0].weight as f32,
            pair[1].payload,
            pair[1].weight as f32,
            acc,
            stage,
        )?;
    }
    for r in pairs.remainder() {
        codec.decode_axpy(r.payload, r.weight as f32, acc, stage)?;
    }
    Ok(())
}

impl Default for FullSharing {
    fn default() -> Self {
        Self::new()
    }
}

impl Sharing for FullSharing {
    fn name(&self) -> &'static str {
        "full"
    }

    fn set_fold(&mut self, fold: FoldCtx) {
        self.fold = fold;
    }

    fn outgoing_into(
        &mut self,
        model: &ParamVec,
        _round: u64,
        _scratch: &mut Scratch,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        self.codec.encode_into(model.as_slice(), out);
        Ok(())
    }

    fn aggregate_with(
        &mut self,
        model: &mut ParamVec,
        self_weight: f64,
        received: &[Received<'_>],
        scratch: &mut Scratch,
    ) -> Result<()> {
        let total: f64 = self_weight + received.iter().map(|r| r.weight).sum::<f64>();
        if (total - 1.0).abs() > 1e-6 {
            bail!("mixing weights sum to {total}, expected 1");
        }
        kernels::scale(model.as_mut_slice(), self_weight as f32);
        // Every codec folds through the fused decode_axpy entry points:
        // raw f32 goes bytes -> accumulator with no staging at all (and
        // pairs of neighbors share one accumulator pass), other codecs
        // stage once in the scratch arena. (This retired the old
        // `codec.name() == "raw_f32"` string-compare dispatch.)
        //
        // Under a tree fold plan, leaf group 0 runs that loop into the
        // model on this thread while groups 1.. run it into zero-seeded
        // arena partials concurrently; partials then combine in group
        // order (see `kernels::fold` for the determinism contract).
        let degree = received.len();
        let fold = self.fold;
        let groups = fold.groups(degree);
        if groups <= 1 {
            return fold_group(
                self.codec.as_ref(),
                received,
                model.as_mut_slice(),
                &mut scratch.dense,
            );
        }
        let dim = model.len();
        scratch.prepare_partials(groups - 1, dim);
        let Scratch { partials, dense, .. } = scratch;
        let codec = self.codec.as_ref();
        let m = model.as_mut_slice();
        let own = move || fold_group(codec, &received[fold.group_range(degree, 0)], m, dense);
        let per_group = |g: usize, p: &mut FoldPartial| {
            fold_group(
                codec,
                &received[fold.group_range(degree, g + 1)],
                &mut p.acc,
                &mut p.stage,
            )
        };
        kernels::fold::run_fold_jobs(fold.workers, &mut partials[..groups - 1], per_group, own)?;
        for p in partials[..groups - 1].iter() {
            kernels::axpy(model.as_mut_slice(), 1.0, &p.acc);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_of_two_models() {
        let mut a = FullSharing::new();
        let own = ParamVec::from_vec(vec![1.0, 2.0]);
        let other = ParamVec::from_vec(vec![3.0, 6.0]);
        let payload = a.outgoing(&other, 0).unwrap();
        let mut model = own.clone();
        a.aggregate(
            &mut model,
            0.5,
            &[Received { src: 1, weight: 0.5, payload: &payload }],
        )
        .unwrap();
        assert_eq!(model.as_slice(), &[2.0, 4.0]);
    }

    #[test]
    fn payload_is_4_bytes_per_param() {
        let mut s = FullSharing::new();
        let m = ParamVec::zeros(100);
        assert_eq!(s.outgoing(&m, 0).unwrap().len(), 400);
        let mut h = FullSharing::fp16();
        assert_eq!(h.outgoing(&m, 0).unwrap().len(), 200);
    }

    #[test]
    fn weight_sum_checked() {
        let mut s = FullSharing::new();
        let payload = s.outgoing(&ParamVec::zeros(2), 0).unwrap();
        let mut model = ParamVec::zeros(2);
        let r = [Received { src: 0, weight: 0.9, payload: &payload }];
        assert!(s.aggregate(&mut model, 0.5, &r).is_err());
    }

    #[test]
    fn three_way_metropolis_average() {
        let mut s = FullSharing::new();
        let p1 = s.outgoing(&ParamVec::from_vec(vec![3.0]), 0).unwrap();
        let p2 = s.outgoing(&ParamVec::from_vec(vec![9.0]), 0).unwrap();
        let mut model = ParamVec::from_vec(vec![0.0]);
        s.aggregate(
            &mut model,
            1.0 / 3.0,
            &[
                Received { src: 1, weight: 1.0 / 3.0, payload: &p1 },
                Received { src: 2, weight: 1.0 / 3.0, payload: &p2 },
            ],
        )
        .unwrap();
        assert!((model.as_slice()[0] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn fp16_aggregation_close_to_exact() {
        let mut s = FullSharing::fp16();
        let other = ParamVec::from_vec(vec![0.123456, -4.5678]);
        let payload = s.outgoing(&other, 0).unwrap();
        let mut model = ParamVec::zeros(2);
        s.aggregate(
            &mut model,
            0.5,
            &[Received { src: 1, weight: 0.5, payload: &payload }],
        )
        .unwrap();
        assert!((model.as_slice()[0] - 0.0617).abs() < 1e-3);
        assert!((model.as_slice()[1] + 2.2839).abs() < 2e-3);
    }
}
