//! Quantized full sharing: every coordinate, QSGD-quantized values.
//!
//! The paper lists quantization (QSGD) as the other big communication-
//! efficiency family next to sparsification; this strategy provides it as
//! an ablation axis: same support as full sharing, 1 byte per value.

use anyhow::{bail, Result};

use crate::compression::{FloatCodec, Qsgd};
use crate::kernels::fold::FoldCtx;
use crate::kernels::{self, FoldPartial, Scratch};
use crate::model::ParamVec;

use super::{Received, Sharing};

pub struct Quantized {
    codec: Qsgd,
    fold: FoldCtx,
}

impl Quantized {
    pub fn new(levels: u32, seed: u64) -> Quantized {
        Quantized { codec: Qsgd::new(levels, seed), fold: FoldCtx::serial() }
    }
}

/// Fold one leaf group: each message dequantizes into `stage` once and
/// folds in with the fused axpy — the serial loop applied to a slice.
fn fold_group(
    codec: &Qsgd,
    group: &[Received<'_>],
    acc: &mut [f32],
    stage: &mut Vec<f32>,
) -> Result<()> {
    for r in group {
        codec.decode_axpy(r.payload, r.weight as f32, acc, stage)?;
    }
    Ok(())
}

impl Sharing for Quantized {
    fn name(&self) -> &'static str {
        "quant"
    }

    fn set_fold(&mut self, fold: FoldCtx) {
        self.fold = fold;
    }

    fn outgoing_into(
        &mut self,
        model: &ParamVec,
        _round: u64,
        _scratch: &mut Scratch,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        self.codec.encode_into(model.as_slice(), out);
        Ok(())
    }

    fn aggregate_with(
        &mut self,
        model: &mut ParamVec,
        self_weight: f64,
        received: &[Received<'_>],
        scratch: &mut Scratch,
    ) -> Result<()> {
        let total: f64 = self_weight + received.iter().map(|r| r.weight).sum::<f64>();
        if (total - 1.0).abs() > 1e-6 {
            bail!("mixing weights sum to {total}, expected 1");
        }
        kernels::scale(model.as_mut_slice(), self_weight as f32);
        // QSGD stages its dequantized values once in the arena and folds
        // them in with the axpy kernel — no fresh vector. Tree plans run
        // leaf group 0 into the model while other groups fold into arena
        // partials concurrently (combined in group order; deterministic
        // at any worker count, see `kernels::fold`).
        let degree = received.len();
        let fold = self.fold;
        let groups = fold.groups(degree);
        if groups <= 1 {
            return fold_group(&self.codec, received, model.as_mut_slice(), &mut scratch.dense);
        }
        let dim = model.len();
        scratch.prepare_partials(groups - 1, dim);
        let Scratch { partials, dense, .. } = scratch;
        let codec = &self.codec;
        let m = model.as_mut_slice();
        let own = move || fold_group(codec, &received[fold.group_range(degree, 0)], m, dense);
        let per_group = |g: usize, p: &mut FoldPartial| {
            fold_group(codec, &received[fold.group_range(degree, g + 1)], &mut p.acc, &mut p.stage)
        };
        kernels::fold::run_fold_jobs(fold.workers, &mut partials[..groups - 1], per_group, own)?;
        for p in partials[..groups - 1].iter() {
            kernels::axpy(model.as_mut_slice(), 1.0, &p.acc);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn payload_one_byte_per_param_plus_header() {
        let mut s = Quantized::new(128, 0);
        let m = ParamVec::zeros(1000);
        assert_eq!(s.outgoing(&m, 0).unwrap().len(), 1004);
    }

    #[test]
    fn aggregation_approximates_average() {
        let mut s = Quantized::new(128, 1);
        let mut rng = Xoshiro256pp::new(2);
        let other = ParamVec::random(512, 1.0, &mut rng);
        let payload = s.outgoing(&other, 0).unwrap();
        let mut model = ParamVec::zeros(512);
        s.aggregate(
            &mut model,
            0.5,
            &[Received { src: 1, weight: 0.5, payload: &payload }],
        )
        .unwrap();
        for (got, want) in model.as_slice().iter().zip(other.as_slice()) {
            assert!((got - want * 0.5).abs() < 0.02, "{got} vs {}", want * 0.5);
        }
    }
}
