//! Choco-SGD: error-compensated compressed gossip (Koloskova, Stich &
//! Jaggi, ICML 2019) — the paper's tuned state-of-the-art sparsifier.
//!
//! Every node `i` maintains a public estimate `x̂_i` of its own model and
//! one estimate `x̂_j` per neighbor. Per round:
//!
//! ```text
//! q_i   = TopK(x_i − x̂_i)            (compressed correction)
//! send q_i;   x̂_i ← x̂_i + q_i        (everyone can track x̂_i)
//! recv q_j;   x̂_j ← x̂_j + q_j
//! x_i   ← x_i + γ Σ_j w_ij (x̂_j − x̂_i)   (gossip on the estimates)
//! ```
//!
//! The correction values (not absolute parameters) go on the wire, so the
//! payload is the same sparse layout as the other sparsifiers. Neighbor
//! estimates start at the common initialization, which all nodes share by
//! construction (same seed), matching the algorithm's assumption.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::kernels::fold::FoldCtx;
use crate::kernels::{self, FoldPartial, Scratch};
use crate::model::{topk_of, ParamVec};

use super::{decode_sparse_into, encode_sparse_parts_into, Received, Sharing};

pub struct ChocoSgd {
    budget: f64,
    gamma: f64,
    dim: usize,
    fold: FoldCtx,
    /// x̂_i — public estimate of our own model.
    x_hat_self: ParamVec,
    /// x̂_j per neighbor (created lazily at the common init = zeros…
    /// actually at `init`, see [`ChocoSgd::set_init`]).
    x_hat_neighbors: HashMap<usize, ParamVec>,
    /// Common initialization for lazily-created estimates.
    init: ParamVec,
    init_set: bool,
}

impl ChocoSgd {
    pub fn new(budget: f64, gamma: f64, dim: usize) -> ChocoSgd {
        assert!(0.0 < budget && budget <= 1.0);
        assert!(0.0 < gamma && gamma <= 1.0);
        ChocoSgd {
            budget,
            gamma,
            dim,
            fold: FoldCtx::serial(),
            x_hat_self: ParamVec::zeros(dim),
            x_hat_neighbors: HashMap::new(),
            init: ParamVec::zeros(dim),
            init_set: false,
        }
    }

    /// Record the common model initialization (all nodes start equal in
    /// D-PSGD); estimates start from it rather than from zero.
    pub fn set_init(&mut self, init: &ParamVec) {
        self.init = init.clone();
        self.x_hat_self = init.clone();
        self.init_set = true;
    }

    fn k(&self) -> usize {
        ((self.dim as f64 * self.budget).round() as usize).clamp(1, self.dim)
    }

    pub fn gamma(&self) -> f64 {
        self.gamma
    }
}

impl Sharing for ChocoSgd {
    fn name(&self) -> &'static str {
        "choco"
    }

    fn set_init(&mut self, init: &ParamVec) {
        ChocoSgd::set_init(self, init);
    }

    fn set_fold(&mut self, fold: FoldCtx) {
        self.fold = fold;
    }

    fn outgoing_into(
        &mut self,
        model: &ParamVec,
        _round: u64,
        scratch: &mut Scratch,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        if !self.init_set {
            // Fallback: treat the first observed model as the common init.
            self.set_init(model);
        }
        // q = TopK(x - x_hat), staged entirely in the arena. Choco
        // touches the full parameter vector three times per outgoing
        // (diff, selection, estimate update) — all of it runs on the
        // kernels with zero fresh O(dim) buffers.
        scratch.dense2.clear();
        scratch.dense2.extend_from_slice(model.as_slice());
        kernels::axpy(&mut scratch.dense2, -1.0, self.x_hat_self.as_slice());
        topk_of(
            &scratch.dense2,
            self.k(),
            &mut scratch.mags,
            &mut scratch.indices,
            &mut scratch.values,
        );
        // x_hat_self += q
        kernels::scatter_axpy(
            self.x_hat_self.as_mut_slice(),
            1.0,
            &scratch.indices,
            &scratch.values,
        );
        encode_sparse_parts_into(
            &scratch.indices,
            &scratch.values,
            self.dim,
            &mut scratch.bytes,
            out,
        );
        Ok(())
    }

    fn aggregate_with(
        &mut self,
        model: &mut ParamVec,
        _self_weight: f64,
        received: &[Received<'_>],
        scratch: &mut Scratch,
    ) -> Result<()> {
        if model.len() != self.dim {
            bail!("model dim {} != choco dim {}", model.len(), self.dim);
        }
        // Update neighbor estimates with their corrections.
        for r in received {
            decode_sparse_into(r.payload, self.dim, &mut scratch.indices, &mut scratch.values)?;
            let x_hat = self
                .x_hat_neighbors
                .entry(r.src)
                .or_insert_with(|| self.init.clone());
            kernels::scatter_axpy(x_hat.as_mut_slice(), 1.0, &scratch.indices, &scratch.values);
        }
        // Gossip step on estimates: x += gamma * sum_j w_j (x_hat_j - x_hat_i).
        // (The estimate updates above stay serial — they mutate per-
        // neighbor state — but this diff-axpy chain over dense estimates
        // is the dominant O(degree · dim) term and folds by leaf group:
        // group 0 into the model, other groups into arena partials,
        // combined in group order. See `kernels::fold`.)
        let degree = received.len();
        let fold = self.fold;
        let groups = fold.groups(degree);
        let gamma = self.gamma;
        let nbrs = &self.x_hat_neighbors;
        let x_self = self.x_hat_self.as_slice();
        if groups <= 1 {
            for r in received {
                let x_hat_j = &nbrs[&r.src];
                let g = (gamma * r.weight) as f32;
                kernels::diff_axpy(model.as_mut_slice(), g, x_hat_j.as_slice(), x_self);
            }
            return Ok(());
        }
        let dim = self.dim;
        scratch.prepare_partials(groups - 1, dim);
        let Scratch { partials, .. } = scratch;
        let m = model.as_mut_slice();
        let own = move || -> Result<()> {
            for r in &received[fold.group_range(degree, 0)] {
                let x_hat_j = &nbrs[&r.src];
                kernels::diff_axpy(m, (gamma * r.weight) as f32, x_hat_j.as_slice(), x_self);
            }
            Ok(())
        };
        let per_group = |g: usize, p: &mut FoldPartial| -> Result<()> {
            for r in &received[fold.group_range(degree, g + 1)] {
                let x_hat_j = &nbrs[&r.src];
                kernels::diff_axpy(&mut p.acc, (gamma * r.weight) as f32, x_hat_j.as_slice(), x_self);
            }
            Ok(())
        };
        kernels::fold::run_fold_jobs(fold.workers, &mut partials[..groups - 1], per_group, own)?;
        for p in partials[..groups - 1].iter() {
            kernels::axpy(model.as_mut_slice(), 1.0, &p.acc);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::sharing::decode_sparse;

    #[test]
    fn estimates_track_model_over_rounds() {
        // With budget 1.0 the compression is exact: x_hat == model after
        // each outgoing, so neighbors hold perfect estimates.
        let mut s = ChocoSgd::new(1.0, 0.5, 8);
        let mut rng = Xoshiro256pp::new(1);
        let m = ParamVec::random(8, 1.0, &mut rng);
        s.set_init(&ParamVec::zeros(8));
        s.outgoing(&m, 0).unwrap();
        assert_eq!(s.x_hat_self, m);
    }

    #[test]
    fn exact_compression_matches_gossip_average() {
        // Two nodes, budget 1, gamma 1: one round moves each model to the
        // weighted average of the estimates == plain gossip.
        let dims = 4;
        let init = ParamVec::zeros(dims);
        let mut sa = ChocoSgd::new(1.0, 1.0, dims);
        let mut sb = ChocoSgd::new(1.0, 1.0, dims);
        sa.set_init(&init);
        sb.set_init(&init);
        let ma0 = ParamVec::from_vec(vec![1.0, 2.0, 3.0, 4.0]);
        let mb0 = ParamVec::from_vec(vec![3.0, 2.0, 1.0, 0.0]);
        let qa = sa.outgoing(&ma0, 0).unwrap();
        let qb = sb.outgoing(&mb0, 0).unwrap();
        let mut ma = ma0.clone();
        let mut mb = mb0.clone();
        sa.aggregate(&mut ma, 0.5, &[Received { src: 1, weight: 0.5, payload: &qb }])
            .unwrap();
        sb.aggregate(&mut mb, 0.5, &[Received { src: 0, weight: 0.5, payload: &qa }])
            .unwrap();
        // x_a + 1.0 * 0.5 * (x_b - x_a) = average.
        for i in 0..dims {
            let avg = (ma0.as_slice()[i] + mb0.as_slice()[i]) / 2.0;
            assert!((ma.as_slice()[i] - avg).abs() < 1e-6);
            assert!((mb.as_slice()[i] - avg).abs() < 1e-6);
        }
    }

    #[test]
    fn consensus_under_10pct_budget() {
        // A 4-clique running pure Choco gossip (no gradients) must drive
        // all models toward the average even at 10% budget.
        let n = 4;
        let dim = 100;
        let mut rng = Xoshiro256pp::new(3);
        let init = ParamVec::zeros(dim);
        let mut sharers: Vec<ChocoSgd> =
            (0..n).map(|_| {
                let mut s = ChocoSgd::new(0.1, 0.4, dim);
                s.set_init(&init);
                s
            }).collect();
        let mut models: Vec<ParamVec> =
            (0..n).map(|_| ParamVec::random(dim, 1.0, &mut rng)).collect();
        let target: Vec<f32> = (0..dim)
            .map(|i| models.iter().map(|m| m.as_slice()[i]).sum::<f32>() / n as f32)
            .collect();
        let spread = |models: &[ParamVec]| -> f64 {
            models
                .iter()
                .map(|m| {
                    m.as_slice()
                        .iter()
                        .zip(&target)
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum::<f64>()
                })
                .sum::<f64>()
        };
        let initial_spread = spread(&models);
        let w = 1.0 / n as f64;
        for round in 0..60 {
            let payloads: Vec<Vec<u8>> = models
                .iter()
                .zip(sharers.iter_mut())
                .map(|(m, s)| s.outgoing(m, round).unwrap())
                .collect();
            for i in 0..n {
                let received: Vec<Received> = (0..n)
                    .filter(|&j| j != i)
                    .map(|j| Received { src: j, weight: w, payload: &payloads[j] })
                    .collect();
                sharers[i].aggregate(&mut models[i], w, &received).unwrap();
            }
        }
        let final_spread = spread(&models);
        assert!(
            final_spread < initial_spread * 0.05,
            "spread {initial_spread} -> {final_spread}"
        );
        // And the consensus point is the initial average (gossip is
        // average-preserving with symmetric weights).
        for i in 0..dim {
            let mean =
                models.iter().map(|m| m.as_slice()[i]).sum::<f32>() / n as f32;
            assert!((mean - target[i]).abs() < 0.05, "coord {i}");
        }
    }

    #[test]
    fn payload_respects_budget() {
        let mut s = ChocoSgd::new(0.1, 0.5, 1000);
        let mut rng = Xoshiro256pp::new(7);
        let m = ParamVec::random(1000, 1.0, &mut rng);
        let payload = s.outgoing(&m, 0).unwrap();
        let sv = decode_sparse(&payload, 1000).unwrap();
        assert_eq!(sv.nnz(), 100);
    }
}
