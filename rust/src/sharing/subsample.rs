//! Random-sampling sparsification: each round, send a uniformly random
//! `budget` fraction of coordinates (paper Fig 4's "random sampling").

use anyhow::Result;

use crate::kernels::fold::FoldCtx;
use crate::kernels::Scratch;
use crate::model::ParamVec;
use crate::rng::{mix_seed, Xoshiro256pp};

use super::{aggregate_sparse_absolute_fold, encode_sparse_parts_into, Received, Sharing};

pub struct SubSampling {
    budget: f64,
    dim: usize,
    fold: FoldCtx,
    rng: Xoshiro256pp,
}

impl SubSampling {
    pub fn new(budget: f64, dim: usize, seed: u64) -> SubSampling {
        assert!(0.0 < budget && budget <= 1.0);
        SubSampling {
            budget,
            dim,
            fold: FoldCtx::serial(),
            rng: Xoshiro256pp::new(mix_seed(&[seed, 0x5AB5])),
        }
    }

    fn k(&self) -> usize {
        ((self.dim as f64 * self.budget).round() as usize).clamp(1, self.dim)
    }
}

impl Sharing for SubSampling {
    fn name(&self) -> &'static str {
        "subsample"
    }

    fn set_fold(&mut self, fold: FoldCtx) {
        self.fold = fold;
    }

    fn outgoing_into(
        &mut self,
        model: &ParamVec,
        _round: u64,
        scratch: &mut Scratch,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        let sv = model.sample_k(self.k(), &mut self.rng);
        encode_sparse_parts_into(&sv.indices, &sv.values, sv.dim, &mut scratch.bytes, out);
        Ok(())
    }

    fn aggregate_with(
        &mut self,
        model: &mut ParamVec,
        _self_weight: f64,
        received: &[Received<'_>],
        scratch: &mut Scratch,
    ) -> Result<()> {
        aggregate_sparse_absolute_fold(model, received, scratch, self.fold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharing::{decode_sparse, encode_sparse};

    #[test]
    fn payload_respects_budget() {
        let mut s = SubSampling::new(0.1, 1000, 1);
        let mut rng = Xoshiro256pp::new(2);
        let m = ParamVec::random(1000, 1.0, &mut rng);
        let payload = s.outgoing(&m, 0).unwrap();
        let sv = decode_sparse(&payload, 1000).unwrap();
        assert_eq!(sv.nnz(), 100);
        // Wire size is far below full sharing (4000 B).
        assert!(payload.len() < 700, "{}", payload.len());
    }

    #[test]
    fn coordinates_change_between_rounds() {
        let mut s = SubSampling::new(0.05, 500, 3);
        let m = ParamVec::zeros(500);
        let a = decode_sparse(&s.outgoing(&m, 0).unwrap(), 500).unwrap();
        let b = decode_sparse(&s.outgoing(&m, 1).unwrap(), 500).unwrap();
        assert_ne!(a.indices, b.indices);
    }

    #[test]
    fn aggregation_blends_received_coords_only() {
        let mut s = SubSampling::new(0.5, 4, 1);
        let mut model = ParamVec::from_vec(vec![1.0; 4]);
        let sv = crate::model::SparseVec {
            dim: 4,
            indices: vec![0, 2],
            values: vec![5.0, 9.0],
        };
        let payload = encode_sparse(&sv);
        s.aggregate(
            &mut model,
            0.5,
            &[Received { src: 1, weight: 0.5, payload: &payload }],
        )
        .unwrap();
        assert_eq!(model.as_slice(), &[3.0, 1.0, 5.0, 1.0]);
    }

    #[test]
    fn full_budget_sends_everything() {
        let mut s = SubSampling::new(1.0, 16, 1);
        let mut rng = Xoshiro256pp::new(9);
        let m = ParamVec::random(16, 1.0, &mut rng);
        let sv = decode_sparse(&s.outgoing(&m, 0).unwrap(), 16).unwrap();
        assert_eq!(sv.nnz(), 16);
        assert_eq!(sv.to_dense(), m);
    }
}
