//! Sharing algorithms (the paper's *Sharing* module): what goes into a
//! model message and how received messages are aggregated.
//!
//! * [`FullSharing`] — serialize all parameters; Metropolis–Hastings
//!   weighted averaging (plain D-PSGD).
//! * [`SubSampling`] — random `budget` fraction of coordinates per round
//!   (the paper's *random sampling* sparsifier, Fig 4).
//! * [`TopK`] — largest-change coordinates with the change metric the
//!   paper's Model module motivates ("how much the learning parameters
//!   changed in the last iteration").
//! * [`ChocoSgd`] — error-compensated compressed gossip (Koloskova et
//!   al. 2019), the paper's tuned state-of-the-art sparsifier.
//! * [`Quantized`] — full support with QSGD-quantized values (ablation).
//! * [`TrimmedMean`] / [`CoordMedian`] / [`Krum`] — Byzantine-robust
//!   aggregation rules ([`robust`]): dense payloads, candidate-matrix
//!   order statistics / Krum selection instead of weighted mixing, plus
//!   a per-round [`DefenseReport`] feeding the attack metrics.
//!
//! Sparse payloads share one wire layout: `u32 index-block length ‖
//! adaptive index codec block ‖ f32 values`. All byte counts flow through
//! the transport counters, which is what Figures 3c/4/5 plot.
//!
//! All five strategies aggregate through the fused primitives in
//! [`crate::kernels`] and stage every intermediate in a per-node
//! [`Scratch`] arena (`aggregate_with` / `outgoing_with`), so
//! steady-state rounds are allocation-free; the scalar loops they
//! replaced are retained as references and pinned bit-identical by the
//! proptests. `docs/PERFORMANCE.md` maps the full hot path.

mod choco;
mod full;
mod quantized;
pub mod robust;
mod subsample;
mod topk;

pub use choco::ChocoSgd;
pub use full::FullSharing;
pub use quantized::Quantized;
pub use robust::{CoordMedian, DefenseReport, DefenseStats, Krum, TrimmedMean, ADMIT_THRESHOLD};
pub use subsample::SubSampling;
pub use topk::TopK;

use anyhow::{bail, Context, Result};

use crate::compression::{decode_indices_best_into, encode_indices_best_into};
use crate::kernels::fold::FoldCtx;
use crate::kernels::{self, FoldPartial, Scratch};
use crate::model::{ParamVec, SparseVec};
use crate::store::Payload;

/// A received model message ready for aggregation.
pub struct Received<'a> {
    pub src: usize,
    /// Mixing weight for this neighbor (Metropolis–Hastings).
    pub weight: f64,
    pub payload: &'a [u8],
}

/// Strategy object owned by one node.
///
/// `outgoing_with` may mutate internal state (error residuals,
/// `x_hat`); `aggregate_with` folds the received messages into the
/// local model. Both take the node's [`Scratch`] arena so steady-state
/// rounds reuse every O(dim) buffer; the scratch-less [`outgoing`]
/// / [`aggregate`] wrappers build a throwaway arena per call (tests,
/// cold paths) and are bit-identical by construction.
///
/// [`outgoing`]: Sharing::outgoing
/// [`aggregate`]: Sharing::aggregate
pub trait Sharing: Send {
    fn name(&self) -> &'static str;

    /// Observe the common model initialization before round 0. Stateful
    /// strategies (Choco-SGD) need it so every node's estimate of every
    /// other node starts from the same point; default is a no-op.
    fn set_init(&mut self, _init: &ParamVec) {}

    /// Install the per-neighbor fold plan ([`FoldCtx`]) used by
    /// [`aggregate_with`](Sharing::aggregate_with). Every strategy
    /// starts serial; the coordinator calls this once at build time with
    /// the configured `fold` spec and the effective worker count.
    /// Results are bit-identical at any worker count by the fold's
    /// determinism contract (`kernels::fold`); the default is a no-op so
    /// strategies without a parallelizable fold stay untouched.
    fn set_fold(&mut self, _fold: FoldCtx) {}

    /// Build this round's payload from the post-training model.
    fn outgoing(&mut self, model: &ParamVec, round: u64) -> Result<Vec<u8>> {
        self.outgoing_with(model, round, &mut Scratch::new())
    }

    /// [`outgoing`](Sharing::outgoing) into a caller-owned scratch
    /// arena and output buffer (cleared + refilled). This is the one
    /// required outgoing method: strategies write their payload bytes
    /// into `out`, so the caller decides whether those bytes land in a
    /// fresh vector ([`outgoing_with`](Sharing::outgoing_with)) or a
    /// pooled broadcast buffer
    /// ([`outgoing_pooled`](Sharing::outgoing_pooled)) — both are
    /// bit-identical by construction.
    fn outgoing_into(
        &mut self,
        model: &ParamVec,
        round: u64,
        scratch: &mut Scratch,
        out: &mut Vec<u8>,
    ) -> Result<()>;

    /// [`outgoing`](Sharing::outgoing) with a caller-owned scratch
    /// arena, returning the payload as a fresh vector.
    fn outgoing_with(
        &mut self,
        model: &ParamVec,
        round: u64,
        scratch: &mut Scratch,
    ) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.outgoing_into(model, round, scratch, &mut out)?;
        Ok(out)
    }

    /// Build this round's payload in a pooled broadcast buffer from the
    /// arena ([`Scratch::checkout_payload`]): byte-identical to
    /// [`outgoing_with`](Sharing::outgoing_with), but once the pool is
    /// warm — i.e. every recipient of a previous broadcast dropped its
    /// handle — the outgoing path performs zero heap allocations. A
    /// clone of the returned payload parks back in the arena for the
    /// next round.
    fn outgoing_pooled(
        &mut self,
        model: &ParamVec,
        round: u64,
        scratch: &mut Scratch,
    ) -> Result<Payload> {
        let mut payload = scratch.checkout_payload().unwrap_or_default();
        let buf = payload.buf_mut().expect("checked-out payload has other holders");
        buf.clear();
        self.outgoing_into(model, round, scratch, buf)?;
        scratch.retain_payload(payload.clone());
        Ok(payload)
    }

    /// Merge received messages into `model`. `self_weight` is the node's
    /// own mixing weight (1 - sum of neighbor weights).
    fn aggregate(
        &mut self,
        model: &mut ParamVec,
        self_weight: f64,
        received: &[Received<'_>],
    ) -> Result<()> {
        self.aggregate_with(model, self_weight, received, &mut Scratch::new())
    }

    /// [`aggregate`](Sharing::aggregate) with a caller-owned scratch
    /// arena; allocation-free once the arena is warm.
    fn aggregate_with(
        &mut self,
        model: &mut ParamVec,
        self_weight: f64,
        received: &[Received<'_>],
        scratch: &mut Scratch,
    ) -> Result<()>;

    /// What the most recent [`aggregate_with`](Sharing::aggregate_with)
    /// admitted per contribution. `None` (the default) means the
    /// strategy admits everything it is given — plain weighted mixing —
    /// so callers treat every contribution as fully admitted. Robust
    /// strategies ([`robust`]) return their per-round report.
    fn defense_report(&self) -> Option<&DefenseReport> {
        None
    }
}

/// Parse a sharing spec into a strategy for a `dim`-parameter model.
///
/// Grammar: `full` | `full:fp16` | `subsample:<budget>` | `topk:<budget>`
/// | `choco:<budget>:<gamma>` | `quant:<levels>` | `trimmed_mean:<frac>`
/// | `coord_median` | `krum:<f>`.
pub fn from_spec(spec: &str, dim: usize, seed: u64) -> Result<Box<dyn Sharing>> {
    let parts: Vec<&str> = spec.split(':').collect();
    Ok(match parts.as_slice() {
        ["full"] => Box::new(FullSharing::new()),
        ["full", "fp16"] => Box::new(FullSharing::fp16()),
        ["subsample", b] => Box::new(SubSampling::new(parse_budget(b)?, dim, seed)),
        ["topk", b] => Box::new(TopK::new(parse_budget(b)?, dim)),
        ["choco", b] => Box::new(ChocoSgd::new(parse_budget(b)?, 0.5, dim)),
        ["choco", b, g] => {
            let gamma: f64 = g.parse().context("choco gamma")?;
            if !(0.0 < gamma && gamma <= 1.0) {
                bail!("choco gamma must be in (0, 1], got {gamma}");
            }
            Box::new(ChocoSgd::new(parse_budget(b)?, gamma, dim))
        }
        ["quant", levels] => Box::new(Quantized::new(levels.parse()?, seed)),
        ["trimmed_mean", f] => {
            let frac: f64 = f.parse().context("trimmed_mean fraction")?;
            if !(0.0..0.5).contains(&frac) {
                bail!("trimmed_mean fraction must be in [0, 0.5), got {frac}");
            }
            Box::new(TrimmedMean::new(frac))
        }
        ["coord_median"] => Box::new(CoordMedian::new()),
        ["krum", f] => Box::new(Krum::new(f.parse().context("krum tolerated byzantine count")?)),
        _ => bail!("unknown sharing spec {spec:?}"),
    })
}

/// Validate a spec without building it (config-time check).
pub fn validate_spec(spec: &str) -> Result<()> {
    from_spec(spec, 8, 0).map(|_| ())
}

fn parse_budget(s: &str) -> Result<f64> {
    let b: f64 = s.parse().context("budget")?;
    if !(0.0 < b && b <= 1.0) {
        bail!("communication budget must be in (0, 1], got {b}");
    }
    Ok(b)
}

// ---------------------------------------------------------------------
// Sparse payload wire helpers (shared by all sparsifying strategies).
// ---------------------------------------------------------------------

/// Encode a sparse vector: `u32 index-block len ‖ index block ‖ f32 values`.
pub fn encode_sparse(sv: &SparseVec) -> Vec<u8> {
    let mut idx_scratch = Vec::new();
    encode_sparse_parts(&sv.indices, &sv.values, sv.dim, &mut idx_scratch)
}

/// [`encode_sparse`] from raw index/value slices, staging the index
/// block in `idx_scratch` (cleared + refilled).
pub fn encode_sparse_parts(
    indices: &[u32],
    values: &[f32],
    dim: usize,
    idx_scratch: &mut Vec<u8>,
) -> Vec<u8> {
    let mut out = Vec::new();
    encode_sparse_parts_into(indices, values, dim, idx_scratch, &mut out);
    out
}

/// [`encode_sparse_parts`] into a reusable payload buffer (cleared +
/// refilled) — with a pooled buffer, a warm sparse broadcast allocates
/// nothing at all.
pub fn encode_sparse_parts_into(
    indices: &[u32],
    values: &[f32],
    dim: usize,
    idx_scratch: &mut Vec<u8>,
    out: &mut Vec<u8>,
) {
    encode_indices_best_into(indices, dim, idx_scratch);
    out.clear();
    out.reserve(4 + idx_scratch.len() + 4 * values.len());
    out.extend_from_slice(&(idx_scratch.len() as u32).to_le_bytes());
    out.extend_from_slice(idx_scratch);
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Inverse of [`encode_sparse`] for a model of dimension `dim`.
pub fn decode_sparse(bytes: &[u8], dim: usize) -> Result<SparseVec> {
    let (mut indices, mut values) = (Vec::new(), Vec::new());
    decode_sparse_into(bytes, dim, &mut indices, &mut values)?;
    Ok(SparseVec { dim, indices, values })
}

/// [`decode_sparse`] into reusable index/value buffers (cleared +
/// refilled) — the hot-path variant that allocates nothing once the
/// buffers have capacity.
pub fn decode_sparse_into(
    bytes: &[u8],
    dim: usize,
    indices: &mut Vec<u32>,
    values: &mut Vec<f32>,
) -> Result<()> {
    if bytes.len() < 4 {
        bail!("sparse payload too short");
    }
    let idx_len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
    if bytes.len() < 4 + idx_len {
        bail!("sparse payload truncated (index block)");
    }
    decode_indices_best_into(&bytes[4..4 + idx_len], dim, indices)?;
    let vals_bytes = &bytes[4 + idx_len..];
    if vals_bytes.len() != indices.len() * 4 {
        bail!(
            "sparse payload value block mismatch: {} indices, {} value bytes",
            indices.len(),
            vals_bytes.len()
        );
    }
    values.clear();
    values.reserve(indices.len());
    values.extend(
        vals_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
    );
    Ok(())
}

/// Shared aggregation rule for sparse messages with *absolute* values:
/// coordinates a neighbor did not send fall back to the receiver's own
/// value, preserving total weight 1 per coordinate
/// (`out[j] = own[j] + Σ_i w_i (recv_i[j] - own[j])` over senders of j).
///
/// This is the retained scalar reference: the hot path runs
/// [`aggregate_sparse_absolute_with`], which the proptests pin
/// bit-identical to this loop.
pub fn aggregate_sparse_absolute(
    model: &mut ParamVec,
    received: &[(f64, SparseVec)],
) -> Result<()> {
    let own = model.clone();
    for (w, sv) in received {
        if sv.dim != model.len() {
            bail!("sparse message dim {} != model dim {}", sv.dim, model.len());
        }
        let m = model.as_mut_slice();
        let o = own.as_slice();
        for (&i, &v) in sv.indices.iter().zip(sv.values.iter()) {
            let i = i as usize;
            m[i] += (*w as f32) * (v - o[i]);
        }
    }
    Ok(())
}

/// Kernel twin of [`aggregate_sparse_absolute`] over still-encoded
/// payloads: each message decodes into the arena's sparse buffers and
/// folds in with [`kernels::scatter_blend`] against an arena snapshot
/// of the receiver's pre-aggregation values — no clone of the model, no
/// per-message vectors. Serial fold plan; the proptests pin it
/// bit-identical to [`aggregate_sparse_absolute`].
pub fn aggregate_sparse_absolute_with(
    model: &mut ParamVec,
    received: &[Received<'_>],
    scratch: &mut Scratch,
) -> Result<()> {
    aggregate_sparse_absolute_fold(model, received, scratch, FoldCtx::serial())
}

/// [`aggregate_sparse_absolute_with`] under an arbitrary fold plan.
///
/// Leaf group 0 folds straight into the model on the calling thread
/// (under the serial plan — or a tree wide enough to hold every message
/// — that is the entire aggregation, bit-identical to the serial
/// reference). Remaining groups scatter-blend into zero-seeded arena
/// partials against the same own-value snapshot, concurrently, then the
/// partials are added to the model **in group order** — deterministic at
/// any worker count because the group shape is fixed by
/// `(degree, width)` and each group owns its buffers.
pub fn aggregate_sparse_absolute_fold(
    model: &mut ParamVec,
    received: &[Received<'_>],
    scratch: &mut Scratch,
    fold: FoldCtx,
) -> Result<()> {
    let dim = model.len();
    scratch.dense2.clear();
    scratch.dense2.extend_from_slice(model.as_slice());
    let degree = received.len();
    let groups = fold.groups(degree);
    if groups <= 1 {
        for r in received {
            decode_sparse_into(r.payload, dim, &mut scratch.indices, &mut scratch.values)?;
            kernels::scatter_blend(
                model.as_mut_slice(),
                r.weight as f32,
                &scratch.indices,
                &scratch.values,
                &scratch.dense2,
            );
        }
        return Ok(());
    }
    scratch.prepare_partials(groups - 1, dim);
    let Scratch { partials, dense2, indices, values, .. } = scratch;
    let own_snapshot: &[f32] = dense2;
    let m = model.as_mut_slice();
    let own = move || -> Result<()> {
        for r in &received[fold.group_range(degree, 0)] {
            decode_sparse_into(r.payload, dim, indices, values)?;
            kernels::scatter_blend(m, r.weight as f32, indices, values, own_snapshot);
        }
        Ok(())
    };
    let per_group = |g: usize, p: &mut FoldPartial| -> Result<()> {
        for r in &received[fold.group_range(degree, g + 1)] {
            decode_sparse_into(r.payload, dim, &mut p.indices, &mut p.values)?;
            kernels::scatter_blend(&mut p.acc, r.weight as f32, &p.indices, &p.values, own_snapshot);
        }
        Ok(())
    };
    kernels::fold::run_fold_jobs(fold.workers, &mut partials[..groups - 1], per_group, own)?;
    for p in partials[..groups - 1].iter() {
        kernels::axpy(model.as_mut_slice(), 1.0, &p.acc);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_dispatch() {
        for spec in [
            "full",
            "full:fp16",
            "subsample:0.1",
            "topk:0.25",
            "choco:0.1:0.7",
            "quant:64",
            "trimmed_mean:0.2",
            "trimmed_mean:0",
            "coord_median",
            "krum:1",
            "krum:0",
        ] {
            assert!(validate_spec(spec).is_ok(), "{spec}");
        }
        for spec in [
            "",
            "nope",
            "subsample:0",
            "subsample:1.5",
            "choco:0.1:0",
            "choco:0.1:2",
            "trimmed_mean:0.5",
            "trimmed_mean:-0.1",
            "trimmed_mean",
            "coord_median:0.2",
            "krum:-1",
            "krum:x",
            "krum",
        ] {
            assert!(validate_spec(spec).is_err(), "{spec}");
        }
    }

    #[test]
    fn sparse_payload_roundtrip() {
        let sv = SparseVec {
            dim: 1000,
            indices: vec![1, 5, 999],
            values: vec![0.5, -2.0, 3.25],
        };
        let enc = encode_sparse(&sv);
        assert_eq!(decode_sparse(&enc, 1000).unwrap(), sv);
    }

    #[test]
    fn sparse_payload_rejects_truncation() {
        let sv = SparseVec { dim: 10, indices: vec![2], values: vec![1.0] };
        let enc = encode_sparse(&sv);
        assert!(decode_sparse(&enc[..enc.len() - 1], 10).is_err());
        assert!(decode_sparse(&[1, 0], 10).is_err());
    }

    #[test]
    fn sparse_absolute_aggregation_weight_preserving() {
        // own = [1, 1, 1]; neighbor (w=0.5) sends coord 1 = 3.
        let mut model = ParamVec::from_vec(vec![1.0, 1.0, 1.0]);
        let sv = SparseVec { dim: 3, indices: vec![1], values: vec![3.0] };
        aggregate_sparse_absolute(&mut model, &[(0.5, sv)]).unwrap();
        assert_eq!(model.as_slice(), &[1.0, 2.0, 1.0]);
    }

    #[test]
    fn sparse_absolute_full_support_equals_dense_average() {
        let own = ParamVec::from_vec(vec![2.0, 4.0]);
        let other = ParamVec::from_vec(vec![0.0, 8.0]);
        let sv = SparseVec {
            dim: 2,
            indices: vec![0, 1],
            values: other.as_slice().to_vec(),
        };
        let mut model = own.clone();
        aggregate_sparse_absolute(&mut model, &[(0.5, sv)]).unwrap();
        assert_eq!(model.as_slice(), &[1.0, 6.0]);
    }

    #[test]
    fn scratch_sparse_aggregation_matches_scalar_reference() {
        let own = ParamVec::from_vec(vec![1.0, -2.0, 0.5, 3.0, 0.0]);
        let sv1 = SparseVec { dim: 5, indices: vec![1, 4], values: vec![2.0, 1.0] };
        let sv2 = SparseVec { dim: 5, indices: vec![0, 1], values: vec![-1.0, 0.25] };
        let mut a = own.clone();
        aggregate_sparse_absolute(&mut a, &[(0.3, sv1.clone()), (0.2, sv2.clone())]).unwrap();
        let (p1, p2) = (encode_sparse(&sv1), encode_sparse(&sv2));
        let recv = [
            Received { src: 1, weight: 0.3, payload: &p1 },
            Received { src: 2, weight: 0.2, payload: &p2 },
        ];
        let mut scratch = Scratch::new();
        let mut b = own.clone();
        aggregate_sparse_absolute_with(&mut b, &recv, &mut scratch).unwrap();
        assert_eq!(a.as_slice(), b.as_slice());
        // A dirty, reused arena changes nothing.
        let mut c = own.clone();
        aggregate_sparse_absolute_with(&mut c, &recv, &mut scratch).unwrap();
        assert_eq!(a.as_slice(), c.as_slice());
    }

    #[test]
    fn encode_sparse_parts_matches_encode_sparse() {
        let sv = SparseVec {
            dim: 1000,
            indices: vec![1, 5, 999],
            values: vec![0.5, -2.0, 3.25],
        };
        let mut idx_scratch = vec![0xAAu8; 9]; // dirty
        let parts = encode_sparse_parts(&sv.indices, &sv.values, sv.dim, &mut idx_scratch);
        assert_eq!(parts, encode_sparse(&sv));
    }

    #[test]
    fn outgoing_pooled_matches_outgoing_with_and_reuses_buffer() {
        use crate::rng::Xoshiro256pp;
        let dim = 64;
        let mut rng = Xoshiro256pp::new(7);
        for spec in ["full", "full:fp16", "subsample:0.25", "topk:0.25", "choco:0.25:0.5", "quant:64"] {
            let init = ParamVec::zeros(dim);
            let mut a = from_spec(spec, dim, 3).unwrap();
            let mut b = from_spec(spec, dim, 3).unwrap();
            a.set_init(&init);
            b.set_init(&init);
            let (mut sa, mut sb) = (Scratch::new(), Scratch::new());
            let mut model = ParamVec::random(dim, 1.0, &mut rng);
            let mut prev_ptr = None;
            for round in 0..3u64 {
                let plain = a.outgoing_with(&model, round, &mut sa).unwrap();
                let pooled = b.outgoing_pooled(&model, round, &mut sb).unwrap();
                assert_eq!(&pooled[..], &plain[..], "{spec} round {round}");
                let ptr = pooled.as_slice().as_ptr() as usize;
                if let Some(prev) = prev_ptr {
                    // Fixed-size payloads: the pooled buffer is reused,
                    // not reallocated, once the previous handle dropped.
                    // (Sparse payloads may regrow while their adaptive
                    // index block settles, so only equality is pinned.)
                    if matches!(spec, "full" | "full:fp16" | "quant:64") {
                        assert_eq!(ptr, prev, "{spec} round {round}: pooled buffer not reused");
                    }
                }
                prev_ptr = Some(ptr);
                drop(pooled); // all recipients let go before the next round
                for v in model.as_mut_slice().iter_mut() {
                    *v += rng.normal_f32(0.0, 0.1);
                }
            }
        }
    }

    #[test]
    fn sparse_dim_mismatch_rejected() {
        let mut model = ParamVec::zeros(4);
        let sv = SparseVec { dim: 5, indices: vec![0], values: vec![1.0] };
        assert!(aggregate_sparse_absolute(&mut model, &[(0.5, sv)]).is_err());
    }
}
