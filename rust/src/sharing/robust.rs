//! Byzantine-robust aggregation strategies: [`TrimmedMean`],
//! [`CoordMedian`], and [`Krum`].
//!
//! Unlike the weighted-mixing strategies, the robust rules treat each
//! round's inputs as a *candidate matrix* — the node's own
//! post-training model plus one decoded row per received message, in
//! canonical sender order (ascending `src`, so the result is invariant
//! in the neighbor arrival/assignment order) — and compute a
//! statistics-based aggregate that bounds the influence any single
//! (or small colluding set of) malicious rows can exert:
//!
//! * [`TrimmedMean`] (`trimmed_mean:<frac>`) — coordinate-wise mean
//!   after dropping the `⌊frac·rows⌋` lowest and highest values per
//!   coordinate.
//! * [`CoordMedian`] (`coord_median`) — coordinate-wise median.
//! * [`Krum`] (`krum:<f>`) — selects the single candidate whose summed
//!   squared distance to its `rows − f − 2` nearest candidates is
//!   minimal (Blanchard et al. 2017), tolerating up to `f` Byzantine
//!   rows.
//!
//! Mixing weights are deliberately ignored: the robust rules are order
//! statistics / geometric selection over candidates, not convex mixing,
//! which is exactly what removes the attacker's ability to buy
//! influence through edge weights. All heavy lifting happens in the
//! fused kernels ([`kernels::trimmed_mean`], [`kernels::coord_median`],
//! [`kernels::pairwise_sq_dist`], [`kernels::krum_select`]) with scalar
//! twins in [`kernels::reference`], staged entirely in the node's
//! [`Scratch`] arena — warm rounds allocate nothing, including Krum's
//! `rows²` distance matrix, which lives in `scratch.doubles` (rows is
//! the node degree + 1, so the matrix is tiny next to the model).
//!
//! Each strategy keeps a per-round [`DefenseReport`] of the admitted
//! fraction per contribution; nodes cross it against the
//! [`crate::scenario::ByzantineRoster`] ground truth to produce the
//! `poisoned_mass_admitted` / `rejected_contribs` / `isolation_rate`
//! metrics.

use anyhow::Result;

use crate::kernels::fold::{FoldCtx, FoldSpec};
use crate::kernels::{self, Scratch};
use crate::model::ParamVec;

use super::{Received, Sharing};

/// Admitted fraction below which a contribution counts as *rejected*
/// (isolated) in the defense metrics.
pub const ADMIT_THRESHOLD: f64 = 0.5;

/// What a robust strategy admitted in its most recent
/// [`Sharing::aggregate_with`] call.
#[derive(Debug, Default)]
pub struct DefenseReport {
    /// Per-contribution admitted fraction in `[0, 1]`, aligned with the
    /// `received` slice the aggregate call was given (NOT canonical
    /// order — callers index it by their own message order).
    pub admitted: Vec<f64>,
}

impl DefenseReport {
    /// Contributions whose admitted fraction fell below
    /// [`ADMIT_THRESHOLD`].
    pub fn rejected(&self) -> u64 {
        self.admitted.iter().filter(|&&a| a < ADMIT_THRESHOLD).count() as u64
    }
}

/// Cumulative node-side defense accounting: the strategy's per-round
/// admitted fractions crossed with the roster's ground truth of which
/// senders are Byzantine. Nodes keep one per run and snapshot it into
/// every eval [`crate::metrics::Record`].
#[derive(Debug, Default, Clone, Copy)]
pub struct DefenseStats {
    /// Σ weight × admitted-fraction over Byzantine-sourced
    /// contributions — the mass of poison that actually entered models.
    pub poisoned_mass: f64,
    /// Contributions rejected (admitted < [`ADMIT_THRESHOLD`]), any
    /// source — honest rows trimmed as collateral count here too.
    pub rejected: u64,
    /// Byzantine-sourced contributions seen.
    pub byz_contribs: u64,
    /// Byzantine-sourced contributions rejected.
    pub byz_rejected: u64,
}

impl DefenseStats {
    /// Fold in one contribution's outcome.
    pub fn observe(&mut self, is_byz: bool, weight: f64, admitted: f64) {
        let rejected = admitted < ADMIT_THRESHOLD;
        if rejected {
            self.rejected += 1;
        }
        if is_byz {
            self.byz_contribs += 1;
            self.poisoned_mass += weight * admitted;
            if rejected {
                self.byz_rejected += 1;
            }
        }
    }

    /// Fraction of Byzantine contributions rejected (0 when none seen).
    pub fn isolation_rate(&self) -> f64 {
        if self.byz_contribs == 0 {
            0.0
        } else {
            self.byz_rejected as f64 / self.byz_contribs as f64
        }
    }
}

/// Stage the candidate matrix in the arena: row 0 is the node's own
/// model, rows 1.. are the received payloads decoded in canonical
/// (src-ascending) order. The canonical permutation lands in
/// `scratch.indices` (`indices[row-1]` = position in `received`), the
/// matrix in `scratch.values`. Returns the row count.
///
/// Under a tree fold plan the per-row payload decodes — the only
/// O(degree · dim) term in the robust path; the reductions that follow
/// are order statistics and stay serial — run row-parallel across the
/// plan's workers. Each row's decode is a pure byte copy into its own
/// disjoint slice, so the staged matrix is trivially bit-identical at
/// any worker count.
fn stage_rows(
    model: &ParamVec,
    received: &[Received<'_>],
    scratch: &mut Scratch,
    fold: FoldCtx,
) -> Result<usize> {
    let dim = model.len();
    let k = received.len();
    scratch.indices.clear();
    scratch.indices.extend(0..k as u32);
    scratch.indices.sort_unstable_by_key(|&i| received[i as usize].src);
    scratch.values.clear();
    scratch.values.resize((k + 1) * dim, 0.0);
    scratch.values[..dim].copy_from_slice(model.as_slice());
    let workers = match fold.spec {
        FoldSpec::Serial => 1,
        FoldSpec::Tree { .. } => fold.workers,
    };
    if dim == 0 || workers <= 1 || k <= 1 {
        for (row, &i) in scratch.indices.iter().enumerate() {
            kernels::decode_le(
                &mut scratch.values[(row + 1) * dim..(row + 2) * dim],
                received[i as usize].payload,
            )?;
        }
    } else {
        let order = &scratch.indices;
        kernels::fold::run_row_jobs(workers, &mut scratch.values[dim..], dim, |row, out| {
            kernels::decode_le(out, received[order[row] as usize].payload)
        })?;
    }
    Ok(k + 1)
}

/// Map per-row admitted *counts* (canonical order, self row excluded)
/// back onto the caller's `received` order as fractions of `dim`.
fn fill_report(report: &mut DefenseReport, order: &[u32], row_counts: &[f64], dim: usize) {
    let d = if dim == 0 { 1.0 } else { dim as f64 };
    report.admitted.clear();
    report.admitted.resize(order.len(), 0.0);
    for (row, &i) in order.iter().enumerate() {
        report.admitted[i as usize] = row_counts[row + 1] / d;
    }
}

/// Coordinate-wise trimmed mean (`trimmed_mean:<frac>`).
pub struct TrimmedMean {
    frac: f64,
    fold: FoldCtx,
    report: DefenseReport,
}

impl TrimmedMean {
    pub fn new(frac: f64) -> TrimmedMean {
        TrimmedMean { frac, fold: FoldCtx::serial(), report: DefenseReport::default() }
    }
}

impl Sharing for TrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed_mean"
    }

    fn set_fold(&mut self, fold: FoldCtx) {
        self.fold = fold;
    }

    fn outgoing_into(
        &mut self,
        model: &ParamVec,
        _round: u64,
        _scratch: &mut Scratch,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        encode_dense(model, out);
        Ok(())
    }

    fn aggregate_with(
        &mut self,
        model: &mut ParamVec,
        _self_weight: f64,
        received: &[Received<'_>],
        scratch: &mut Scratch,
    ) -> Result<()> {
        let dim = model.len();
        let rows = stage_rows(model, received, scratch, self.fold)?;
        let trim = ((self.frac * rows as f64).floor() as usize).min((rows - 1) / 2);
        scratch.dense.clear();
        scratch.dense.resize(dim, 0.0);
        scratch.mags.clear();
        scratch.mags.resize(2 * rows, 0.0); // gather contract: 2 · rows
        scratch.doubles.clear();
        scratch.doubles.resize(rows, 0.0);
        kernels::trimmed_mean(
            &mut scratch.dense,
            &scratch.values,
            rows,
            trim,
            &mut scratch.mags,
            &mut scratch.doubles,
        );
        model.as_mut_slice().copy_from_slice(&scratch.dense);
        fill_report(&mut self.report, &scratch.indices, &scratch.doubles, dim);
        Ok(())
    }

    fn defense_report(&self) -> Option<&DefenseReport> {
        Some(&self.report)
    }
}

/// Coordinate-wise median (`coord_median`).
#[derive(Default)]
pub struct CoordMedian {
    fold: FoldCtx,
    report: DefenseReport,
}

impl CoordMedian {
    pub fn new() -> CoordMedian {
        CoordMedian::default()
    }
}

impl Sharing for CoordMedian {
    fn name(&self) -> &'static str {
        "coord_median"
    }

    fn set_fold(&mut self, fold: FoldCtx) {
        self.fold = fold;
    }

    fn outgoing_into(
        &mut self,
        model: &ParamVec,
        _round: u64,
        _scratch: &mut Scratch,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        encode_dense(model, out);
        Ok(())
    }

    fn aggregate_with(
        &mut self,
        model: &mut ParamVec,
        _self_weight: f64,
        received: &[Received<'_>],
        scratch: &mut Scratch,
    ) -> Result<()> {
        let dim = model.len();
        let rows = stage_rows(model, received, scratch, self.fold)?;
        scratch.dense.clear();
        scratch.dense.resize(dim, 0.0);
        scratch.mags.clear();
        scratch.mags.resize(2 * rows, 0.0); // gather contract: 2 · rows
        scratch.doubles.clear();
        scratch.doubles.resize(rows, 0.0);
        kernels::coord_median(
            &mut scratch.dense,
            &scratch.values,
            rows,
            &mut scratch.mags,
            &mut scratch.doubles,
        );
        model.as_mut_slice().copy_from_slice(&scratch.dense);
        fill_report(&mut self.report, &scratch.indices, &scratch.doubles, dim);
        Ok(())
    }

    fn defense_report(&self) -> Option<&DefenseReport> {
        Some(&self.report)
    }
}

/// Krum selection (`krum:<f>`): the aggregate IS the single most
/// centrally-located candidate; everything else is rejected outright.
pub struct Krum {
    f: usize,
    fold: FoldCtx,
    report: DefenseReport,
}

impl Krum {
    pub fn new(f: usize) -> Krum {
        Krum { f, fold: FoldCtx::serial(), report: DefenseReport::default() }
    }
}

impl Sharing for Krum {
    fn name(&self) -> &'static str {
        "krum"
    }

    fn set_fold(&mut self, fold: FoldCtx) {
        self.fold = fold;
    }

    fn outgoing_into(
        &mut self,
        model: &ParamVec,
        _round: u64,
        _scratch: &mut Scratch,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        encode_dense(model, out);
        Ok(())
    }

    fn aggregate_with(
        &mut self,
        model: &mut ParamVec,
        _self_weight: f64,
        received: &[Received<'_>],
        scratch: &mut Scratch,
    ) -> Result<()> {
        let dim = model.len();
        let rows = stage_rows(model, received, scratch, self.fold)?;
        // Standard Krum sums the n−f−2 nearest; clamp so degenerate
        // degrees (rows ≤ f+2) still score over at least one neighbor.
        let closest =
            if rows <= 1 { 0 } else { rows.saturating_sub(self.f + 2).clamp(1, rows - 1) };
        scratch.doubles.clear();
        scratch.doubles.resize(rows * rows + rows, 0.0);
        let (dist, row_buf) = scratch.doubles.split_at_mut(rows * rows);
        kernels::pairwise_sq_dist(&scratch.values, rows, dim, dist);
        let pick = kernels::krum_select(dist, rows, closest, row_buf);
        model
            .as_mut_slice()
            .copy_from_slice(&scratch.values[pick * dim..(pick + 1) * dim]);
        // All-or-nothing admission: only the selected row (if it is a
        // neighbor's) was admitted.
        self.report.admitted.clear();
        self.report.admitted.resize(received.len(), 0.0);
        if pick >= 1 {
            self.report.admitted[scratch.indices[pick - 1] as usize] = 1.0;
        }
        Ok(())
    }

    fn defense_report(&self) -> Option<&DefenseReport> {
        Some(&self.report)
    }
}

/// Dense little-endian f32 payload, worst case reserved up front so a
/// pooled buffer never regrows (the zero-alloc warm outgoing contract).
fn encode_dense(model: &ParamVec, out: &mut Vec<u8>) {
    out.clear();
    out.reserve(model.len() * 4);
    for v in model.as_slice() {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharing;

    fn recv<'a>(src: usize, payload: &'a [u8]) -> Received<'a> {
        Received { src, weight: 0.25, payload }
    }

    fn enc(vals: &[f32]) -> Vec<u8> {
        vals.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    #[test]
    fn trimmed_mean_ignores_a_poisoned_neighbor() {
        let mut s = sharing::from_spec("trimmed_mean:0.25", 4, 0).unwrap();
        let mut model = ParamVec::from_vec(vec![1.0; 4]);
        let honest1 = enc(&[1.1; 4]);
        let honest2 = enc(&[0.9; 4]);
        let poison = enc(&[-50.0; 4]);
        let received =
            [recv(1, &honest1), recv(2, &honest2), recv(3, &poison)];
        let mut scratch = Scratch::new();
        s.aggregate_with(&mut model, 0.25, &received, &mut scratch).unwrap();
        // rows = 4, trim = 1: the -50 row and the 1.1 row are trimmed,
        // survivors are {0.9, 1.0} per coordinate.
        for &v in model.as_slice() {
            assert!((v - 0.95).abs() < 1e-6, "{v}");
        }
        let report = s.defense_report().unwrap();
        assert_eq!(report.admitted.len(), 3);
        assert_eq!(report.admitted[2], 0.0, "poisoned row admitted");
        assert_eq!(report.rejected(), 1 + 1, "poison + trimmed-high honest row");
    }

    #[test]
    fn coord_median_tracks_the_honest_majority() {
        let mut s = sharing::from_spec("coord_median", 3, 0).unwrap();
        let mut model = ParamVec::from_vec(vec![2.0, 2.0, 2.0]);
        let honest = enc(&[2.2, 2.2, 2.2]);
        let poison = enc(&[100.0, -100.0, 100.0]);
        let received = [recv(1, &honest), recv(2, &poison)];
        s.aggregate_with(&mut model, 0.4, &received, &mut Scratch::new()).unwrap();
        // rows = 3: median per coordinate is the honest 2.2 or own 2.0.
        for &v in model.as_slice() {
            assert!((2.0..=2.2).contains(&v), "{v}");
        }
        let report = s.defense_report().unwrap();
        assert!(report.admitted[1] < ADMIT_THRESHOLD);
    }

    #[test]
    fn krum_selects_within_the_cluster_and_reports_all_or_nothing() {
        let mut s = sharing::from_spec("krum:1", 2, 0).unwrap();
        let mut model = ParamVec::from_vec(vec![1.0, 1.0]);
        let near1 = enc(&[1.01, 1.01]);
        let near2 = enc(&[0.99, 0.99]);
        let far = enc(&[80.0, -80.0]);
        let received = [recv(5, &far), recv(1, &near1), recv(3, &near2)];
        s.aggregate_with(&mut model, 0.25, &received, &mut Scratch::new()).unwrap();
        assert!(model.as_slice().iter().all(|&v| (v - 1.0).abs() < 0.05), "{:?}", model.as_slice());
        let report = s.defense_report().unwrap();
        assert!(report.admitted.iter().filter(|&&a| a > 0.0).count() <= 1);
        assert_eq!(report.admitted[0], 0.0, "outlier must never be selected");
    }

    #[test]
    fn empty_round_keeps_the_own_model() {
        for spec in ["trimmed_mean:0.2", "coord_median", "krum:1"] {
            let mut s = sharing::from_spec(spec, 3, 0).unwrap();
            let mut model = ParamVec::from_vec(vec![0.5, -0.25, 4.0]);
            s.aggregate_with(&mut model, 1.0, &[], &mut Scratch::new()).unwrap();
            assert_eq!(model.as_slice(), &[0.5, -0.25, 4.0], "{spec}");
        }
    }

    #[test]
    fn defense_stats_accumulate_and_rate() {
        let mut d = DefenseStats::default();
        d.observe(true, 0.2, 0.0); // byzantine, rejected
        d.observe(true, 0.2, 1.0); // byzantine, admitted
        d.observe(false, 0.2, 1.0); // honest, admitted
        d.observe(false, 0.2, 0.1); // honest, collateral rejection
        assert_eq!(d.byz_contribs, 2);
        assert_eq!(d.byz_rejected, 1);
        assert_eq!(d.rejected, 2);
        assert!((d.isolation_rate() - 0.5).abs() < 1e-12);
        assert!((d.poisoned_mass - 0.2).abs() < 1e-12);
        assert_eq!(DefenseStats::default().isolation_rate(), 0.0);
    }
}
