//! TopK sparsification: send the coordinates that changed the most since
//! they were last shared, with error feedback.
//!
//! The selection metric is `|model - last_shared|` accumulated over
//! rounds: a coordinate's pending change keeps growing until it is big
//! enough to be sent (classic error-feedback semantics, and exactly the
//! "store how much the learning parameters changed" state the paper's
//! Model module motivates). Sent values are the *absolute* parameter
//! values at those coordinates, so aggregation uses the same
//! missing-coordinate rule as random sampling.

use anyhow::Result;

use crate::kernels::fold::FoldCtx;
use crate::kernels::{self, Scratch};
use crate::model::{topk_of, ParamVec};

use super::{aggregate_sparse_absolute_fold, encode_sparse_parts_into, Received, Sharing};

pub struct TopK {
    budget: f64,
    dim: usize,
    fold: FoldCtx,
    /// Snapshot of each coordinate's value when it was last included in a
    /// message (the reference point for "change since last shared").
    last_shared: ParamVec,
    initialized: bool,
}

impl TopK {
    pub fn new(budget: f64, dim: usize) -> TopK {
        assert!(0.0 < budget && budget <= 1.0);
        TopK {
            budget,
            dim,
            fold: FoldCtx::serial(),
            last_shared: ParamVec::zeros(dim),
            initialized: false,
        }
    }

    fn k(&self) -> usize {
        ((self.dim as f64 * self.budget).round() as usize).clamp(1, self.dim)
    }
}

impl Sharing for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn set_fold(&mut self, fold: FoldCtx) {
        self.fold = fold;
    }

    fn outgoing_into(
        &mut self,
        model: &ParamVec,
        _round: u64,
        scratch: &mut Scratch,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        if !self.initialized {
            // First round: everyone knows the common init; change = model
            // - init is not defined here, so share the largest-magnitude
            // values to bootstrap.
            self.initialized = true;
            self.last_shared = model.clone();
            topk_of(
                model.as_slice(),
                self.k(),
                &mut scratch.mags,
                &mut scratch.indices,
                &mut scratch.values,
            );
            encode_sparse_parts_into(
                &scratch.indices,
                &scratch.values,
                self.dim,
                &mut scratch.bytes,
                out,
            );
            return Ok(());
        }
        // Change since last shared, per coordinate, staged in the arena.
        scratch.dense2.clear();
        scratch.dense2.extend_from_slice(model.as_slice());
        kernels::axpy(&mut scratch.dense2, -1.0, self.last_shared.as_slice());
        topk_of(
            &scratch.dense2,
            self.k(),
            &mut scratch.mags,
            &mut scratch.indices,
            &mut scratch.values,
        );
        // Send absolute values at the selected coordinates and move the
        // reference point for exactly those coordinates.
        for (&i, v) in scratch.indices.iter().zip(scratch.values.iter_mut()) {
            *v = model.as_slice()[i as usize];
            self.last_shared.as_mut_slice()[i as usize] = *v;
        }
        encode_sparse_parts_into(
            &scratch.indices,
            &scratch.values,
            self.dim,
            &mut scratch.bytes,
            out,
        );
        Ok(())
    }

    fn aggregate_with(
        &mut self,
        model: &mut ParamVec,
        _self_weight: f64,
        received: &[Received<'_>],
        scratch: &mut Scratch,
    ) -> Result<()> {
        aggregate_sparse_absolute_fold(model, received, scratch, self.fold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharing::decode_sparse;

    #[test]
    fn first_round_sends_largest_values() {
        let mut s = TopK::new(0.5, 4);
        let m = ParamVec::from_vec(vec![0.1, -9.0, 5.0, 0.2]);
        let sv = decode_sparse(&s.outgoing(&m, 0).unwrap(), 4).unwrap();
        assert_eq!(sv.indices, vec![1, 2]);
        assert_eq!(sv.values, vec![-9.0, 5.0]);
    }

    #[test]
    fn later_rounds_select_by_change() {
        let mut s = TopK::new(0.25, 4);
        let m0 = ParamVec::from_vec(vec![10.0, 0.0, 0.0, 0.0]);
        s.outgoing(&m0, 0).unwrap(); // bootstraps last_shared = m0
        // Coordinate 2 changed the most since last shared.
        let m1 = ParamVec::from_vec(vec![10.1, 0.0, 3.0, 0.5]);
        let sv = decode_sparse(&s.outgoing(&m1, 1).unwrap(), 4).unwrap();
        assert_eq!(sv.indices, vec![2]);
        assert_eq!(sv.values, vec![3.0]);
    }

    #[test]
    fn unsent_change_accumulates() {
        let mut s = TopK::new(0.25, 4);
        s.outgoing(&ParamVec::zeros(4), 0).unwrap();
        // Coordinate 1 drifts slowly: 0.4 per round; coordinate 3 jumps.
        let m1 = ParamVec::from_vec(vec![0.0, 0.4, 0.0, 1.0]);
        let sv1 = decode_sparse(&s.outgoing(&m1, 1).unwrap(), 4).unwrap();
        assert_eq!(sv1.indices, vec![3]); // jump wins round 1
        // Next round coordinate 1 has accumulated 0.8 of unsent change
        // while 3 only moved 0.1 more -> 1 is now selected.
        let m2 = ParamVec::from_vec(vec![0.0, 0.8, 0.0, 1.1]);
        let sv2 = decode_sparse(&s.outgoing(&m2, 2).unwrap(), 4).unwrap();
        assert_eq!(sv2.indices, vec![1]);
        assert_eq!(sv2.values, vec![0.8]);
    }

    #[test]
    fn budget_respected() {
        let mut s = TopK::new(0.1, 1000);
        let mut rng = crate::rng::Xoshiro256pp::new(1);
        let m = ParamVec::random(1000, 1.0, &mut rng);
        let sv = decode_sparse(&s.outgoing(&m, 0).unwrap(), 1000).unwrap();
        assert_eq!(sv.nnz(), 100);
    }
}
