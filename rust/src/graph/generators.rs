//! Topology generators: every family used in the paper's evaluation
//! (ring, random d-regular, fully connected) plus common research
//! topologies (Erdős–Rényi, Watts–Strogatz small-world, star, 2-D torus).

use super::Graph;
use crate::rng::Xoshiro256pp;

/// Attempts the pairing-model sampler makes before giving up. Each
/// attempt is a full stub shuffle + matching; failures this deep mean
/// the `(n, d)` combination is pathologically constrained, not unlucky.
pub const REGULAR_MAX_ATTEMPTS: usize = 1000;

/// Typed failure modes of [`random_regular`], surfaced through config
/// validation instead of panicking the process.
#[derive(Debug, thiserror::Error)]
pub enum RegularGraphError {
    #[error("d-regular topology needs degree < nodes (got d = {d}, n = {n})")]
    DegreeTooLarge { n: usize, d: usize },
    #[error("d-regular topology needs n*d even (got n = {n}, d = {d}); add a node or change the degree")]
    OddStubTotal { n: usize, d: usize },
    #[error(
        "no connected {d}-regular graph on {n} nodes after {attempts} sampling attempts \
         (deterministic in the seed; retry with a different seed, degree, or node count)"
    )]
    Exhausted { n: usize, d: usize, attempts: usize },
}

/// Ring (cycle) over n nodes — the sparsest connected 2-regular topology.
pub fn ring(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    if n < 2 {
        return g;
    }
    for i in 0..n {
        g.add_edge(i, (i + 1) % n);
    }
    g
}

/// Fully-connected (complete) graph.
pub fn fully_connected(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    for a in 0..n {
        for b in (a + 1)..n {
            g.add_edge(a, b);
        }
    }
    g
}

/// Star: node 0 is the hub (FL-like communication shape).
pub fn star(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    for i in 1..n {
        g.add_edge(0, i);
    }
    g
}

/// Random d-regular graph via the pairing (configuration) model with
/// retries; result is simple (no self-loops/multi-edges) and connected.
///
/// `n * d` must be even and `d < n` — violations and retry exhaustion
/// return a typed [`RegularGraphError`] (config validation surfaces it)
/// rather than panicking. Retries are capped at
/// [`REGULAR_MAX_ATTEMPTS`]; the whole sampler is deterministic in the
/// caller's RNG state. This is the generator behind both the static
/// d-regular topologies and the per-round dynamic graphs the
/// centralized peer sampler instantiates (paper §3.2).
pub fn random_regular(
    n: usize,
    d: usize,
    rng: &mut Xoshiro256pp,
) -> Result<Graph, RegularGraphError> {
    if d >= n {
        return Err(RegularGraphError::DegreeTooLarge { n, d });
    }
    if n * d % 2 != 0 {
        return Err(RegularGraphError::OddStubTotal { n, d });
    }
    if d == 0 {
        return Ok(Graph::empty(n));
    }
    'attempt: for _ in 0..REGULAR_MAX_ATTEMPTS {
        // Stubs: each node appears d times; greedily match random stubs,
        // skipping pairs that would create self-loops or multi-edges
        // (networkx-style `random_regular_graph` matching). Restart the
        // attempt only when no legal partner remains for a stub.
        let mut stubs: Vec<usize> =
            (0..n).flat_map(|v| std::iter::repeat(v).take(d)).collect();
        rng.shuffle(&mut stubs);
        let mut g = Graph::empty(n);
        while !stubs.is_empty() {
            let a = stubs.pop().unwrap();
            // Find a legal partner among remaining stubs.
            let mut found = None;
            for probe in 0..stubs.len() {
                // Randomized probe order: swap a random candidate to the
                // end region being examined.
                let j = rng.range(0, stubs.len() - probe);
                let b = stubs[j];
                if b != a && !g.has_edge(a, b) {
                    stubs.swap_remove(j);
                    found = Some(b);
                    break;
                }
                // Move the illegal candidate out of the probe window.
                let last = stubs.len() - 1 - probe;
                stubs.swap(j, last);
            }
            match found {
                Some(b) => g.add_edge(a, b),
                None => continue 'attempt, // dead end: restart
            }
        }
        if super::is_connected(&g) {
            return Ok(g);
        }
    }
    Err(RegularGraphError::Exhausted { n, d, attempts: REGULAR_MAX_ATTEMPTS })
}

/// Erdős–Rényi G(n, p).
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Xoshiro256pp) -> Graph {
    assert!((0.0..=1.0).contains(&p));
    let mut g = Graph::empty(n);
    for a in 0..n {
        for b in (a + 1)..n {
            if rng.next_f64() < p {
                g.add_edge(a, b);
            }
        }
    }
    g
}

/// Watts–Strogatz small-world: ring lattice of even degree `k`, each edge
/// rewired with probability `beta`.
pub fn small_world(n: usize, k: usize, beta: f64, rng: &mut Xoshiro256pp) -> Graph {
    assert!(k % 2 == 0 && k < n, "k must be even and < n");
    let mut g = Graph::empty(n);
    for i in 0..n {
        for j in 1..=(k / 2) {
            g.add_edge(i, (i + j) % n);
        }
    }
    // Rewire clockwise edges.
    for i in 0..n {
        for j in 1..=(k / 2) {
            let old = (i + j) % n;
            if rng.next_f64() < beta && g.has_edge(i, old) {
                // Pick a new endpoint avoiding self-loops and duplicates.
                for _ in 0..32 {
                    let cand = rng.range(0, n);
                    if cand != i && !g.has_edge(i, cand) {
                        g.remove_edge(i, old);
                        g.add_edge(i, cand);
                        break;
                    }
                }
            }
        }
    }
    g
}

/// 2-D torus on an r x c grid (n = r * c).
pub fn torus(rows: usize, cols: usize) -> Graph {
    let n = rows * cols;
    let mut g = Graph::empty(n);
    for r in 0..rows {
        for c in 0..cols {
            let v = r * cols + c;
            if cols > 1 {
                g.add_edge(v, r * cols + (c + 1) % cols);
            }
            if rows > 1 {
                g.add_edge(v, ((r + 1) % rows) * cols + c);
            }
        }
    }
    g
}

/// Named generator dispatch used by the config system.
///
/// `spec` grammar: `ring`, `full`, `star`, `regular:<d>`, `er:<p>`,
/// `smallworld:<k>:<beta>`, `torus:<rows>:<cols>`.
pub fn from_spec(spec: &str, n: usize, rng: &mut Xoshiro256pp) -> anyhow::Result<Graph> {
    let parts: Vec<&str> = spec.split(':').collect();
    let g = match parts.as_slice() {
        ["ring"] => ring(n),
        ["full"] | ["fully_connected"] => fully_connected(n),
        ["star"] => star(n),
        ["regular", d] => random_regular(n, d.parse()?, rng)?,
        ["er", p] => erdos_renyi(n, p.parse()?, rng),
        ["smallworld", k, beta] => small_world(n, k.parse()?, beta.parse()?, rng),
        ["torus", r, c] => {
            let (r, c): (usize, usize) = (r.parse()?, c.parse()?);
            anyhow::ensure!(r * c == n, "torus {r}x{c} != n={n}");
            torus(r, c)
        }
        _ => anyhow::bail!("unknown topology spec {spec:?}"),
    };
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_connected;

    fn rng() -> Xoshiro256pp {
        Xoshiro256pp::new(1234)
    }

    #[test]
    fn ring_properties() {
        let g = ring(8);
        assert_eq!(g.edge_count(), 8);
        assert!((0..8).all(|v| g.degree(v) == 2));
        assert!(is_connected(&g));
    }

    #[test]
    fn full_properties() {
        let g = fully_connected(10);
        assert_eq!(g.edge_count(), 45);
        assert!((0..10).all(|v| g.degree(v) == 9));
    }

    #[test]
    fn star_properties() {
        let g = star(6);
        assert_eq!(g.degree(0), 5);
        assert!((1..6).all(|v| g.degree(v) == 1));
    }

    #[test]
    fn regular_is_regular_and_connected() {
        let mut r = rng();
        for (n, d) in [(16, 5), (64, 5), (32, 9), (10, 3)] {
            let g = random_regular(n, d, &mut r).unwrap();
            assert!((0..n).all(|v| g.degree(v) == d), "n={n} d={d}");
            assert!(is_connected(&g));
        }
    }

    #[test]
    fn regular_degree_zero_ok() {
        let g = random_regular(6, 0, &mut rng()).unwrap();
        assert_eq!(g.edge_count(), 0);
    }

    #[test]
    fn regular_rejects_bad_shapes_with_typed_errors() {
        // Odd stub total: no 3-regular graph on 5 nodes exists.
        let err = random_regular(5, 3, &mut rng()).unwrap_err();
        assert!(matches!(err, RegularGraphError::OddStubTotal { n: 5, d: 3 }), "{err}");
        // Degree >= n.
        let err = random_regular(4, 4, &mut rng()).unwrap_err();
        assert!(matches!(err, RegularGraphError::DegreeTooLarge { n: 4, d: 4 }), "{err}");
        // The messages are self-explanatory (what config validation shows).
        assert!(err.to_string().contains("degree < nodes"), "{err}");
    }

    #[test]
    fn regular_error_surfaces_through_spec_dispatch() {
        let mut r = rng();
        let err = from_spec("regular:3", 5, &mut r).unwrap_err();
        assert!(err.to_string().contains("n*d even"), "{err}");
    }

    #[test]
    fn dynamic_regular_differs_per_round() {
        let mut r = rng();
        let g1 = random_regular(24, 5, &mut r).unwrap();
        let g2 = random_regular(24, 5, &mut r).unwrap();
        assert_ne!(g1, g2); // overwhelmingly likely
    }

    #[test]
    fn er_edge_density() {
        let g = erdos_renyi(60, 0.2, &mut rng());
        let expected = 0.2 * (60.0 * 59.0 / 2.0);
        let got = g.edge_count() as f64;
        assert!((got - expected).abs() < expected * 0.35, "got {got}");
    }

    #[test]
    fn small_world_preserves_edge_count() {
        let g = small_world(40, 4, 0.3, &mut rng());
        // Rewiring moves edges but keeps ~n*k/2 of them (duplicates on
        // rewire-failure may drop a few).
        assert!((70..=80).contains(&g.edge_count()), "{}", g.edge_count());
        assert!(is_connected(&g));
    }

    #[test]
    fn torus_properties() {
        let g = torus(4, 5);
        assert_eq!(g.len(), 20);
        assert!((0..20).all(|v| g.degree(v) == 4));
        assert!(is_connected(&g));
    }

    #[test]
    fn spec_dispatch() {
        let mut r = rng();
        assert_eq!(from_spec("ring", 6, &mut r).unwrap(), ring(6));
        assert_eq!(from_spec("full", 4, &mut r).unwrap(), fully_connected(4));
        let g = from_spec("regular:5", 16, &mut r).unwrap();
        assert!((0..16).all(|v| g.degree(v) == 5));
        assert!(from_spec("bogus", 4, &mut r).is_err());
        assert!(from_spec("torus:3:3", 8, &mut r).is_err());
    }
}
