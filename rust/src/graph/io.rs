//! Graph file I/O: edge-list and adjacency-list formats (paper §2.2 —
//! "the topology can be read from a graph file having edges or an
//! adjacency list", enabling externally-generated topologies).
//!
//! Edge list:          first line `n`, then one `a b` pair per line.
//! Adjacency list:     first line `n`, then line i = neighbors of node i
//!                     (possibly empty), whitespace-separated.
//! Lines starting with `#` are comments in both formats.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Graph;

/// Parse an edge-list document.
pub fn parse_edge_list(text: &str) -> Result<Graph> {
    let mut lines = content_lines(text);
    let n: usize = lines
        .next()
        .context("empty edge-list file")?
        .trim()
        .parse()
        .context("first line must be the node count")?;
    let mut g = Graph::empty(n);
    for (lineno, line) in lines.enumerate() {
        let mut it = line.split_whitespace();
        let a: usize = match it.next() {
            None => continue,
            Some(t) => t.parse().with_context(|| format!("line {}", lineno + 2))?,
        };
        let b: usize = it
            .next()
            .with_context(|| format!("line {}: missing endpoint", lineno + 2))?
            .parse()
            .with_context(|| format!("line {}", lineno + 2))?;
        if it.next().is_some() {
            bail!("line {}: expected exactly two endpoints", lineno + 2);
        }
        if a >= n || b >= n {
            bail!("line {}: node id out of range (n={n})", lineno + 2);
        }
        g.add_edge(a, b);
    }
    Ok(g)
}

/// Parse an adjacency-list document. Blank lines are significant here:
/// they encode isolated nodes (comment lines are still skipped).
pub fn parse_adjacency_list(text: &str) -> Result<Graph> {
    let mut lines = text
        .lines()
        .map(|l| l.trim())
        .filter(|l| !l.starts_with('#'));
    let header = lines
        .by_ref()
        .find(|l| !l.is_empty())
        .context("empty adjacency-list file")?;
    let n: usize = header
        .parse()
        .context("first line must be the node count")?;
    let mut g = Graph::empty(n);
    let mut row = 0usize;
    for line in lines {
        if row >= n {
            if line.is_empty() {
                continue; // trailing blank lines are fine
            }
            bail!("more adjacency rows than nodes (n={n})");
        }
        for tok in line.split_whitespace() {
            let b: usize = tok.parse().with_context(|| format!("row {row}"))?;
            if b >= n {
                bail!("row {row}: neighbor {b} out of range");
            }
            g.add_edge(row, b);
        }
        row += 1;
    }
    if row != n {
        bail!("expected {n} adjacency rows, found {row}");
    }
    Ok(g)
}

/// Serialize as edge list.
pub fn to_edge_list(g: &Graph) -> String {
    let mut out = format!("{}\n", g.len());
    for (a, b) in g.edges() {
        out.push_str(&format!("{a} {b}\n"));
    }
    out
}

/// Serialize as adjacency list.
pub fn to_adjacency_list(g: &Graph) -> String {
    let mut out = format!("{}\n", g.len());
    for v in 0..g.len() {
        let row: Vec<String> = g.neighbors(v).map(|x| x.to_string()).collect();
        out.push_str(&row.join(" "));
        out.push('\n');
    }
    out
}

/// Load a graph file. Format is detected from the extension first
/// (`.adj`/`.adjacency` → adjacency list, `.edges`/`.edgelist`/`.el` →
/// edge list); unknown extensions fall back to a structural heuristic
/// (exactly `n` data rows → adjacency, else edge list).
pub fn load(path: &Path) -> Result<Graph> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading graph file {}", path.display()))?;
    match path.extension().and_then(|e| e.to_str()) {
        Some("adj") | Some("adjacency") => return parse_adjacency_list(&text),
        Some("edges") | Some("edgelist") | Some("el") => return parse_edge_list(&text),
        _ => {}
    }
    let rows: Vec<&str> = content_lines(&text).collect();
    if rows.is_empty() {
        bail!("empty graph file {}", path.display());
    }
    let n: usize = rows[0].trim().parse().context("first line must be node count")?;
    if rows.len() - 1 == n {
        if let Ok(g) = parse_adjacency_list(&text) {
            return Ok(g);
        }
    }
    parse_edge_list(&text)
}

pub fn save_edge_list(g: &Graph, path: &Path) -> Result<()> {
    std::fs::write(path, to_edge_list(g))
        .with_context(|| format!("writing {}", path.display()))
}

pub fn save_adjacency_list(g: &Graph, path: &Path) -> Result<()> {
    std::fs::write(path, to_adjacency_list(g))
        .with_context(|| format!("writing {}", path.display()))
}

fn content_lines(text: &str) -> impl Iterator<Item = &str> {
    text.lines()
        .map(|l| l.trim())
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ring, small_world};
    use crate::rng::Xoshiro256pp;

    #[test]
    fn edge_list_roundtrip() {
        let g = ring(7);
        let text = to_edge_list(&g);
        assert_eq!(parse_edge_list(&text).unwrap(), g);
    }

    #[test]
    fn adjacency_roundtrip() {
        let mut rng = Xoshiro256pp::new(3);
        let g = small_world(20, 4, 0.2, &mut rng);
        let text = to_adjacency_list(&g);
        assert_eq!(parse_adjacency_list(&text).unwrap(), g);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let text = "# topology\n4\n\n0 1\n# middle\n2 3\n";
        let g = parse_edge_list(text).unwrap();
        assert_eq!(g.edge_count(), 2);
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(parse_edge_list("3\n0 5\n").is_err());
        assert!(parse_adjacency_list("2\n1\n5\n").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_edge_list("").is_err());
        assert!(parse_edge_list("2\n0\n").is_err());
        assert!(parse_edge_list("2\n0 1 2\n").is_err());
        assert!(parse_adjacency_list("3\n1\n0\n").is_err()); // missing row
    }

    #[test]
    fn load_autodetects_both_formats() {
        let dir = std::env::temp_dir().join("decentra_graph_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let g = ring(6);

        let ep = dir.join("g.edges");
        save_edge_list(&g, &ep).unwrap();
        assert_eq!(load(&ep).unwrap(), g);

        let ap = dir.join("g.adj");
        save_adjacency_list(&g, &ap).unwrap();
        assert_eq!(load(&ap).unwrap(), g);
    }

    #[test]
    fn isolated_nodes_survive_adjacency_roundtrip() {
        let mut g = Graph::empty(4);
        g.add_edge(0, 1);
        let text = to_adjacency_list(&g);
        let parsed = parse_adjacency_list(&text).unwrap();
        assert_eq!(parsed, g);
        assert_eq!(parsed.degree(3), 0);
    }
}
