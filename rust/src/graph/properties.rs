//! Graph analysis: connectivity, degree statistics, spectral gap.
//!
//! The spectral gap of the mixing matrix governs D-PSGD convergence speed
//! (the reason denser topologies converge faster per round, paper Fig 3a);
//! we expose an estimate so experiments can report it alongside accuracy.

use super::{metropolis_hastings, Graph};

/// BFS connectivity check.
pub fn is_connected(g: &Graph) -> bool {
    let n = g.len();
    if n == 0 {
        return true;
    }
    let mut seen = vec![false; n];
    let mut queue = std::collections::VecDeque::from([0usize]);
    seen[0] = true;
    let mut count = 1;
    while let Some(v) = queue.pop_front() {
        for w in g.neighbors(v) {
            if !seen[w] {
                seen[w] = true;
                count += 1;
                queue.push_back(w);
            }
        }
    }
    count == n
}

/// (min, mean, max) node degree.
pub fn degree_stats(g: &Graph) -> (usize, f64, usize) {
    let n = g.len();
    if n == 0 {
        return (0, 0.0, 0);
    }
    let degs: Vec<usize> = (0..n).map(|v| g.degree(v)).collect();
    let min = *degs.iter().min().unwrap();
    let max = *degs.iter().max().unwrap();
    let mean = degs.iter().sum::<usize>() as f64 / n as f64;
    (min, mean, max)
}

/// Graph diameter via per-node BFS (O(n·m); fine at experiment scales).
/// Returns `None` for disconnected graphs.
pub fn diameter(g: &Graph) -> Option<usize> {
    let n = g.len();
    let mut diam = 0;
    for s in 0..n {
        let mut dist = vec![usize::MAX; n];
        dist[s] = 0;
        let mut q = std::collections::VecDeque::from([s]);
        while let Some(v) = q.pop_front() {
            for w in g.neighbors(v) {
                if dist[w] == usize::MAX {
                    dist[w] = dist[v] + 1;
                    q.push_back(w);
                }
            }
        }
        let far = *dist.iter().max().unwrap();
        if far == usize::MAX {
            return None;
        }
        diam = diam.max(far);
    }
    Some(diam)
}

/// Estimate the spectral gap `1 - |lambda_2|` of the Metropolis-Hastings
/// mixing matrix `W` by power iteration on the space orthogonal to the
/// all-ones vector (the top eigenvector of a doubly-stochastic matrix).
pub fn spectral_gap(g: &Graph, iters: usize) -> f64 {
    let n = g.len();
    if n <= 1 {
        return 1.0;
    }
    let w = metropolis_hastings(g);
    // Start from a deterministic pseudo-random vector, deflate mean.
    let mut v: Vec<f64> = (0..n)
        .map(|i| (i as f64 * 0.754_877_666 + 0.1).sin())
        .collect();
    deflate(&mut v);
    normalize(&mut v);
    let mut lambda = 0.0;
    for _ in 0..iters {
        // v <- W v  (W is symmetric for MH on undirected graphs)
        let mut nv = vec![0.0f64; n];
        for a in 0..n {
            nv[a] += w.self_weight(a) * v[a];
            for (b, wt) in w.neighbor_weights(a) {
                nv[a] += wt * v[b];
            }
        }
        deflate(&mut nv);
        lambda = norm(&nv);
        if lambda < 1e-15 {
            return 1.0; // second eigenvalue ~0
        }
        for x in nv.iter_mut() {
            *x /= lambda;
        }
        v = nv;
    }
    (1.0 - lambda).max(0.0)
}

fn deflate(v: &mut [f64]) {
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    for x in v.iter_mut() {
        *x -= mean;
    }
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v);
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{fully_connected, random_regular, ring};
    use crate::rng::Xoshiro256pp;

    #[test]
    fn connectivity() {
        let mut g = Graph::empty(4);
        assert!(!is_connected(&g));
        g.add_edge(0, 1);
        g.add_edge(2, 3);
        assert!(!is_connected(&g));
        g.add_edge(1, 2);
        assert!(is_connected(&g));
        assert!(is_connected(&Graph::empty(0)));
        assert!(is_connected(&Graph::empty(1)));
    }

    #[test]
    fn degree_stats_basic() {
        let g = ring(6);
        assert_eq!(degree_stats(&g), (2, 2.0, 2));
    }

    #[test]
    fn diameters() {
        assert_eq!(diameter(&ring(8)), Some(4));
        assert_eq!(diameter(&fully_connected(5)), Some(1));
        let mut g = Graph::empty(3);
        g.add_edge(0, 1);
        assert_eq!(diameter(&g), None);
    }

    #[test]
    fn spectral_gap_ordering_matches_paper_intuition() {
        // full > regular > ring: denser graphs mix faster.
        let mut rng = Xoshiro256pp::new(5);
        let full = spectral_gap(&fully_connected(32), 200);
        let reg = spectral_gap(&random_regular(32, 5, &mut rng).unwrap(), 200);
        let rng_gap = spectral_gap(&ring(32), 200);
        assert!(full > reg, "full {full} vs regular {reg}");
        assert!(reg > rng_gap, "regular {reg} vs ring {rng_gap}");
    }

    #[test]
    fn spectral_gap_complete_graph_closed_form() {
        // For K_n with MH weights, W = J/n, lambda_2 = 0 -> gap = 1.
        let gap = spectral_gap(&fully_connected(16), 100);
        assert!((gap - 1.0).abs() < 1e-6, "gap {gap}");
    }
}
