//! Metropolis–Hastings mixing weights (Xiao, Boyd & Kim 2007) — the
//! aggregation weights the paper's D-PSGD clients use.
//!
//! For an undirected graph, `W[a][b] = 1 / (1 + max(deg(a), deg(b)))` for
//! each edge and `W[a][a] = 1 - sum_b W[a][b]`. The resulting matrix is
//! symmetric and doubly stochastic, which guarantees average consensus.

use super::Graph;

/// Row-compressed mixing matrix aligned with a specific [`Graph`].
#[derive(Debug, Clone, PartialEq)]
pub struct MixingWeights {
    /// Per node: (neighbor, weight) in neighbor-sorted order.
    rows: Vec<Vec<(usize, f64)>>,
    /// Per node: self weight.
    self_w: Vec<f64>,
}

impl MixingWeights {
    pub fn len(&self) -> usize {
        self.self_w.len()
    }

    pub fn is_empty(&self) -> bool {
        self.self_w.is_empty()
    }

    pub fn self_weight(&self, v: usize) -> f64 {
        self.self_w[v]
    }

    pub fn neighbor_weights(&self, v: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        self.rows[v].iter().copied()
    }

    /// Weight on edge (a, b); zero when not adjacent.
    pub fn weight(&self, a: usize, b: usize) -> f64 {
        if a == b {
            return self.self_w[a];
        }
        self.rows[a]
            .iter()
            .find(|(n, _)| *n == b)
            .map(|(_, w)| *w)
            .unwrap_or(0.0)
    }
}

/// Compute Metropolis–Hastings weights for `g`.
pub fn metropolis_hastings(g: &Graph) -> MixingWeights {
    let n = g.len();
    let mut rows = Vec::with_capacity(n);
    let mut self_w = Vec::with_capacity(n);
    for a in 0..n {
        let mut row = Vec::with_capacity(g.degree(a));
        let mut total = 0.0;
        for b in g.neighbors(a) {
            let w = 1.0 / (1.0 + g.degree(a).max(g.degree(b)) as f64);
            row.push((b, w));
            total += w;
        }
        rows.push(row);
        self_w.push(1.0 - total);
    }
    MixingWeights { rows, self_w }
}

/// Uniform averaging weights (1/(deg+1) everywhere) — a simpler baseline
/// some DL works use; kept for ablations.
pub fn uniform(g: &Graph) -> MixingWeights {
    let n = g.len();
    let mut rows = Vec::with_capacity(n);
    let mut self_w = Vec::with_capacity(n);
    for a in 0..n {
        let w = 1.0 / (1.0 + g.degree(a) as f64);
        rows.push(g.neighbors(a).map(|b| (b, w)).collect());
        self_w.push(w);
    }
    MixingWeights { rows, self_w }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{fully_connected, random_regular, ring, star};
    use crate::rng::Xoshiro256pp;

    fn assert_doubly_stochastic(w: &MixingWeights) {
        let n = w.len();
        // Row sums = 1.
        for a in 0..n {
            let sum: f64 =
                w.self_weight(a) + w.neighbor_weights(a).map(|(_, x)| x).sum::<f64>();
            assert!((sum - 1.0).abs() < 1e-12, "row {a} sums to {sum}");
        }
        // Symmetry => column sums = 1 too.
        for a in 0..n {
            for (b, wab) in w.neighbor_weights(a) {
                assert!((wab - w.weight(b, a)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn mh_ring_values() {
        let w = metropolis_hastings(&ring(5));
        // All degrees 2 -> edge weight 1/3, self 1/3.
        assert!((w.weight(0, 1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((w.self_weight(0) - 1.0 / 3.0).abs() < 1e-12);
        assert_doubly_stochastic(&w);
    }

    #[test]
    fn mh_star_heterogeneous_degrees() {
        let w = metropolis_hastings(&star(5));
        // Hub degree 4, leaves degree 1 -> edge weight 1/5.
        assert!((w.weight(0, 3) - 0.2).abs() < 1e-12);
        // Leaf self-weight 0.8; hub self-weight 1 - 4/5 = 0.2.
        assert!((w.self_weight(3) - 0.8).abs() < 1e-12);
        assert!((w.self_weight(0) - 0.2).abs() < 1e-12);
        assert_doubly_stochastic(&w);
    }

    #[test]
    fn mh_complete_graph_is_uniform() {
        let w = metropolis_hastings(&fully_connected(8));
        for a in 0..8 {
            assert!((w.self_weight(a) - 1.0 / 8.0).abs() < 1e-12);
            for (_, x) in w.neighbor_weights(a) {
                assert!((x - 1.0 / 8.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn mh_random_regular_doubly_stochastic() {
        let mut rng = Xoshiro256pp::new(2);
        let g = random_regular(30, 5, &mut rng).unwrap();
        assert_doubly_stochastic(&metropolis_hastings(&g));
    }

    #[test]
    fn uniform_rows_sum_to_one() {
        let g = star(6);
        let w = uniform(&g);
        for a in 0..6 {
            let sum: f64 =
                w.self_weight(a) + w.neighbor_weights(a).map(|(_, x)| x).sum::<f64>();
            assert!((sum - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn absent_edge_weight_is_zero() {
        let w = metropolis_hastings(&ring(6));
        assert_eq!(w.weight(0, 3), 0.0);
    }
}
