//! Overlay topology management (the paper's *Graph* module).
//!
//! The graph constrains node communication to immediate neighbors, can be
//! regenerated at run time (dynamic topologies via the peer sampler), and
//! is readable from / writable to edge-list and adjacency-list files so
//! externally-generated topologies can be swapped in ("swift switching of
//! topologies", §2.2).

mod generators;
mod io;
mod properties;
mod weights;

pub use generators::*;
pub use io::*;
pub use properties::*;
pub use weights::*;

use std::collections::BTreeSet;

/// Undirected overlay graph over nodes `0..n`.
///
/// Adjacency is kept as ordered sets: deterministic iteration order makes
/// every downstream consumer (weights, sharing, wire messages) reproducible
/// for a fixed seed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    adj: Vec<BTreeSet<usize>>,
}

impl Graph {
    /// Empty graph on `n` nodes.
    pub fn empty(n: usize) -> Graph {
        Graph { adj: vec![BTreeSet::new(); n] }
    }

    pub fn len(&self) -> usize {
        self.adj.len()
    }

    pub fn is_empty(&self) -> bool {
        self.adj.is_empty()
    }

    /// Add an undirected edge; self-loops are ignored (a node always has
    /// implicit access to its own model).
    pub fn add_edge(&mut self, a: usize, b: usize) {
        assert!(a < self.len() && b < self.len(), "edge out of range");
        if a == b {
            return;
        }
        self.adj[a].insert(b);
        self.adj[b].insert(a);
    }

    pub fn remove_edge(&mut self, a: usize, b: usize) {
        self.adj[a].remove(&b);
        self.adj[b].remove(&a);
    }

    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        self.adj[a].contains(&b)
    }

    /// Neighbor set of `v` (sorted).
    pub fn neighbors(&self, v: usize) -> impl Iterator<Item = usize> + '_ {
        self.adj[v].iter().copied()
    }

    pub fn neighbors_vec(&self, v: usize) -> Vec<usize> {
        self.adj[v].iter().copied().collect()
    }

    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|s| s.len()).sum::<usize>() / 2
    }

    /// All edges as (a, b) with a < b, sorted.
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.edge_count());
        for (a, nbrs) in self.adj.iter().enumerate() {
            for &b in nbrs.iter() {
                if a < b {
                    out.push((a, b));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edges_are_undirected_and_deduped() {
        let mut g = Graph::empty(4);
        g.add_edge(0, 1);
        g.add_edge(1, 0);
        g.add_edge(2, 3);
        assert_eq!(g.edge_count(), 2);
        assert!(g.has_edge(1, 0));
        assert_eq!(g.neighbors_vec(1), vec![0]);
        g.remove_edge(0, 1);
        assert_eq!(g.edge_count(), 1);
        assert!(!g.has_edge(0, 1));
    }

    #[test]
    fn self_loops_ignored() {
        let mut g = Graph::empty(2);
        g.add_edge(1, 1);
        assert_eq!(g.edge_count(), 0);
        assert_eq!(g.degree(1), 0);
    }

    #[test]
    #[should_panic]
    fn out_of_range_edge_panics() {
        let mut g = Graph::empty(2);
        g.add_edge(0, 5);
    }

    #[test]
    fn edges_sorted_canonical() {
        let mut g = Graph::empty(5);
        g.add_edge(4, 0);
        g.add_edge(2, 1);
        assert_eq!(g.edges(), vec![(0, 4), (1, 2)]);
    }
}
