//! Experiment configuration (JSON), validation, and presets.
//!
//! One config fully describes a run: dataset + partition, model, topology
//! (static or dynamic), sharing algorithm, secure aggregation, optimizer
//! settings, network model, and output locations. The figure harnesses in
//! `examples/` are thin loops over these configs, mirroring how the paper
//! swaps graph/sharing specifications per experiment (Fig 1).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Json};

/// Fully-resolved experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Experiment label (used for the results directory name).
    pub name: String,
    pub nodes: usize,
    /// Communication rounds to run.
    pub rounds: u64,
    /// Evaluate every k rounds (1 = every round).
    pub eval_every: u64,
    /// Master seed; per-node / per-round streams derive from it.
    pub seed: u64,
    /// Model name in the artifact manifest: mlp | cnn | celeba.
    pub model: String,
    /// Dataset family: cifar10s | celebas.
    pub dataset: String,
    /// Square image resolution (must match the lowered artifacts).
    pub image: usize,
    /// Global train/test example counts (split across nodes).
    pub train_total: usize,
    pub test_total: usize,
    /// Synthetic noise sigma (task difficulty).
    pub noise: f32,
    /// Partition spec: `iid` | `shards:<k>` | `dirichlet:<alpha>`.
    pub partition: String,
    /// Topology spec: `ring` | `full` | `star` | `regular:<d>` |
    /// `er:<p>` | `smallworld:<k>:<b>` | `torus:<r>:<c>`.
    pub topology: String,
    /// Re-sample the topology every round via the peer sampler.
    pub dynamic: bool,
    /// Sharing spec: `full` | `subsample:<budget>` | `topk:<budget>` |
    /// `choco:<budget>:<gamma>` (budget = fraction of params sent).
    pub sharing: String,
    /// Round execution model: `dl` (synchronous D-PSGD: every round
    /// barriers on all neighbor models) | `async_dl` (asynchronous
    /// gossip: aggregate whatever arrived by a virtual deadline,
    /// staleness-weighted; scheduler runner only).
    /// See [`crate::scheduler::AsyncDlNodeSm`].
    pub mode: String,
    /// Async deadline spec: `fixed:<seconds>` | `p<q>` (quantile-
    /// adaptive) | `factor:<f>` (of the node's own round compute time).
    /// See [`crate::node::DeadlineSpec`]. Ignored for `mode = "dl"`.
    pub deadline: String,
    /// Async staleness policy: `none` | `linear:<tau>` | `poly:<alpha>`.
    /// See [`crate::node::StalenessPolicy`]. Ignored for `mode = "dl"`.
    pub staleness: String,
    /// Async late-delivery policy: `buffer` | `drop`.
    /// See [`crate::node::LatePolicy`]. Ignored for `mode = "dl"`.
    pub late: String,
    /// Wrap sharing in pairwise-mask secure aggregation.
    pub secure: bool,
    /// Secure-agg mask amplitude. Masks are uniform in [-m, m); larger
    /// masks give stronger hiding but more f32 cancellation residue (the
    /// paper's ~3% accuracy loss is this precision effect).
    pub mask_scale: f32,
    /// Per-round probability a node is unavailable (dynamic mode only;
    /// FedScale-style availability churn).
    pub churn: f64,
    /// Replayable availability trace, replacing the Bernoulli `churn`
    /// draw: empty (off) | `trace:<path>` |
    /// `sessions:<mean_on>:<mean_off>` | `departures:<frac>`.
    /// See [`crate::scenario`].
    pub churn_trace: String,
    /// Byzantine adversary spec: empty (all honest) |
    /// `byzantine:<frac>:flood[:<factor>]` |
    /// `byzantine:<frac>:poison[:<scale>]` |
    /// `byzantine:<frac>:collude:<k>`.
    /// See [`crate::scenario::ByzantineRoster`].
    pub byzantine: String,
    pub lr: f32,
    /// Local SGD steps per communication round.
    pub local_steps: u32,
    /// Network model for the emulated clock: `lan` | `wan` | `none`.
    pub network: String,
    /// Per-node compute heterogeneity (step-time multipliers):
    /// `uniform` | `stragglers:<frac>:<factor>` | `lognormal:<sigma>` |
    /// `trace:<path>`. See [`crate::scenario::ComputePlan`].
    pub step_time: String,
    /// Per-link delay model for the scheduler: `uniform` (use
    /// `network`) | `geo:<clusters>` | `matrix:<path>`.
    /// See [`crate::communication::shaper::LinkMatrix`].
    pub link_model: String,
    /// In-process runner: `scheduler` (discrete-event virtual time on a
    /// bounded worker pool, the default) | `threads` (one thread/node).
    pub runner: String,
    /// Worker threads for the scheduler runner (0 = number of cores).
    pub workers: usize,
    /// Per-neighbor aggregation fold plan: `serial` (left-to-right, the
    /// historical default) | `tree:<width>` (group neighbors into
    /// `<width>`-wide leaf groups folded concurrently, then combine in
    /// group order). The reduction-tree shape is a pure function of
    /// (degree, width), so results are bit-identical at any worker
    /// count. See [`crate::kernels::fold`].
    pub fold: String,
    /// Model-state ownership: `owned` (every node clones the init, the
    /// historical default) | `shared` (one copy-on-write
    /// [`crate::store::ParamStore`]; nodes materialize a private shard
    /// on first write, so memory is O(active divergence) and 4096+-node
    /// fleets fit in one process) | `paged` (shared, but divergent
    /// state is tracked per fixed-size *page* and byte-identical pages
    /// are interned back into one copy, so memory is O(pages actually
    /// written) — the 100k-node tier). Bit-identical results either way.
    pub param_store: String,
    /// Page size in *elements* (f32 lanes) for `param_store: "paged"`;
    /// ignored by the other modes. Must be > 0.
    pub page_size: usize,
    /// Dual-clock span tracing: `off` | `sample:<rate>` | `full`.
    /// Scheduler runner only. See [`crate::trace`].
    pub trace: String,
    pub artifacts_dir: PathBuf,
    pub results_dir: PathBuf,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "experiment".into(),
            nodes: 16,
            rounds: 40,
            eval_every: 4,
            seed: 1,
            model: "mlp".into(),
            dataset: "cifar10s".into(),
            image: 16,
            train_total: 2048,
            test_total: 512,
            noise: 0.8,
            partition: "shards:2".into(),
            topology: "regular:5".into(),
            dynamic: false,
            sharing: "full".into(),
            mode: "dl".into(),
            deadline: "factor:2".into(),
            staleness: "none".into(),
            late: "buffer".into(),
            secure: false,
            mask_scale: 4.0,
            churn: 0.0,
            churn_trace: String::new(),
            byzantine: String::new(),
            lr: 0.05,
            local_steps: 2,
            network: "lan".into(),
            step_time: "uniform".into(),
            link_model: "uniform".into(),
            runner: "scheduler".into(),
            workers: 0,
            fold: "serial".into(),
            param_store: "owned".into(),
            page_size: 1024,
            trace: "off".into(),
            artifacts_dir: PathBuf::from("artifacts"),
            results_dir: PathBuf::from("results"),
        }
    }
}

impl ExperimentConfig {
    pub fn from_json(v: &Json) -> Result<ExperimentConfig> {
        let d = ExperimentConfig::default();
        let obj = v.as_obj().context("config must be a JSON object")?;
        // Reject unknown keys: typos in experiment configs are expensive.
        const KNOWN: &[&str] = &[
            "name", "nodes", "rounds", "eval_every", "seed", "model",
            "dataset", "image", "train_total", "test_total", "noise",
            "partition", "topology", "dynamic", "sharing", "mode", "deadline", "staleness",
            "late", "secure", "mask_scale", "churn",
            "churn_trace", "byzantine", "lr", "local_steps", "network", "step_time", "link_model",
            "runner", "workers", "fold", "param_store", "page_size", "trace",
            "artifacts_dir", "results_dir",
        ];
        for k in obj.keys() {
            if !KNOWN.contains(&k.as_str()) {
                bail!("unknown config key {k:?}");
            }
        }
        let s = |k: &str, dflt: &str| -> String {
            v.get(k).as_str().unwrap_or(dflt).to_string()
        };
        let n = |k: &str, dflt: usize| v.get(k).as_usize().unwrap_or(dflt);
        let f = |k: &str, dflt: f64| v.get(k).as_f64().unwrap_or(dflt);
        let b = |k: &str, dflt: bool| v.get(k).as_bool().unwrap_or(dflt);
        let cfg = ExperimentConfig {
            name: s("name", &d.name),
            nodes: n("nodes", d.nodes),
            rounds: n("rounds", d.rounds as usize) as u64,
            eval_every: n("eval_every", d.eval_every as usize) as u64,
            seed: n("seed", d.seed as usize) as u64,
            model: s("model", &d.model),
            dataset: s("dataset", &d.dataset),
            image: n("image", d.image),
            train_total: n("train_total", d.train_total),
            test_total: n("test_total", d.test_total),
            noise: f("noise", d.noise as f64) as f32,
            partition: s("partition", &d.partition),
            topology: s("topology", &d.topology),
            dynamic: b("dynamic", d.dynamic),
            sharing: s("sharing", &d.sharing),
            mode: s("mode", &d.mode),
            deadline: s("deadline", &d.deadline),
            staleness: s("staleness", &d.staleness),
            late: s("late", &d.late),
            secure: b("secure", d.secure),
            mask_scale: f("mask_scale", d.mask_scale as f64) as f32,
            churn: f("churn", d.churn),
            churn_trace: s("churn_trace", &d.churn_trace),
            byzantine: s("byzantine", &d.byzantine),
            lr: f("lr", d.lr as f64) as f32,
            local_steps: n("local_steps", d.local_steps as usize) as u32,
            network: s("network", &d.network),
            step_time: s("step_time", &d.step_time),
            link_model: s("link_model", &d.link_model),
            runner: s("runner", &d.runner),
            workers: n("workers", d.workers),
            fold: s("fold", &d.fold),
            param_store: s("param_store", &d.param_store),
            page_size: n("page_size", d.page_size),
            trace: s("trace", &d.trace),
            artifacts_dir: PathBuf::from(s("artifacts_dir", "artifacts")),
            results_dir: PathBuf::from(s("results_dir", "results")),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let v = parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&v).with_context(|| format!("in config {}", path.display()))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("nodes", Json::num(self.nodes as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("model", Json::str(self.model.clone())),
            ("dataset", Json::str(self.dataset.clone())),
            ("image", Json::num(self.image as f64)),
            ("train_total", Json::num(self.train_total as f64)),
            ("test_total", Json::num(self.test_total as f64)),
            ("noise", Json::num(self.noise as f64)),
            ("partition", Json::str(self.partition.clone())),
            ("topology", Json::str(self.topology.clone())),
            ("dynamic", Json::Bool(self.dynamic)),
            ("sharing", Json::str(self.sharing.clone())),
            ("mode", Json::str(self.mode.clone())),
            ("deadline", Json::str(self.deadline.clone())),
            ("staleness", Json::str(self.staleness.clone())),
            ("late", Json::str(self.late.clone())),
            ("secure", Json::Bool(self.secure)),
            ("mask_scale", Json::num(self.mask_scale as f64)),
            ("churn", Json::num(self.churn)),
            ("churn_trace", Json::str(self.churn_trace.clone())),
            ("byzantine", Json::str(self.byzantine.clone())),
            ("lr", Json::num(self.lr as f64)),
            ("local_steps", Json::num(self.local_steps as f64)),
            ("network", Json::str(self.network.clone())),
            ("step_time", Json::str(self.step_time.clone())),
            ("link_model", Json::str(self.link_model.clone())),
            ("runner", Json::str(self.runner.clone())),
            ("workers", Json::num(self.workers as f64)),
            ("fold", Json::str(self.fold.clone())),
            ("param_store", Json::str(self.param_store.clone())),
            ("page_size", Json::num(self.page_size as f64)),
            ("trace", Json::str(self.trace.clone())),
            ("artifacts_dir", Json::str(self.artifacts_dir.display().to_string())),
            ("results_dir", Json::str(self.results_dir.display().to_string())),
        ])
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes < 2 {
            bail!("nodes must be >= 2 (got {})", self.nodes);
        }
        if self.rounds == 0 || self.eval_every == 0 {
            bail!("rounds and eval_every must be positive");
        }
        if !["mlp", "cnn", "celeba"].contains(&self.model.as_str()) {
            bail!("unknown model {:?}", self.model);
        }
        if !["cifar10s", "celebas"].contains(&self.dataset.as_str()) {
            bail!("unknown dataset {:?}", self.dataset);
        }
        if self.model == "celeba" && self.dataset != "celebas" {
            bail!("model celeba requires dataset celebas");
        }
        if self.dataset == "celebas" && self.model != "celeba" {
            bail!("dataset celebas requires model celeba (2 classes)");
        }
        if !(0.0..1.0).contains(&self.churn) {
            bail!("churn must be in [0, 1)");
        }
        if self.churn > 0.0 && !self.dynamic {
            bail!("churn requires dynamic topologies (the peer sampler draws availability)");
        }
        if !(self.lr > 0.0) {
            bail!("lr must be positive");
        }
        if self.local_steps == 0 {
            bail!("local_steps must be >= 1");
        }
        if self.train_total < self.nodes {
            bail!("train_total {} < nodes {}", self.train_total, self.nodes);
        }
        if !["lan", "wan", "none"].contains(&self.network.as_str()) {
            bail!("unknown network model {:?}", self.network);
        }
        // Execution mode + async-gossip policies. Spec syntax is checked
        // even in synchronous mode so a typo surfaces immediately.
        if !["dl", "async_dl"].contains(&self.mode.as_str()) {
            bail!("unknown mode {:?} (expected dl | async_dl)", self.mode);
        }
        crate::node::DeadlineSpec::validate_spec(&self.deadline)
            .with_context(|| format!("invalid deadline {:?}", self.deadline))?;
        crate::node::StalenessPolicy::validate_spec(&self.staleness)
            .with_context(|| format!("invalid staleness {:?}", self.staleness))?;
        crate::node::LatePolicy::validate_spec(&self.late)
            .with_context(|| format!("invalid late policy {:?}", self.late))?;
        if self.mode == "async_dl" {
            // Async gossip is a scheduler-only execution model: it needs
            // timer events and per-message virtual timestamps.
            if self.runner != "scheduler" {
                bail!("mode \"async_dl\" requires runner \"scheduler\"");
            }
            if self.secure {
                bail!("mode \"async_dl\" is incompatible with secure aggregation (pairwise masks need every neighbor's message, asynchrony drops that guarantee)");
            }
            if self.dynamic {
                bail!("mode \"async_dl\" requires a static topology (the peer sampler is a per-round barrier, which asynchrony removes)");
            }
            if self.sharing.starts_with("choco") {
                bail!("mode \"async_dl\" is incompatible with choco sharing (per-neighbor estimates desync under partial aggregation)");
            }
        }
        // Scenario axes: spec syntax (trace files are only read at
        // prepare) and runner compatibility. Per-link delays and
        // static-topology churn traces are delivery-level semantics only
        // the virtual-time scheduler implements.
        crate::scenario::ComputePlan::validate_spec(&self.step_time)?;
        crate::scenario::validate_link_spec(&self.link_model)?;
        crate::scenario::ChurnTrace::validate_spec(&self.churn_trace)?;
        // Time-indexed crashes kill a node mid-round without notice; a
        // synchronous fleet would deadlock waiting for it, so crashes
        // require the timeout-driven async mode.
        if crate::scenario::is_crash_spec(&self.churn_trace) && self.mode != "async_dl" {
            bail!("churn_trace \"crashes:\" requires mode \"async_dl\" (synchronous rounds would deadlock on the crashed node)");
        }
        if !matches!(self.link_model.as_str(), "" | "uniform") && self.runner != "scheduler" {
            bail!("link_model {:?} requires runner \"scheduler\"", self.link_model);
        }
        crate::scenario::ByzantineRoster::validate_spec(&self.byzantine)?;
        if !self.byzantine.is_empty() {
            if self.secure {
                bail!("byzantine scenarios are incompatible with secure aggregation (pairwise masks assume honest-but-curious peers, not active adversaries)");
            }
            if self.sharing.starts_with("choco") {
                bail!("byzantine scenarios are incompatible with choco sharing (error-feedback state assumes honest self-broadcast)");
            }
        }
        // CHOCO keeps per-neighbor estimate replicas that must observe
        // every increment; a changing neighbor set (dynamic topologies)
        // or missed rounds (churn) silently desync them.
        if self.sharing.starts_with("choco") && (self.dynamic || !self.churn_trace.is_empty()) {
            bail!("choco sharing requires a static, fully-participating topology (no dynamic mode or churn traces)");
        }
        if !self.churn_trace.is_empty() {
            if self.secure {
                bail!("churn traces are incompatible with secure aggregation (pairwise masks need full participation)");
            }
            if self.churn > 0.0 {
                bail!("set either churn (Bernoulli) or churn_trace, not both");
            }
            if !self.dynamic && self.runner != "scheduler" {
                bail!("static-topology churn traces require runner \"scheduler\"");
            }
        }
        // The coordinator owns the runner-name mapping; delegate so a new
        // runner only has to be registered in one place.
        crate::coordinator::runner_from_spec(&self.runner, self.workers).map(|_| ())?;
        crate::kernels::fold::FoldSpec::parse(&self.fold)
            .with_context(|| format!("invalid fold {:?}", self.fold))?;
        if !["owned", "shared", "paged"].contains(&self.param_store.as_str()) {
            bail!(
                "unknown param_store {:?} (expected owned | shared | paged)",
                self.param_store
            );
        }
        if self.page_size == 0 {
            bail!("page_size must be > 0 (elements per page)");
        }
        let trace_mode = crate::trace::TraceMode::parse(&self.trace)
            .with_context(|| format!("invalid trace {:?}", self.trace))?;
        if trace_mode != crate::trace::TraceMode::Off && self.runner != "scheduler" {
            // Spans hang off the virtual-time event loop; the threaded
            // runner has no scheduler to instrument.
            bail!("trace {:?} requires runner \"scheduler\"", self.trace);
        }
        if self.secure && self.dynamic {
            bail!("secure aggregation supports static topologies only");
        }
        if self.secure && self.sharing != "full" {
            bail!("secure aggregation requires full sharing (masks are dense)");
        }
        // Spec strings are validated by their own parsers; do it eagerly
        // so config errors surface before any work happens.
        crate::dataset::Partition::from_spec(&self.partition)?;
        let mut rng = crate::rng::Xoshiro256pp::new(0);
        crate::graph::from_spec(&self.topology, self.nodes, &mut rng)
            .with_context(|| format!("invalid topology {:?}", self.topology))?;
        crate::sharing::validate_spec(&self.sharing)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ExperimentConfig::default();
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let v = parse(r#"{"nodes": 8, "topology": "ring"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.nodes, 8);
        assert_eq!(cfg.topology, "ring");
        assert_eq!(cfg.model, "mlp");
    }

    #[test]
    fn unknown_keys_rejected() {
        let v = parse(r#"{"nodez": 8}"#).unwrap();
        assert!(ExperimentConfig::from_json(&v).is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = ExperimentConfig::default();
        cfg.nodes = 1;
        assert!(cfg.validate().is_err());
        cfg = ExperimentConfig::default();
        cfg.model = "resnet".into();
        assert!(cfg.validate().is_err());
        cfg = ExperimentConfig::default();
        cfg.sharing = "magic".into();
        assert!(cfg.validate().is_err());
        cfg = ExperimentConfig::default();
        cfg.topology = "regular".into();
        assert!(cfg.validate().is_err());
        cfg = ExperimentConfig::default();
        cfg.model = "celeba".into();
        assert!(cfg.validate().is_err()); // dataset mismatch
        cfg = ExperimentConfig::default();
        cfg.runner = "fibers".into();
        assert!(cfg.validate().is_err());
        cfg = ExperimentConfig::default();
        cfg.fold = "tree".into();
        assert!(cfg.validate().is_err()); // serial | tree:<width> only
        cfg = ExperimentConfig::default();
        cfg.fold = "tree:1".into();
        assert!(cfg.validate().is_err()); // width must be >= 2
        cfg = ExperimentConfig::default();
        cfg.fold = "tree:8".into();
        cfg.validate().unwrap();
        cfg = ExperimentConfig::default();
        cfg.param_store = "mmap".into();
        assert!(cfg.validate().is_err()); // owned | shared | paged only
        cfg = ExperimentConfig::default();
        cfg.secure = true;
        cfg.dynamic = true;
        assert!(cfg.validate().is_err()); // secure needs a static graph
        cfg = ExperimentConfig::default();
        cfg.secure = true;
        cfg.sharing = "topk:0.1".into();
        assert!(cfg.validate().is_err()); // secure needs dense sharing
        cfg = ExperimentConfig::default();
        cfg.step_time = "stragglers:2:4".into();
        assert!(cfg.validate().is_err()); // fraction out of range
        cfg = ExperimentConfig::default();
        cfg.link_model = "geo:4".into();
        cfg.runner = "threads".into();
        assert!(cfg.validate().is_err()); // per-link needs the scheduler
        cfg = ExperimentConfig::default();
        cfg.churn_trace = "departures:0.2".into();
        cfg.secure = true;
        assert!(cfg.validate().is_err()); // churn trace vs secure agg
        cfg = ExperimentConfig::default();
        cfg.churn_trace = "sessions:6:3".into();
        cfg.dynamic = true;
        cfg.churn = 0.2;
        assert!(cfg.validate().is_err()); // two churn models at once
        cfg = ExperimentConfig::default();
        cfg.sharing = "choco:0.1:0.5".into();
        cfg.churn_trace = "departures:0.2".into();
        assert!(cfg.validate().is_err()); // choco estimates desync under churn
        cfg = ExperimentConfig::default();
        cfg.sharing = "choco:0.1:0.5".into();
        cfg.dynamic = true;
        assert!(cfg.validate().is_err()); // ...and under changing neighbor sets
    }

    #[test]
    fn async_mode_validation() {
        // Happy path: async + scheduler + scenario axes compose.
        let mut cfg = ExperimentConfig::default();
        cfg.mode = "async_dl".into();
        cfg.deadline = "p90".into();
        cfg.staleness = "linear:3".into();
        cfg.late = "drop".into();
        cfg.step_time = "stragglers:0.125:4".into();
        cfg.link_model = "geo:4".into();
        cfg.churn_trace = "crashes:0.2:10".into();
        cfg.validate().unwrap();

        let base = cfg.clone();
        cfg = base.clone();
        cfg.runner = "threads".into();
        assert!(cfg.validate().is_err()); // scheduler-only
        cfg = base.clone();
        cfg.secure = true;
        assert!(cfg.validate().is_err()); // no secure aggregation
        cfg = base.clone();
        cfg.dynamic = true;
        assert!(cfg.validate().is_err()); // no peer-sampler barrier
        cfg = base.clone();
        cfg.churn_trace = String::new();
        cfg.sharing = "choco:0.1:0.5".into();
        assert!(cfg.validate().is_err()); // choco needs full rounds
        cfg = base.clone();
        cfg.mode = "eventually".into();
        assert!(cfg.validate().is_err()); // unknown mode
        cfg = base.clone();
        cfg.deadline = "whenever".into();
        assert!(cfg.validate().is_err()); // bad deadline spec
        cfg = base.clone();
        cfg.staleness = "exp:2".into();
        assert!(cfg.validate().is_err()); // bad staleness spec
        cfg = base.clone();
        cfg.late = "requeue".into();
        assert!(cfg.validate().is_err()); // bad late policy
        // Crash traces are async-only.
        cfg = ExperimentConfig::default();
        cfg.churn_trace = "crashes:0.2:10".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn trace_modes_validate() {
        let mut cfg = ExperimentConfig::default();
        cfg.trace = "full".into();
        cfg.validate().unwrap();
        cfg.trace = "sample:0.01".into();
        cfg.validate().unwrap();
        cfg.trace = "sample:2".into();
        assert!(cfg.validate().is_err()); // rate out of (0, 1]
        cfg.trace = "verbose".into();
        assert!(cfg.validate().is_err()); // unknown mode
        cfg.trace = "full".into();
        cfg.runner = "threads".into();
        assert!(cfg.validate().is_err()); // scheduler-only
        cfg.trace = "off".into();
        cfg.validate().unwrap(); // off composes with any runner
    }

    #[test]
    fn param_store_modes_validate() {
        // Shared store composes with both runners and with secure mode.
        let mut cfg = ExperimentConfig::default();
        cfg.param_store = "shared".into();
        cfg.validate().unwrap();
        cfg.runner = "threads".into();
        cfg.validate().unwrap();
        cfg.runner = "scheduler".into();
        cfg.secure = true;
        cfg.validate().unwrap();
        // Paged mode validates; a zero page size does not.
        cfg = ExperimentConfig::default();
        cfg.param_store = "paged".into();
        cfg.validate().unwrap();
        cfg.page_size = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn scenario_specs_validate() {
        let mut cfg = ExperimentConfig::default();
        cfg.step_time = "stragglers:0.125:4".into();
        cfg.link_model = "geo:4".into();
        cfg.churn_trace = "sessions:12:3".into();
        cfg.validate().unwrap(); // static + scheduler: the WAN scenario
        cfg.dynamic = true;
        cfg.validate().unwrap(); // dynamic churn traces too
    }

    #[test]
    fn byzantine_spec_validation() {
        // Attacks compose with robust sharing on either runner.
        let mut cfg = ExperimentConfig::default();
        cfg.byzantine = "byzantine:0.2:poison:2".into();
        cfg.sharing = "trimmed_mean:0.2".into();
        cfg.validate().unwrap();
        cfg.runner = "threads".into();
        cfg.validate().unwrap();
        cfg.runner = "scheduler".into();
        cfg.sharing = "coord_median".into();
        cfg.validate().unwrap();
        cfg.sharing = "krum:2".into();
        cfg.byzantine = "byzantine:0.1:collude:3".into();
        cfg.validate().unwrap();
        // Malformed specs fail in validation, not mid-run.
        for bad in ["byzantine:1.5:flood", "byzantine:-0.1:poison:2", "byzantine:0.1:ddos"] {
            cfg = ExperimentConfig::default();
            cfg.byzantine = bad.into();
            assert!(cfg.validate().is_err(), "{bad}");
        }
        // Incompatible subsystems are rejected eagerly.
        cfg = ExperimentConfig::default();
        cfg.byzantine = "byzantine:0.2:flood".into();
        cfg.secure = true;
        assert!(cfg.validate().is_err()); // masks assume honest peers
        cfg = ExperimentConfig::default();
        cfg.byzantine = "byzantine:0.2:poison".into();
        cfg.sharing = "choco:0.1:0.5".into();
        assert!(cfg.validate().is_err()); // error feedback assumes honesty
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("decentra_config_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        let cfg = ExperimentConfig::default();
        std::fs::write(&path, cfg.to_json().pretty()).unwrap();
        assert_eq!(ExperimentConfig::from_file(&path).unwrap(), cfg);
    }
}
