//! Model parameter representation (the paper's *Model* module).
//!
//! Models cross the HLO boundary as one flat f32 vector, so the Rust-side
//! model is a [`ParamVec`] plus whatever extra state a sharing algorithm
//! needs (the paper motivates the Model module exactly as "a place to
//! store additional states", e.g. Choco-SGD's `x_hat` or error residuals —
//! see [`crate::sharing`]).

use crate::rng::Xoshiro256pp;

/// Dense flat parameter vector with the vector ops the DL hot path needs.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamVec {
    data: Vec<f32>,
}

impl ParamVec {
    pub fn zeros(n: usize) -> ParamVec {
        ParamVec { data: vec![0.0; n] }
    }

    pub fn from_vec(data: Vec<f32>) -> ParamVec {
        ParamVec { data }
    }

    /// Random init matching the scale of the python-side He-uniform init;
    /// used only by tests/benches that don't load artifacts.
    pub fn random(n: usize, scale: f32, rng: &mut Xoshiro256pp) -> ParamVec {
        ParamVec {
            data: (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &ParamVec) {
        assert_eq!(self.len(), other.len());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// self *= alpha
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// self += alpha at sparse positions: data[idx] += alpha * val
    pub fn axpy_sparse(&mut self, alpha: f32, sv: &SparseVec) {
        for (&i, &v) in sv.indices.iter().zip(sv.values.iter()) {
            self.data[i as usize] += alpha * v;
        }
    }

    pub fn dot(&self, other: &ParamVec) -> f64 {
        assert_eq!(self.len(), other.len());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum()
    }

    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn linf_norm(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Magnitude of the k-th largest |value| (the TopK threshold).
    /// `k == 0` returns +inf (send nothing); `k >= len` returns 0.
    pub fn topk_threshold(&self, k: usize) -> f32 {
        topk_threshold_of(&self.data, k, &mut Vec::new())
    }

    /// Extract the top-k entries by magnitude as a sparse vector.
    /// Ties at the threshold are broken by index order, and exactly `k`
    /// entries are returned (assuming `k <= len`; NaN coordinates are
    /// never selected — see [`topk_threshold_of`]).
    pub fn topk(&self, k: usize) -> SparseVec {
        let (mut mags, mut indices, mut values) = (Vec::new(), Vec::new(), Vec::new());
        topk_of(&self.data, k, &mut mags, &mut indices, &mut values);
        SparseVec { dim: self.len(), indices, values }
    }

    /// Uniformly sample `k` coordinates (random-sampling sparsification).
    pub fn sample_k(&self, k: usize, rng: &mut Xoshiro256pp) -> SparseVec {
        let k = k.min(self.len());
        let mut idx = rng.sample_indices(self.len(), k);
        idx.sort_unstable();
        SparseVec {
            dim: self.len(),
            values: idx.iter().map(|&i| self.data[i]).collect(),
            indices: idx.into_iter().map(|i| i as u32).collect(),
        }
    }
}

/// Magnitude of the k-th largest |value| over a raw slice, selecting
/// inside `mags` (cleared + refilled — a reusable scratch buffer, so
/// the per-round hot path allocates nothing). NaN-safe: the comparator
/// is [`f32::total_cmp`], under which NaN magnitudes sort above every
/// finite value instead of panicking mid-selection (the old
/// `partial_cmp().unwrap()` comparator aborted the whole run on a
/// single NaN parameter).
pub fn topk_threshold_of(data: &[f32], k: usize, mags: &mut Vec<f32>) -> f32 {
    if k == 0 {
        return f32::INFINITY;
    }
    if k >= data.len() {
        return 0.0;
    }
    mags.clear();
    mags.reserve(data.len());
    mags.extend(data.iter().map(|x| x.abs()));
    // k-th largest = (len - k)-th smallest.
    let pos = mags.len() - k;
    mags.select_nth_unstable_by(pos, |a, b| a.total_cmp(b));
    mags[pos]
}

/// Top-k by |value| over a raw slice into caller-owned buffers
/// (`indices`/`values` cleared + refilled; `mags` is the selection
/// scratch). Same algorithm as [`ParamVec::topk`] — strictly-above
/// threshold first, index-order tie fill, canonical index order — with
/// every O(dim) buffer supplied by the caller. NaN coordinates compare
/// neither above nor equal to the threshold, so they are never
/// selected (and the result may then hold fewer than `k` entries).
pub fn topk_of(
    data: &[f32],
    k: usize,
    mags: &mut Vec<f32>,
    indices: &mut Vec<u32>,
    values: &mut Vec<f32>,
) {
    indices.clear();
    values.clear();
    let k = k.min(data.len());
    if k == 0 {
        return;
    }
    let t = topk_threshold_of(data, k, mags);
    indices.reserve(k);
    values.reserve(k);
    // First pass: strictly above threshold.
    for (i, &v) in data.iter().enumerate() {
        if v.abs() > t && indices.len() < k {
            indices.push(i as u32);
            values.push(v);
        }
    }
    // Second pass: fill with ties at the threshold, then restore
    // canonical index order (tie indices were appended out of order).
    if indices.len() < k {
        let above = indices.len();
        for (i, &v) in data.iter().enumerate() {
            if v.abs() == t {
                indices.push(i as u32);
                values.push(v);
                if indices.len() == k {
                    break;
                }
            }
        }
        if above > 0 {
            let mut pairs: Vec<(u32, f32)> =
                indices.iter().copied().zip(values.iter().copied()).collect();
            pairs.sort_by_key(|(i, _)| *i);
            for (j, (i, v)) in pairs.into_iter().enumerate() {
                indices[j] = i;
                values[j] = v;
            }
        }
    }
}

/// Sparse parameter message: sorted indices + values.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseVec {
    pub dim: usize,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseVec {
    pub fn empty(dim: usize) -> SparseVec {
        SparseVec { dim, indices: Vec::new(), values: Vec::new() }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Densify into a full vector (absent coordinates are zero).
    pub fn to_dense(&self) -> ParamVec {
        let mut out = ParamVec::zeros(self.dim);
        for (&i, &v) in self.indices.iter().zip(self.values.iter()) {
            out.data[i as usize] = v;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pv(v: &[f32]) -> ParamVec {
        ParamVec::from_vec(v.to_vec())
    }

    #[test]
    fn axpy_scale_dot() {
        let mut a = pv(&[1.0, 2.0, 3.0]);
        let b = pv(&[0.5, 0.5, 0.5]);
        a.axpy(2.0, &b);
        assert_eq!(a.as_slice(), &[2.0, 3.0, 4.0]);
        a.scale(0.5);
        assert_eq!(a.as_slice(), &[1.0, 1.5, 2.0]);
        assert!((a.dot(&b) - (0.5 + 0.75 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn norms() {
        let a = pv(&[3.0, -4.0]);
        assert!((a.l2_norm() - 5.0).abs() < 1e-12);
        assert_eq!(a.linf_norm(), 4.0);
    }

    #[test]
    fn topk_selects_largest_magnitudes() {
        let a = pv(&[0.1, -5.0, 3.0, -0.2, 4.0]);
        let s = a.topk(2);
        assert_eq!(s.indices, vec![1, 4]);
        assert_eq!(s.values, vec![-5.0, 4.0]);
        assert_eq!(s.nnz(), 2);
    }

    #[test]
    fn topk_exact_count_with_ties() {
        let a = pv(&[1.0, 1.0, 1.0, 1.0]);
        let s = a.topk(2);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.indices, vec![0, 1]); // index-order tie-break
    }

    #[test]
    fn topk_threshold_edges() {
        let a = pv(&[1.0, 2.0, 3.0]);
        assert_eq!(a.topk_threshold(0), f32::INFINITY);
        assert_eq!(a.topk_threshold(3), 0.0);
        assert_eq!(a.topk_threshold(5), 0.0);
        assert_eq!(a.topk_threshold(1), 3.0);
        assert_eq!(a.topk_threshold(2), 2.0);
    }

    #[test]
    fn topk_tolerates_nan_parameters() {
        // Regression: the selection comparator was
        // `partial_cmp().unwrap()`, which panicked the moment a NaN
        // parameter reached top-k selection (diverged training, bad
        // payload). total_cmp sorts NaN magnitudes above every finite
        // value; NaN coordinates are simply never selected.
        let a = pv(&[0.5, f32::NAN, 2.0, -1.0]);
        assert_eq!(a.topk_threshold(2), 2.0);
        let s = a.topk(2);
        assert!(s.values.iter().all(|v| !v.is_nan()));
        assert_eq!(s.indices, vec![2]);
        assert_eq!(s.values, vec![2.0]);
        // All-NaN never panics either.
        let b = pv(&[f32::NAN, f32::NAN]);
        let t = b.topk_threshold(1);
        assert!(t.is_nan());
        assert_eq!(b.topk(1).nnz(), 0);
    }

    #[test]
    fn topk_of_matches_method_with_dirty_scratch() {
        let a = pv(&[0.1, -5.0, 3.0, -0.2, 4.0, 4.0]);
        let want = a.topk(3);
        let (mut mags, mut idx, mut vals) =
            (vec![9.0f32; 2], vec![7u32; 5], vec![1.0f32]);
        topk_of(a.as_slice(), 3, &mut mags, &mut idx, &mut vals);
        assert_eq!(idx, want.indices);
        assert_eq!(vals, want.values);
    }

    #[test]
    fn topk_full_is_identity_support() {
        let a = pv(&[0.5, -0.1, 0.0, 2.0]);
        let s = a.topk(4);
        assert_eq!(s.nnz(), 4);
        assert_eq!(s.to_dense(), a);
    }

    #[test]
    fn sample_k_distinct_sorted() {
        let mut rng = Xoshiro256pp::new(1);
        let a = ParamVec::random(100, 1.0, &mut rng);
        let s = a.sample_k(10, &mut rng);
        assert_eq!(s.nnz(), 10);
        assert!(s.indices.windows(2).all(|w| w[0] < w[1]));
        for (&i, &v) in s.indices.iter().zip(s.values.iter()) {
            assert_eq!(v, a.as_slice()[i as usize]);
        }
    }

    #[test]
    fn sparse_roundtrip_and_axpy() {
        let sv = SparseVec { dim: 5, indices: vec![1, 3], values: vec![2.0, -1.0] };
        let dense = sv.to_dense();
        assert_eq!(dense.as_slice(), &[0.0, 2.0, 0.0, -1.0, 0.0]);
        let mut acc = ParamVec::zeros(5);
        acc.axpy_sparse(0.5, &sv);
        assert_eq!(acc.as_slice(), &[0.0, 1.0, 0.0, -0.5, 0.0]);
    }

    #[test]
    #[should_panic]
    fn axpy_length_mismatch_panics() {
        let mut a = pv(&[1.0]);
        a.axpy(1.0, &pv(&[1.0, 2.0]));
    }
}
