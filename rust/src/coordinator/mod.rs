//! Experiment coordinator: the Fig 1 "driver" — takes a config, builds
//! the dataset partition / topology / nodes, runs the rounds, collects
//! per-node logs, and aggregates the series the figures plot.
//!
//! In-process mode emulates one-node-one-process as one-node-one-thread
//! over the [`InprocHub`]; the TCP transport drops in for real
//! multi-process deployments (`decentra node` subcommand).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::communication::inproc::InprocHub;
use crate::communication::shaper::NetworkModel;
use crate::config::ExperimentConfig;
use crate::dataset::{generate, DataLoader, Dataset, Partition, SyntheticSpec};
use crate::graph::{from_spec, metropolis_hastings, Graph};
use crate::metrics::{aggregate, NodeLog, SeriesPoint};
use crate::model::ParamVec;
use crate::node::{DlNode, PeerSampler, SecureDlNode, TopologyView};
use crate::rng::{mix_seed, Xoshiro256pp};
use crate::runtime::EngineHandle;
use crate::secure::Masker;
use crate::sharing;
use crate::training::Trainer;
use crate::util::Timer;

/// Everything a finished run produces.
pub struct RunResult {
    pub config: ExperimentConfig,
    pub logs: Vec<NodeLog>,
    pub series: Vec<SeriesPoint>,
    /// Real wall-clock seconds for the whole run.
    pub wall_s: f64,
}

impl RunResult {
    /// Final mean test accuracy.
    pub fn final_accuracy(&self) -> f64 {
        self.series.last().map(|p| p.test_acc.mean).unwrap_or(f64::NAN)
    }

    /// Final mean cumulative bytes sent per node.
    pub fn final_bytes_per_node(&self) -> f64 {
        self.series.last().map(|p| p.bytes_sent.mean).unwrap_or(f64::NAN)
    }

    /// Final emulated wall-clock.
    pub fn final_emu_time(&self) -> f64 {
        self.series.last().map(|p| p.emu_time_s.mean).unwrap_or(f64::NAN)
    }

    /// Persist logs + config + aggregated series under
    /// `results_dir/<name>/`.
    pub fn save(&self) -> Result<std::path::PathBuf> {
        let dir = self.config.results_dir.join(&self.config.name);
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join("config.json"), self.config.to_json().pretty())?;
        for log in &self.logs {
            log.save(&dir)?;
        }
        std::fs::write(
            dir.join("series.txt"),
            crate::metrics::render_series(&self.config.name, &self.series),
        )?;
        Ok(dir)
    }
}

/// Build the synthetic dataset pair for a config.
pub fn build_dataset(cfg: &ExperimentConfig, eval_batch: usize) -> (Dataset, Dataset) {
    // Round the test set up to a whole number of eval batches so the
    // fixed-shape eval executable covers it exactly.
    let test_total = cfg.test_total.div_ceil(eval_batch) * eval_batch;
    let mut spec = match cfg.dataset.as_str() {
        "celebas" => SyntheticSpec::celebas(cfg.image, cfg.train_total, test_total, cfg.seed),
        _ => SyntheticSpec::cifar10s(cfg.image, cfg.train_total, test_total, cfg.seed),
    };
    spec.noise = cfg.noise;
    generate(&spec)
}

/// Run a full experiment in-process. The engine must already host the
/// config's model.
pub fn run_experiment(cfg: &ExperimentConfig, engine: &EngineHandle) -> Result<RunResult> {
    cfg.validate()?;
    let wall = Timer::start();
    let meta = engine.manifest().model(&cfg.model)?.clone();
    if engine.manifest().image != cfg.image {
        bail!(
            "config image {} != artifact image {} (re-run `make artifacts` with --image)",
            cfg.image,
            engine.manifest().image
        );
    }

    // Dataset + partition.
    let (train, test) = build_dataset(cfg, meta.eval_batch);
    let test = Arc::new(test);
    let mut part_rng = Xoshiro256pp::new(mix_seed(&[cfg.seed, 0x9A27]));
    let partition = Partition::from_spec(&cfg.partition)?;
    let shards = partition.split(&train.labels, cfg.nodes, &mut part_rng);

    // Common initial parameters from the artifact.
    let init = meta.load_init()?;

    // Topology.
    let mut topo_rng = Xoshiro256pp::new(mix_seed(&[cfg.seed, 0x7090]));
    let static_graph: Option<(Arc<Graph>, Arc<crate::graph::MixingWeights>)> = if cfg.dynamic {
        None
    } else {
        let g = from_spec(&cfg.topology, cfg.nodes, &mut topo_rng)?;
        let w = metropolis_hastings(&g);
        Some((Arc::new(g), Arc::new(w)))
    };
    if cfg.secure && cfg.dynamic {
        bail!("secure aggregation supports static topologies only");
    }
    if cfg.secure && cfg.sharing != "full" {
        bail!("secure aggregation requires full sharing (masks are dense)");
    }

    // Emulated-clock calibration: one uncontended training step.
    let step_time_s = calibrate_step(engine, cfg, &meta, &train)?;
    let eval_time_s = step_time_s * (test.len() as f64 / meta.train_batch as f64) * 0.4;
    let network = match cfg.network.as_str() {
        "lan" => Some(NetworkModel::lan()),
        "wan" => Some(NetworkModel::wan()),
        _ => None,
    };

    // Transport hub: nodes + (dynamic ? sampler : 0).
    let ranks = cfg.nodes + usize::from(cfg.dynamic);
    let hub = InprocHub::new(ranks);

    // Spawn everything.
    let mut logs: Vec<NodeLog> = Vec::with_capacity(cfg.nodes);
    std::thread::scope(|scope| -> Result<()> {
        let sampler_handle = if cfg.dynamic {
            let sampler = PeerSampler {
                rank: cfg.nodes,
                nodes: cfg.nodes,
                rounds: cfg.rounds,
                spec: cfg.topology.clone(),
                seed: cfg.seed,
                churn: cfg.churn,
                transport: Box::new(hub.endpoint(cfg.nodes)),
            };
            Some(scope.spawn(move || sampler.run()))
        } else {
            None
        };

        let mut handles = Vec::with_capacity(cfg.nodes);
        for id in 0..cfg.nodes {
            let shard = train.subset(&shards[id]);
            let loader = DataLoader::new(
                shard,
                meta.train_batch,
                mix_seed(&[cfg.seed, 0xDA7A, id as u64]),
            );
            let trainer = Trainer::new(
                engine.clone(),
                &cfg.model,
                loader,
                cfg.lr,
                cfg.local_steps,
            )?;
            let transport = Box::new(hub.endpoint(id));
            let test = Arc::clone(&test);
            let init = init.clone();
            if cfg.secure {
                let (g, w) = static_graph.as_ref().unwrap();
                let node = SecureDlNode {
                    id,
                    rounds: cfg.rounds,
                    eval_every: cfg.eval_every,
                    transport,
                    trainer,
                    params: init,
                    graph: Arc::clone(g),
                    weights: Arc::clone(w),
                    masker: Masker::new(id, cfg.seed, cfg.mask_scale),
                    test,
                    network,
                    step_time_s,
                    eval_time_s,
                };
                handles.push(scope.spawn(move || node.run()));
            } else {
                let topology = match &static_graph {
                    Some((_g, w)) => TopologyView::Static {
                        self_weight: w.self_weight(id),
                        neighbors: w.neighbor_weights(id).collect(),
                    },
                    None => TopologyView::Dynamic { sampler_rank: cfg.nodes },
                };
                let mut sharing_impl =
                    sharing::from_spec(&cfg.sharing, meta.param_count, mix_seed(&[cfg.seed, id as u64]))?;
                sharing_impl.set_init(&ParamVec::from_vec(init.clone()));
                let node = DlNode {
                    id,
                    rounds: cfg.rounds,
                    eval_every: cfg.eval_every,
                    transport,
                    trainer,
                    sharing: sharing_impl,
                    params: init,
                    topology,
                    test,
                    network,
                    step_time_s,
                    eval_time_s,
                };
                handles.push(scope.spawn(move || node.run()));
            }
        }
        for h in handles {
            let log = h.join().map_err(|_| anyhow::anyhow!("node thread panicked"))??;
            logs.push(log);
        }
        if let Some(sh) = sampler_handle {
            sh.join()
                .map_err(|_| anyhow::anyhow!("sampler thread panicked"))??;
        }
        Ok(())
    })?;
    hub.shutdown();

    logs.sort_by_key(|l| l.node);
    let series = aggregate(&logs);
    Ok(RunResult {
        config: cfg.clone(),
        logs,
        series,
        wall_s: wall.elapsed().as_secs_f64(),
    })
}

/// Time one uncontended local step for the emulated clock.
fn calibrate_step(
    engine: &EngineHandle,
    cfg: &ExperimentConfig,
    meta: &crate::runtime::ModelMeta,
    train: &Dataset,
) -> Result<f64> {
    let probe = train.subset(&(0..meta.train_batch.min(train.len())).collect::<Vec<_>>());
    let mut loader = DataLoader::new(probe, meta.train_batch, 0);
    let params = meta.load_init()?;
    let batch = loader.next_batch();
    // Warm-up (first call may hit lazy allocation), then measure.
    let (p, _) = engine.train_step(&cfg.model, params, batch.features.clone(), batch.labels.clone(), cfg.lr)?;
    let t = Timer::start();
    let (_, _) = engine.train_step(&cfg.model, p, batch.features, batch.labels, cfg.lr)?;
    Ok(t.elapsed().as_secs_f64())
}
