//! Experiment coordinator: the Fig 1 "driver" — takes a config, builds
//! the dataset partition / topology / nodes, runs the rounds, collects
//! per-node logs, and aggregates the series the figures plot.
//!
//! In-process execution goes through a [`Runner`]:
//!
//! * [`SchedulerRunner`] (default) — the discrete-event virtual-time
//!   scheduler ([`crate::scheduler`]): node logic runs as resumable
//!   state machines on a bounded worker pool (`workers ≈ cores`), so
//!   1000+ node emulations fit on one machine. With
//!   `param_store = "shared"` all model state further lives in one
//!   copy-on-write [`ParamStore`], which is what carries `fig6` to
//!   4096 nodes.
//! * [`ThreadedRunner`] — the legacy one-node-one-thread emulation over
//!   the [`InprocHub`]; also the semantics reference for the scheduler
//!   (the equivalence test pins them to bit-identical results).
//!
//! The TCP transport drops in for real multi-process deployments
//! (`decentra node` subcommand), which keeps the thread-per-node loop.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::communication::inproc::InprocHub;
use crate::communication::shaper::NetworkModel;
use crate::scenario::Scenario;
use crate::config::ExperimentConfig;
use crate::dataset::{generate, DataLoader, Dataset, Partition, SyntheticSpec};
use crate::graph::{from_spec, metropolis_hastings, Graph, MixingWeights};
use crate::metrics::{aggregate, NodeLog, SeriesPoint, Telemetry, TelemetryEvent};
use crate::model::ParamVec;
use crate::node::{AsyncPolicy, DlNode, PeerSampler, SecureDlNode, TopologyView};
use crate::rng::{mix_seed, Xoshiro256pp};
use crate::runtime::{EngineHandle, ModelMeta};
use crate::scheduler::{AsyncDlNodeSm, DlNodeSm, SamplerSm, Scheduler, SecureDlNodeSm};

pub use crate::scheduler::RunControl;
use crate::secure::Masker;
use crate::sharing;
use crate::store::{ParamSlot, ParamStore, StoreReport};
use crate::training::Trainer;
use crate::util::Timer;

/// Everything a finished run produces.
pub struct RunResult {
    pub config: ExperimentConfig,
    pub logs: Vec<NodeLog>,
    pub series: Vec<SeriesPoint>,
    /// Real wall-clock seconds for the whole run.
    pub wall_s: f64,
    /// Model parameter count (benches derive owned-mode memory from it).
    pub param_count: usize,
    /// Store accounting for `param_store = "shared"` **or** `"paged"`
    /// runs (`None` in owned mode). Each report row carries the store
    /// kind so consumers can tell the two apart.
    pub store: Option<StoreReport>,
    /// True when the run was stopped early through its [`RunControl`]
    /// (logs then end at the last completed evaluation round).
    pub cancelled: bool,
}

impl RunResult {
    /// Final mean test accuracy.
    pub fn final_accuracy(&self) -> f64 {
        self.series.last().map(|p| p.test_acc.mean).unwrap_or(f64::NAN)
    }

    /// Final mean cumulative bytes sent per node.
    pub fn final_bytes_per_node(&self) -> f64 {
        self.series.last().map(|p| p.bytes_sent.mean).unwrap_or(f64::NAN)
    }

    /// Final emulated wall-clock.
    pub fn final_emu_time(&self) -> f64 {
        self.series.last().map(|p| p.emu_time_s.mean).unwrap_or(f64::NAN)
    }

    /// Persist logs + config + aggregated series under
    /// `results_dir/<name>/`.
    pub fn save(&self) -> Result<std::path::PathBuf> {
        let dir = self.config.results_dir.join(&self.config.name);
        std::fs::create_dir_all(&dir)?;
        // Remove the previous run's outputs first: a smaller fleet
        // re-run into the same directory would otherwise leave the old
        // run's higher-numbered node_*.jsonl behind, and load_dir would
        // silently aggregate the two runs.
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            let stale = (name.starts_with("node_") && name.ends_with(".jsonl"))
                || name == "store.jsonl"
                || name == "series.txt";
            if stale {
                std::fs::remove_file(&path)?;
            }
        }
        std::fs::write(dir.join("config.json"), self.config.to_json().pretty())?;
        for log in &self.logs {
            log.save(&dir)?;
        }
        std::fs::write(
            dir.join("series.txt"),
            crate::metrics::render_series(&self.config.name, &self.series),
        )?;
        if let Some(report) = &self.store {
            std::fs::write(dir.join("store.jsonl"), report.to_jsonl())?;
        }
        Ok(dir)
    }
}

/// Build the synthetic dataset pair for a config.
pub fn build_dataset(cfg: &ExperimentConfig, eval_batch: usize) -> (Dataset, Dataset) {
    // Round the test set up to a whole number of eval batches so the
    // fixed-shape eval executable covers it exactly.
    let test_total = cfg.test_total.div_ceil(eval_batch) * eval_batch;
    let mut spec = match cfg.dataset.as_str() {
        "celebas" => SyntheticSpec::celebas(cfg.image, cfg.train_total, test_total, cfg.seed),
        _ => SyntheticSpec::cifar10s(cfg.image, cfg.train_total, test_total, cfg.seed),
    };
    spec.noise = cfg.noise;
    generate(&spec)
}

/// Everything both runners need, prepared once per experiment:
/// dataset + shards, common init, static topology, calibrated times,
/// and the resolved heterogeneity/WAN/churn [`Scenario`].
pub struct RunSetup {
    pub meta: ModelMeta,
    pub train: Dataset,
    pub test: Arc<Dataset>,
    pub shards: Vec<Vec<usize>>,
    /// Shared base snapshot of the common model initialization. Runners
    /// either clone it per node (`param_store = "owned"`) or hand it to
    /// a per-run [`ParamStore`] whose nodes copy-on-write from it.
    pub init: Arc<[f32]>,
    pub static_graph: Option<(Arc<Graph>, Arc<MixingWeights>)>,
    pub network: Option<NetworkModel>,
    /// Calibrated seconds per local training step (for the emu clock).
    pub step_time_s: f64,
    /// Eval time estimate per full test pass (emu clock).
    pub eval_time_s: f64,
    /// Heterogeneity/WAN/churn scenario (degenerate by default).
    pub scenario: Scenario,
    /// Per-node step time: `step_time_s` × the scenario's multiplier.
    pub step_times: Vec<f64>,
    /// Per-node eval time, scaled the same way.
    pub eval_times: Vec<f64>,
}

/// Validate the config and prepare the shared run state.
pub fn prepare(cfg: &ExperimentConfig, engine: &EngineHandle) -> Result<RunSetup> {
    cfg.validate()?;
    let meta = engine.manifest().model(&cfg.model)?.clone();
    if engine.manifest().image != cfg.image {
        bail!(
            "config image {} != artifact image {} (re-run `make artifacts` with --image)",
            cfg.image,
            engine.manifest().image
        );
    }

    // Dataset + partition.
    let (train, test) = build_dataset(cfg, meta.eval_batch);
    let test = Arc::new(test);
    let mut part_rng = Xoshiro256pp::new(mix_seed(&[cfg.seed, 0x9A27]));
    let partition = Partition::from_spec(&cfg.partition)?;
    let shards = partition.split(&train.labels, cfg.nodes, &mut part_rng);

    // Common initial parameters from the artifact, held once as the
    // shared base snapshot.
    let init: Arc<[f32]> = meta.load_init()?.into();

    // Topology.
    let mut topo_rng = Xoshiro256pp::new(mix_seed(&[cfg.seed, 0x7090]));
    let static_graph: Option<(Arc<Graph>, Arc<MixingWeights>)> = if cfg.dynamic {
        None
    } else {
        let g = from_spec(&cfg.topology, cfg.nodes, &mut topo_rng)?;
        let w = metropolis_hastings(&g);
        Some((Arc::new(g), Arc::new(w)))
    };
    // (secure+dynamic / secure+sparse combinations are rejected by
    // cfg.validate() above.)

    // Emulated-clock calibration: one uncontended training step.
    let step_time_s = calibrate_step(engine, cfg, &meta, &train)?;
    let eval_time_s = step_time_s * (test.len() as f64 / meta.train_batch as f64) * 0.4;
    let network = match cfg.network.as_str() {
        "lan" => Some(NetworkModel::lan()),
        "wan" => Some(NetworkModel::wan()),
        _ => None,
    };

    // Scenario axes (all degenerate by default): per-node step-time
    // multipliers, per-link delays, availability churn, adversaries.
    let scenario = Scenario::from_specs(
        &cfg.step_time,
        &cfg.link_model,
        &cfg.churn_trace,
        &cfg.byzantine,
        network,
        cfg.nodes,
        cfg.rounds,
        cfg.seed,
    )?;
    let step_times: Vec<f64> =
        (0..cfg.nodes).map(|i| step_time_s * scenario.compute.multiplier(i)).collect();
    let eval_times: Vec<f64> =
        (0..cfg.nodes).map(|i| eval_time_s * scenario.compute.multiplier(i)).collect();

    Ok(RunSetup {
        meta,
        train,
        test,
        shards,
        init,
        static_graph,
        network,
        step_time_s,
        eval_time_s,
        scenario,
        step_times,
        eval_times,
    })
}

/// What a [`Runner`] hands back: per-node logs, the store's accounting
/// report (shared/paged runs), and whether the run was cancelled.
pub struct RunnerOutput {
    pub logs: Vec<NodeLog>,
    pub store: Option<StoreReport>,
    /// True when the runner stopped on its [`RunControl`] instead of
    /// completing every round.
    pub cancelled: bool,
}

/// Hooks a caller threads through a run: a cooperative cancel flag and
/// an optional live telemetry sink. `RunHooks::default()` is inert —
/// never cancelled, no sink — so batch callers pay nothing.
#[derive(Clone, Default)]
pub struct RunHooks {
    /// Cancel flag, checked by the scheduler at event boundaries. The
    /// threaded runner does not support cancellation (its nodes block in
    /// `recv`) and ignores this.
    pub control: RunControl,
    /// Live sink for round/store events ([`TelemetryEvent`]).
    pub telemetry: Option<Telemetry>,
    /// Span recorder for dual-clock tracing ([`crate::trace`]). Only the
    /// scheduler runner honors it; a recorder in mode `off` is ignored.
    pub trace: Option<crate::trace::TraceRecorder>,
}

impl RunHooks {
    /// Emit both phases of a store report into the sink, labeled with
    /// the store kind.
    fn emit_store(&self, report: &Option<StoreReport>) {
        if let (Some(sink), Some(report)) = (&self.telemetry, report) {
            sink.emit(TelemetryEvent::Store {
                phase: "start".into(),
                kind: report.at_start.kind().into(),
                stats: report.at_start,
            });
            sink.emit(TelemetryEvent::Store {
                phase: "end".into(),
                kind: report.at_end.kind().into(),
                stats: report.at_end,
            });
        }
    }
}

/// Strategy for executing the in-process node fleet.
pub trait Runner {
    fn name(&self) -> &'static str;

    /// Run every node to completion (or until `hooks.control` cancels)
    /// and return their logs (any order).
    fn run(
        &self,
        cfg: &ExperimentConfig,
        engine: &EngineHandle,
        setup: &RunSetup,
        hooks: &RunHooks,
    ) -> Result<RunnerOutput>;
}

/// Build the per-run parameter slots: one fresh [`ParamStore`] over the
/// prepared base snapshot in shared/paged mode (a run must never see
/// another run's materialized shards), plain per-node clones otherwise.
fn param_store_for(cfg: &ExperimentConfig, setup: &RunSetup) -> Option<ParamStore> {
    match cfg.param_store.as_str() {
        "shared" => Some(ParamStore::with_base(Arc::clone(&setup.init))),
        "paged" => {
            Some(ParamStore::with_base_paged(Arc::clone(&setup.init), cfg.page_size))
        }
        _ => None,
    }
}

fn param_slot(store: &Option<ParamStore>, setup: &RunSetup) -> ParamSlot {
    match store {
        Some(s) => ParamSlot::stored(s.register()),
        None => ParamSlot::owned(setup.init.to_vec()),
    }
}

/// Resolve a runner spec (`scheduler` | `threads`).
pub fn runner_from_spec(spec: &str, workers: usize) -> Result<Box<dyn Runner>> {
    match spec {
        "scheduler" => Ok(Box::new(SchedulerRunner { workers })),
        "threads" => Ok(Box::new(ThreadedRunner)),
        other => bail!("unknown runner {other:?} (expected scheduler | threads)"),
    }
}

/// Run a full experiment in-process. The engine must already host the
/// config's model. Dispatches to the runner named by `cfg.runner`.
pub fn run_experiment(cfg: &ExperimentConfig, engine: &EngineHandle) -> Result<RunResult> {
    run_experiment_with(cfg, engine, &RunHooks::default())
}

/// [`run_experiment`] with caller-supplied [`RunHooks`]: the `decentra
/// serve` daemon threads its cancel flag and telemetry ring through
/// here. The sink (when present) sees `run_started`, per-round, and
/// store events during the run, then `run_finished` and a close on
/// every exit path — success, cancellation, or error — so SSE consumers
/// never hang on a dead run.
pub fn run_experiment_with(
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    hooks: &RunHooks,
) -> Result<RunResult> {
    let result = run_experiment_inner(cfg, engine, hooks);
    if let Some(sink) = &hooks.telemetry {
        if let Ok(r) = &result {
            sink.emit(TelemetryEvent::RunFinished { cancelled: r.cancelled, wall_s: r.wall_s });
        }
        sink.close();
    }
    result
}

fn run_experiment_inner(
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    hooks: &RunHooks,
) -> Result<RunResult> {
    let wall = Timer::start();
    let setup = prepare(cfg, engine)?;
    if let Some(sink) = &hooks.telemetry {
        sink.emit(TelemetryEvent::RunStarted { nodes: cfg.nodes, rounds: cfg.rounds });
    }
    let runner = runner_from_spec(&cfg.runner, cfg.workers)?;
    let RunnerOutput { mut logs, store, cancelled } = runner.run(cfg, engine, &setup, hooks)?;
    logs.sort_by_key(|l| l.node);
    let series = aggregate(&logs);
    Ok(RunResult {
        config: cfg.clone(),
        logs,
        series,
        wall_s: wall.elapsed().as_secs_f64(),
        param_count: setup.meta.param_count,
        store,
        cancelled,
    })
}

fn build_trainer(
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    setup: &RunSetup,
    id: usize,
) -> Result<Trainer> {
    let shard = setup.train.subset(&setup.shards[id]);
    let loader = DataLoader::new(
        shard,
        setup.meta.train_batch,
        mix_seed(&[cfg.seed, 0xDA7A, id as u64]),
    );
    Trainer::new(engine.clone(), &cfg.model, loader, cfg.lr, cfg.local_steps)
}

/// `init` is the run's one borrowed init `ParamVec` (building a fresh
/// copy per node would reintroduce the O(nodes × params) startup cost
/// the shared store removes; stateful strategies clone what they keep).
fn build_sharing(
    cfg: &ExperimentConfig,
    setup: &RunSetup,
    id: usize,
    init: &ParamVec,
) -> Result<Box<dyn sharing::Sharing>> {
    let mut s = sharing::from_spec(
        &cfg.sharing,
        setup.meta.param_count,
        mix_seed(&[cfg.seed, id as u64]),
    )?;
    s.set_init(init);
    // The fold plan's shape is fixed by the spec alone; workers only
    // bound the executor, so reusing the scheduler's worker budget is
    // safe (bit-identical results at any count).
    let workers = if cfg.workers > 0 {
        cfg.workers
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    s.set_fold(crate::kernels::fold::FoldCtx {
        spec: crate::kernels::fold::FoldSpec::parse(&cfg.fold)?,
        workers,
    });
    Ok(s)
}

fn topology_view(cfg: &ExperimentConfig, setup: &RunSetup, id: usize) -> TopologyView {
    match &setup.static_graph {
        Some((_g, w)) => TopologyView::Static {
            self_weight: w.self_weight(id),
            neighbors: w.neighbor_weights(id).collect(),
        },
        None => TopologyView::Dynamic { sampler_rank: cfg.nodes },
    }
}

/// Discrete-event virtual-time execution: all nodes as state machines on
/// a bounded worker pool. `workers == 0` means "number of cores".
pub struct SchedulerRunner {
    pub workers: usize,
}

impl Runner for SchedulerRunner {
    fn name(&self) -> &'static str {
        "scheduler"
    }

    fn run(
        &self,
        cfg: &ExperimentConfig,
        engine: &EngineHandle,
        setup: &RunSetup,
        hooks: &RunHooks,
    ) -> Result<RunnerOutput> {
        let workers = if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        };
        let store = param_store_for(cfg, setup);
        let init_pv = ParamVec::from_vec(setup.init.to_vec());
        let mut sched = Scheduler::with_links(setup.scenario.links.clone(), workers);
        sched.set_control(hooks.control.clone());
        if let Some(tr) = &hooks.trace {
            sched.set_tracer(tr.clone());
        }
        if let Some(sink) = &hooks.telemetry {
            sched.set_telemetry(sink.clone());
        }
        // Static topologies handle churn traces node-side (each node
        // filters by the shared trace); dynamic ones centrally in the
        // sampler, so the nodes stay trace-unaware there.
        let node_churn = if cfg.dynamic { None } else { setup.scenario.churn.clone() };
        let async_policy = if cfg.mode == "async_dl" {
            Some(AsyncPolicy::from_specs(&cfg.deadline, &cfg.staleness, &cfg.late)?)
        } else {
            None
        };
        for id in 0..cfg.nodes {
            let trainer = build_trainer(cfg, engine, setup, id)?;
            let params = param_slot(&store, setup);
            if let Some(policy) = async_policy {
                // Asynchronous gossip (validation guarantees a static,
                // non-secure topology here).
                let (_g, w) = setup.static_graph.as_ref().unwrap();
                sched.add_node(Box::new(AsyncDlNodeSm::new(
                    id,
                    cfg.rounds,
                    cfg.eval_every,
                    trainer,
                    build_sharing(cfg, setup, id, &init_pv)?,
                    params,
                    w.self_weight(id),
                    w.neighbor_weights(id).collect(),
                    Arc::clone(&setup.test),
                    node_churn.clone(),
                    setup.scenario.byzantine.clone(),
                    setup.step_times[id],
                    setup.eval_times[id],
                    policy,
                )));
            } else if cfg.secure {
                let (g, w) = setup.static_graph.as_ref().unwrap();
                sched.add_node(Box::new(SecureDlNodeSm::new(
                    id,
                    cfg.rounds,
                    cfg.eval_every,
                    trainer,
                    params,
                    Arc::clone(g),
                    Arc::clone(w),
                    Masker::new(id, cfg.seed, cfg.mask_scale),
                    Arc::clone(&setup.test),
                    setup.step_times[id],
                    setup.eval_times[id],
                )));
            } else {
                sched.add_node(Box::new(DlNodeSm::new(
                    id,
                    cfg.rounds,
                    cfg.eval_every,
                    trainer,
                    build_sharing(cfg, setup, id, &init_pv)?,
                    params,
                    topology_view(cfg, setup, id),
                    Arc::clone(&setup.test),
                    node_churn.clone(),
                    setup.scenario.byzantine.clone(),
                    setup.step_times[id],
                    setup.eval_times[id],
                )));
            }
        }
        if cfg.dynamic {
            sched.add_node(Box::new(SamplerSm::new(
                cfg.nodes,
                cfg.nodes,
                cfg.rounds,
                cfg.topology.clone(),
                cfg.seed,
                setup.scenario.availability(cfg.churn),
            )));
        }
        // Time-indexed crashes (a `crashes:` trace): the scheduler kills
        // the node mid-round at its crash instant; neighbors time out.
        if let Some(trace) = &setup.scenario.churn {
            for id in 0..cfg.nodes {
                if let Some(at_s) = trace.crash_time(id) {
                    sched.set_crash_time(id, at_s);
                }
            }
        }
        // Accounting: every node is registered but nothing has trained
        // yet — in shared mode this snapshot stays O(1) in node count.
        let at_start = store.as_ref().map(|s| s.stats());
        sched.run()?;
        let cancelled = sched.was_cancelled();
        let logs = sched.take_logs();
        let report = store.as_ref().map(|s| StoreReport {
            at_start: at_start.unwrap(),
            at_end: s.stats(),
        });
        hooks.emit_store(&report);
        Ok(RunnerOutput { logs, store: report, cancelled })
    }
}

/// Legacy one-node-one-thread emulation over the in-process hub.
pub struct ThreadedRunner;

impl Runner for ThreadedRunner {
    fn name(&self) -> &'static str {
        "threads"
    }

    fn run(
        &self,
        cfg: &ExperimentConfig,
        engine: &EngineHandle,
        setup: &RunSetup,
        hooks: &RunHooks,
    ) -> Result<RunnerOutput> {
        // Transport hub: nodes + (dynamic ? sampler : 0).
        let ranks = cfg.nodes + usize::from(cfg.dynamic);
        let hub = InprocHub::new(ranks);
        let store = param_store_for(cfg, setup);
        // Register every node's slot up front so the `at_start` snapshot
        // means the same thing as on the scheduler runner: whole fleet
        // registered, nothing trained yet.
        let mut slots: Vec<ParamSlot> =
            (0..cfg.nodes).map(|_| param_slot(&store, setup)).collect();
        let at_start = store.as_ref().map(|s| s.stats());
        let init_pv = ParamVec::from_vec(setup.init.to_vec());

        let mut logs: Vec<NodeLog> = Vec::with_capacity(cfg.nodes);
        std::thread::scope(|scope| -> Result<()> {
            let sampler_handle = if cfg.dynamic {
                let sampler = PeerSampler {
                    rank: cfg.nodes,
                    nodes: cfg.nodes,
                    rounds: cfg.rounds,
                    spec: cfg.topology.clone(),
                    seed: cfg.seed,
                    avail: setup.scenario.availability(cfg.churn),
                    transport: Box::new(hub.endpoint(cfg.nodes)),
                };
                Some(scope.spawn(move || sampler.run()))
            } else {
                None
            };

            let mut handles = Vec::with_capacity(cfg.nodes);
            for (id, params) in slots.drain(..).enumerate() {
                let trainer = build_trainer(cfg, engine, setup, id)?;
                let transport = Box::new(hub.endpoint(id));
                let test = Arc::clone(&setup.test);
                if cfg.secure {
                    let (g, w) = setup.static_graph.as_ref().unwrap();
                    let node = SecureDlNode {
                        id,
                        rounds: cfg.rounds,
                        eval_every: cfg.eval_every,
                        transport,
                        trainer,
                        params,
                        graph: Arc::clone(g),
                        weights: Arc::clone(w),
                        masker: Masker::new(id, cfg.seed, cfg.mask_scale),
                        test,
                        network: setup.network,
                        step_time_s: setup.step_times[id],
                        eval_time_s: setup.eval_times[id],
                        telemetry: hooks.telemetry.clone(),
                    };
                    handles.push(scope.spawn(move || node.run()));
                } else {
                    let node = DlNode {
                        id,
                        rounds: cfg.rounds,
                        eval_every: cfg.eval_every,
                        transport,
                        trainer,
                        sharing: build_sharing(cfg, setup, id, &init_pv)?,
                        params,
                        topology: topology_view(cfg, setup, id),
                        test,
                        byz: setup.scenario.byzantine.clone(),
                        network: setup.network,
                        step_time_s: setup.step_times[id],
                        eval_time_s: setup.eval_times[id],
                        telemetry: hooks.telemetry.clone(),
                    };
                    handles.push(scope.spawn(move || node.run()));
                }
            }
            for h in handles {
                let log = h.join().map_err(|_| anyhow::anyhow!("node thread panicked"))??;
                logs.push(log);
            }
            if let Some(sh) = sampler_handle {
                sh.join()
                    .map_err(|_| anyhow::anyhow!("sampler thread panicked"))??;
            }
            Ok(())
        })?;
        hub.shutdown();
        // Threaded nodes are consumed by their threads, so their shard
        // handles are already released here: `at_end` reports zero live
        // shards and the peak is the number that matters.
        let report = store.as_ref().map(|s| StoreReport {
            at_start: at_start.unwrap(),
            at_end: s.stats(),
        });
        hooks.emit_store(&report);
        // Thread-per-node nodes block in recv; cancellation is a
        // scheduler-runner capability.
        Ok(RunnerOutput { logs, store: report, cancelled: false })
    }
}

/// Time one uncontended local step for the emulated clock.
fn calibrate_step(
    engine: &EngineHandle,
    cfg: &ExperimentConfig,
    meta: &crate::runtime::ModelMeta,
    train: &Dataset,
) -> Result<f64> {
    let probe = train.subset(&(0..meta.train_batch.min(train.len())).collect::<Vec<_>>());
    let mut loader = DataLoader::new(probe, meta.train_batch, 0);
    let params = meta.load_init()?;
    let batch = loader.next_batch();
    // Warm-up (first call may hit lazy allocation), then measure.
    let (p, _) =
        engine.train_step(&cfg.model, params, batch.features.clone(), batch.labels.clone(), cfg.lr)?;
    let t = Timer::start();
    let (_, _) = engine.train_step(&cfg.model, p, batch.features, batch.labels, cfg.lr)?;
    Ok(t.elapsed().as_secs_f64())
}
