//! TCP transport: real sockets for multi-process / multi-machine
//! deployment ("the same testbed can run in a cluster environment or on
//! real-world machines over WANs by just configuring the IP address
//! information", paper §2.1).
//!
//! Frames are the same wire encoding as everywhere else, length-delimited
//! by the header's `len` field. One listener thread accepts inbound
//! connections and spawns a reader thread per peer; outbound connections
//! are cached per destination. All inbound messages funnel into one
//! mailbox, preserving per-sender FIFO order (TCP guarantees in-order
//! delivery per connection).

use std::collections::HashMap;
use std::io::{IoSlice, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::{
    encode_envelope_header, Counters, CountersSnapshot, Envelope, Transport,
    WIRE_HEADER_BYTES,
};

/// Shared inbox fed by reader threads.
struct Inbox {
    queue: Mutex<InboxState>,
    signal: Condvar,
}

struct InboxState {
    messages: std::collections::VecDeque<Envelope>,
    open: bool,
}

/// TCP transport endpoint for one node.
pub struct TcpTransport {
    id: usize,
    /// node id -> address of every peer (the mapping module provides it).
    peers: Vec<SocketAddr>,
    inbox: Arc<Inbox>,
    outbound: Mutex<HashMap<usize, TcpStream>>,
    counters: Counters,
    listener_addr: SocketAddr,
}

impl TcpTransport {
    /// Bind `addr` for node `id` and start the acceptor thread.
    ///
    /// `peers[i]` must be the listen address of node `i` (including our
    /// own, which is ignored for sends).
    pub fn bind(id: usize, addr: SocketAddr, peers: Vec<SocketAddr>) -> Result<Arc<TcpTransport>> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr} for node {id}"))?;
        let local = listener.local_addr()?;
        let inbox = Arc::new(Inbox {
            queue: Mutex::new(InboxState {
                messages: std::collections::VecDeque::new(),
                open: true,
            }),
            signal: Condvar::new(),
        });
        let t = Arc::new(TcpTransport {
            id,
            peers,
            inbox: Arc::clone(&inbox),
            outbound: Mutex::new(HashMap::new()),
            counters: Counters::new(),
            listener_addr: local,
        });
        let accept_inbox = Arc::clone(&inbox);
        let counters = t.counters.clone();
        std::thread::Builder::new()
            .name(format!("tcp-accept-{id}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(stream) = stream else { break };
                    let inbox = Arc::clone(&accept_inbox);
                    let counters = counters.clone();
                    std::thread::Builder::new()
                        .name("tcp-reader".into())
                        .spawn(move || {
                            let _ = reader_loop(stream, &inbox, &counters);
                        })
                        .ok();
                    // Stop accepting once the inbox is closed.
                    if !accept_inbox.queue.lock().unwrap().open {
                        break;
                    }
                }
            })
            .context("spawning acceptor")?;
        Ok(t)
    }

    /// The actual bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.listener_addr
    }

    /// Close the inbox; readers drain, receivers observe `None`.
    pub fn shutdown(&self) {
        let mut q = self.inbox.queue.lock().unwrap();
        q.open = false;
        self.inbox.signal.notify_all();
        // Nudge the acceptor loop awake so it can exit.
        drop(q);
        let _ = TcpStream::connect(self.listener_addr);
    }
}

/// Dial `addr` with a bounded exponential-backoff retry loop. Peers in a
/// multi-process deployment start in arbitrary order, so first sends may
/// race the remote listener coming up; retrying here replaces the fixed
/// startup sleep the CLI used to need.
fn connect_with_retry(addr: SocketAddr, total_wait: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + total_wait;
    let mut delay = Duration::from_millis(20);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(e).with_context(|| {
                        format!("connecting to {addr} (gave up after ~{total_wait:?})")
                    });
                }
                // Sleep the backoff, truncated so the budget's final
                // attempt still happens right at the deadline.
                std::thread::sleep(delay.min(deadline - now));
                delay = (delay * 2).min(Duration::from_millis(500));
            }
        }
    }
}

fn reader_loop(mut stream: TcpStream, inbox: &Inbox, counters: &Counters) -> Result<()> {
    loop {
        let mut header = [0u8; WIRE_HEADER_BYTES];
        if read_exact_or_eof(&mut stream, &mut header)? {
            return Ok(()); // clean EOF
        }
        // Payload length is the last header field (see wire.rs layout).
        let len = u32::from_le_bytes(header[WIRE_HEADER_BYTES - 4..].try_into().unwrap()) as usize;
        let mut frame = vec![0u8; WIRE_HEADER_BYTES + len];
        frame[..WIRE_HEADER_BYTES].copy_from_slice(&header);
        stream.read_exact(&mut frame[WIRE_HEADER_BYTES..])?;
        let env = super::decode_envelope(&frame)?;
        counters.on_recv(frame.len());
        let mut q = inbox.queue.lock().unwrap();
        if !q.open {
            return Ok(());
        }
        q.messages.push_back(env);
        inbox.signal.notify_one();
    }
}

/// Write `header ‖ payload` as one frame without first copying them
/// into a contiguous buffer. Vectored writes handle partial progress:
/// while the header is unfinished both slices are offered, afterwards
/// the remaining payload is written directly from the shared buffer.
fn write_frame(stream: &mut TcpStream, header: &[u8], payload: &[u8]) -> std::io::Result<()> {
    let total = header.len() + payload.len();
    let mut written = 0usize;
    while written < total {
        let res = if written < header.len() {
            stream.write_vectored(&[IoSlice::new(&header[written..]), IoSlice::new(payload)])
        } else {
            stream.write(&payload[written - header.len()..])
        };
        let n = match res {
            Ok(n) => n,
            // Retry EINTR like write_all did — aborting here would leave
            // a half-written frame and desync the peer's reader.
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::WriteZero,
                "connection closed mid-frame",
            ));
        }
        written += n;
    }
    Ok(())
}

/// Returns Ok(true) on EOF before any byte, Ok(false) when filled.
fn read_exact_or_eof(stream: &mut TcpStream, buf: &mut [u8]) -> Result<bool> {
    let mut read = 0usize;
    while read < buf.len() {
        let n = stream.read(&mut buf[read..])?;
        if n == 0 {
            if read == 0 {
                return Ok(true);
            }
            bail!("connection closed mid-frame");
        }
        read += n;
    }
    Ok(false)
}

impl Transport for Arc<TcpTransport> {
    fn node_id(&self) -> usize {
        self.id
    }

    fn send(&self, env: Envelope) -> Result<()> {
        if env.dst >= self.peers.len() {
            bail!("send to unknown node {}", env.dst);
        }
        // Header-only encode + vectored write: the payload is the
        // broadcast-shared `Arc<[u8]>`, and it goes on the socket
        // straight from that buffer instead of being copied into a
        // fresh per-recipient frame first.
        let header = encode_envelope_header(&env);
        let wire_bytes = WIRE_HEADER_BYTES + env.payload.len();
        let mut outbound = self.outbound.lock().unwrap();
        let stream = match outbound.entry(env.dst) {
            std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::hash_map::Entry::Vacant(e) => {
                let s = connect_with_retry(self.peers[env.dst], Duration::from_secs(10))
                    .with_context(|| format!("connecting to node {}", env.dst))?;
                s.set_nodelay(true).ok();
                e.insert(s)
            }
        };
        write_frame(stream, &header, &env.payload)?;
        self.counters.on_send(wire_bytes);
        Ok(())
    }

    fn recv(&self) -> Result<Option<Envelope>> {
        let mut q = self.inbox.queue.lock().unwrap();
        loop {
            if let Some(env) = q.messages.pop_front() {
                return Ok(Some(env));
            }
            if !q.open {
                return Ok(None);
            }
            q = self.inbox.signal.wait(q).unwrap();
        }
    }

    fn try_recv(&self) -> Result<Option<Envelope>> {
        let mut q = self.inbox.queue.lock().unwrap();
        Ok(q.messages.pop_front())
    }

    fn note_serialized(&self, bytes: usize) {
        self.counters.on_serialize(bytes);
    }

    fn counters(&self) -> CountersSnapshot {
        self.counters.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::communication::{wire_size, MsgKind};

    fn localhost() -> SocketAddr {
        "127.0.0.1:0".parse().unwrap()
    }

    /// Reserve `n` ephemeral ports, then bind a transport per node with
    /// the full peer table (the tiny release/re-bind race is fine for
    /// loopback tests).
    fn mesh(n: usize) -> Vec<Arc<TcpTransport>> {
        let raw: Vec<(TcpListener, SocketAddr)> = (0..n)
            .map(|_| {
                let l = TcpListener::bind(localhost()).unwrap();
                let a = l.local_addr().unwrap();
                (l, a)
            })
            .collect();
        let table: Vec<SocketAddr> = raw.iter().map(|(_, a)| *a).collect();
        drop(raw);
        (0..n)
            .map(|i| TcpTransport::bind(i, table[i], table.clone()).unwrap())
            .collect()
    }

    fn env(src: usize, dst: usize, round: u64, len: usize) -> Envelope {
        Envelope {
            src,
            dst,
            round,
            kind: MsgKind::Model,
            sent_at_s: 0.25,
            trace: 0,
            payload: vec![7; len].into(),
        }
    }

    #[test]
    fn two_node_roundtrip() {
        let nodes = mesh(2);
        nodes[0].send(env(0, 1, 5, 100)).unwrap();
        let got = nodes[1].recv().unwrap().unwrap();
        assert_eq!(got.src, 0);
        assert_eq!(got.round, 5);
        assert_eq!(got.payload.len(), 100);
        for n in &nodes {
            n.shutdown();
        }
    }

    #[test]
    fn large_frame_and_counters() {
        let nodes = mesh(2);
        let e = env(0, 1, 0, 200_000);
        let expect = wire_size(&e) as u64;
        nodes[0].send(e).unwrap();
        let got = nodes[1].recv().unwrap().unwrap();
        assert_eq!(got.payload.len(), 200_000);
        assert_eq!(nodes[0].counters().bytes_sent, expect);
        assert_eq!(nodes[1].counters().bytes_recv, expect);
        for n in &nodes {
            n.shutdown();
        }
    }

    #[test]
    fn shared_payload_fanout_arrives_intact() {
        // One Arc-backed payload, two recipients: the vectored send path
        // writes from the shared buffer, and both frames decode whole.
        let nodes = mesh(3);
        let payload: crate::communication::Payload = vec![9u8; 50_000].into();
        for dst in [1usize, 2] {
            nodes[0]
                .send(Envelope {
                    src: 0,
                    dst,
                    round: 1,
                    kind: MsgKind::Model,
                    sent_at_s: 0.0,
                    trace: 0,
                    payload: payload.clone(),
                })
                .unwrap();
        }
        for n in &nodes[1..] {
            let got = n.recv().unwrap().unwrap();
            assert_eq!(got.payload.as_slice(), payload.as_slice());
        }
        for n in &nodes {
            n.shutdown();
        }
    }

    #[test]
    fn bidirectional_and_fifo() {
        let nodes = mesh(3);
        for r in 0..20 {
            nodes[0].send(env(0, 2, r, 10)).unwrap();
            nodes[1].send(env(1, 2, r, 10)).unwrap();
        }
        let mut from0 = Vec::new();
        let mut from1 = Vec::new();
        for _ in 0..40 {
            let e = nodes[2].recv().unwrap().unwrap();
            if e.src == 0 {
                from0.push(e.round);
            } else {
                from1.push(e.round);
            }
        }
        assert_eq!(from0, (0..20).collect::<Vec<_>>());
        assert_eq!(from1, (0..20).collect::<Vec<_>>());
        for n in &nodes {
            n.shutdown();
        }
    }

    #[test]
    fn send_retries_until_peer_listener_binds() {
        // Reserve two ports, but bring node 1's listener up LATE: the
        // first send must retry instead of failing (replaces the fixed
        // 500 ms startup sleep in `decentra node`).
        let raw: Vec<(TcpListener, SocketAddr)> = (0..2)
            .map(|_| {
                let l = TcpListener::bind(localhost()).unwrap();
                let a = l.local_addr().unwrap();
                (l, a)
            })
            .collect();
        let table: Vec<SocketAddr> = raw.iter().map(|(_, a)| *a).collect();
        drop(raw);
        let n0 = TcpTransport::bind(0, table[0], table.clone()).unwrap();
        let late_table = table.clone();
        let late = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            TcpTransport::bind(1, late_table[1], late_table.clone()).unwrap()
        });
        n0.send(env(0, 1, 9, 32)).unwrap(); // retries internally
        let n1 = late.join().unwrap();
        let got = n1.recv().unwrap().unwrap();
        assert_eq!(got.round, 9);
        n0.shutdown();
        n1.shutdown();
    }

    #[test]
    fn connect_gives_up_with_clear_error() {
        // A port nobody ever listens on: bounded retry, then error.
        let dead = {
            let l = TcpListener::bind(localhost()).unwrap();
            l.local_addr().unwrap()
        };
        let err = connect_with_retry(dead, Duration::from_millis(120)).unwrap_err();
        assert!(format!("{err:#}").contains("gave up"), "{err:#}");
    }

    #[test]
    fn shutdown_unblocks() {
        let nodes = mesh(1);
        let n0 = Arc::clone(&nodes[0]);
        let t = std::thread::spawn(move || n0.recv().unwrap());
        std::thread::sleep(std::time::Duration::from_millis(30));
        nodes[0].shutdown();
        assert!(t.join().unwrap().is_none());
    }
}
