//! Per-endpoint traffic counters.
//!
//! Figures 3c, 4, and 5 plot *cumulative bytes sent per node*; these
//! counters are the source of truth for that series. Counted bytes are
//! wire bytes (header + payload), identically for both transports.
//!
//! # Serialized vs wire bytes
//!
//! `bytes_sent` is *wire* bytes: a model broadcast to `k` neighbors
//! counts `k ×` (header + payload), because that is what a real
//! deployment puts on the network and what the figures plot. Before the
//! zero-copy broadcast ([`crate::store::Payload`]) the same number also
//! doubled as a proxy for serialization work — effectively counting
//! each payload's construction once per recipient, a k-fold
//! double-count of CPU/memory cost. `bytes_serialized` separates the
//! two: it counts each *built* payload exactly once
//! ([`Counters::on_serialize`], called by the sender when it encodes a
//! model), regardless of how many queues the shared buffer fans out
//! into. Delivered bytes stay per-recipient in `bytes_recv`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared atomic counters (cloneable handle).
#[derive(Debug, Clone, Default)]
pub struct Counters {
    inner: Arc<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    bytes_sent: AtomicU64,
    bytes_recv: AtomicU64,
    msgs_sent: AtomicU64,
    msgs_recv: AtomicU64,
    bytes_serialized: AtomicU64,
}

/// Point-in-time snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CountersSnapshot {
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    pub msgs_sent: u64,
    pub msgs_recv: u64,
    /// Payload bytes this endpoint actually serialized (once per built
    /// payload; broadcast fan-out does not multiply it).
    pub bytes_serialized: u64,
}

impl Counters {
    pub fn new() -> Counters {
        Counters::default()
    }

    pub fn on_send(&self, wire_bytes: usize) {
        self.inner.bytes_sent.fetch_add(wire_bytes as u64, Ordering::Relaxed);
        self.inner.msgs_sent.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_recv(&self, wire_bytes: usize) {
        self.inner.bytes_recv.fetch_add(wire_bytes as u64, Ordering::Relaxed);
        self.inner.msgs_recv.fetch_add(1, Ordering::Relaxed);
    }

    /// One freshly built payload of `payload_bytes` (counted once per
    /// serialization, however many recipients share the buffer).
    pub fn on_serialize(&self, payload_bytes: usize) {
        self.inner
            .bytes_serialized
            .fetch_add(payload_bytes as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CountersSnapshot {
        CountersSnapshot {
            bytes_sent: self.inner.bytes_sent.load(Ordering::Relaxed),
            bytes_recv: self.inner.bytes_recv.load(Ordering::Relaxed),
            msgs_sent: self.inner.msgs_sent.load(Ordering::Relaxed),
            msgs_recv: self.inner.msgs_recv.load(Ordering::Relaxed),
            bytes_serialized: self.inner.bytes_serialized.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let c = Counters::new();
        c.on_send(100);
        c.on_send(50);
        c.on_recv(10);
        let s = c.snapshot();
        assert_eq!(s.bytes_sent, 150);
        assert_eq!(s.msgs_sent, 2);
        assert_eq!(s.bytes_recv, 10);
        assert_eq!(s.msgs_recv, 1);
        assert_eq!(s.bytes_serialized, 0);
    }

    #[test]
    fn serialized_bytes_count_once_per_payload_not_per_recipient() {
        let c = Counters::new();
        // One 100-byte payload broadcast to 4 recipients: serialization
        // counted once, wire bytes per recipient.
        c.on_serialize(100);
        for _ in 0..4 {
            c.on_send(100 + 32);
        }
        let s = c.snapshot();
        assert_eq!(s.bytes_serialized, 100);
        assert_eq!(s.bytes_sent, 4 * 132);
        assert_eq!(s.msgs_sent, 4);
    }

    #[test]
    fn clones_share_state() {
        let c = Counters::new();
        let c2 = c.clone();
        c2.on_send(7);
        assert_eq!(c.snapshot().bytes_sent, 7);
    }

    #[test]
    fn concurrent_updates() {
        let c = Counters::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        h.on_send(1);
                    }
                });
            }
        });
        assert_eq!(c.snapshot().bytes_sent, 4000);
        assert_eq!(c.snapshot().msgs_sent, 4000);
    }
}
