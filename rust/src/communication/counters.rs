//! Per-endpoint traffic counters.
//!
//! Figures 3c, 4, and 5 plot *cumulative bytes sent per node*; these
//! counters are the source of truth for that series. Counted bytes are
//! wire bytes (header + payload), identically for both transports.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared atomic counters (cloneable handle).
#[derive(Debug, Clone, Default)]
pub struct Counters {
    inner: Arc<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    bytes_sent: AtomicU64,
    bytes_recv: AtomicU64,
    msgs_sent: AtomicU64,
    msgs_recv: AtomicU64,
}

/// Point-in-time snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CountersSnapshot {
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    pub msgs_sent: u64,
    pub msgs_recv: u64,
}

impl Counters {
    pub fn new() -> Counters {
        Counters::default()
    }

    pub fn on_send(&self, wire_bytes: usize) {
        self.inner.bytes_sent.fetch_add(wire_bytes as u64, Ordering::Relaxed);
        self.inner.msgs_sent.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_recv(&self, wire_bytes: usize) {
        self.inner.bytes_recv.fetch_add(wire_bytes as u64, Ordering::Relaxed);
        self.inner.msgs_recv.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CountersSnapshot {
        CountersSnapshot {
            bytes_sent: self.inner.bytes_sent.load(Ordering::Relaxed),
            bytes_recv: self.inner.bytes_recv.load(Ordering::Relaxed),
            msgs_sent: self.inner.msgs_sent.load(Ordering::Relaxed),
            msgs_recv: self.inner.msgs_recv.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let c = Counters::new();
        c.on_send(100);
        c.on_send(50);
        c.on_recv(10);
        let s = c.snapshot();
        assert_eq!(s.bytes_sent, 150);
        assert_eq!(s.msgs_sent, 2);
        assert_eq!(s.bytes_recv, 10);
        assert_eq!(s.msgs_recv, 1);
    }

    #[test]
    fn clones_share_state() {
        let c = Counters::new();
        let c2 = c.clone();
        c2.on_send(7);
        assert_eq!(c.snapshot().bytes_sent, 7);
    }

    #[test]
    fn concurrent_updates() {
        let c = Counters::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        h.on_send(1);
                    }
                });
            }
        });
        assert_eq!(c.snapshot().bytes_sent, 4000);
        assert_eq!(c.snapshot().msgs_sent, 4000);
    }
}
