//! In-process transport: one mailbox per node, used to emulate hundreds
//! of nodes as threads on one machine (the scale mode of the paper's
//! evaluation, minus the 16 physical hosts — see DESIGN.md).
//!
//! Semantics match the TCP transport: per-sender FIFO order, non-blocking
//! sends, blocking receives, and wire-byte accounting on both ends.
//!
//! Broadcasts are zero-copy: payloads are [`Payload`] buffers
//! (`Arc<[u8]>`), so staging the same model into every neighbor's queue
//! shares one allocation — the per-recipient duplication that used to
//! dominate threaded-path memory at scale is gone. Accounting follows
//! the split described in [`super::counters`]: `bytes_sent` stays
//! per-recipient wire bytes, while [`Transport::note_serialized`] counts
//! each built payload once.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Result};

use super::{wire_size, Counters, CountersSnapshot, Envelope, Payload, Transport};

struct Mailbox {
    queue: Mutex<MailboxState>,
    signal: Condvar,
}

struct MailboxState {
    messages: VecDeque<Envelope>,
    open: bool,
}

/// Shared hub connecting `n` endpoints.
pub struct InprocHub {
    boxes: Vec<Arc<Mailbox>>,
    counters: Vec<Counters>,
}

impl InprocHub {
    pub fn new(n: usize) -> Arc<InprocHub> {
        Arc::new(InprocHub {
            boxes: (0..n)
                .map(|_| {
                    Arc::new(Mailbox {
                        queue: Mutex::new(MailboxState {
                            messages: VecDeque::new(),
                            open: true,
                        }),
                        signal: Condvar::new(),
                    })
                })
                .collect(),
            counters: (0..n).map(|_| Counters::new()).collect(),
        })
    }

    pub fn len(&self) -> usize {
        self.boxes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.boxes.is_empty()
    }

    /// Create the endpoint for node `id`.
    pub fn endpoint(self: &Arc<Self>, id: usize) -> InprocEndpoint {
        assert!(id < self.len(), "endpoint id out of range");
        InprocEndpoint { hub: Arc::clone(self), id }
    }

    /// Close all mailboxes; blocked receivers drain then observe `None`.
    pub fn shutdown(&self) {
        for b in &self.boxes {
            let mut q = b.queue.lock().unwrap();
            q.open = false;
            b.signal.notify_all();
        }
    }
}

/// One node's handle onto the hub.
pub struct InprocEndpoint {
    hub: Arc<InprocHub>,
    id: usize,
}

impl Transport for InprocEndpoint {
    fn node_id(&self) -> usize {
        self.id
    }

    fn send(&self, env: Envelope) -> Result<()> {
        if env.dst >= self.hub.len() {
            bail!("send to unknown node {}", env.dst);
        }
        let bytes = wire_size(&env);
        let mbox = &self.hub.boxes[env.dst];
        {
            let mut q = mbox.queue.lock().unwrap();
            if !q.open {
                bail!("hub is shut down");
            }
            q.messages.push_back(env);
        }
        mbox.signal.notify_one();
        self.hub.counters[self.id].on_send(bytes);
        Ok(())
    }

    fn recv(&self) -> Result<Option<Envelope>> {
        let mbox = &self.hub.boxes[self.id];
        let mut q = mbox.queue.lock().unwrap();
        loop {
            if let Some(env) = q.messages.pop_front() {
                self.hub.counters[self.id].on_recv(wire_size(&env));
                return Ok(Some(env));
            }
            if !q.open {
                return Ok(None);
            }
            q = mbox.signal.wait(q).unwrap();
        }
    }

    fn try_recv(&self) -> Result<Option<Envelope>> {
        let mbox = &self.hub.boxes[self.id];
        let mut q = mbox.queue.lock().unwrap();
        if let Some(env) = q.messages.pop_front() {
            self.hub.counters[self.id].on_recv(wire_size(&env));
            return Ok(Some(env));
        }
        Ok(None)
    }

    fn note_serialized(&self, bytes: usize) {
        self.hub.counters[self.id].on_serialize(bytes);
    }

    fn counters(&self) -> CountersSnapshot {
        self.hub.counters[self.id].snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::communication::MsgKind;

    fn env(src: usize, dst: usize, round: u64) -> Envelope {
        Envelope {
            src,
            dst,
            round,
            kind: MsgKind::Model,
            sent_at_s: 0.0,
            trace: 0,
            payload: vec![0; 10].into(),
        }
    }

    #[test]
    fn send_recv_roundtrip() {
        let hub = InprocHub::new(2);
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        a.send(env(0, 1, 1)).unwrap();
        let got = b.recv().unwrap().unwrap();
        assert_eq!(got.src, 0);
        assert_eq!(got.round, 1);
    }

    #[test]
    fn per_sender_fifo_order() {
        let hub = InprocHub::new(2);
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        for r in 0..50 {
            a.send(env(0, 1, r)).unwrap();
        }
        for r in 0..50 {
            assert_eq!(b.recv().unwrap().unwrap().round, r);
        }
    }

    #[test]
    fn try_recv_nonblocking() {
        let hub = InprocHub::new(2);
        let b = hub.endpoint(1);
        assert!(b.try_recv().unwrap().is_none());
        hub.endpoint(0).send(env(0, 1, 0)).unwrap();
        assert!(b.try_recv().unwrap().is_some());
        assert!(b.try_recv().unwrap().is_none());
    }

    #[test]
    fn counters_track_wire_bytes() {
        let hub = InprocHub::new(2);
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        let e = env(0, 1, 0);
        let expect = wire_size(&e) as u64;
        a.send(e).unwrap();
        b.recv().unwrap();
        assert_eq!(a.counters().bytes_sent, expect);
        assert_eq!(b.counters().bytes_recv, expect);
        assert_eq!(a.counters().msgs_sent, 1);
    }

    #[test]
    fn broadcast_shares_one_payload_across_queues() {
        // One staged payload, three destinations: every delivered
        // envelope must point at the SAME allocation (zero-copy), and
        // serialization accounting counts the payload once while wire
        // bytes count per recipient.
        let hub = InprocHub::new(4);
        let a = hub.endpoint(0);
        let payload: Payload = vec![42u8; 4096].into();
        a.note_serialized(payload.len());
        for dst in 1..4 {
            a.send(Envelope {
                src: 0,
                dst,
                round: 0,
                kind: MsgKind::Model,
                sent_at_s: 0.0,
                trace: 0,
                payload: payload.clone(),
            })
            .unwrap();
        }
        for dst in 1..4 {
            let got = hub.endpoint(dst).recv().unwrap().unwrap();
            assert!(Payload::ptr_eq(&got.payload, &payload), "copied for {dst}");
        }
        let c = a.counters();
        assert_eq!(c.bytes_serialized, 4096);
        assert_eq!(c.msgs_sent, 3);
        assert!(c.bytes_sent > 3 * 4096); // wire bytes: 3 × (header + payload)
    }

    #[test]
    fn shutdown_unblocks_receivers() {
        let hub = InprocHub::new(1);
        let e = hub.endpoint(0);
        let h = Arc::clone(&hub);
        let t = std::thread::spawn(move || h.endpoint(0).recv().unwrap());
        std::thread::sleep(std::time::Duration::from_millis(20));
        hub.shutdown();
        assert!(t.join().unwrap().is_none());
        assert!(e.send(env(0, 0, 0)).is_err());
    }

    #[test]
    fn shutdown_drains_pending_first() {
        let hub = InprocHub::new(2);
        hub.endpoint(0).send(env(0, 1, 7)).unwrap();
        hub.shutdown();
        let b = hub.endpoint(1);
        assert_eq!(b.recv().unwrap().unwrap().round, 7);
        assert!(b.recv().unwrap().is_none());
    }

    #[test]
    fn send_to_unknown_node_fails() {
        let hub = InprocHub::new(1);
        assert!(hub.endpoint(0).send(env(0, 9, 0)).is_err());
    }

    #[test]
    fn cross_thread_traffic() {
        let hub = InprocHub::new(4);
        std::thread::scope(|s| {
            for id in 0..4usize {
                let hub = Arc::clone(&hub);
                s.spawn(move || {
                    let ep = hub.endpoint(id);
                    // Everyone sends to everyone.
                    for dst in 0..4 {
                        if dst != id {
                            ep.send(env(id, dst, 0)).unwrap();
                        }
                    }
                    // And receives from everyone else.
                    let mut seen = std::collections::HashSet::new();
                    while seen.len() < 3 {
                        let e = ep.recv().unwrap().unwrap();
                        seen.insert(e.src);
                    }
                });
            }
        });
    }
}
