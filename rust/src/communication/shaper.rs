//! Deterministic WAN cost model (network "shaper").
//!
//! The paper's emulation measured real wall-clock on 16 machines; on a
//! single core we instead charge each message a deterministic network
//! cost and advance an **emulated clock** per node. Per round, a node's
//! emulated time advances by
//!
//! ```text
//! compute_time + max(0, serialization) + per-neighbor transfer
//! transfer(bytes) = latency + bytes / bandwidth
//! ```
//!
//! Sends to distinct neighbors share the node's uplink, so a round's
//! upload time is `latency + total_bytes / bandwidth` under the
//! (paper-accurate) assumption that the NIC is the bottleneck, and the
//! round completes when the slowest node's inbound neighbors finish —
//! which the coordinator computes as a max over the graph. This is what
//! reproduces Fig 3b's "fully-connected takes ~3x longer for the same
//! number of rounds" on one machine.
//!
//! The per-round accounting above is the *threaded* path's model. The
//! virtual-time scheduler ([`crate::scheduler`]) applies the same
//! parameters per **message**: sends serialize on the sender's uplink
//! (`bytes / bandwidth_bps`, queuing behind earlier sends) and arrive
//! one `latency_s` later, so delivery order — not just round cost — is
//! network-faithful.
//!
//! # Per-link delays
//!
//! A single [`NetworkModel`] gives every sender the same uplink and
//! every message the same latency. [`LinkMatrix`] generalizes that to a
//! dense `(src, dst)` lookup — each *link* owns a latency and a
//! bandwidth — for geo-distributed WAN scenarios where intra-datacenter
//! and cross-ocean links differ by orders of magnitude.
//! [`LinkModel`] is what the scheduler consumes at delivery
//! timestamping: either the uniform model (bit-identical to PR-1
//! behavior) or a matrix. The sender's uplink stays serial in both
//! cases: a burst queues in staging order, each message transfers at
//! its link's bandwidth and then pays its link's latency.

use std::sync::Arc;

/// Link/host parameters for the emulated network.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// One-way message latency in seconds.
    pub latency_s: f64,
    /// Per-node uplink bandwidth in bytes/second.
    pub bandwidth_bps: f64,
}

impl NetworkModel {
    /// A LAN-ish default (0.5 ms, 1 Gbit/s).
    pub fn lan() -> NetworkModel {
        NetworkModel { latency_s: 0.5e-3, bandwidth_bps: 125e6 }
    }

    /// A WAN-ish default (40 ms, 100 Mbit/s).
    pub fn wan() -> NetworkModel {
        NetworkModel { latency_s: 40e-3, bandwidth_bps: 12.5e6 }
    }

    /// Time to push `bytes` through the uplink once.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Upload time for one round: all messages share the uplink, latency
    /// is pipelined (paid once).
    pub fn round_upload_time(&self, total_bytes: u64) -> f64 {
        self.latency_s + total_bytes as f64 / self.bandwidth_bps
    }
}

/// Heterogeneous fleet: assign each node a network class (paper future
/// work: FedScale-style device heterogeneity). Deterministic per seed.
#[derive(Debug, Clone)]
pub struct HeterogeneousNetwork {
    models: Vec<NetworkModel>,
}

impl HeterogeneousNetwork {
    /// `wan_fraction` of nodes get WAN links, the rest LAN.
    pub fn lan_wan_mix(nodes: usize, wan_fraction: f64, seed: u64) -> HeterogeneousNetwork {
        let mut rng = crate::rng::Xoshiro256pp::new(seed);
        let models = (0..nodes)
            .map(|_| {
                if rng.next_f64() < wan_fraction {
                    NetworkModel::wan()
                } else {
                    NetworkModel::lan()
                }
            })
            .collect();
        HeterogeneousNetwork { models }
    }

    pub fn model_for(&self, node: usize) -> NetworkModel {
        self.models[node % self.models.len().max(1)]
    }

    /// The straggler effect: a synchronous round completes when the
    /// slowest node finishes its upload.
    pub fn round_time(&self, bytes_per_node: u64) -> f64 {
        self.models
            .iter()
            .map(|m| m.round_upload_time(bytes_per_node))
            .fold(0.0, f64::max)
    }
}

/// Dense `(src, dst)` link parameters for WAN scenarios.
///
/// Built by the scenario subsystem ([`crate::scenario`]) from a
/// generator preset (`geo:<clusters>`) or a matrix file, or as a
/// uniform matrix for equivalence testing. Ranks outside the matrix
/// (e.g. the peer sampler's service rank) fall back to LAN-class
/// defaults — coordination traffic is not the modeled bottleneck.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkMatrix {
    n: usize,
    /// Row-major `n * n` one-way latencies in seconds.
    latency_s: Vec<f64>,
    /// Row-major `n * n` link bandwidths in bytes/second.
    bandwidth_bps: Vec<f64>,
}

impl LinkMatrix {
    /// Every link gets `m`'s parameters (reproduces the per-sender
    /// [`NetworkModel`] behavior exactly).
    pub fn uniform(n: usize, m: NetworkModel) -> LinkMatrix {
        LinkMatrix {
            n,
            latency_s: vec![m.latency_s; n * n],
            bandwidth_bps: vec![m.bandwidth_bps; n * n],
        }
    }

    pub fn nodes(&self) -> usize {
        self.n
    }

    /// Set one directed link's parameters.
    pub fn set(&mut self, src: usize, dst: usize, latency_s: f64, bandwidth_bps: f64) {
        assert!(src < self.n && dst < self.n, "link ({src}, {dst}) out of range");
        self.latency_s[src * self.n + dst] = latency_s;
        self.bandwidth_bps[src * self.n + dst] = bandwidth_bps;
    }

    /// `(latency_s, bandwidth_bps)` for the directed link `src -> dst`.
    pub fn link(&self, src: usize, dst: usize) -> (f64, f64) {
        if src >= self.n || dst >= self.n {
            let lan = NetworkModel::lan();
            return (lan.latency_s, lan.bandwidth_bps);
        }
        (self.latency_s[src * self.n + dst], self.bandwidth_bps[src * self.n + dst])
    }

    /// Geo-clustered WAN preset: nodes split into `clusters` contiguous
    /// blocks (datacenters). Intra-cluster links are LAN-class;
    /// inter-cluster links get WAN bandwidth and a per-cluster-pair
    /// latency drawn deterministically in [30 ms, 120 ms], symmetric.
    /// `geo:1` therefore degenerates to a uniform LAN matrix.
    pub fn geo_clustered(n: usize, clusters: usize, seed: u64) -> LinkMatrix {
        let clusters = clusters.max(1).min(n.max(1));
        let lan = NetworkModel::lan();
        let wan = NetworkModel::wan();
        // Symmetric cluster-pair latency table.
        let mut rng = crate::rng::Xoshiro256pp::new(seed);
        let mut pair_latency = vec![0.0f64; clusters * clusters];
        for a in 0..clusters {
            for b in (a + 1)..clusters {
                let l = 0.030 + 0.090 * rng.next_f64();
                pair_latency[a * clusters + b] = l;
                pair_latency[b * clusters + a] = l;
            }
        }
        let cluster_of = |i: usize| i * clusters / n.max(1);
        let mut m = LinkMatrix::uniform(n, lan);
        for src in 0..n {
            for dst in 0..n {
                let (ca, cb) = (cluster_of(src), cluster_of(dst));
                if ca != cb {
                    m.set(src, dst, pair_latency[ca * clusters + cb], wan.bandwidth_bps);
                }
            }
        }
        m
    }

    /// Parse a link file: one `src dst latency_s bandwidth_bps` line per
    /// directed link (`#` comments allowed); unspecified links use
    /// `default`.
    pub fn from_file(path: &str, n: usize, default: NetworkModel) -> anyhow::Result<LinkMatrix> {
        use anyhow::Context;
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading link matrix {path}"))?;
        let mut m = LinkMatrix::uniform(n, default);
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let bad = || format!("{path}:{}: expected `src dst latency_s bandwidth_bps`", i + 1);
            let mut parts = line.split_whitespace();
            let src: usize = parts.next().with_context(bad)?.parse().with_context(bad)?;
            let dst: usize = parts.next().with_context(bad)?.parse().with_context(bad)?;
            let latency_s: f64 = parts.next().with_context(bad)?.parse().with_context(bad)?;
            let bandwidth_bps: f64 = parts.next().with_context(bad)?.parse().with_context(bad)?;
            if src >= n || dst >= n {
                anyhow::bail!("{path}:{}: link ({src}, {dst}) out of range for {n} nodes", i + 1);
            }
            if !(latency_s >= 0.0) || !(bandwidth_bps > 0.0) {
                anyhow::bail!("{path}:{}: latency must be >= 0 and bandwidth > 0", i + 1);
            }
            m.set(src, dst, latency_s, bandwidth_bps);
        }
        Ok(m)
    }

    /// True when every link has identical parameters (the degenerate
    /// matrix; equivalent to a uniform [`NetworkModel`]).
    pub fn is_uniform(&self) -> bool {
        self.latency_s.windows(2).all(|w| w[0] == w[1])
            && self.bandwidth_bps.windows(2).all(|w| w[0] == w[1])
    }
}

/// What the scheduler consumes at delivery timestamping: one model for
/// every link, or a per-link matrix.
#[derive(Debug, Clone)]
pub enum LinkModel {
    /// Every link shares `NetworkModel` parameters (PR-1 behavior).
    Uniform(NetworkModel),
    /// Dense per-link lookup.
    Matrix(Arc<LinkMatrix>),
}

impl LinkModel {
    /// `(latency_s, bandwidth_bps)` for the directed link `src -> dst`.
    #[inline]
    pub fn link(&self, src: usize, dst: usize) -> (f64, f64) {
        match self {
            LinkModel::Uniform(m) => (m.latency_s, m.bandwidth_bps),
            LinkModel::Matrix(m) => m.link(src, dst),
        }
    }
}

/// Per-node emulated clock.
#[derive(Debug, Clone, Default)]
pub struct EmuClock {
    now_s: f64,
}

impl EmuClock {
    pub fn new() -> EmuClock {
        EmuClock { now_s: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now_s
    }

    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative time step");
        self.now_s += dt;
    }

    /// Synchronize to a barrier instant (round end).
    pub fn sync_to(&mut self, t: f64) {
        if t > self.now_s {
            self.now_s = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_components() {
        let m = NetworkModel { latency_s: 0.01, bandwidth_bps: 1000.0 };
        assert!((m.transfer_time(500) - 0.51).abs() < 1e-12);
        assert!((m.transfer_time(0) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn upload_shares_uplink() {
        let m = NetworkModel { latency_s: 0.0, bandwidth_bps: 100.0 };
        // 10 messages of 100B = 1000B -> 10 s, not 10 x (100/100) in parallel.
        assert!((m.round_upload_time(1000) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn denser_topology_costs_more_time() {
        // The Fig 3b mechanism: same payload per neighbor, more neighbors
        // -> proportionally longer upload.
        let m = NetworkModel::lan();
        let per_msg = 200_000u64;
        let ring = m.round_upload_time(2 * per_msg);
        let reg5 = m.round_upload_time(5 * per_msg);
        let full = m.round_upload_time(255 * per_msg);
        assert!(ring < reg5 && reg5 < full);
        assert!(full / reg5 > 10.0);
    }

    #[test]
    fn clock_advances_and_syncs() {
        let mut c = EmuClock::new();
        c.advance(1.5);
        c.sync_to(1.0); // no-op backwards
        assert!((c.now() - 1.5).abs() < 1e-12);
        c.sync_to(2.0);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_mix_deterministic_and_mixed() {
        let h1 = HeterogeneousNetwork::lan_wan_mix(64, 0.5, 9);
        let h2 = HeterogeneousNetwork::lan_wan_mix(64, 0.5, 9);
        let lans = (0..64)
            .filter(|&i| h1.model_for(i) == NetworkModel::lan())
            .count();
        assert!((16..=48).contains(&lans), "{lans} LAN nodes");
        for i in 0..64 {
            assert_eq!(h1.model_for(i), h2.model_for(i));
        }
    }

    #[test]
    fn heterogeneous_round_time_is_straggler_bound() {
        let h = HeterogeneousNetwork::lan_wan_mix(32, 0.25, 3);
        let t = h.round_time(1_000_000);
        // Must equal the WAN upload time (the slowest class present).
        let wan = NetworkModel::wan().round_upload_time(1_000_000);
        assert!((t - wan).abs() < 1e-12);
    }

    #[test]
    fn all_lan_mix_has_lan_round_time() {
        let h = HeterogeneousNetwork::lan_wan_mix(8, 0.0, 1);
        let t = h.round_time(500_000);
        assert!((t - NetworkModel::lan().round_upload_time(500_000)).abs() < 1e-12);
    }

    #[test]
    fn presets_sane() {
        assert!(NetworkModel::wan().latency_s > NetworkModel::lan().latency_s);
        assert!(NetworkModel::wan().bandwidth_bps < NetworkModel::lan().bandwidth_bps);
    }

    #[test]
    fn uniform_matrix_matches_network_model() {
        let net = NetworkModel { latency_s: 0.02, bandwidth_bps: 5e6 };
        let m = LinkMatrix::uniform(4, net);
        assert!(m.is_uniform());
        for src in 0..4 {
            for dst in 0..4 {
                assert_eq!(m.link(src, dst), (net.latency_s, net.bandwidth_bps));
            }
        }
        let lm = LinkModel::Matrix(Arc::new(m));
        assert_eq!(lm.link(1, 2), LinkModel::Uniform(net).link(1, 2));
    }

    #[test]
    fn out_of_range_rank_gets_lan_fallback() {
        let m = LinkMatrix::uniform(2, NetworkModel::wan());
        let lan = NetworkModel::lan();
        // The peer sampler's service rank sits beyond the matrix.
        assert_eq!(m.link(0, 2), (lan.latency_s, lan.bandwidth_bps));
        assert_eq!(m.link(2, 0), (lan.latency_s, lan.bandwidth_bps));
    }

    #[test]
    fn geo_clusters_split_lan_wan() {
        let m = LinkMatrix::geo_clustered(16, 4, 7);
        let lan = NetworkModel::lan();
        // Contiguous blocks of 4: 0 and 1 share a cluster, 0 and 15 don't.
        assert_eq!(m.link(0, 1), (lan.latency_s, lan.bandwidth_bps));
        let (inter_lat, inter_bw) = m.link(0, 15);
        assert!((0.030..=0.120).contains(&inter_lat), "{inter_lat}");
        assert_eq!(inter_bw, NetworkModel::wan().bandwidth_bps);
        // Latencies are symmetric per cluster pair and deterministic.
        assert_eq!(m.link(0, 15), m.link(15, 0));
        assert_eq!(m, LinkMatrix::geo_clustered(16, 4, 7));
        assert!(!m.is_uniform());
    }

    #[test]
    fn geo_single_cluster_is_uniform_lan() {
        let m = LinkMatrix::geo_clustered(8, 1, 3);
        assert!(m.is_uniform());
        assert_eq!(m, LinkMatrix::uniform(8, NetworkModel::lan()));
    }

    #[test]
    fn matrix_file_overrides_defaults() {
        let dir = std::env::temp_dir().join("decentra_link_matrix");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("links.txt");
        std::fs::write(&path, "# slow cross-link\n0 1 0.1 1000\n1 0 0.2 500\n").unwrap();
        let lan = NetworkModel::lan();
        let m = LinkMatrix::from_file(path.to_str().unwrap(), 3, lan).unwrap();
        assert_eq!(m.link(0, 1), (0.1, 1000.0));
        assert_eq!(m.link(1, 0), (0.2, 500.0));
        assert_eq!(m.link(0, 2), (lan.latency_s, lan.bandwidth_bps));
        // Bad lines rejected.
        std::fs::write(&path, "0 9 0.1 1000\n").unwrap();
        assert!(LinkMatrix::from_file(path.to_str().unwrap(), 3, lan).is_err());
        std::fs::write(&path, "0 1 -0.1 1000\n").unwrap();
        assert!(LinkMatrix::from_file(path.to_str().unwrap(), 3, lan).is_err());
    }
}
