//! Deterministic WAN cost model (network "shaper").
//!
//! The paper's emulation measured real wall-clock on 16 machines; on a
//! single core we instead charge each message a deterministic network
//! cost and advance an **emulated clock** per node. Per round, a node's
//! emulated time advances by
//!
//! ```text
//! compute_time + max(0, serialization) + per-neighbor transfer
//! transfer(bytes) = latency + bytes / bandwidth
//! ```
//!
//! Sends to distinct neighbors share the node's uplink, so a round's
//! upload time is `latency + total_bytes / bandwidth` under the
//! (paper-accurate) assumption that the NIC is the bottleneck, and the
//! round completes when the slowest node's inbound neighbors finish —
//! which the coordinator computes as a max over the graph. This is what
//! reproduces Fig 3b's "fully-connected takes ~3x longer for the same
//! number of rounds" on one machine.
//!
//! The per-round accounting above is the *threaded* path's model. The
//! virtual-time scheduler ([`crate::scheduler`]) applies the same
//! parameters per **message**: sends serialize on the sender's uplink
//! (`bytes / bandwidth_bps`, queuing behind earlier sends) and arrive
//! one `latency_s` later, so delivery order — not just round cost — is
//! network-faithful.

/// Link/host parameters for the emulated network.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// One-way message latency in seconds.
    pub latency_s: f64,
    /// Per-node uplink bandwidth in bytes/second.
    pub bandwidth_bps: f64,
}

impl NetworkModel {
    /// A LAN-ish default (0.5 ms, 1 Gbit/s).
    pub fn lan() -> NetworkModel {
        NetworkModel { latency_s: 0.5e-3, bandwidth_bps: 125e6 }
    }

    /// A WAN-ish default (40 ms, 100 Mbit/s).
    pub fn wan() -> NetworkModel {
        NetworkModel { latency_s: 40e-3, bandwidth_bps: 12.5e6 }
    }

    /// Time to push `bytes` through the uplink once.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Upload time for one round: all messages share the uplink, latency
    /// is pipelined (paid once).
    pub fn round_upload_time(&self, total_bytes: u64) -> f64 {
        self.latency_s + total_bytes as f64 / self.bandwidth_bps
    }
}

/// Heterogeneous fleet: assign each node a network class (paper future
/// work: FedScale-style device heterogeneity). Deterministic per seed.
#[derive(Debug, Clone)]
pub struct HeterogeneousNetwork {
    models: Vec<NetworkModel>,
}

impl HeterogeneousNetwork {
    /// `wan_fraction` of nodes get WAN links, the rest LAN.
    pub fn lan_wan_mix(nodes: usize, wan_fraction: f64, seed: u64) -> HeterogeneousNetwork {
        let mut rng = crate::rng::Xoshiro256pp::new(seed);
        let models = (0..nodes)
            .map(|_| {
                if rng.next_f64() < wan_fraction {
                    NetworkModel::wan()
                } else {
                    NetworkModel::lan()
                }
            })
            .collect();
        HeterogeneousNetwork { models }
    }

    pub fn model_for(&self, node: usize) -> NetworkModel {
        self.models[node % self.models.len().max(1)]
    }

    /// The straggler effect: a synchronous round completes when the
    /// slowest node finishes its upload.
    pub fn round_time(&self, bytes_per_node: u64) -> f64 {
        self.models
            .iter()
            .map(|m| m.round_upload_time(bytes_per_node))
            .fold(0.0, f64::max)
    }
}

/// Per-node emulated clock.
#[derive(Debug, Clone, Default)]
pub struct EmuClock {
    now_s: f64,
}

impl EmuClock {
    pub fn new() -> EmuClock {
        EmuClock { now_s: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now_s
    }

    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative time step");
        self.now_s += dt;
    }

    /// Synchronize to a barrier instant (round end).
    pub fn sync_to(&mut self, t: f64) {
        if t > self.now_s {
            self.now_s = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_components() {
        let m = NetworkModel { latency_s: 0.01, bandwidth_bps: 1000.0 };
        assert!((m.transfer_time(500) - 0.51).abs() < 1e-12);
        assert!((m.transfer_time(0) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn upload_shares_uplink() {
        let m = NetworkModel { latency_s: 0.0, bandwidth_bps: 100.0 };
        // 10 messages of 100B = 1000B -> 10 s, not 10 x (100/100) in parallel.
        assert!((m.round_upload_time(1000) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn denser_topology_costs_more_time() {
        // The Fig 3b mechanism: same payload per neighbor, more neighbors
        // -> proportionally longer upload.
        let m = NetworkModel::lan();
        let per_msg = 200_000u64;
        let ring = m.round_upload_time(2 * per_msg);
        let reg5 = m.round_upload_time(5 * per_msg);
        let full = m.round_upload_time(255 * per_msg);
        assert!(ring < reg5 && reg5 < full);
        assert!(full / reg5 > 10.0);
    }

    #[test]
    fn clock_advances_and_syncs() {
        let mut c = EmuClock::new();
        c.advance(1.5);
        c.sync_to(1.0); // no-op backwards
        assert!((c.now() - 1.5).abs() < 1e-12);
        c.sync_to(2.0);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_mix_deterministic_and_mixed() {
        let h1 = HeterogeneousNetwork::lan_wan_mix(64, 0.5, 9);
        let h2 = HeterogeneousNetwork::lan_wan_mix(64, 0.5, 9);
        let lans = (0..64)
            .filter(|&i| h1.model_for(i) == NetworkModel::lan())
            .count();
        assert!((16..=48).contains(&lans), "{lans} LAN nodes");
        for i in 0..64 {
            assert_eq!(h1.model_for(i), h2.model_for(i));
        }
    }

    #[test]
    fn heterogeneous_round_time_is_straggler_bound() {
        let h = HeterogeneousNetwork::lan_wan_mix(32, 0.25, 3);
        let t = h.round_time(1_000_000);
        // Must equal the WAN upload time (the slowest class present).
        let wan = NetworkModel::wan().round_upload_time(1_000_000);
        assert!((t - wan).abs() < 1e-12);
    }

    #[test]
    fn all_lan_mix_has_lan_round_time() {
        let h = HeterogeneousNetwork::lan_wan_mix(8, 0.0, 1);
        let t = h.round_time(500_000);
        assert!((t - NetworkModel::lan().round_upload_time(500_000)).abs() < 1e-12);
    }

    #[test]
    fn presets_sane() {
        assert!(NetworkModel::wan().latency_s > NetworkModel::lan().latency_s);
        assert!(NetworkModel::wan().bandwidth_bps < NetworkModel::lan().bandwidth_bps);
    }
}
