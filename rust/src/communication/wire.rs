//! Binary wire format for envelopes.
//!
//! Frame layout (little-endian):
//! ```text
//! magic   u16  0xDC17
//! version u8   3
//! kind    u8
//! src     u32
//! dst     u32
//! round   u64
//! sent_at f64  sender's virtual send time in seconds (bit pattern)
//! trace   u64  flow id correlating send and delivery (0 = untraced)
//! len     u32  payload byte length
//! payload [u8; len]
//! ```
//! Both transports count `wire_size()` bytes per message, so in-process
//! emulation reports exactly what a TCP deployment would put on the wire.
//!
//! Version 2 added the `sent_at` virtual timestamp: asynchronous gossip
//! weights a received model by its *age*, so the send instant must ride
//! with the message rather than being reconstructed at the receiver.
//! Version 3 added the `trace` flow id ([`crate::trace`]): a gossip
//! hop's send and delivery are paired into one causal flow edge, so the
//! correlation key must survive the wire like `sent_at` does.

use anyhow::{bail, Result};

use super::{Envelope, MsgKind};

pub const WIRE_MAGIC: u16 = 0xDC17;
pub const WIRE_VERSION: u8 = 3;
/// Fixed header size in bytes.
pub const WIRE_HEADER_BYTES: usize = 2 + 1 + 1 + 4 + 4 + 8 + 8 + 8 + 4;

/// Total wire bytes for an envelope.
pub fn wire_size(env: &Envelope) -> usize {
    WIRE_HEADER_BYTES + env.payload.len()
}

/// Encode just the fixed header. The payload rides separately: the TCP
/// transport writes `header ‖ payload` with a vectored write, so the
/// broadcast-shared `Arc<[u8]>` payload is never copied into a
/// per-recipient frame buffer (at degree *k* that copy was *k* full
/// serialized models per round).
pub fn encode_envelope_header(env: &Envelope) -> [u8; WIRE_HEADER_BYTES] {
    let mut out = [0u8; WIRE_HEADER_BYTES];
    out[0..2].copy_from_slice(&WIRE_MAGIC.to_le_bytes());
    out[2] = WIRE_VERSION;
    out[3] = env.kind as u8;
    out[4..8].copy_from_slice(&(env.src as u32).to_le_bytes());
    out[8..12].copy_from_slice(&(env.dst as u32).to_le_bytes());
    out[12..20].copy_from_slice(&env.round.to_le_bytes());
    out[20..28].copy_from_slice(&env.sent_at_s.to_le_bytes());
    out[28..36].copy_from_slice(&env.trace.to_le_bytes());
    out[36..40].copy_from_slice(&(env.payload.len() as u32).to_le_bytes());
    out
}

/// Encode to a fresh buffer (tests, transports without vectored I/O).
pub fn encode_envelope(env: &Envelope) -> Vec<u8> {
    let mut out = Vec::with_capacity(wire_size(env));
    out.extend_from_slice(&encode_envelope_header(env));
    out.extend_from_slice(&env.payload);
    out
}

/// Decode a full frame (exact fit required).
pub fn decode_envelope(bytes: &[u8]) -> Result<Envelope> {
    if bytes.len() < WIRE_HEADER_BYTES {
        bail!("frame too short: {} bytes", bytes.len());
    }
    let magic = u16::from_le_bytes([bytes[0], bytes[1]]);
    if magic != WIRE_MAGIC {
        bail!("bad magic {magic:#06x}");
    }
    if bytes[2] != WIRE_VERSION {
        bail!("unsupported wire version {}", bytes[2]);
    }
    let kind = MsgKind::from_u8(bytes[3])
        .ok_or_else(|| anyhow::anyhow!("unknown message kind {}", bytes[3]))?;
    let src = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let dst = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let round = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
    let sent_at_s = f64::from_le_bytes(bytes[20..28].try_into().unwrap());
    let trace = u64::from_le_bytes(bytes[28..36].try_into().unwrap());
    let len = u32::from_le_bytes(bytes[36..40].try_into().unwrap()) as usize;
    if bytes.len() != WIRE_HEADER_BYTES + len {
        bail!(
            "frame length mismatch: header says {}, have {}",
            WIRE_HEADER_BYTES + len,
            bytes.len()
        );
    }
    Ok(Envelope {
        src,
        dst,
        round,
        kind,
        sent_at_s,
        trace,
        payload: crate::store::Payload::from(&bytes[WIRE_HEADER_BYTES..]),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> Envelope {
        Envelope {
            src: 3,
            dst: 77,
            round: 12345,
            kind: MsgKind::Model,
            sent_at_s: 1.25,
            trace: 9001,
            payload: vec![1, 2, 3, 4, 5].into(),
        }
    }

    #[test]
    fn roundtrip() {
        let e = env();
        let bytes = encode_envelope(&e);
        assert_eq!(bytes.len(), wire_size(&e));
        assert_eq!(decode_envelope(&bytes).unwrap(), e);
    }

    #[test]
    fn empty_payload() {
        let e = Envelope { payload: crate::communication::Payload::empty(), ..env() };
        assert_eq!(decode_envelope(&encode_envelope(&e)).unwrap(), e);
        assert_eq!(wire_size(&e), WIRE_HEADER_BYTES);
    }

    #[test]
    fn rejects_corruption() {
        let e = env();
        let bytes = encode_envelope(&e);
        assert!(decode_envelope(&bytes[..10]).is_err()); // truncated
        let mut bad_magic = bytes.clone();
        bad_magic[0] = 0;
        assert!(decode_envelope(&bad_magic).is_err());
        let mut bad_ver = bytes.clone();
        bad_ver[2] = 9;
        assert!(decode_envelope(&bad_ver).is_err());
        let mut bad_kind = bytes.clone();
        bad_kind[3] = 200;
        assert!(decode_envelope(&bad_kind).is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(decode_envelope(&extra).is_err());
    }

    #[test]
    fn header_size_constant_matches() {
        let e = Envelope { payload: crate::communication::Payload::empty(), ..env() };
        assert_eq!(encode_envelope(&e).len(), WIRE_HEADER_BYTES);
    }

    #[test]
    fn header_only_encode_is_frame_prefix() {
        let e = env();
        let frame = encode_envelope(&e);
        let header = encode_envelope_header(&e);
        assert_eq!(&frame[..WIRE_HEADER_BYTES], &header[..]);
        assert_eq!(&frame[WIRE_HEADER_BYTES..], &e.payload[..]);
    }
}
