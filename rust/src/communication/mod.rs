//! Peer-to-peer communication (the paper's *Communication* module).
//!
//! The paper uses ZeroMQ over TCP with a one-node-one-process design. We
//! provide the same semantics behind a [`Transport`] trait with two
//! implementations:
//!
//! * [`inproc::InprocHub`] — per-node mailboxes over in-process channels,
//!   used for single-machine emulation of hundreds of nodes (one node =
//!   one thread). Byte accounting is identical to the TCP path because
//!   both count the *wire encoding* of every envelope.
//! * [`tcp::TcpTransport`] — length-prefixed frames over `std::net` TCP
//!   sockets, used for real multi-process / multi-machine deployment
//!   (tokio/zmq are unavailable offline; blocking sockets + threads give
//!   the same per-peer ordered async delivery).
//!
//! [`shaper::NetworkModel`] adds a deterministic WAN cost model (latency +
//! bandwidth) so emulated runs can report wall-clock behavior
//! (paper Fig 3b) without 128 physical cores. The thread-per-node path
//! charges it per-round after the fact ([`shaper::EmuClock`]); the
//! virtual-time scheduler ([`crate::scheduler`]) instead uses it to
//! timestamp individual message *deliveries*, so emulated time reflects
//! actual arrival order.

pub mod counters;
pub mod inproc;
pub mod shaper;
pub mod tcp;
mod wire;

pub use counters::{Counters, CountersSnapshot};
pub use wire::{
    decode_envelope, encode_envelope, encode_envelope_header, wire_size, WIRE_HEADER_BYTES,
};

/// Re-exported from [`crate::store`]: the zero-copy payload buffer every
/// envelope carries (serialize once, share across all recipients).
pub use crate::store::Payload;

use anyhow::Result;

/// Message kinds exchanged by nodes. Kept as a flat u8 enum so the wire
/// format stays stable and loggable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgKind {
    /// Model parameters (dense or sparse payload per the sharing module).
    Model = 0,
    /// Secure-aggregation seed exchange.
    SecureSeed = 1,
    /// Peer-sampler topology update: the node's neighbor list for a round.
    Neighbors = 2,
    /// Control: start/stop/barrier.
    Control = 3,
    /// FL: server -> clients global model broadcast.
    FlBroadcast = 4,
    /// FL: client -> server update.
    FlUpdate = 5,
    /// Evaluation/metrics report to the coordinator.
    Report = 6,
}

impl MsgKind {
    pub fn from_u8(v: u8) -> Option<MsgKind> {
        Some(match v {
            0 => MsgKind::Model,
            1 => MsgKind::SecureSeed,
            2 => MsgKind::Neighbors,
            3 => MsgKind::Control,
            4 => MsgKind::FlBroadcast,
            5 => MsgKind::FlUpdate,
            6 => MsgKind::Report,
            _ => return None,
        })
    }
}

/// A routed message.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    pub src: usize,
    pub dst: usize,
    /// Communication round the payload belongs to (nodes buffer messages
    /// for future rounds — neighbors may run slightly ahead).
    pub round: u64,
    pub kind: MsgKind,
    /// Virtual time at which the sender put this message on the wire.
    /// Stamped by the virtual-time scheduler when the send is staged;
    /// `0.0` on transports without a virtual clock (threads / TCP).
    /// Receivers use it to compute a message's *staleness* (its age at
    /// aggregation time) for asynchronous gossip.
    pub sent_at_s: f64,
    /// Trace id correlating this hop's send and delivery into one causal
    /// flow edge ([`crate::trace`]). Stamped by the scheduler when the
    /// send is staged on a sampled round; `0` means untraced.
    pub trace: u64,
    /// Shared immutable bytes: cloning an envelope (or fanning one
    /// payload out to many destinations) never copies the payload.
    pub payload: Payload,
}

/// Point-to-point transport endpoint owned by one node.
///
/// Sends are non-blocking (buffered); `recv` blocks until a message
/// arrives or the hub shuts down.
pub trait Transport: Send {
    fn node_id(&self) -> usize;

    fn send(&self, env: Envelope) -> Result<()>;

    /// Blocking receive; `None` when the transport has been shut down and
    /// drained.
    fn recv(&self) -> Result<Option<Envelope>>;

    /// Non-blocking receive.
    fn try_recv(&self) -> Result<Option<Envelope>>;

    /// Record that the sender just serialized `bytes` of fresh payload.
    /// Called once per *built* payload, not per recipient — the
    /// broadcast fan-out shares one buffer — so `bytes_serialized`
    /// tracks serialization work while `bytes_sent` tracks the wire.
    /// Default is a no-op for transports that keep no counters.
    fn note_serialized(&self, _bytes: usize) {}

    /// Wire-byte and message counters for this endpoint.
    fn counters(&self) -> CountersSnapshot;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msgkind_roundtrip() {
        for k in [
            MsgKind::Model,
            MsgKind::SecureSeed,
            MsgKind::Neighbors,
            MsgKind::Control,
            MsgKind::FlBroadcast,
            MsgKind::FlUpdate,
            MsgKind::Report,
        ] {
            assert_eq!(MsgKind::from_u8(k as u8), Some(k));
        }
        assert_eq!(MsgKind::from_u8(99), None);
    }
}
