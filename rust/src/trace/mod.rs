//! Dual-clock span tracing for the virtual-time scheduler.
//!
//! Every handled scheduler event — train step, encode/outgoing,
//! aggregate, timer, wire delivery — can record a [`Span`] carrying
//! **both clocks**: where the event sits on the deterministic virtual
//! timeline (`virt_start_s`, `virt_dur_s`) and how much real wall time
//! the handler burned (`wall_start_s`, `wall_dur_s`). Virtual fields are
//! bit-identical across worker counts on the same seed; wall fields are
//! the only run-to-run difference, which is what makes traces usable as
//! evidence in performance work: the layout is reproducible, the cost
//! annotations are measured.
//!
//! Gossip hops become **causal flow edges**: when a send is staged the
//! scheduler stamps a fresh flow id into the envelope
//! ([`crate::communication::Envelope::trace`]), records the send point,
//! and on delivery records the receive point. The pair exports as a
//! Chrome `ph:"s"`/`ph:"f"` flow arrow from the sender's track to the
//! receiver's, spanning exactly the shaper delay the link model charged.
//!
//! Spans land in bounded, sharded rings (lossy, with drop accounting).
//! When tracing is off the scheduler holds no recorder at all, so the
//! warm path pays one `Option` check and allocates nothing — the
//! `hotpath_alloc.rs` budget is untouched.
//!
//! Exports:
//! - [`TraceSnapshot::to_chrome_json`]: Chrome/Perfetto `trace.json`,
//!   virtual time as the timeline (µs), wall time in `args`, one thread
//!   track per node, flow events for message hops.
//! - [`TraceSnapshot::to_folded`]: folded stacks (`node;round;phase dur`)
//!   for flamegraph tooling, weighted by wall microseconds.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::metrics::Registry;
use crate::util::json::Json;

/// Round value for spans recorded before the node reported one.
pub const ROUND_NONE: u64 = u64::MAX;

/// Spans are sharded by node id across this many independently locked
/// rings, so recording from the scheduler thread and from worker-pool
/// threads (compute spans) never contends on one lock.
const SPAN_SHARDS: usize = 16;

/// Default ring capacity per shard (spans). Oldest spans are
/// overwritten once a shard fills; see [`TraceRecorder::dropped_spans`].
const DEFAULT_SHARD_CAP: usize = 1 << 16;

/// Histogram buckets for per-phase wall-clock seconds
/// (`decentra_phase_seconds{phase=...}`).
pub const PHASE_BUCKETS: [f64; 10] = [1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0, 10.0, 60.0];

/// Tracing mode parsed from the `trace` config key / `--trace` flag.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceMode {
    /// No recorder attached; zero overhead.
    Off,
    /// Record the given fraction of rounds (deterministic per-round
    /// hash, so both ends of a hop agree on whether it is sampled).
    Sample(f64),
    /// Record every round.
    Full,
}

impl TraceMode {
    /// Parse `"off"`, `"full"`, or `"sample:<rate>"` with
    /// `0 < rate <= 1`.
    pub fn parse(spec: &str) -> Result<TraceMode> {
        match spec {
            "off" => Ok(TraceMode::Off),
            "full" => Ok(TraceMode::Full),
            _ => match spec.strip_prefix("sample:") {
                Some(rate) => {
                    let parsed: f64 = match rate.parse() {
                        Ok(r) => r,
                        Err(_) => bail!("trace sample rate {rate:?} is not a number"),
                    };
                    if !(parsed > 0.0 && parsed <= 1.0) {
                        bail!("trace sample rate must be in (0, 1], got {parsed}");
                    }
                    Ok(TraceMode::Sample(parsed))
                }
                None => {
                    bail!("trace must be \"off\", \"full\", or \"sample:<rate>\", got {spec:?}")
                }
            },
        }
    }
}

/// What a span measures. One label per instrumented phase; these are the
/// stack frames of the folded export and the `phase` label of the
/// `decentra_phase_seconds` histograms.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// The initial `Wake::Start` dispatch.
    Start,
    /// Local training (virtual duration = the modeled step time).
    Train,
    /// Evaluation on the worker pool.
    Eval,
    /// Outgoing payload serialization (encode / `outgoing_pooled`).
    Encode,
    /// Neighbor-model aggregation.
    Aggregate,
    /// The strategy's per-neighbor fold inside an [`Phase::Aggregate`]
    /// span — recorded only when a `tree:<width>` plan actually staged
    /// partial accumulators, so serial rounds add no spans.
    Fold,
    /// Wire delivery of one envelope to its destination node.
    Deliver,
    /// A virtual timer firing (async deadlines, sim step clock).
    Timer,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Start => "start",
            Phase::Train => "train",
            Phase::Eval => "eval",
            Phase::Encode => "encode",
            Phase::Aggregate => "aggregate",
            Phase::Fold => "fold",
            Phase::Deliver => "deliver",
            Phase::Timer => "timer",
        }
    }
}

/// One dual-clock span.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub node: u32,
    /// Round the span belongs to, or [`ROUND_NONE`].
    pub round: u64,
    pub phase: Phase,
    /// Virtual start (seconds on the scheduler clock). Deterministic.
    pub virt_start_s: f64,
    /// Virtual duration. Deterministic (0 for instantaneous handlers).
    pub virt_dur_s: f64,
    /// Wall-clock start, seconds since the recorder was created.
    pub wall_start_s: f64,
    /// Wall-clock cost of the handler.
    pub wall_dur_s: f64,
}

/// One endpoint of a gossip-hop flow edge.
#[derive(Debug, Clone, Copy, PartialEq)]
struct FlowPoint {
    id: u64,
    node: u32,
    round: u64,
    virt_s: f64,
}

/// A paired send → deliver hop, ready for export.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlowEdge {
    pub id: u64,
    pub src: u32,
    pub dst: u32,
    pub round: u64,
    pub send_virt_s: f64,
    pub recv_virt_s: f64,
}

struct Ring {
    spans: Vec<Span>,
    /// Next overwrite slot once the ring is full.
    head: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, cap: usize, s: Span) {
        if self.spans.len() < cap {
            self.spans.push(s);
        } else {
            self.spans[self.head] = s;
            self.head = (self.head + 1) % cap;
            self.dropped += 1;
        }
    }
}

struct FlowBuf {
    sends: Vec<FlowPoint>,
    recvs: Vec<FlowPoint>,
    dropped: u64,
}

struct Inner {
    mode: TraceMode,
    shard_cap: usize,
    epoch: Instant,
    shards: [Mutex<Ring>; SPAN_SHARDS],
    flows: Mutex<FlowBuf>,
    next_flow: AtomicU64,
}

/// Shared handle to a sampling span recorder. Cloning is an `Arc` bump;
/// the scheduler, worker-pool closures, and the serve daemon all hold
/// the same rings.
#[derive(Clone)]
pub struct TraceRecorder {
    inner: Arc<Inner>,
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl TraceRecorder {
    pub fn new(mode: TraceMode) -> TraceRecorder {
        TraceRecorder::with_capacity(mode, DEFAULT_SHARD_CAP)
    }

    /// Recorder with an explicit per-shard span capacity (tests use tiny
    /// rings to exercise the lossy path).
    pub fn with_capacity(mode: TraceMode, shard_cap: usize) -> TraceRecorder {
        let shard_cap = shard_cap.max(1);
        TraceRecorder {
            inner: Arc::new(Inner {
                mode,
                shard_cap,
                epoch: Instant::now(),
                shards: std::array::from_fn(|_| {
                    Mutex::new(Ring { spans: Vec::new(), head: 0, dropped: 0 })
                }),
                flows: Mutex::new(FlowBuf { sends: Vec::new(), recvs: Vec::new(), dropped: 0 }),
                next_flow: AtomicU64::new(1),
            }),
        }
    }

    pub fn mode(&self) -> TraceMode {
        self.inner.mode
    }

    pub fn enabled(&self) -> bool {
        self.inner.mode != TraceMode::Off
    }

    /// Deterministic per-round sampling decision: both the sender and
    /// the receiver of a hop hash the same round number, so flow edges
    /// never dangle because only one side sampled.
    pub fn sampled(&self, round: u64) -> bool {
        match self.inner.mode {
            TraceMode::Off => false,
            TraceMode::Full => true,
            TraceMode::Sample(rate) => {
                let unit = (splitmix64(round) >> 11) as f64 / (1u64 << 53) as f64;
                unit < rate
            }
        }
    }

    /// Wall-clock seconds since the recorder was created.
    pub fn wall_now_s(&self) -> f64 {
        self.inner.epoch.elapsed().as_secs_f64()
    }

    pub fn record(&self, span: Span) {
        let shard = span.node as usize % SPAN_SHARDS;
        let mut ring = self.inner.shards[shard].lock().expect("trace ring poisoned");
        ring.push(self.inner.shard_cap, span);
    }

    /// Allocate a fresh flow id (0 is reserved for "untraced").
    pub fn next_flow_id(&self) -> u64 {
        self.inner.next_flow.fetch_add(1, Ordering::Relaxed)
    }

    pub fn flow_send(&self, id: u64, node: u32, round: u64, virt_s: f64) {
        let mut flows = self.inner.flows.lock().expect("trace flows poisoned");
        if flows.sends.len() < self.inner.shard_cap * SPAN_SHARDS {
            flows.sends.push(FlowPoint { id, node, round, virt_s });
        } else {
            flows.dropped += 1;
        }
    }

    pub fn flow_recv(&self, id: u64, node: u32, round: u64, virt_s: f64) {
        let mut flows = self.inner.flows.lock().expect("trace flows poisoned");
        if flows.recvs.len() < self.inner.shard_cap * SPAN_SHARDS {
            flows.recvs.push(FlowPoint { id, node, round, virt_s });
        } else {
            flows.dropped += 1;
        }
    }

    /// Spans overwritten because a shard ring filled.
    pub fn dropped_spans(&self) -> u64 {
        self.inner
            .shards
            .iter()
            .map(|s| s.lock().expect("trace ring poisoned").dropped)
            .sum()
    }

    pub fn span_count(&self) -> usize {
        self.inner
            .shards
            .iter()
            .map(|s| s.lock().expect("trace ring poisoned").spans.len())
            .sum()
    }

    /// Copy out a consistent, deterministically ordered view of the
    /// recorded spans and paired flow edges.
    pub fn snapshot(&self) -> TraceSnapshot {
        let mut spans: Vec<Span> = Vec::with_capacity(self.span_count());
        let mut dropped_spans = 0;
        for shard in &self.inner.shards {
            let ring = shard.lock().expect("trace ring poisoned");
            spans.extend_from_slice(&ring.spans);
            dropped_spans += ring.dropped;
        }
        spans.sort_by(|a, b| {
            a.virt_start_s
                .total_cmp(&b.virt_start_s)
                .then(a.node.cmp(&b.node))
                .then(a.phase.cmp(&b.phase))
                .then(a.round.cmp(&b.round))
                .then(a.virt_dur_s.total_cmp(&b.virt_dur_s))
        });
        let flows = self.inner.flows.lock().expect("trace flows poisoned");
        let mut by_id: BTreeMap<u64, (Option<FlowPoint>, Option<FlowPoint>)> = BTreeMap::new();
        for s in &flows.sends {
            by_id.entry(s.id).or_insert((None, None)).0 = Some(*s);
        }
        for r in &flows.recvs {
            by_id.entry(r.id).or_insert((None, None)).1 = Some(*r);
        }
        let edges = by_id
            .into_iter()
            .filter_map(|(id, (send, recv))| match (send, recv) {
                (Some(s), Some(r)) => Some(FlowEdge {
                    id,
                    src: s.node,
                    dst: r.node,
                    round: s.round,
                    send_virt_s: s.virt_s,
                    recv_virt_s: r.virt_s,
                }),
                // In-flight at shutdown or dropped by the scheduler
                // (departed/crashed receiver): no edge.
                _ => None,
            })
            .collect();
        TraceSnapshot {
            spans,
            flows: edges,
            dropped_spans,
            dropped_flows: flows.dropped,
        }
    }

    /// Feed every span's wall-clock duration into per-phase histograms
    /// (`decentra_phase_seconds{phase=...}`) on `registry`.
    pub fn observe_phases(&self, registry: &Registry) {
        for span in self.snapshot().spans {
            registry.observe_with(
                "decentra_phase_seconds",
                &format!("phase=\"{}\"", span.phase.name()),
                &PHASE_BUCKETS,
                span.wall_dur_s,
            );
        }
    }
}

/// A consistent copy of a recorder's contents, ordered by virtual time.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSnapshot {
    pub spans: Vec<Span>,
    pub flows: Vec<FlowEdge>,
    pub dropped_spans: u64,
    pub dropped_flows: u64,
}

impl TraceSnapshot {
    /// The virtual half of the trace as an exact text form: one line
    /// per span (`node round phase virt_start_bits virt_dur_bits`) then
    /// one per flow edge. Two runs are trace-deterministic iff their
    /// signatures are byte-identical — wall fields are excluded.
    pub fn virtual_signature(&self) -> String {
        let mut out = String::new();
        for s in &self.spans {
            out.push_str(&format!(
                "span {} {} {} {:016x} {:016x}\n",
                s.node,
                s.round,
                s.phase.name(),
                s.virt_start_s.to_bits(),
                s.virt_dur_s.to_bits()
            ));
        }
        for f in &self.flows {
            out.push_str(&format!(
                "flow {} {} {} {} {:016x} {:016x}\n",
                f.id,
                f.src,
                f.dst,
                f.round,
                f.send_virt_s.to_bits(),
                f.recv_virt_s.to_bits()
            ));
        }
        out
    }

    /// Chrome trace event format (load in Perfetto or `chrome://tracing`):
    /// the virtual clock is the timeline (µs), wall-clock cost rides in
    /// each event's `args`, every node gets its own thread track, and
    /// gossip hops are `ph:"s"` / `ph:"f"` flow pairs.
    pub fn to_chrome_json(&self) -> String {
        let mut events: Vec<Json> = Vec::new();
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(1.0)),
            ("args", Json::obj(vec![("name", Json::str("fleet (virtual time)"))])),
        ]));
        let mut nodes: Vec<u32> = self
            .spans
            .iter()
            .map(|s| s.node)
            .chain(self.flows.iter().flat_map(|f| [f.src, f.dst]))
            .collect();
        nodes.sort_unstable();
        nodes.dedup();
        for &node in &nodes {
            events.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(node as f64)),
                ("args", Json::obj(vec![("name", Json::str(format!("node {node}")))])),
            ]));
        }
        for s in &self.spans {
            let round = if s.round == ROUND_NONE {
                Json::Null
            } else {
                Json::num(s.round as f64)
            };
            events.push(Json::obj(vec![
                ("name", Json::str(s.phase.name())),
                ("cat", Json::str("phase")),
                ("ph", Json::str("X")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(s.node as f64)),
                ("ts", Json::num(s.virt_start_s * 1e6)),
                ("dur", Json::num(s.virt_dur_s * 1e6)),
                (
                    "args",
                    Json::obj(vec![
                        ("round", round),
                        ("wall_start_s", Json::num(s.wall_start_s)),
                        ("wall_dur_s", Json::num(s.wall_dur_s)),
                    ]),
                ),
            ]));
        }
        for f in &self.flows {
            events.push(Json::obj(vec![
                ("name", Json::str("gossip")),
                ("cat", Json::str("hop")),
                ("ph", Json::str("s")),
                ("id", Json::num(f.id as f64)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(f.src as f64)),
                ("ts", Json::num(f.send_virt_s * 1e6)),
                ("args", Json::obj(vec![("round", Json::num(f.round as f64))])),
            ]));
            events.push(Json::obj(vec![
                ("name", Json::str("gossip")),
                ("cat", Json::str("hop")),
                ("ph", Json::str("f")),
                ("bp", Json::str("e")),
                ("id", Json::num(f.id as f64)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(f.dst as f64)),
                ("ts", Json::num(f.recv_virt_s * 1e6)),
                ("args", Json::obj(vec![("round", Json::num(f.round as f64))])),
            ]));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            (
                "otherData",
                Json::obj(vec![
                    ("clock", Json::str("virtual")),
                    ("dropped_spans", Json::num(self.dropped_spans as f64)),
                    ("dropped_flows", Json::num(self.dropped_flows as f64)),
                ]),
            ),
        ])
        .dump()
    }

    /// Folded stacks (`node;round;phase weight`) for flamegraph tooling,
    /// weighted by wall-clock microseconds (what profiling cares about).
    pub fn to_folded(&self) -> String {
        let mut folded: BTreeMap<(u32, u64, Phase), u64> = BTreeMap::new();
        for s in &self.spans {
            let us = (s.wall_dur_s * 1e6).round().max(0.0) as u64;
            *folded.entry((s.node, s.round, s.phase)).or_insert(0) += us;
        }
        let mut out = String::new();
        for ((node, round, phase), us) in folded {
            let round = if round == ROUND_NONE {
                "none".to_string()
            } else {
                round.to_string()
            };
            out.push_str(&format!("node{node};round{round};{} {us}\n", phase.name()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn span(node: u32, round: u64, phase: Phase, virt: f64) -> Span {
        Span {
            node,
            round,
            phase,
            virt_start_s: virt,
            virt_dur_s: 0.5,
            wall_start_s: virt * 2.0,
            wall_dur_s: 1e-4,
        }
    }

    #[test]
    fn mode_parses_and_rejects() {
        assert_eq!(TraceMode::parse("off").unwrap(), TraceMode::Off);
        assert_eq!(TraceMode::parse("full").unwrap(), TraceMode::Full);
        assert_eq!(TraceMode::parse("sample:0.25").unwrap(), TraceMode::Sample(0.25));
        assert!(TraceMode::parse("sample:0").is_err());
        assert!(TraceMode::parse("sample:1.5").is_err());
        assert!(TraceMode::parse("sample:x").is_err());
        assert!(TraceMode::parse("verbose").is_err());
    }

    #[test]
    fn sampling_is_deterministic_and_roughly_calibrated() {
        let rec = TraceRecorder::new(TraceMode::Sample(0.25));
        let a: Vec<bool> = (0..10_000).map(|r| rec.sampled(r)).collect();
        let b: Vec<bool> = (0..10_000).map(|r| rec.sampled(r)).collect();
        assert_eq!(a, b);
        let hits = a.iter().filter(|&&h| h).count();
        assert!((2000..3000).contains(&hits), "hits {hits} far from 25%");
        let full = TraceRecorder::new(TraceMode::Full);
        assert!((0..100).all(|r| full.sampled(r)));
        let off = TraceRecorder::new(TraceMode::Off);
        assert!(!off.enabled());
        assert!((0..100).all(|r| !off.sampled(r)));
    }

    #[test]
    fn ring_is_lossy_with_drop_accounting() {
        let rec = TraceRecorder::with_capacity(TraceMode::Full, 4);
        // All spans target node 0, i.e. one shard of capacity 4.
        for i in 0..10 {
            rec.record(span(0, i, Phase::Deliver, i as f64));
        }
        assert_eq!(rec.span_count(), 4);
        assert_eq!(rec.dropped_spans(), 6);
        let snap = rec.snapshot();
        assert_eq!(snap.spans.len(), 4);
        assert_eq!(snap.dropped_spans, 6);
    }

    #[test]
    fn snapshot_orders_by_virtual_time_and_pairs_flows() {
        let rec = TraceRecorder::new(TraceMode::Full);
        rec.record(span(3, 1, Phase::Aggregate, 2.0));
        rec.record(span(1, 0, Phase::Train, 0.0));
        rec.record(span(2, 0, Phase::Deliver, 1.0));
        let id = rec.next_flow_id();
        rec.flow_send(id, 1, 0, 0.5);
        rec.flow_recv(id, 2, 0, 1.0);
        let dangling = rec.next_flow_id();
        rec.flow_send(dangling, 1, 0, 0.75);
        let snap = rec.snapshot();
        assert_eq!(snap.spans[0].node, 1);
        assert_eq!(snap.spans[1].node, 2);
        assert_eq!(snap.spans[2].node, 3);
        assert_eq!(snap.flows.len(), 1);
        assert_eq!(snap.flows[0].src, 1);
        assert_eq!(snap.flows[0].dst, 2);
        assert!(snap.virtual_signature().contains("flow 1 1 2 0"));
    }

    #[test]
    fn chrome_export_is_valid_and_carries_both_clocks() {
        let rec = TraceRecorder::new(TraceMode::Full);
        rec.record(span(0, 0, Phase::Train, 0.0));
        rec.record(span(1, 0, Phase::Deliver, 1.0));
        let id = rec.next_flow_id();
        rec.flow_send(id, 0, 0, 0.5);
        rec.flow_recv(id, 1, 0, 1.0);
        let doc = parse(&rec.snapshot().to_chrome_json()).unwrap();
        let events = doc.get("traceEvents").as_arr().unwrap();
        let phs: Vec<&str> = events.iter().filter_map(|e| e.get("ph").as_str()).collect();
        assert!(phs.contains(&"M"));
        assert!(phs.contains(&"X"));
        assert!(phs.contains(&"s"));
        assert!(phs.contains(&"f"));
        let x = events
            .iter()
            .find(|e| e.get("ph").as_str() == Some("X"))
            .unwrap();
        assert_eq!(x.get("ts").as_f64(), Some(0.0));
        assert_eq!(x.get("dur").as_f64(), Some(0.5e6));
        assert!(x.get("args").get("wall_dur_s").as_f64().is_some());
        // One thread_name metadata track per node.
        let tracks = events
            .iter()
            .filter(|e| {
                e.get("ph").as_str() == Some("M") && e.get("name").as_str() == Some("thread_name")
            })
            .count();
        assert_eq!(tracks, 2);
    }

    #[test]
    fn folded_stacks_fold_by_node_round_phase() {
        let rec = TraceRecorder::new(TraceMode::Full);
        rec.record(span(0, 0, Phase::Train, 0.0));
        rec.record(span(0, 0, Phase::Train, 1.0));
        rec.record(span(0, ROUND_NONE, Phase::Start, 0.0));
        let folded = rec.snapshot().to_folded();
        assert!(folded.contains("node0;round0;train 200"), "{folded}");
        assert!(folded.contains("node0;roundnone;start 100"), "{folded}");
    }
}
