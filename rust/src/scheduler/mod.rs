//! Discrete-event, virtual-time scheduler: the scale mode that emulates
//! 1000+ nodes on a bounded worker pool (the paper's headline capability
//! without one OS thread per node).
//!
//! # Event model
//!
//! The scheduler owns a **global virtual clock** and a priority queue of
//! timestamped events, physically laid out as **per-worker heap shards**
//! (events land in the shard of their home node, `node % workers`; the
//! drain loop pops the min of the shard heads by `(at, seq)`, so the
//! event order — and the run — is bit-identical for every worker
//! count). Four event kinds exist:
//!
//! * `Start` — a node's first activation at t = 0.
//! * `Deliver` — a message arrival. Delivery timestamps come from the
//!   [`LinkModel`]: each sender owns a serial uplink, so message *k*
//!   of a burst finishes at `max(now, uplink_free) + bytes/bandwidth`
//!   and arrives one latency later; with a per-link matrix
//!   ([`crate::communication::shaper::LinkMatrix`]) the bandwidth and
//!   latency are looked up per `(src, dst)` pair, with a uniform
//!   [`NetworkModel`] every link shares them. Virtual time therefore
//!   reflects the actual arrival *order* under the modeled network —
//!   unlike the thread-per-node path, which only charged an aggregate
//!   per-round upload cost after the fact. Without a network model,
//!   delivery is immediate and ordered by sequence number. Deliveries
//!   addressed to a **departed** node (one that called
//!   [`NodeCtx::depart`], e.g. on a churn-trace departure) are dropped
//!   at pop time and counted in [`Scheduler::dropped_deliveries`].
//! * `ComputeDone` — completion of a node's local compute (training
//!   step(s), evaluation), stamped with the calibrated step time. The
//!   actual computation runs on a **bounded worker pool** (`workers ≈
//!   cores`, not `workers = nodes`); virtual completion time is fixed at
//!   submission, so wall-clock execution order never affects virtual
//!   order.
//! * `Timer` — a node's own alarm, staged with [`NodeCtx::set_timer`]
//!   and delivered as [`Wake::Timer`] at `now + delay`. Timers are
//!   **cancelable** ([`NodeCtx::cancel_timer`]): a canceled timer is
//!   discarded at pop time instead of waking its node. Timers are what
//!   give nodes *deadlines* — the asynchronous gossip state machine
//!   ([`AsyncDlNodeSm`]) aggregates whatever neighbor models arrived
//!   when its per-round deadline timer fires, so a slow or crashed
//!   neighbor can never stall it.
//!
//! # Crashes
//!
//! [`Scheduler::set_crash_time`] registers a virtual instant at which a
//! node fails mid-run (a `crashes:` churn trace). From that instant on
//! the node is treated exactly like a departed node: every event
//! addressed to it — deliveries (counted in
//! [`Scheduler::dropped_deliveries`]), timers, compute completions — is
//! discarded instead of waking it, and the final deadlock check exempts
//! it. Crucially the node itself gets no notification: its neighbors
//! must discover the silence through their own timeouts, which is the
//! behavior the async gossip subsystem exists to model.
//!
//! Nodes are resumable state machines ([`EventNode`]) woken with a
//! [`Wake`]; they react by staging sends and at most one compute job per
//! wake through the [`NodeCtx`]. Determinism: events are totally ordered
//! by `(virtual time, sequence number)`, sequence numbers are assigned
//! by the single scheduler thread, and per-node compute is pure w.r.t.
//! its own state — so two runs of the same configuration produce
//! identical event orders and bit-identical results regardless of worker
//! count (see `rust/tests/scheduler_virtual_time.rs`).
//!
//! Per-sender FIFO (the [`crate::communication::Transport`] contract) is
//! preserved: a sender's messages serialize on its uplink, so later
//! sends never arrive earlier; at equal timestamps the sequence number
//! breaks the tie in staging order.

mod nodes;

pub use nodes::{AsyncDlNodeSm, DlNodeSm, SamplerSm, SecureDlNodeSm};

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering as AtomicOrdering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::communication::shaper::{LinkModel, NetworkModel};
use crate::communication::{wire_size, Counters, CountersSnapshot, Envelope};
use crate::dataset::Dataset;
use crate::metrics::{NodeLog, Telemetry};
use crate::trace::{self, TraceRecorder};
use crate::training::Trainer;

/// Cooperative cancellation handle for a run. Cheap to clone; any clone
/// can [`cancel`](RunControl::cancel) from any thread. The scheduler
/// checks the flag between event dispatches, so a cancelled run stops at
/// an event boundary — and, from every node log's perspective, at a
/// round boundary: logs only ever contain fully completed evaluation
/// rounds.
#[derive(Debug, Clone, Default)]
pub struct RunControl {
    cancelled: Arc<AtomicBool>,
}

impl RunControl {
    pub fn new() -> RunControl {
        RunControl::default()
    }

    /// Request cancellation (idempotent; safe from any thread).
    pub fn cancel(&self) {
        self.cancelled.store(true, AtomicOrdering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(AtomicOrdering::SeqCst)
    }
}

/// Result of a compute job executed on the worker pool. Train/Eval carry
/// the node's [`Trainer`] through the pool and back (a node has at most
/// one job in flight, so ownership round-trips are safe).
#[allow(clippy::large_enum_variant)]
pub enum ComputeOutput {
    Train { trainer: Trainer, params: Vec<f32>, loss: f64 },
    Eval { trainer: Trainer, test_loss: f64, test_acc: f64 },
    /// Free-form output for tests and custom nodes.
    Value(f64),
}

/// A compute job body, run once on a pool worker.
pub type ComputeFn = Box<dyn FnOnce() -> Result<ComputeOutput> + Send>;

/// Why a node is being woken.
#[allow(clippy::large_enum_variant)]
pub enum Wake {
    /// First activation, at virtual t = 0.
    Start,
    /// A message addressed to this node arrived.
    Message(Envelope),
    /// The node's in-flight compute job finished.
    ComputeDone(ComputeOutput),
    /// A timer staged with [`NodeCtx::set_timer`] fired; carries the id
    /// `set_timer` returned.
    Timer(u64),
}

/// A node's window onto the scheduler during one wake.
pub struct NodeCtx {
    /// This node's id (== its transport rank).
    pub id: usize,
    /// The node's virtual clock, already advanced to the wake time.
    pub now_s: f64,
    counters: Counters,
    sends: Vec<Envelope>,
    compute: Option<(f64, ComputeFn)>,
    /// First id handed out by `set_timer` this wake (scheduler-global).
    timer_base: u64,
    /// Delays of timers staged this wake; id = `timer_base + index`.
    timers: Vec<f64>,
    /// Timer ids canceled this wake.
    cancels: Vec<u64>,
    departed: bool,
    /// Present iff a [`TraceRecorder`] is attached to the scheduler.
    trace: Option<TraceCtx>,
}

/// Per-wake tracing state threaded through [`NodeCtx`].
struct TraceCtx {
    rec: TraceRecorder,
    /// Round the node reported via [`NodeCtx::trace_round`]
    /// ([`trace::ROUND_NONE`] until then; deliveries start from the
    /// envelope's round).
    round: u64,
    /// Phase label for a compute job staged this wake.
    compute_phase: trace::Phase,
}

impl NodeCtx {
    /// Stage a message send at the current virtual time. Delivery is
    /// timestamped by the scheduler's network model after the wake; the
    /// envelope's `sent_at_s` is stamped with this node's clock.
    pub fn send(&mut self, env: Envelope) {
        self.sends.push(env);
    }

    /// Stage this wake's compute job: `duration_s` of virtual time, body
    /// executed on the worker pool. At most one job per wake — a second
    /// call is a node-logic bug (the first job would silently vanish),
    /// so it panics in release builds too.
    pub fn start_compute(&mut self, duration_s: f64, f: ComputeFn) {
        assert!(self.compute.is_none(), "one compute job per wake");
        self.compute = Some((duration_s, f));
    }

    /// Arm a timer that wakes this node with [`Wake::Timer`] at
    /// `now + delay_s` of virtual time. Returns the id the wake will
    /// carry; pass it to [`cancel_timer`](NodeCtx::cancel_timer) to
    /// disarm. Negative delays clamp to 0 (fire at the current instant,
    /// after already-queued same-time events).
    pub fn set_timer(&mut self, delay_s: f64) -> u64 {
        let id = self.timer_base + self.timers.len() as u64;
        self.timers.push(delay_s.max(0.0));
        id
    }

    /// Cancel a timer set in this or an earlier wake. Canceling a timer
    /// that already fired (or was never set) is a silent no-op, so state
    /// machines don't need to track firing races.
    pub fn cancel_timer(&mut self, id: u64) {
        self.cancels.push(id);
    }

    /// Record that this node just serialized `bytes` of fresh payload
    /// (once per built payload — the zero-copy broadcast shares one
    /// buffer across recipients, so fan-out must not multiply this;
    /// see [`crate::communication::counters`]). Counted immediately.
    pub fn note_serialized(&self, bytes: usize) {
        self.counters.on_serialize(bytes);
    }

    /// Wire-byte counters for this node (sends staged in *earlier* wakes
    /// are included; the current wake's are counted after it returns).
    pub fn counters(&self) -> CountersSnapshot {
        self.counters.snapshot()
    }

    /// Mark this node as permanently departed (churn-trace departure).
    /// Sends staged in the same wake still go out — a node may push its
    /// last update and leave — but every delivery addressed to it from
    /// now on is dropped instead of waking it.
    pub fn depart(&mut self) {
        self.departed = true;
    }

    /// Report the round this wake belongs to — it labels the wake's
    /// trace spans and drives round-based sampling. No-op (one branch)
    /// when tracing is off.
    pub fn trace_round(&mut self, round: u64) {
        if let Some(tc) = &mut self.trace {
            tc.round = round;
        }
    }

    /// Start a wall-clock measurement for a node-internal phase span
    /// ([`trace::Phase::Encode`], [`trace::Phase::Aggregate`]). Returns
    /// `None` — and costs one branch — when tracing is off.
    pub fn trace_begin(&self) -> Option<std::time::Instant> {
        self.trace.as_ref().map(|_| std::time::Instant::now())
    }

    /// Record a node-internal phase span: virtual instant = this wake's
    /// clock, wall duration measured from the matching
    /// [`trace_begin`](NodeCtx::trace_begin).
    pub fn trace_phase(&self, phase: trace::Phase, started: Option<std::time::Instant>) {
        let (Some(tc), Some(t0)) = (&self.trace, started) else {
            return;
        };
        if !tc.rec.sampled(tc.round) {
            return;
        }
        let wall_dur_s = t0.elapsed().as_secs_f64();
        tc.rec.record(trace::Span {
            node: self.id as u32,
            round: tc.round,
            phase,
            virt_start_s: self.now_s,
            virt_dur_s: 0.0,
            wall_start_s: tc.rec.wall_now_s() - wall_dur_s,
            wall_dur_s,
        });
    }

    /// Label the compute job staged this wake ([`trace::Phase::Train`]
    /// by default; evals pass [`trace::Phase::Eval`]). The span covers
    /// the job's full virtual duration; its wall fields are measured on
    /// the worker that runs it.
    pub fn trace_compute_kind(&mut self, phase: trace::Phase) {
        if let Some(tc) = &mut self.trace {
            tc.compute_phase = phase;
        }
    }
}

/// A resumable node driven by the scheduler.
///
/// Implementations decompose their round loop into explicit states
/// (Train → Broadcast → AwaitModels → Aggregate → Eval) and advance one
/// transition per wake; blocking receives become buffered `pending`
/// maps checked on every `Wake::Message`.
pub trait EventNode {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()>;

    /// True once the node has finished all rounds. The scheduler treats
    /// an empty queue with un-done nodes as a deadlock.
    fn done(&self) -> bool;

    /// Hand over the metric log (nodes that keep none return `None`).
    fn take_log(&mut self) -> Option<NodeLog> {
        None
    }

    /// Offered a live [`Telemetry`] sink by
    /// [`Scheduler::set_telemetry`]. Nodes that keep a [`NodeLog`]
    /// should forward it with [`NodeLog::set_sink`] so completed rounds
    /// stream out as they happen; nodes without logs ignore it.
    fn attach_telemetry(&mut self, _sink: &Telemetry) {}
}

enum EventKind {
    Start { node: usize },
    Deliver { env: Envelope },
    ComputeDone { node: usize, job: u64 },
    Timer { node: usize, timer: u64 },
}

struct Event {
    at: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    /// Total order: virtual time, then staging sequence (unique).
    fn cmp(&self, other: &Self) -> Ordering {
        self.at
            .total_cmp(&other.at)
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

struct Job {
    id: u64,
    body: ComputeFn,
}

/// Bounded pool executing compute jobs off the scheduler thread.
struct WorkerPool {
    job_tx: Option<mpsc::Sender<Job>>,
    res_rx: mpsc::Receiver<(u64, Result<ComputeOutput>)>,
    handles: Vec<std::thread::JoinHandle<()>>,
    stash: HashMap<u64, Result<ComputeOutput>>,
}

impl WorkerPool {
    fn start(workers: usize) -> Result<WorkerPool> {
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) = mpsc::channel();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers.max(1) {
            let rx = Arc::clone(&job_rx);
            let tx = res_tx.clone();
            let h = std::thread::Builder::new()
                .name(format!("sched-worker-{w}"))
                .spawn(move || loop {
                    // Hold the lock only while dequeuing.
                    let job = { rx.lock().unwrap().recv() };
                    let Ok(Job { id, body }) = job else { break };
                    // Convert panics into job errors: an unwinding worker
                    // would otherwise never report, leaving the scheduler
                    // blocked in wait_for while idle workers keep the
                    // result channel open.
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body))
                        .unwrap_or_else(|_| Err(anyhow!("compute job panicked")));
                    if tx.send((id, out)).is_err() {
                        break;
                    }
                })
                .context("spawning scheduler worker")?;
            handles.push(h);
        }
        Ok(WorkerPool { job_tx: Some(job_tx), res_rx, handles, stash: HashMap::new() })
    }

    fn submit(&self, id: u64, body: ComputeFn) -> Result<()> {
        self.job_tx
            .as_ref()
            .expect("pool already shut down")
            .send(Job { id, body })
            .map_err(|_| anyhow!("scheduler worker pool is gone"))
    }

    /// Block until job `id` has a result (stashing other completions).
    fn wait_for(&mut self, id: u64) -> Result<ComputeOutput> {
        if let Some(res) = self.stash.remove(&id) {
            return res;
        }
        loop {
            let (got, res) = self
                .res_rx
                .recv()
                .map_err(|_| anyhow!("all scheduler workers exited (a compute job panicked?)"))?;
            if got == id {
                return res;
            }
            self.stash.insert(got, res);
        }
    }

    fn shutdown(mut self) {
        self.job_tx.take(); // closes the channel; idle workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The discrete-event scheduler. Add nodes in rank order, then [`run`].
///
/// [`run`]: Scheduler::run
pub struct Scheduler {
    links: Option<LinkModel>,
    workers: usize,
    nodes: Vec<Option<Box<dyn EventNode>>>,
    /// Per-worker event heaps, sharded by the event's home node id
    /// (`node % shards.len()`). One global heap serializes every push
    /// and pop through a single `log n`-of-everything structure; at
    /// fleet scale the heap becomes the scheduler's own hot spot.
    /// Sharding keeps each heap `fleet / workers` deep while a
    /// min-of-heads merge frontier preserves the exact `(at, seq)`
    /// total order — `seq` is assigned globally at push, so pop order
    /// is bit-identical to the single-heap scheduler for every worker
    /// count (pinned by the workers-1/4/8 equivalence tests).
    shards: Vec<BinaryHeap<std::cmp::Reverse<Event>>>,
    seq: u64,
    next_job: u64,
    next_timer: u64,
    /// Timer ids with an event still in the queue. Bounds
    /// `canceled_timers`: canceling an already-fired id is a true no-op
    /// instead of a permanent HashSet entry.
    pending_timers: HashSet<u64>,
    canceled_timers: HashSet<u64>,
    node_time: Vec<f64>,
    uplink_free: Vec<f64>,
    counters: Vec<Counters>,
    departed: Vec<bool>,
    /// Virtual instant at which each node crashes (`NAN` = never).
    crash_at: Vec<f64>,
    dropped: u64,
    /// Cooperative cancel flag, checked between event dispatches.
    control: RunControl,
    /// Live sink handed to every node via `EventNode::attach_telemetry`.
    telemetry: Option<Telemetry>,
    /// Span recorder for dual-clock tracing; `None` keeps the warm path
    /// at a single branch per wake.
    tracer: Option<TraceRecorder>,
    was_cancelled: bool,
}

impl Scheduler {
    /// `network = None` means untimed delivery (all events at t = 0, in
    /// staging order); `workers` is the pool size (>= 1 enforced).
    pub fn new(network: Option<NetworkModel>, workers: usize) -> Scheduler {
        Scheduler::with_links(network.map(LinkModel::Uniform), workers)
    }

    /// Like [`new`](Scheduler::new), but with a general [`LinkModel`]
    /// (a per-link matrix for WAN scenarios, or the uniform model).
    pub fn with_links(links: Option<LinkModel>, workers: usize) -> Scheduler {
        let workers = workers.max(1);
        Scheduler {
            links,
            workers,
            nodes: Vec::new(),
            shards: (0..workers).map(|_| BinaryHeap::new()).collect(),
            seq: 0,
            next_job: 0,
            next_timer: 0,
            pending_timers: HashSet::new(),
            canceled_timers: HashSet::new(),
            node_time: Vec::new(),
            uplink_free: Vec::new(),
            counters: Vec::new(),
            departed: Vec::new(),
            crash_at: Vec::new(),
            dropped: 0,
            control: RunControl::default(),
            telemetry: None,
            tracer: None,
            was_cancelled: false,
        }
    }

    /// Install a cancellation handle checked between event dispatches;
    /// see [`RunControl`].
    pub fn set_control(&mut self, control: RunControl) {
        self.control = control;
    }

    /// Stream completed rounds into `sink`: it is attached to every
    /// node already added and to every node added afterwards.
    pub fn set_telemetry(&mut self, sink: Telemetry) {
        for node in self.nodes.iter_mut().flatten() {
            node.attach_telemetry(&sink);
        }
        self.telemetry = Some(sink);
    }

    /// Attach a span recorder ([`crate::trace`]): every dispatched
    /// event records a dual-clock span, staged sends are stamped with
    /// flow ids, and compute jobs report worker-measured wall time. A
    /// recorder in mode `off` is ignored, so the warm path keeps its
    /// zero-cost `None` branch.
    pub fn set_tracer(&mut self, rec: TraceRecorder) {
        if rec.enabled() {
            self.tracer = Some(rec);
        }
    }

    /// True iff the last [`run`](Scheduler::run) stopped on its
    /// [`RunControl`] instead of draining the event queue.
    pub fn was_cancelled(&self) -> bool {
        self.was_cancelled
    }

    /// Register a node; its id (== transport rank) is the add order.
    pub fn add_node(&mut self, mut node: Box<dyn EventNode>) -> usize {
        if let Some(sink) = &self.telemetry {
            node.attach_telemetry(sink);
        }
        let id = self.nodes.len();
        self.nodes.push(Some(node));
        self.node_time.push(0.0);
        self.uplink_free.push(0.0);
        self.counters.push(Counters::new());
        self.departed.push(false);
        self.crash_at.push(f64::NAN);
        id
    }

    /// Schedule `node` to fail-stop at virtual time `at_s` (a `crashes:`
    /// churn trace). The node is not told: from `at_s` on, every event
    /// addressed to it is silently discarded (deliveries are counted in
    /// [`dropped_deliveries`](Scheduler::dropped_deliveries)), and the
    /// end-of-run deadlock check exempts it. Neighbors only notice
    /// through their own timeouts.
    pub fn set_crash_time(&mut self, node: usize, at_s: f64) {
        self.crash_at[node] = at_s;
    }

    /// A node's virtual clock (its last wake time).
    pub fn node_time(&self, id: usize) -> f64 {
        self.node_time[id]
    }

    /// Global virtual time = the furthest any node has progressed.
    pub fn now(&self) -> f64 {
        self.node_time.iter().fold(0.0, |a, &b| a.max(b))
    }

    pub fn counters(&self, id: usize) -> CountersSnapshot {
        self.counters[id].snapshot()
    }

    /// Deliveries dropped because their destination had departed.
    pub fn dropped_deliveries(&self) -> u64 {
        self.dropped
    }

    /// Heap shard an event lives in: keyed by the event's home node so
    /// a node's wakes cluster, independent of who pushed them.
    fn shard_of(&self, kind: &EventKind) -> usize {
        let node = match kind {
            EventKind::Start { node }
            | EventKind::ComputeDone { node, .. }
            | EventKind::Timer { node, .. } => *node,
            EventKind::Deliver { env } => env.dst,
        };
        node % self.shards.len()
    }

    fn push(&mut self, at: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        let shard = self.shard_of(&kind);
        self.shards[shard].push(std::cmp::Reverse(Event { at, seq, kind }));
    }

    /// Pop the globally next event: the minimum of the shard heads by
    /// `(at, seq)`. `seq` is unique across shards, so the total order —
    /// and therefore the run — is identical for every shard count.
    fn pop_next(&mut self) -> Option<Event> {
        let mut best: Option<(usize, f64, u64)> = None;
        for (i, heap) in self.shards.iter().enumerate() {
            if let Some(std::cmp::Reverse(ev)) = heap.peek() {
                let better = match best {
                    None => true,
                    Some((_, at, seq)) => {
                        ev.at.total_cmp(&at).then(ev.seq.cmp(&seq)) == Ordering::Less
                    }
                };
                if better {
                    best = Some((i, ev.at, ev.seq));
                }
            }
        }
        let (i, _, _) = best?;
        self.shards[i].pop().map(|std::cmp::Reverse(ev)| ev)
    }

    /// Run to quiescence: process events in virtual-time order until the
    /// queue drains; error if any node is not done (a deadlock, e.g. a
    /// node waiting for a message that can never arrive).
    pub fn run(&mut self) -> Result<()> {
        self.was_cancelled = false;
        let mut pool = WorkerPool::start(self.workers)?;
        for node in 0..self.nodes.len() {
            self.push(0.0, EventKind::Start { node });
        }
        let result = self.drain(&mut pool);
        pool.shutdown();
        result?;
        if self.was_cancelled {
            // A cancelled run stops mid-protocol by design: nodes are
            // legitimately not done, so the deadlock check is moot.
            return Ok(());
        }
        // Departed / crashed nodes are exempt from the deadlock check:
        // they legitimately stop mid-protocol. A node with a crash
        // *scheduled* counts too, even if no event ever popped at or
        // after its crash instant (crash marking is lazy): the queue has
        // quiesced, so nothing can reach it before it dies.
        let stuck: Vec<usize> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(i, n)| {
                !self.departed[*i]
                    && self.crash_at[*i].is_nan()
                    && n.as_ref().is_some_and(|n| !n.done())
            })
            .map(|(i, _)| i)
            .collect();
        if !stuck.is_empty() {
            bail!(
                "virtual-time deadlock: event queue drained but nodes {stuck:?} \
                 are still waiting (missing neighbor messages?)"
            );
        }
        Ok(())
    }

    /// True once `node` has passed its registered crash instant at
    /// event time `at` (and marks it departed on the first observation).
    fn crashed(&mut self, node: usize, at: f64) -> bool {
        // NaN (no crash scheduled) compares false.
        if at >= self.crash_at[node] {
            self.departed[node] = true;
            true
        } else {
            false
        }
    }

    fn drain(&mut self, pool: &mut WorkerPool) -> Result<()> {
        while let Some(ev) = self.pop_next() {
            // Cooperative cancellation: the flag is checked between
            // event dispatches, never inside one, so the run stops at a
            // clean event boundary (in-flight pool jobs are reaped by
            // the pool shutdown that follows).
            if self.control.is_cancelled() {
                self.was_cancelled = true;
                return Ok(());
            }
            let (node, wake) = match ev.kind {
                EventKind::Start { node } => {
                    if self.crashed(node, ev.at) {
                        continue;
                    }
                    (node, Wake::Start)
                }
                EventKind::Deliver { env } => {
                    let dst = env.dst;
                    if dst >= self.nodes.len() {
                        bail!("message to unknown node {dst}");
                    }
                    if self.departed[dst] || self.crashed(dst, ev.at) {
                        // In flight to a node that left; drop on the floor.
                        self.dropped += 1;
                        continue;
                    }
                    self.counters[dst].on_recv(wire_size(&env));
                    (dst, Wake::Message(env))
                }
                EventKind::ComputeDone { node, job } => {
                    // Always reap the pool result (otherwise it would sit
                    // in the stash forever); discard it if the node
                    // crashed while the job was in flight.
                    let out = pool.wait_for(job);
                    if self.departed[node] || self.crashed(node, ev.at) {
                        drop(out);
                        continue;
                    }
                    (node, Wake::ComputeDone(out?))
                }
                EventKind::Timer { node, timer } => {
                    self.pending_timers.remove(&timer);
                    if self.canceled_timers.remove(&timer) {
                        continue;
                    }
                    if self.departed[node] || self.crashed(node, ev.at) {
                        continue;
                    }
                    (node, Wake::Timer(timer))
                }
            };
            self.wake(node, ev.at, wake, pool)?;
        }
        Ok(())
    }

    fn wake(&mut self, node: usize, at: f64, wake: Wake, pool: &WorkerPool) -> Result<()> {
        if self.node_time[node] < at {
            self.node_time[node] = at;
        }
        let tracer = self.tracer.clone();
        // Event metadata captured before the wake is consumed: deliveries
        // know their round and inbound flow id up front; other wakes
        // learn their round from the node ([`NodeCtx::trace_round`]).
        let (ev_phase, ev_round, in_flow) = match &wake {
            Wake::Start => (Some(trace::Phase::Start), trace::ROUND_NONE, 0),
            Wake::Message(env) => (Some(trace::Phase::Deliver), env.round, env.trace),
            Wake::ComputeDone(_) => (None, trace::ROUND_NONE, 0),
            Wake::Timer(_) => (Some(trace::Phase::Timer), trace::ROUND_NONE, 0),
        };
        let wall_t0 = tracer.as_ref().map(|rec| (rec.wall_now_s(), Instant::now()));
        let mut sm = self.nodes[node].take().expect("node is being woken re-entrantly");
        let mut ctx = NodeCtx {
            id: node,
            now_s: self.node_time[node],
            counters: self.counters[node].clone(),
            sends: Vec::new(),
            compute: None,
            timer_base: self.next_timer,
            timers: Vec::new(),
            cancels: Vec::new(),
            departed: false,
            trace: tracer.as_ref().map(|rec| TraceCtx {
                rec: rec.clone(),
                round: ev_round,
                compute_phase: trace::Phase::Train,
            }),
        };
        let handled = sm.on_event(&mut ctx, wake);
        self.nodes[node] = Some(sm);
        handled?;
        let NodeCtx { sends, compute, timers, cancels, departed, trace: trace_ctx, .. } = ctx;
        if departed {
            self.departed[node] = true;
        }
        // Deliveries keep the envelope's round (the node may still be on
        // an earlier round when a fast neighbor's model arrives); every
        // other span takes the round the node reported.
        let span_round = match ev_phase {
            Some(trace::Phase::Deliver) => ev_round,
            _ => trace_ctx.as_ref().map_or(ev_round, |tc| tc.round),
        };
        let compute_phase = trace_ctx.as_ref().map_or(trace::Phase::Train, |tc| tc.compute_phase);
        if let (Some(rec), Some((wall_start_s, t0))) = (&tracer, wall_t0) {
            if in_flow != 0 {
                rec.flow_recv(in_flow, node as u32, ev_round, at);
            }
            if let Some(phase) = ev_phase {
                if rec.sampled(span_round) {
                    rec.record(trace::Span {
                        node: node as u32,
                        round: span_round,
                        phase,
                        virt_start_s: at,
                        virt_dur_s: 0.0,
                        wall_start_s,
                        wall_dur_s: t0.elapsed().as_secs_f64(),
                    });
                }
            }
        }
        let now = self.node_time[node];
        let staged_timers = timers.len() as u64;
        for (i, delay_s) in timers.into_iter().enumerate() {
            let timer = self.next_timer + i as u64;
            self.pending_timers.insert(timer);
            self.push(now + delay_s, EventKind::Timer { node, timer });
        }
        self.next_timer += staged_timers;
        for id in cancels {
            // Only remember cancellations of timers still in the queue;
            // canceling a fired (or never-set) id is a no-op.
            if self.pending_timers.contains(&id) {
                self.canceled_timers.insert(id);
            }
        }
        for mut env in sends {
            env.sent_at_s = now;
            if let Some(rec) = &tracer {
                // Flow ids are allocated on the scheduler thread only, in
                // staging order, so they are deterministic; the receiving
                // wake re-derives the same sampling decision from the
                // envelope's round, so edges never dangle.
                if rec.sampled(env.round) {
                    let id = rec.next_flow_id();
                    env.trace = id;
                    rec.flow_send(id, node as u32, env.round, now);
                }
            }
            let bytes = wire_size(&env);
            self.counters[node].on_send(bytes);
            let deliver_at = match &self.links {
                Some(links) => {
                    // The sender's uplink is serial: bursts queue behind
                    // each other; latency is per-message and pipelined.
                    // Bandwidth and latency are the (src, dst) link's.
                    let (latency_s, bandwidth_bps) = links.link(node, env.dst);
                    let start = self.uplink_free[node].max(now);
                    let finish = start + bytes as f64 / bandwidth_bps;
                    self.uplink_free[node] = finish;
                    finish + latency_s
                }
                None => now,
            };
            self.push(deliver_at, EventKind::Deliver { env });
        }
        if let Some((duration_s, body)) = compute {
            let duration_s = if self.links.is_some() { duration_s } else { 0.0 };
            let job = self.next_job;
            self.next_job += 1;
            let body = match &tracer {
                Some(rec) if rec.sampled(span_round) => {
                    // The span's virtual interval is fixed at submission
                    // ([now, now + duration_s]); its wall fields are
                    // measured on whichever worker runs the job.
                    let rec = rec.clone();
                    let node = node as u32;
                    Box::new(move || {
                        let wall_start_s = rec.wall_now_s();
                        let t0 = Instant::now();
                        let out = body();
                        rec.record(trace::Span {
                            node,
                            round: span_round,
                            phase: compute_phase,
                            virt_start_s: now,
                            virt_dur_s: duration_s,
                            wall_start_s,
                            wall_dur_s: t0.elapsed().as_secs_f64(),
                        });
                        out
                    }) as ComputeFn
                }
                _ => body,
            };
            self.push(now + duration_s, EventKind::ComputeDone { node, job });
            pool.submit(job, body)?;
        }
        Ok(())
    }

    /// Collect all node logs (after [`run`]).
    ///
    /// [`run`]: Scheduler::run
    pub fn take_logs(&mut self) -> Vec<NodeLog> {
        self.nodes
            .iter_mut()
            .filter_map(|n| n.as_mut().and_then(|n| n.take_log()))
            .collect()
    }
}

/// Convenience used by eval state machines: clone-free handle bundle.
pub(crate) struct EvalJob {
    pub trainer: Trainer,
    pub params: Vec<f32>,
    pub test: std::sync::Arc<Dataset>,
}

impl EvalJob {
    pub(crate) fn into_compute(self) -> ComputeFn {
        Box::new(move || {
            let (test_loss, test_acc) = self.trainer.evaluate(&self.params, &self.test)?;
            Ok(ComputeOutput::Eval { trainer: self.trainer, test_loss, test_acc })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::communication::MsgKind;

    fn ev(at: f64, seq: u64) -> Event {
        Event { at, seq, kind: EventKind::Start { node: 0 } }
    }

    #[test]
    fn event_order_is_time_then_seq() {
        let mut heap = BinaryHeap::new();
        for (at, seq) in [(2.0, 0), (1.0, 3), (1.0, 1), (0.5, 2)] {
            heap.push(std::cmp::Reverse(ev(at, seq)));
        }
        let order: Vec<(f64, u64)> = std::iter::from_fn(|| heap.pop())
            .map(|std::cmp::Reverse(e)| (e.at, e.seq))
            .collect();
        assert_eq!(order, vec![(0.5, 2), (1.0, 1), (1.0, 3), (2.0, 0)]);
    }

    #[test]
    fn sharded_heaps_pop_in_global_time_seq_order() {
        // Push events for many nodes (spread across the 4 shards) in a
        // scrambled order; pop_next must yield the exact (at, seq) total
        // order a single heap would — including the seq tiebreak among
        // equal-time events living in *different* shards.
        let mut s = Scheduler::new(None, 4);
        let times = [3.0, 1.0, 2.0, 1.0, 0.5, 2.0, 1.0, 3.0, 0.5, 2.0, 1.0, 0.0];
        for (node, at) in times.iter().enumerate() {
            s.push(*at, EventKind::Start { node });
        }
        assert!(s.shards.iter().filter(|h| !h.is_empty()).count() > 1);
        let mut popped = Vec::new();
        while let Some(ev) = s.pop_next() {
            popped.push((ev.at, ev.seq));
        }
        assert_eq!(popped.len(), times.len());
        let mut want: Vec<(f64, u64)> =
            times.iter().enumerate().map(|(seq, at)| (*at, seq as u64)).collect();
        want.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        assert_eq!(popped, want);
    }

    /// Sends `burst` messages at start, then waits for `burst` replies.
    struct Caller {
        burst: u64,
        seen: u64,
    }
    /// Echoes every message back to its sender.
    struct Responder {
        id: usize,
        expect: u64,
        seen: u64,
    }

    impl EventNode for Caller {
        fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
            match wake {
                Wake::Start => {
                    for r in 0..self.burst {
                        ctx.send(Envelope {
                            src: ctx.id,
                            dst: 1,
                            round: r,
                            kind: MsgKind::Control,
                            sent_at_s: 0.0,
                            trace: 0,
                            payload: vec![1].into(),
                        });
                    }
                }
                Wake::Message(_) => self.seen += 1,
                _ => {}
            }
            Ok(())
        }
        fn done(&self) -> bool {
            self.seen >= self.burst
        }
    }

    impl EventNode for Responder {
        fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
            if let Wake::Message(env) = wake {
                self.seen += 1;
                ctx.send(Envelope {
                    src: self.id,
                    dst: env.src,
                    round: env.round,
                    kind: MsgKind::Control,
                    sent_at_s: 0.0,
                    trace: 0,
                    payload: vec![2].into(),
                });
            }
            Ok(())
        }
        fn done(&self) -> bool {
            self.seen >= self.expect
        }
    }

    #[test]
    fn request_reply_terminates_and_counts() {
        let mut s = Scheduler::new(None, 1);
        s.add_node(Box::new(Caller { burst: 3, seen: 0 }));
        s.add_node(Box::new(Responder { id: 1, expect: 3, seen: 0 }));
        s.run().unwrap();
        assert_eq!(s.counters(0).msgs_sent, 3);
        assert_eq!(s.counters(1).msgs_sent, 3);
        assert_eq!(s.counters(1).msgs_recv, 3);
        assert_eq!(s.counters(0).msgs_recv, 3);
    }

    #[test]
    fn cancel_flag_stops_drain_without_deadlock_error() {
        // The request/reply pair normally terminates with 3 exchanges;
        // with the cancel flag already set, the drain loop must stop
        // before dispatching anything, and the not-done nodes must NOT
        // trip the deadlock check.
        let mut s = Scheduler::new(None, 1);
        s.add_node(Box::new(Caller { burst: 3, seen: 0 }));
        s.add_node(Box::new(Responder { id: 1, expect: 3, seen: 0 }));
        let control = RunControl::new();
        s.set_control(control.clone());
        control.cancel();
        s.run().unwrap();
        assert!(s.was_cancelled());
        assert_eq!(s.counters(0).msgs_sent, 0);
    }

    #[test]
    fn deadlock_is_detected() {
        struct Waiter;
        impl EventNode for Waiter {
            fn on_event(&mut self, _ctx: &mut NodeCtx, _wake: Wake) -> Result<()> {
                Ok(())
            }
            fn done(&self) -> bool {
                false // forever waiting for a message that never comes
            }
        }
        let mut s = Scheduler::new(None, 1);
        s.add_node(Box::new(Waiter));
        let err = s.run().unwrap_err().to_string();
        assert!(err.contains("deadlock"), "{err}");
    }

    #[test]
    fn compute_jobs_round_trip_through_pool() {
        struct Computer {
            got: Option<f64>,
        }
        impl EventNode for Computer {
            fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
                match wake {
                    Wake::Start => {
                        ctx.start_compute(0.5, Box::new(|| Ok(ComputeOutput::Value(42.0))));
                    }
                    Wake::ComputeDone(ComputeOutput::Value(v)) => self.got = Some(v),
                    _ => {}
                }
                Ok(())
            }
            fn done(&self) -> bool {
                self.got.is_some()
            }
        }
        let net = NetworkModel { latency_s: 0.0, bandwidth_bps: 1e9 };
        let mut s = Scheduler::new(Some(net), 2);
        let id = s.add_node(Box::new(Computer { got: None }));
        s.run().unwrap();
        assert!((s.node_time(id) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn compute_panic_surfaces_as_error_not_hang() {
        // A panicking job must become a job error even when OTHER idle
        // workers keep the result channel open (the hang scenario).
        struct Panicky;
        impl EventNode for Panicky {
            fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
                match wake {
                    Wake::Start => {
                        ctx.start_compute(0.1, Box::new(|| panic!("boom")));
                        Ok(())
                    }
                    Wake::ComputeDone(_) => unreachable!("panic surfaces before the wake"),
                    _ => Ok(()),
                }
            }
            fn done(&self) -> bool {
                false
            }
        }
        let mut s = Scheduler::new(None, 4);
        s.add_node(Box::new(Panicky));
        let err = s.run().unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
    }

    /// Arms a timer at start; optionally cancels it on a later wake.
    struct Alarm {
        delay_s: f64,
        cancel_on_message: bool,
        timer: Option<u64>,
        fired_at: Option<f64>,
        done_when_fired: bool,
    }

    impl EventNode for Alarm {
        fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
            match wake {
                Wake::Start => {
                    self.timer = Some(ctx.set_timer(self.delay_s));
                }
                Wake::Message(_) => {
                    if self.cancel_on_message {
                        if let Some(id) = self.timer {
                            ctx.cancel_timer(id);
                        }
                    }
                }
                Wake::Timer(id) => {
                    assert_eq!(Some(id), self.timer, "foreign timer id");
                    self.fired_at = Some(ctx.now_s);
                }
                _ => {}
            }
            Ok(())
        }
        fn done(&self) -> bool {
            !self.done_when_fired || self.fired_at.is_some()
        }
    }

    /// Sends one message to `dst` at start; immediately done.
    struct OneShot {
        id: usize,
        dst: usize,
    }

    impl EventNode for OneShot {
        fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
            if let Wake::Start = wake {
                ctx.send(Envelope {
                    src: self.id,
                    dst: self.dst,
                    round: 0,
                    kind: MsgKind::Control,
                    sent_at_s: 0.0,
                    trace: 0,
                    payload: vec![9].into(),
                });
            }
            Ok(())
        }
        fn done(&self) -> bool {
            true
        }
    }

    #[test]
    fn timer_fires_at_virtual_deadline() {
        let net = NetworkModel { latency_s: 0.0, bandwidth_bps: 1e9 };
        let mut s = Scheduler::new(Some(net), 1);
        let id = s.add_node(Box::new(Alarm {
            delay_s: 0.75,
            cancel_on_message: false,
            timer: None,
            fired_at: None,
            done_when_fired: true,
        }));
        s.run().unwrap();
        assert!((s.node_time(id) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn canceled_timer_never_fires() {
        // The alarm cancels its own pending timer when the neighbor's
        // message (delivered well before the deadline) arrives.
        let net = NetworkModel { latency_s: 0.001, bandwidth_bps: 1e9 };
        let mut s = Scheduler::new(Some(net), 1);
        s.add_node(Box::new(Alarm {
            delay_s: 100.0,
            cancel_on_message: true,
            timer: None,
            fired_at: None,
            done_when_fired: false,
        }));
        s.add_node(Box::new(OneShot { id: 1, dst: 0 }));
        s.run().unwrap();
        // The queue drained without ever waking the alarm at t = 100.
        assert!(s.node_time(0) < 1.0);
    }

    #[test]
    fn scheduled_crash_exempts_even_eventless_node() {
        // The crashed node has NO pending events at or after its crash
        // instant (crash marking is lazy), yet the deadlock check must
        // still exempt it per the set_crash_time contract.
        struct Waiter;
        impl EventNode for Waiter {
            fn on_event(&mut self, _ctx: &mut NodeCtx, _wake: Wake) -> Result<()> {
                Ok(())
            }
            fn done(&self) -> bool {
                false // forever waiting for a message that never comes
            }
        }
        let mut s = Scheduler::new(None, 1);
        s.add_node(Box::new(Waiter));
        s.set_crash_time(0, 1.0); // queue drains at t = 0, before this
        s.run().unwrap();
    }

    #[test]
    fn crashed_node_drops_events_and_run_completes() {
        // The alarm's deadline is at t = 5 but the node crashes at t = 1:
        // the timer is discarded, the node is exempt from the deadlock
        // check, and deliveries after the crash are dropped + counted.
        let net = NetworkModel { latency_s: 2.0, bandwidth_bps: 1e9 };
        let mut s = Scheduler::new(Some(net), 1);
        s.add_node(Box::new(Alarm {
            delay_s: 5.0,
            cancel_on_message: false,
            timer: None,
            fired_at: None,
            done_when_fired: true, // would deadlock if not crash-exempt
        }));
        s.add_node(Box::new(OneShot { id: 1, dst: 0 })); // one msg, arrives t > 2
        s.set_crash_time(0, 1.0);
        s.run().unwrap();
        assert_eq!(s.dropped_deliveries(), 1);
        assert_eq!(s.counters(0).msgs_recv, 0);
    }

    #[test]
    fn compute_error_aborts_run() {
        struct Bad;
        impl EventNode for Bad {
            fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
                match wake {
                    Wake::Start => {
                        ctx.start_compute(0.1, Box::new(|| bail!("engine exploded")));
                        Ok(())
                    }
                    Wake::ComputeDone(_) => unreachable!("error surfaces before the wake"),
                    _ => Ok(()),
                }
            }
            fn done(&self) -> bool {
                false
            }
        }
        let mut s = Scheduler::new(None, 1);
        s.add_node(Box::new(Bad));
        assert!(s.run().is_err());
    }
}
