//! Discrete-event, virtual-time scheduler: the scale mode that emulates
//! 1000+ nodes on a bounded worker pool (the paper's headline capability
//! without one OS thread per node).
//!
//! # Event model
//!
//! The scheduler owns a **global virtual clock** and a priority queue of
//! timestamped events. Three event kinds exist:
//!
//! * `Start` — a node's first activation at t = 0.
//! * `Deliver` — a message arrival. Delivery timestamps come from the
//!   [`LinkModel`]: each sender owns a serial uplink, so message *k*
//!   of a burst finishes at `max(now, uplink_free) + bytes/bandwidth`
//!   and arrives one latency later; with a per-link matrix
//!   ([`crate::communication::shaper::LinkMatrix`]) the bandwidth and
//!   latency are looked up per `(src, dst)` pair, with a uniform
//!   [`NetworkModel`] every link shares them. Virtual time therefore
//!   reflects the actual arrival *order* under the modeled network —
//!   unlike the thread-per-node path, which only charged an aggregate
//!   per-round upload cost after the fact. Without a network model,
//!   delivery is immediate and ordered by sequence number. Deliveries
//!   addressed to a **departed** node (one that called
//!   [`NodeCtx::depart`], e.g. on a churn-trace departure) are dropped
//!   at pop time and counted in [`Scheduler::dropped_deliveries`].
//! * `ComputeDone` — completion of a node's local compute (training
//!   step(s), evaluation), stamped with the calibrated step time. The
//!   actual computation runs on a **bounded worker pool** (`workers ≈
//!   cores`, not `workers = nodes`); virtual completion time is fixed at
//!   submission, so wall-clock execution order never affects virtual
//!   order.
//!
//! Nodes are resumable state machines ([`EventNode`]) woken with a
//! [`Wake`]; they react by staging sends and at most one compute job per
//! wake through the [`NodeCtx`]. Determinism: events are totally ordered
//! by `(virtual time, sequence number)`, sequence numbers are assigned
//! by the single scheduler thread, and per-node compute is pure w.r.t.
//! its own state — so two runs of the same configuration produce
//! identical event orders and bit-identical results regardless of worker
//! count (see `rust/tests/scheduler_virtual_time.rs`).
//!
//! Per-sender FIFO (the [`crate::communication::Transport`] contract) is
//! preserved: a sender's messages serialize on its uplink, so later
//! sends never arrive earlier; at equal timestamps the sequence number
//! breaks the tie in staging order.

mod nodes;

pub use nodes::{DlNodeSm, SamplerSm, SecureDlNodeSm};

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::communication::shaper::{LinkModel, NetworkModel};
use crate::communication::{wire_size, Counters, CountersSnapshot, Envelope};
use crate::dataset::Dataset;
use crate::metrics::NodeLog;
use crate::training::Trainer;

/// Result of a compute job executed on the worker pool. Train/Eval carry
/// the node's [`Trainer`] through the pool and back (a node has at most
/// one job in flight, so ownership round-trips are safe).
#[allow(clippy::large_enum_variant)]
pub enum ComputeOutput {
    Train { trainer: Trainer, params: Vec<f32>, loss: f64 },
    Eval { trainer: Trainer, test_loss: f64, test_acc: f64 },
    /// Free-form output for tests and custom nodes.
    Value(f64),
}

/// A compute job body, run once on a pool worker.
pub type ComputeFn = Box<dyn FnOnce() -> Result<ComputeOutput> + Send>;

/// Why a node is being woken.
#[allow(clippy::large_enum_variant)]
pub enum Wake {
    /// First activation, at virtual t = 0.
    Start,
    /// A message addressed to this node arrived.
    Message(Envelope),
    /// The node's in-flight compute job finished.
    ComputeDone(ComputeOutput),
}

/// A node's window onto the scheduler during one wake.
pub struct NodeCtx {
    /// This node's id (== its transport rank).
    pub id: usize,
    /// The node's virtual clock, already advanced to the wake time.
    pub now_s: f64,
    counters: Counters,
    sends: Vec<Envelope>,
    compute: Option<(f64, ComputeFn)>,
    departed: bool,
}

impl NodeCtx {
    /// Stage a message send at the current virtual time. Delivery is
    /// timestamped by the scheduler's network model after the wake.
    pub fn send(&mut self, env: Envelope) {
        self.sends.push(env);
    }

    /// Stage this wake's compute job: `duration_s` of virtual time, body
    /// executed on the worker pool. At most one job per wake — a second
    /// call is a node-logic bug (the first job would silently vanish),
    /// so it panics in release builds too.
    pub fn start_compute(&mut self, duration_s: f64, f: ComputeFn) {
        assert!(self.compute.is_none(), "one compute job per wake");
        self.compute = Some((duration_s, f));
    }

    /// Wire-byte counters for this node (sends staged in *earlier* wakes
    /// are included; the current wake's are counted after it returns).
    pub fn counters(&self) -> CountersSnapshot {
        self.counters.snapshot()
    }

    /// Mark this node as permanently departed (churn-trace departure).
    /// Sends staged in the same wake still go out — a node may push its
    /// last update and leave — but every delivery addressed to it from
    /// now on is dropped instead of waking it.
    pub fn depart(&mut self) {
        self.departed = true;
    }
}

/// A resumable node driven by the scheduler.
///
/// Implementations decompose their round loop into explicit states
/// (Train → Broadcast → AwaitModels → Aggregate → Eval) and advance one
/// transition per wake; blocking receives become buffered `pending`
/// maps checked on every `Wake::Message`.
pub trait EventNode {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()>;

    /// True once the node has finished all rounds. The scheduler treats
    /// an empty queue with un-done nodes as a deadlock.
    fn done(&self) -> bool;

    /// Hand over the metric log (nodes that keep none return `None`).
    fn take_log(&mut self) -> Option<NodeLog> {
        None
    }
}

enum EventKind {
    Start { node: usize },
    Deliver { env: Envelope },
    ComputeDone { node: usize, job: u64 },
}

struct Event {
    at: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    /// Total order: virtual time, then staging sequence (unique).
    fn cmp(&self, other: &Self) -> Ordering {
        self.at
            .total_cmp(&other.at)
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

struct Job {
    id: u64,
    body: ComputeFn,
}

/// Bounded pool executing compute jobs off the scheduler thread.
struct WorkerPool {
    job_tx: Option<mpsc::Sender<Job>>,
    res_rx: mpsc::Receiver<(u64, Result<ComputeOutput>)>,
    handles: Vec<std::thread::JoinHandle<()>>,
    stash: HashMap<u64, Result<ComputeOutput>>,
}

impl WorkerPool {
    fn start(workers: usize) -> Result<WorkerPool> {
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) = mpsc::channel();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers.max(1) {
            let rx = Arc::clone(&job_rx);
            let tx = res_tx.clone();
            let h = std::thread::Builder::new()
                .name(format!("sched-worker-{w}"))
                .spawn(move || loop {
                    // Hold the lock only while dequeuing.
                    let job = { rx.lock().unwrap().recv() };
                    let Ok(Job { id, body }) = job else { break };
                    // Convert panics into job errors: an unwinding worker
                    // would otherwise never report, leaving the scheduler
                    // blocked in wait_for while idle workers keep the
                    // result channel open.
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body))
                        .unwrap_or_else(|_| Err(anyhow!("compute job panicked")));
                    if tx.send((id, out)).is_err() {
                        break;
                    }
                })
                .context("spawning scheduler worker")?;
            handles.push(h);
        }
        Ok(WorkerPool { job_tx: Some(job_tx), res_rx, handles, stash: HashMap::new() })
    }

    fn submit(&self, id: u64, body: ComputeFn) -> Result<()> {
        self.job_tx
            .as_ref()
            .expect("pool already shut down")
            .send(Job { id, body })
            .map_err(|_| anyhow!("scheduler worker pool is gone"))
    }

    /// Block until job `id` has a result (stashing other completions).
    fn wait_for(&mut self, id: u64) -> Result<ComputeOutput> {
        if let Some(res) = self.stash.remove(&id) {
            return res;
        }
        loop {
            let (got, res) = self
                .res_rx
                .recv()
                .map_err(|_| anyhow!("all scheduler workers exited (a compute job panicked?)"))?;
            if got == id {
                return res;
            }
            self.stash.insert(got, res);
        }
    }

    fn shutdown(mut self) {
        self.job_tx.take(); // closes the channel; idle workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The discrete-event scheduler. Add nodes in rank order, then [`run`].
///
/// [`run`]: Scheduler::run
pub struct Scheduler {
    links: Option<LinkModel>,
    workers: usize,
    nodes: Vec<Option<Box<dyn EventNode>>>,
    queue: BinaryHeap<std::cmp::Reverse<Event>>,
    seq: u64,
    next_job: u64,
    node_time: Vec<f64>,
    uplink_free: Vec<f64>,
    counters: Vec<Counters>,
    departed: Vec<bool>,
    dropped: u64,
}

impl Scheduler {
    /// `network = None` means untimed delivery (all events at t = 0, in
    /// staging order); `workers` is the pool size (>= 1 enforced).
    pub fn new(network: Option<NetworkModel>, workers: usize) -> Scheduler {
        Scheduler::with_links(network.map(LinkModel::Uniform), workers)
    }

    /// Like [`new`](Scheduler::new), but with a general [`LinkModel`]
    /// (a per-link matrix for WAN scenarios, or the uniform model).
    pub fn with_links(links: Option<LinkModel>, workers: usize) -> Scheduler {
        Scheduler {
            links,
            workers: workers.max(1),
            nodes: Vec::new(),
            queue: BinaryHeap::new(),
            seq: 0,
            next_job: 0,
            node_time: Vec::new(),
            uplink_free: Vec::new(),
            counters: Vec::new(),
            departed: Vec::new(),
            dropped: 0,
        }
    }

    /// Register a node; its id (== transport rank) is the add order.
    pub fn add_node(&mut self, node: Box<dyn EventNode>) -> usize {
        let id = self.nodes.len();
        self.nodes.push(Some(node));
        self.node_time.push(0.0);
        self.uplink_free.push(0.0);
        self.counters.push(Counters::new());
        self.departed.push(false);
        id
    }

    /// A node's virtual clock (its last wake time).
    pub fn node_time(&self, id: usize) -> f64 {
        self.node_time[id]
    }

    /// Global virtual time = the furthest any node has progressed.
    pub fn now(&self) -> f64 {
        self.node_time.iter().fold(0.0, |a, &b| a.max(b))
    }

    pub fn counters(&self, id: usize) -> CountersSnapshot {
        self.counters[id].snapshot()
    }

    /// Deliveries dropped because their destination had departed.
    pub fn dropped_deliveries(&self) -> u64 {
        self.dropped
    }

    fn push(&mut self, at: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(std::cmp::Reverse(Event { at, seq, kind }));
    }

    /// Run to quiescence: process events in virtual-time order until the
    /// queue drains; error if any node is not done (a deadlock, e.g. a
    /// node waiting for a message that can never arrive).
    pub fn run(&mut self) -> Result<()> {
        let mut pool = WorkerPool::start(self.workers)?;
        for node in 0..self.nodes.len() {
            self.push(0.0, EventKind::Start { node });
        }
        let result = self.drain(&mut pool);
        pool.shutdown();
        result?;
        let stuck: Vec<usize> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.as_ref().is_some_and(|n| !n.done()))
            .map(|(i, _)| i)
            .collect();
        if !stuck.is_empty() {
            bail!(
                "virtual-time deadlock: event queue drained but nodes {stuck:?} \
                 are still waiting (missing neighbor messages?)"
            );
        }
        Ok(())
    }

    fn drain(&mut self, pool: &mut WorkerPool) -> Result<()> {
        while let Some(std::cmp::Reverse(ev)) = self.queue.pop() {
            let (node, wake) = match ev.kind {
                EventKind::Start { node } => (node, Wake::Start),
                EventKind::Deliver { env } => {
                    let dst = env.dst;
                    if dst >= self.nodes.len() {
                        bail!("message to unknown node {dst}");
                    }
                    if self.departed[dst] {
                        // In flight to a node that left; drop on the floor.
                        self.dropped += 1;
                        continue;
                    }
                    self.counters[dst].on_recv(wire_size(&env));
                    (dst, Wake::Message(env))
                }
                EventKind::ComputeDone { node, job } => {
                    (node, Wake::ComputeDone(pool.wait_for(job)?))
                }
            };
            self.wake(node, ev.at, wake, pool)?;
        }
        Ok(())
    }

    fn wake(&mut self, node: usize, at: f64, wake: Wake, pool: &WorkerPool) -> Result<()> {
        if self.node_time[node] < at {
            self.node_time[node] = at;
        }
        let mut sm = self.nodes[node].take().expect("node is being woken re-entrantly");
        let mut ctx = NodeCtx {
            id: node,
            now_s: self.node_time[node],
            counters: self.counters[node].clone(),
            sends: Vec::new(),
            compute: None,
            departed: false,
        };
        let handled = sm.on_event(&mut ctx, wake);
        self.nodes[node] = Some(sm);
        handled?;
        let NodeCtx { sends, compute, departed, .. } = ctx;
        if departed {
            self.departed[node] = true;
        }
        let now = self.node_time[node];
        for env in sends {
            let bytes = wire_size(&env);
            self.counters[node].on_send(bytes);
            let deliver_at = match &self.links {
                Some(links) => {
                    // The sender's uplink is serial: bursts queue behind
                    // each other; latency is per-message and pipelined.
                    // Bandwidth and latency are the (src, dst) link's.
                    let (latency_s, bandwidth_bps) = links.link(node, env.dst);
                    let start = self.uplink_free[node].max(now);
                    let finish = start + bytes as f64 / bandwidth_bps;
                    self.uplink_free[node] = finish;
                    finish + latency_s
                }
                None => now,
            };
            self.push(deliver_at, EventKind::Deliver { env });
        }
        if let Some((duration_s, body)) = compute {
            let duration_s = if self.links.is_some() { duration_s } else { 0.0 };
            let job = self.next_job;
            self.next_job += 1;
            self.push(now + duration_s, EventKind::ComputeDone { node, job });
            pool.submit(job, body)?;
        }
        Ok(())
    }

    /// Collect all node logs (after [`run`]).
    ///
    /// [`run`]: Scheduler::run
    pub fn take_logs(&mut self) -> Vec<NodeLog> {
        self.nodes
            .iter_mut()
            .filter_map(|n| n.as_mut().and_then(|n| n.take_log()))
            .collect()
    }
}

/// Convenience used by eval state machines: clone-free handle bundle.
pub(crate) struct EvalJob {
    pub trainer: Trainer,
    pub params: Vec<f32>,
    pub test: std::sync::Arc<Dataset>,
}

impl EvalJob {
    pub(crate) fn into_compute(self) -> ComputeFn {
        Box::new(move || {
            let (test_loss, test_acc) = self.trainer.evaluate(&self.params, &self.test)?;
            Ok(ComputeOutput::Eval { trainer: self.trainer, test_loss, test_acc })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::communication::MsgKind;

    fn ev(at: f64, seq: u64) -> Event {
        Event { at, seq, kind: EventKind::Start { node: 0 } }
    }

    #[test]
    fn event_order_is_time_then_seq() {
        let mut heap = BinaryHeap::new();
        for (at, seq) in [(2.0, 0), (1.0, 3), (1.0, 1), (0.5, 2)] {
            heap.push(std::cmp::Reverse(ev(at, seq)));
        }
        let order: Vec<(f64, u64)> = std::iter::from_fn(|| heap.pop())
            .map(|std::cmp::Reverse(e)| (e.at, e.seq))
            .collect();
        assert_eq!(order, vec![(0.5, 2), (1.0, 1), (1.0, 3), (2.0, 0)]);
    }

    /// Sends `burst` messages at start, then waits for `burst` replies.
    struct Caller {
        burst: u64,
        seen: u64,
    }
    /// Echoes every message back to its sender.
    struct Responder {
        id: usize,
        expect: u64,
        seen: u64,
    }

    impl EventNode for Caller {
        fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
            match wake {
                Wake::Start => {
                    for r in 0..self.burst {
                        ctx.send(Envelope {
                            src: ctx.id,
                            dst: 1,
                            round: r,
                            kind: MsgKind::Control,
                            payload: vec![1],
                        });
                    }
                }
                Wake::Message(_) => self.seen += 1,
                _ => {}
            }
            Ok(())
        }
        fn done(&self) -> bool {
            self.seen >= self.burst
        }
    }

    impl EventNode for Responder {
        fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
            if let Wake::Message(env) = wake {
                self.seen += 1;
                ctx.send(Envelope {
                    src: self.id,
                    dst: env.src,
                    round: env.round,
                    kind: MsgKind::Control,
                    payload: vec![2],
                });
            }
            Ok(())
        }
        fn done(&self) -> bool {
            self.seen >= self.expect
        }
    }

    #[test]
    fn request_reply_terminates_and_counts() {
        let mut s = Scheduler::new(None, 1);
        s.add_node(Box::new(Caller { burst: 3, seen: 0 }));
        s.add_node(Box::new(Responder { id: 1, expect: 3, seen: 0 }));
        s.run().unwrap();
        assert_eq!(s.counters(0).msgs_sent, 3);
        assert_eq!(s.counters(1).msgs_sent, 3);
        assert_eq!(s.counters(1).msgs_recv, 3);
        assert_eq!(s.counters(0).msgs_recv, 3);
    }

    #[test]
    fn deadlock_is_detected() {
        struct Waiter;
        impl EventNode for Waiter {
            fn on_event(&mut self, _ctx: &mut NodeCtx, _wake: Wake) -> Result<()> {
                Ok(())
            }
            fn done(&self) -> bool {
                false // forever waiting for a message that never comes
            }
        }
        let mut s = Scheduler::new(None, 1);
        s.add_node(Box::new(Waiter));
        let err = s.run().unwrap_err().to_string();
        assert!(err.contains("deadlock"), "{err}");
    }

    #[test]
    fn compute_jobs_round_trip_through_pool() {
        struct Computer {
            got: Option<f64>,
        }
        impl EventNode for Computer {
            fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
                match wake {
                    Wake::Start => {
                        ctx.start_compute(0.5, Box::new(|| Ok(ComputeOutput::Value(42.0))));
                    }
                    Wake::ComputeDone(ComputeOutput::Value(v)) => self.got = Some(v),
                    _ => {}
                }
                Ok(())
            }
            fn done(&self) -> bool {
                self.got.is_some()
            }
        }
        let net = NetworkModel { latency_s: 0.0, bandwidth_bps: 1e9 };
        let mut s = Scheduler::new(Some(net), 2);
        let id = s.add_node(Box::new(Computer { got: None }));
        s.run().unwrap();
        assert!((s.node_time(id) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn compute_panic_surfaces_as_error_not_hang() {
        // A panicking job must become a job error even when OTHER idle
        // workers keep the result channel open (the hang scenario).
        struct Panicky;
        impl EventNode for Panicky {
            fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
                match wake {
                    Wake::Start => {
                        ctx.start_compute(0.1, Box::new(|| panic!("boom")));
                        Ok(())
                    }
                    Wake::ComputeDone(_) => unreachable!("panic surfaces before the wake"),
                    _ => Ok(()),
                }
            }
            fn done(&self) -> bool {
                false
            }
        }
        let mut s = Scheduler::new(None, 4);
        s.add_node(Box::new(Panicky));
        let err = s.run().unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
    }

    #[test]
    fn compute_error_aborts_run() {
        struct Bad;
        impl EventNode for Bad {
            fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
                match wake {
                    Wake::Start => {
                        ctx.start_compute(0.1, Box::new(|| bail!("engine exploded")));
                        Ok(())
                    }
                    Wake::ComputeDone(_) => unreachable!("error surfaces before the wake"),
                    _ => Ok(()),
                }
            }
            fn done(&self) -> bool {
                false
            }
        }
        let mut s = Scheduler::new(None, 1);
        s.add_node(Box::new(Bad));
        assert!(s.run().is_err());
    }
}
