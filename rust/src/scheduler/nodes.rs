//! Node round loops decomposed into scheduler state machines.
//!
//! Each threaded node (`DlNode`, `SecureDlNode`, `PeerSampler`) has an
//! event-driven twin here with the blocking receive loops turned into
//! explicit states: Train → Broadcast → AwaitModels → Aggregate → Eval.
//! The arithmetic is kept order-identical to the threaded path (same
//! sharing-state mutation order, same neighbor-order aggregation, same
//! loss averaging), so a static-topology run produces bit-identical
//! final parameters under either runner — enforced by the equivalence
//! test in `rust/tests/dl_integration.rs`.
//!
//! [`AsyncDlNodeSm`] has **no** threaded twin: it is a genuinely new
//! execution model (asynchronous gossip over virtual deadlines) that
//! only exists on the scheduler, because it needs first-class timer
//! events and per-message virtual timestamps.
//!
//! # Churn traces (static topologies)
//!
//! With a [`ChurnTrace`], [`DlNodeSm`] consults the shared trace each
//! round: offline rounds are skipped without training (all nodes filter
//! the offline node out of their neighbor sets for those rounds, folding
//! its mixing weight into their self-weight, so no one waits on it); a
//! node whose trace never brings it back *departs* — on its final online
//! round it trains and pushes its last model to its neighbors, then
//! leaves without pulling theirs, and the scheduler drops the in-flight
//! deliveries still addressed to it. Dynamic (peer-sampler) topologies
//! handle churn centrally instead: [`SamplerSm`] draws each round's
//! graph over the trace's active set and hands inactive nodes an empty
//! assignment.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::communication::{Envelope, MsgKind};
use crate::dataset::Dataset;
use crate::graph::{Graph, MixingWeights};
use crate::kernels::{self, Scratch};
use crate::metrics::{NodeLog, Record, Telemetry};
use crate::model::ParamVec;
use crate::node::async_dl::{AsyncPolicy, AsyncStats, DeadlineSpec, LatePolicy};
use crate::node::proto::{decode_control, decode_neighbors, encode_control, encode_neighbors};
use crate::node::proto::{Control, NeighborAssignment};
use crate::node::TopologyView;
use crate::node::{draw_round, key_agreement_envelopes, secure_round_envelopes};
use crate::scenario::{Availability, ByzantineRoster, ChurnTrace};
use crate::secure::Masker;
use crate::sharing::{DefenseStats, Received, Sharing};
use crate::store::{ParamSlot, Payload};
use crate::trace::Phase as TracePhase;
use crate::training::Trainer;
use crate::util::Timer;

use super::{ComputeOutput, EvalJob, EventNode, NodeCtx, Wake};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DlState {
    /// Waiting for the peer sampler's neighbor row (dynamic mode).
    AwaitAssignment,
    /// Local training in flight on the worker pool.
    Training,
    /// Broadcast done; waiting for this round's neighbor models.
    AwaitModels,
    /// Evaluation in flight on the worker pool.
    Evaluating,
    /// All rounds finished.
    Done,
    /// Left for good mid-experiment (churn-trace departure).
    Departed,
}

/// Event-driven D-PSGD client (state-machine twin of
/// [`crate::node::DlNode`]).
pub struct DlNodeSm {
    id: usize,
    rounds: u64,
    eval_every: u64,
    trainer: Option<Trainer>,
    sharing: Box<dyn Sharing>,
    /// Model parameters: a private vector (`param_store = "owned"`) or a
    /// copy-on-write handle into the shared [`crate::store::ParamStore`].
    params: ParamSlot,
    topology: TopologyView,
    test: Arc<Dataset>,
    /// Availability trace (static topologies only; `None` = always on).
    churn: Option<Arc<ChurnTrace>>,
    /// Byzantine attack roster (`None` = every node honest).
    byz: Option<Arc<ByzantineRoster>>,
    step_time_s: f64,
    eval_time_s: f64,
    // --- runtime state ---
    round: u64,
    state: DlState,
    assign: Option<NeighborAssignment>,
    /// Post-training model parked between Broadcast and Aggregate.
    model: Option<ParamVec>,
    train_loss: f64,
    /// Early/buffered model payloads keyed by (round, sender).
    pending: HashMap<(u64, usize), Payload>,
    /// Reusable hot-path buffers (decode, diff, sparse staging): warm
    /// after round 0, so steady-state rounds allocate nothing.
    scratch: Scratch,
    /// Cumulative defense accounting (admitted/rejected contributions).
    defense: DefenseStats,
    log: Option<NodeLog>,
    wall: Timer,
}

impl DlNodeSm {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        rounds: u64,
        eval_every: u64,
        trainer: Trainer,
        sharing: Box<dyn Sharing>,
        params: ParamSlot,
        topology: TopologyView,
        test: Arc<Dataset>,
        churn: Option<Arc<ChurnTrace>>,
        byz: Option<Arc<ByzantineRoster>>,
        step_time_s: f64,
        eval_time_s: f64,
    ) -> DlNodeSm {
        DlNodeSm {
            id,
            rounds,
            eval_every,
            trainer: Some(trainer),
            sharing,
            params,
            topology,
            test,
            churn,
            byz,
            step_time_s,
            eval_time_s,
            round: 0,
            state: DlState::Training,
            assign: None,
            model: None,
            train_loss: 0.0,
            pending: HashMap::new(),
            scratch: Scratch::new(),
            defense: DefenseStats::default(),
            log: Some(NodeLog::new(id)),
            wall: Timer::start(),
        }
    }

    fn begin_round(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        if let Some(tr) = &self.churn {
            // Sit out offline rounds; leave for good once the trace
            // never brings this node back online.
            while self.round < self.rounds && !tr.active(self.id, self.round) {
                if tr.last_online_round(self.id).map_or(true, |l| l < self.round) {
                    self.params.release();
                    self.state = DlState::Departed;
                    ctx.depart();
                    return Ok(());
                }
                self.round += 1;
            }
        }
        if self.round == self.rounds {
            self.state = DlState::Done;
            return Ok(());
        }
        let assign = match &self.topology {
            TopologyView::Static { self_weight, neighbors } => {
                // Filter out neighbors the shared trace marks offline
                // this round (they send nothing and expect nothing);
                // their mixing weight folds into the self-weight so the
                // row stays stochastic.
                let (self_weight, neighbors) = match &self.churn {
                    Some(tr) => {
                        let mut sw = *self_weight;
                        let mut nbrs = Vec::with_capacity(neighbors.len());
                        for &(n, w) in neighbors {
                            if tr.active(n, self.round) {
                                nbrs.push((n, w));
                            } else {
                                sw += w;
                            }
                        }
                        (sw, nbrs)
                    }
                    None => (*self_weight, neighbors.clone()),
                };
                NeighborAssignment { round: self.round, self_weight, neighbors }
            }
            TopologyView::Dynamic { sampler_rank } => {
                ctx.send(Envelope {
                    src: self.id,
                    dst: *sampler_rank,
                    round: self.round,
                    kind: MsgKind::Control,
                    sent_at_s: 0.0,
                    trace: 0,
                    payload: encode_control(&Control::Ready { round: self.round }).into(),
                });
                self.state = DlState::AwaitAssignment;
                return Ok(());
            }
        };
        self.assign = Some(assign);
        self.start_train(ctx)
    }

    fn start_train(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        let trainer = self.trainer.take().context("trainer already in flight")?;
        // First take materializes this node's CoW shard in shared mode.
        let params = self.params.take();
        let duration_s = self.step_time_s * trainer.local_steps() as f64;
        ctx.start_compute(
            duration_s,
            Box::new(move || {
                let mut trainer = trainer;
                let (params, loss) = trainer.train_round(params)?;
                Ok(ComputeOutput::Train { trainer, params, loss })
            }),
        );
        self.state = DlState::Training;
        Ok(())
    }

    fn start_eval(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        let trainer = self.trainer.take().context("trainer already in flight")?;
        let job = EvalJob {
            trainer,
            params: self.params.to_vec(),
            test: Arc::clone(&self.test),
        };
        ctx.trace_compute_kind(TracePhase::Eval);
        ctx.start_compute(self.eval_time_s, job.into_compute());
        self.state = DlState::Evaluating;
        Ok(())
    }

    /// True when the trace says this is the node's last online round —
    /// it should broadcast and leave rather than await aggregation.
    fn parting_round(&self) -> bool {
        self.churn
            .as_ref()
            .is_some_and(|tr| tr.last_online_round(self.id) == Some(self.round))
    }

    /// Aggregate once every current neighbor's model has arrived.
    fn try_aggregate(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        let (self_weight, order): (f64, Vec<(usize, f64)>) = {
            let a = self.assign.as_ref().context("no neighbor assignment")?;
            (a.self_weight, a.neighbors.clone())
        };
        if !order.iter().all(|&(n, _)| self.pending.contains_key(&(self.round, n))) {
            return Ok(());
        }
        let t = ctx.trace_begin();
        let msgs: Vec<(usize, f64, Payload)> = order
            .iter()
            .map(|&(n, w)| (n, w, self.pending.remove(&(self.round, n)).unwrap()))
            .collect();
        let mut model = self.model.take().context("no trained model to aggregate")?;
        {
            let received: Vec<Received> = msgs
                .iter()
                .map(|(src, weight, payload)| Received {
                    src: *src,
                    weight: *weight,
                    payload: payload.as_slice(),
                })
                .collect();
            let tf = ctx.trace_begin();
            self.sharing
                .aggregate_with(&mut model, self_weight, &received, &mut self.scratch)?;
            // Nested fold span (under Aggregate): only meaningful when a
            // tree plan actually staged partial accumulators.
            if !self.scratch.partials.is_empty() {
                ctx.trace_phase(TracePhase::Fold, tf);
            }
            // Defense accounting: how much adversarial mass did the
            // aggregation admit, how much did it isolate?
            if let Some(roster) = &self.byz {
                let report = self.sharing.defense_report();
                for (i, r) in received.iter().enumerate() {
                    let admitted =
                        report.map_or(1.0, |rep| rep.admitted.get(i).copied().unwrap_or(1.0));
                    self.defense.observe(roster.is_byzantine(r.src), r.weight, admitted);
                }
            }
        }
        self.params.put(model.into_vec());
        ctx.trace_phase(TracePhase::Aggregate, t);
        if (self.round + 1) % self.eval_every == 0 || self.round + 1 == self.rounds {
            self.start_eval(ctx)
        } else {
            self.round += 1;
            self.begin_round(ctx)
        }
    }
}

impl EventNode for DlNodeSm {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
        ctx.trace_round(self.round);
        match wake {
            Wake::Start => self.begin_round(ctx),
            Wake::Message(env) => match env.kind {
                MsgKind::Neighbors => {
                    if self.state != DlState::AwaitAssignment {
                        return Ok(()); // late duplicate; ignore
                    }
                    let a = decode_neighbors(&env.payload)?;
                    if a.round != self.round {
                        bail!(
                            "sampler sent round {} while node {} waits for {}",
                            a.round,
                            self.id,
                            self.round
                        );
                    }
                    self.assign = Some(a);
                    self.start_train(ctx)
                }
                MsgKind::Model => {
                    // Buffer current/future rounds; stale duplicates are
                    // dropped (possible after a dynamic topology change).
                    if env.round >= self.round {
                        self.pending.insert((env.round, env.src), env.payload);
                    }
                    if self.state == DlState::AwaitModels {
                        self.try_aggregate(ctx)
                    } else {
                        Ok(())
                    }
                }
                _ => Ok(()),
            },
            Wake::ComputeDone(out) => match out {
                ComputeOutput::Train { trainer, params, loss } => {
                    self.trainer = Some(trainer);
                    self.train_loss = loss;
                    let model = ParamVec::from_vec(params);
                    // Serialize once; every neighbor's envelope shares
                    // the same buffer (zero-copy broadcast), and the
                    // buffer itself comes from the arena's payload pool
                    // once recipients of earlier rounds let go.
                    // A Byzantine node swaps in its attack model here —
                    // its *own* params keep the honest training result,
                    // so the attack is sustained round after round. The
                    // attack payload depends only on (seed, id/group,
                    // round), never on event interleaving, which keeps
                    // adversarial runs bit-identical across workers.
                    // Flood copies overwrite in receivers' per-(round,
                    // sender) buffers; the damage is wire bytes + junk.
                    let t = ctx.trace_begin();
                    let (payload, copies): (Payload, u32) = match self
                        .byz
                        .as_ref()
                        .and_then(|b| b.payload_model(self.id, self.round, model.as_slice()))
                    {
                        Some((attack, copies)) => {
                            let attack = ParamVec::from_vec(attack);
                            (
                                self.sharing.outgoing_pooled(
                                    &attack,
                                    self.round,
                                    &mut self.scratch,
                                )?,
                                copies,
                            )
                        }
                        None => (
                            self.sharing.outgoing_pooled(&model, self.round, &mut self.scratch)?,
                            1,
                        ),
                    };
                    ctx.note_serialized(payload.len());
                    ctx.trace_phase(TracePhase::Encode, t);
                    let assign = self.assign.as_ref().context("no neighbor assignment")?;
                    for &(nbr, _) in &assign.neighbors {
                        for _ in 0..copies {
                            ctx.send(Envelope {
                                src: self.id,
                                dst: nbr,
                                round: self.round,
                                kind: MsgKind::Model,
                                sent_at_s: 0.0,
                                trace: 0,
                                payload: payload.clone(),
                            });
                        }
                    }
                    if self.parting_round() {
                        // Final online round: push the last update, then
                        // leave without pulling. Neighbor models still in
                        // flight after this wake are dropped by the
                        // scheduler; any delivered earlier just sit in
                        // `pending` and are discarded with the node. The
                        // parameter shard goes back to the store.
                        self.params.put(model.into_vec());
                        self.params.release();
                        self.state = DlState::Departed;
                        ctx.depart();
                        return Ok(());
                    }
                    self.model = Some(model);
                    self.state = DlState::AwaitModels;
                    self.try_aggregate(ctx)
                }
                ComputeOutput::Eval { trainer, test_loss, test_acc } => {
                    self.trainer = Some(trainer);
                    let c = ctx.counters();
                    self.log.as_mut().expect("log taken mid-run").push(Record {
                        round: self.round,
                        emu_time_s: ctx.now_s,
                        real_time_s: self.wall.elapsed().as_secs_f64(),
                        train_loss: self.train_loss,
                        test_loss,
                        test_acc,
                        bytes_sent: c.bytes_sent,
                        bytes_recv: c.bytes_recv,
                        msgs_sent: c.msgs_sent,
                        bytes_serialized: c.bytes_serialized,
                        late_msgs: 0,
                        dropped_msgs: 0,
                        mean_staleness_s: 0.0,
                        poisoned_mass_admitted: self.defense.poisoned_mass,
                        rejected_contribs: self.defense.rejected,
                        isolation_rate: self.defense.isolation_rate(),
                    });
                    self.round += 1;
                    self.begin_round(ctx)
                }
                ComputeOutput::Value(_) => bail!("unexpected compute output"),
            },
            // Synchronous nodes arm no timers.
            Wake::Timer(_) => Ok(()),
        }
    }

    fn done(&self) -> bool {
        matches!(self.state, DlState::Done | DlState::Departed)
    }

    fn take_log(&mut self) -> Option<NodeLog> {
        self.log.take()
    }

    fn attach_telemetry(&mut self, sink: &Telemetry) {
        if let Some(log) = &mut self.log {
            log.set_sink(sink.clone());
        }
    }
}

/// Event-driven secure-aggregation client (state-machine twin of
/// [`crate::node::SecureDlNode`]).
pub struct SecureDlNodeSm {
    id: usize,
    rounds: u64,
    eval_every: u64,
    trainer: Option<Trainer>,
    params: ParamSlot,
    graph: Arc<Graph>,
    weights: Arc<MixingWeights>,
    masker: Masker,
    test: Arc<Dataset>,
    step_time_s: f64,
    eval_time_s: f64,
    // --- runtime state ---
    neighbors: Vec<usize>,
    round: u64,
    state: DlState,
    train_loss: f64,
    pending: HashMap<(u64, usize), Payload>,
    /// Reusable f64 accumulator (+ decode staging) for the masked fold.
    scratch: Scratch,
    log: Option<NodeLog>,
    wall: Timer,
}

impl SecureDlNodeSm {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        rounds: u64,
        eval_every: u64,
        trainer: Trainer,
        params: ParamSlot,
        graph: Arc<Graph>,
        weights: Arc<MixingWeights>,
        masker: Masker,
        test: Arc<Dataset>,
        step_time_s: f64,
        eval_time_s: f64,
    ) -> SecureDlNodeSm {
        let neighbors = graph.neighbors_vec(id);
        SecureDlNodeSm {
            id,
            rounds,
            eval_every,
            trainer: Some(trainer),
            params,
            graph,
            weights,
            masker,
            test,
            step_time_s,
            eval_time_s,
            neighbors,
            round: 0,
            state: DlState::Training,
            train_loss: 0.0,
            pending: HashMap::new(),
            scratch: Scratch::new(),
            log: Some(NodeLog::new(id)),
            wall: Timer::start(),
        }
    }

    fn begin_round(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        if self.round == self.rounds {
            self.state = DlState::Done;
            return Ok(());
        }
        let trainer = self.trainer.take().context("trainer already in flight")?;
        let params = self.params.take();
        let duration_s = self.step_time_s * trainer.local_steps() as f64;
        ctx.start_compute(
            duration_s,
            Box::new(move || {
                let mut trainer = trainer;
                let (params, loss) = trainer.train_round(params)?;
                Ok(ComputeOutput::Train { trainer, params, loss })
            }),
        );
        self.state = DlState::Training;
        Ok(())
    }

    fn try_aggregate(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        if !self
            .neighbors
            .iter()
            .all(|&n| self.pending.contains_key(&(self.round, n)))
        {
            return Ok(());
        }
        // x <- w_self x + sum_i w_i x~_i (masks cancel pairwise); f64
        // accumulation in neighbor order, exactly as the threaded path,
        // fused straight from the raw-f32 payload bytes into the
        // arena's reusable accumulator.
        let t = ctx.trace_begin();
        let mut params = self.params.take();
        kernels::widen_scale(
            &mut self.scratch.doubles,
            &params,
            self.weights.self_weight(self.id),
        );
        for &nbr in &self.neighbors {
            let payload = self.pending.remove(&(self.round, nbr)).unwrap();
            let w = self.weights.weight(self.id, nbr);
            kernels::decode_le_axpy_widen(&mut self.scratch.doubles, w, &payload)?;
        }
        kernels::narrow(&mut params, &self.scratch.doubles);
        self.params.put(params);
        ctx.trace_phase(TracePhase::Aggregate, t);
        if (self.round + 1) % self.eval_every == 0 || self.round + 1 == self.rounds {
            let trainer = self.trainer.take().context("trainer already in flight")?;
            let job = EvalJob {
                trainer,
                params: self.params.to_vec(),
                test: Arc::clone(&self.test),
            };
            ctx.trace_compute_kind(TracePhase::Eval);
            ctx.start_compute(self.eval_time_s, job.into_compute());
            self.state = DlState::Evaluating;
            Ok(())
        } else {
            self.round += 1;
            self.begin_round(ctx)
        }
    }
}

impl EventNode for SecureDlNodeSm {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
        ctx.trace_round(self.round);
        match wake {
            Wake::Start => {
                for env in key_agreement_envelopes(
                    self.id,
                    self.masker.experiment_seed(),
                    &self.graph,
                    &self.neighbors,
                ) {
                    ctx.note_serialized(env.payload.len());
                    ctx.send(env);
                }
                self.begin_round(ctx)
            }
            Wake::Message(env) => match env.kind {
                MsgKind::Model => {
                    if env.round >= self.round {
                        self.pending.insert((env.round, env.src), env.payload);
                    }
                    if self.state == DlState::AwaitModels {
                        self.try_aggregate(ctx)
                    } else {
                        Ok(())
                    }
                }
                // Seed/key messages carry no state (both sides derive
                // deterministically); they exist for byte accounting.
                _ => Ok(()),
            },
            Wake::ComputeDone(out) => match out {
                ComputeOutput::Train { trainer, params, loss } => {
                    self.trainer = Some(trainer);
                    self.train_loss = loss;
                    // Masked payloads are per-receiver (each one is a
                    // distinct buffer), so serialization is counted per
                    // envelope here — there is nothing to share.
                    let t = ctx.trace_begin();
                    for env in secure_round_envelopes(
                        self.id,
                        self.round,
                        &params,
                        &self.graph,
                        &self.weights,
                        &self.masker,
                    ) {
                        ctx.note_serialized(env.payload.len());
                        ctx.send(env);
                    }
                    ctx.trace_phase(TracePhase::Encode, t);
                    self.params.put(params);
                    self.state = DlState::AwaitModels;
                    self.try_aggregate(ctx)
                }
                ComputeOutput::Eval { trainer, test_loss, test_acc } => {
                    self.trainer = Some(trainer);
                    let c = ctx.counters();
                    self.log.as_mut().expect("log taken mid-run").push(Record {
                        round: self.round,
                        emu_time_s: ctx.now_s,
                        real_time_s: self.wall.elapsed().as_secs_f64(),
                        train_loss: self.train_loss,
                        test_loss,
                        test_acc,
                        bytes_sent: c.bytes_sent,
                        bytes_recv: c.bytes_recv,
                        msgs_sent: c.msgs_sent,
                        bytes_serialized: c.bytes_serialized,
                        late_msgs: 0,
                        dropped_msgs: 0,
                        mean_staleness_s: 0.0,
                        poisoned_mass_admitted: 0.0,
                        rejected_contribs: 0,
                        isolation_rate: 0.0,
                    });
                    self.round += 1;
                    self.begin_round(ctx)
                }
                ComputeOutput::Value(_) => bail!("unexpected compute output"),
            },
            // Secure aggregation runs fully synchronously; no timers.
            Wake::Timer(_) => Ok(()),
        }
    }

    fn done(&self) -> bool {
        self.state == DlState::Done
    }

    fn take_log(&mut self) -> Option<NodeLog> {
        self.log.take()
    }

    fn attach_telemetry(&mut self, sink: &Telemetry) {
        if let Some(log) = &mut self.log {
            log.set_sink(sink.clone());
        }
    }
}

/// Event-driven centralized peer sampler (state-machine twin of
/// [`crate::node::PeerSampler`]): counts per-round `Ready` barriers and
/// replies with each node's neighbor row, drawn by the same
/// deterministic `draw_round` the threaded sampler uses.
pub struct SamplerSm {
    rank: usize,
    nodes: usize,
    rounds: u64,
    spec: String,
    seed: u64,
    avail: Availability,
    round: u64,
    ready: HashMap<u64, usize>,
    stopped: bool,
}

impl SamplerSm {
    pub fn new(
        rank: usize,
        nodes: usize,
        rounds: u64,
        spec: String,
        seed: u64,
        avail: Availability,
    ) -> SamplerSm {
        SamplerSm {
            rank,
            nodes,
            rounds,
            spec,
            seed,
            avail,
            round: 0,
            ready: HashMap::new(),
            stopped: false,
        }
    }

    /// Serve every round whose barrier is complete.
    fn pump(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        while self.round < self.rounds
            && self.ready.get(&self.round).copied().unwrap_or(0) >= self.nodes
        {
            self.ready.remove(&self.round);
            let assignments =
                draw_round(&self.spec, self.seed, &self.avail, self.nodes, self.round)?;
            for (node, assign) in assignments.into_iter().enumerate() {
                ctx.send(Envelope {
                    src: self.rank,
                    dst: node,
                    round: self.round,
                    kind: MsgKind::Neighbors,
                    sent_at_s: 0.0,
                    trace: 0,
                    payload: encode_neighbors(&assign).into(),
                });
            }
            self.round += 1;
        }
        Ok(())
    }
}

impl EventNode for SamplerSm {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
        ctx.trace_round(self.round);
        match wake {
            Wake::Start => Ok(()),
            Wake::Message(env) => {
                if env.kind != MsgKind::Control {
                    bail!("peer sampler got unexpected {:?}", env.kind);
                }
                match decode_control(&env.payload)? {
                    Control::Ready { round } => {
                        if round >= self.round {
                            *self.ready.entry(round).or_insert(0) += 1;
                        }
                        self.pump(ctx)
                    }
                    Control::Stop => {
                        self.stopped = true;
                        Ok(())
                    }
                }
            }
            Wake::ComputeDone(_) => bail!("sampler schedules no compute"),
            Wake::Timer(_) => bail!("sampler arms no timers"),
        }
    }

    fn done(&self) -> bool {
        self.stopped || self.round == self.rounds
    }
}

/// Most recent arrival offsets a quantile deadline considers. Bounds
/// both memory and the per-round clone-and-sort in
/// [`DeadlineSpec::window_s`] on long runs.
const OFFSET_HISTORY_CAP: usize = 512;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum AsyncState {
    /// Local training in flight on the worker pool.
    Training,
    /// Trained and broadcast; waiting for the deadline timer.
    AwaitDeadline,
    /// Offline round (churn trace): idling for one window, no training.
    Idling,
    /// Evaluation in flight on the worker pool.
    Evaluating,
    /// All rounds finished.
    Done,
    /// Left for good mid-experiment (churn-trace departure).
    Departed,
}

/// Asynchronous D-PSGD client: the `mode = "async_dl"` execution model.
///
/// Unlike [`DlNodeSm`] there is **no** `AwaitModels` completeness
/// requirement. Each round the node
///
/// 1. arms a *deadline timer* ([`crate::node::DeadlineSpec`]) and starts
///    training,
/// 2. broadcasts its model to every neighbor the moment training
///    finishes (the scheduler stamps the envelope's `sent_at_s`),
/// 3. when the deadline fires (and training is done), aggregates
///    **whatever neighbor models have arrived**, weighting each by the
///    staleness policy applied to its virtual age; weight shed by aged
///    or absent neighbors folds into the self-weight so the mixing row
///    stays stochastic,
/// 4. then immediately begins the next round.
///
/// A message that was already in flight when a deadline fired is *late*:
/// the [`crate::node::LatePolicy`] either buffers it for the next
/// round's aggregation or drops it, counted per node either way. Only
/// the freshest buffered model per neighbor is kept (per-sender FIFO
/// makes later arrivals strictly newer).
///
/// Because everything is driven by virtual deadlines, a slow straggler
/// delays nobody, and a neighbor killed mid-round by a `crashes:` churn
/// trace simply stops contributing models — its neighbors' timers fire
/// regardless, so the run completes instead of deadlocking.
pub struct AsyncDlNodeSm {
    id: usize,
    rounds: u64,
    eval_every: u64,
    trainer: Option<Trainer>,
    sharing: Box<dyn Sharing>,
    params: ParamSlot,
    /// Static mixing row (async mode is static-topology only).
    self_weight: f64,
    neighbors: Vec<(usize, f64)>,
    test: Arc<Dataset>,
    /// Round-indexed availability trace (`None` = always on).
    churn: Option<Arc<ChurnTrace>>,
    /// Byzantine attack roster (`None` = every node honest).
    byz: Option<Arc<ByzantineRoster>>,
    eval_time_s: f64,
    /// Own per-round training time (step time × local steps).
    round_compute_s: f64,
    policy: AsyncPolicy,
    // --- runtime state ---
    round: u64,
    state: AsyncState,
    /// Virtual instant the current round's collection window opened.
    window_start_s: f64,
    /// Virtual instant of the last *aggregating* deadline.
    last_deadline_s: f64,
    deadline_timer: Option<u64>,
    /// The deadline fired while training was still in flight.
    deadline_passed: bool,
    /// Post-training model parked until the deadline.
    model: Option<ParamVec>,
    train_loss: f64,
    /// Freshest buffered model per neighbor: src -> (sent_at_s, payload).
    inbox: HashMap<usize, (f64, Payload)>,
    /// Arrival offsets (arrival - window start) for quantile deadlines.
    /// Only fed under a `p<q>` spec, and bounded to the most recent
    /// [`OFFSET_HISTORY_CAP`] observations (rotating overwrite).
    arrival_offsets: Vec<f64>,
    /// Next rotating slot in `arrival_offsets` once it reaches the cap.
    offset_cursor: usize,
    stats: AsyncStats,
    /// Reusable hot-path buffers, as in [`DlNodeSm`].
    scratch: Scratch,
    /// Cumulative defense accounting (admitted/rejected contributions).
    defense: DefenseStats,
    log: Option<NodeLog>,
    wall: Timer,
}

impl AsyncDlNodeSm {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        rounds: u64,
        eval_every: u64,
        trainer: Trainer,
        sharing: Box<dyn Sharing>,
        params: ParamSlot,
        self_weight: f64,
        neighbors: Vec<(usize, f64)>,
        test: Arc<Dataset>,
        churn: Option<Arc<ChurnTrace>>,
        byz: Option<Arc<ByzantineRoster>>,
        step_time_s: f64,
        eval_time_s: f64,
        policy: AsyncPolicy,
    ) -> AsyncDlNodeSm {
        let round_compute_s = step_time_s * trainer.local_steps() as f64;
        AsyncDlNodeSm {
            id,
            rounds,
            eval_every,
            trainer: Some(trainer),
            sharing,
            params,
            self_weight,
            neighbors,
            test,
            churn,
            byz,
            eval_time_s,
            round_compute_s,
            policy,
            round: 0,
            state: AsyncState::Training,
            window_start_s: 0.0,
            last_deadline_s: 0.0,
            deadline_timer: None,
            deadline_passed: false,
            model: None,
            train_loss: 0.0,
            inbox: HashMap::new(),
            arrival_offsets: Vec::new(),
            offset_cursor: 0,
            stats: AsyncStats::default(),
            scratch: Scratch::new(),
            defense: DefenseStats::default(),
            log: Some(NodeLog::new(id)),
            wall: Timer::start(),
        }
    }

    /// Record one arrival offset for the quantile-adaptive deadline.
    /// No-op under fixed/factor specs (the history is never read), and
    /// bounded: once full, the oldest observation is overwritten, so a
    /// long run tracks the *recent* arrival distribution at O(1) cost.
    fn record_offset(&mut self, offset_s: f64) {
        if !matches!(self.policy.deadline, DeadlineSpec::Quantile(_)) {
            return;
        }
        if self.arrival_offsets.len() < OFFSET_HISTORY_CAP {
            self.arrival_offsets.push(offset_s);
        } else {
            self.arrival_offsets[self.offset_cursor] = offset_s;
            self.offset_cursor = (self.offset_cursor + 1) % OFFSET_HISTORY_CAP;
        }
    }

    /// True when the trace says this is the node's last online round.
    fn parting_round(&self) -> bool {
        self.churn
            .as_ref()
            .is_some_and(|tr| tr.last_online_round(self.id) == Some(self.round))
    }

    /// Open the next round's collection window: arm the deadline and
    /// start training (or idle one window on an offline round).
    fn begin_round(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        if self.round == self.rounds {
            self.state = AsyncState::Done;
            return Ok(());
        }
        if let Some(tr) = &self.churn {
            if !tr.active(self.id, self.round) {
                if tr.last_online_round(self.id).map_or(true, |l| l < self.round) {
                    // Never coming back: leave for good and give the
                    // parameter shard back to the store.
                    self.params.release();
                    self.state = AsyncState::Departed;
                    ctx.depart();
                    return Ok(());
                }
                // Offline round: idle one window of virtual time without
                // training or broadcasting, then move on.
                self.window_start_s = ctx.now_s;
                let window = self
                    .policy
                    .deadline
                    .window_s(self.round_compute_s, &self.arrival_offsets);
                self.deadline_timer = Some(ctx.set_timer(window));
                self.state = AsyncState::Idling;
                return Ok(());
            }
        }
        self.window_start_s = ctx.now_s;
        self.deadline_passed = false;
        let window = self
            .policy
            .deadline
            .window_s(self.round_compute_s, &self.arrival_offsets);
        self.deadline_timer = Some(ctx.set_timer(window));
        let trainer = self.trainer.take().context("trainer already in flight")?;
        let params = self.params.take();
        ctx.start_compute(
            self.round_compute_s,
            Box::new(move || {
                let mut trainer = trainer;
                let (params, loss) = trainer.train_round(params)?;
                Ok(ComputeOutput::Train { trainer, params, loss })
            }),
        );
        self.state = AsyncState::Training;
        Ok(())
    }

    /// Aggregate whatever arrived, staleness-weighted, then advance.
    fn aggregate_and_advance(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        let t = ctx.trace_begin();
        let mut model = self.model.take().context("no trained model to aggregate")?;
        // Deterministic: walk the static neighbor row in order, pulling
        // each neighbor's freshest buffered model if one arrived.
        let mut self_w = self.self_weight;
        let mut msgs: Vec<(usize, f64, Payload)> = Vec::new();
        for &(nbr, w) in &self.neighbors {
            match self.inbox.remove(&nbr) {
                Some((sent_at_s, payload)) => {
                    let age = (ctx.now_s - sent_at_s).max(0.0);
                    let eff = w * self.policy.staleness.factor(age);
                    self_w += w - eff;
                    self.stats.staleness_sum_s += age;
                    self.stats.aggregated += 1;
                    msgs.push((nbr, eff, payload));
                }
                // Nothing arrived in time: the absent neighbor's weight
                // folds into the self-weight (the row stays stochastic).
                None => self_w += w,
            }
        }
        {
            let received: Vec<Received> = msgs
                .iter()
                .map(|(src, weight, payload)| Received {
                    src: *src,
                    weight: *weight,
                    payload: payload.as_slice(),
                })
                .collect();
            let tf = ctx.trace_begin();
            self.sharing
                .aggregate_with(&mut model, self_w, &received, &mut self.scratch)?;
            // Nested fold span, as in [`DlNodeSm::try_aggregate`].
            if !self.scratch.partials.is_empty() {
                ctx.trace_phase(TracePhase::Fold, tf);
            }
            // Defense accounting, as in [`DlNodeSm::try_aggregate`].
            if let Some(roster) = &self.byz {
                let report = self.sharing.defense_report();
                for (i, r) in received.iter().enumerate() {
                    let admitted =
                        report.map_or(1.0, |rep| rep.admitted.get(i).copied().unwrap_or(1.0));
                    self.defense.observe(roster.is_byzantine(r.src), r.weight, admitted);
                }
            }
        }
        self.params.put(model.into_vec());
        ctx.trace_phase(TracePhase::Aggregate, t);
        if (self.round + 1) % self.eval_every == 0 || self.round + 1 == self.rounds {
            let trainer = self.trainer.take().context("trainer already in flight")?;
            let job = EvalJob {
                trainer,
                params: self.params.to_vec(),
                test: Arc::clone(&self.test),
            };
            ctx.trace_compute_kind(TracePhase::Eval);
            ctx.start_compute(self.eval_time_s, job.into_compute());
            self.state = AsyncState::Evaluating;
            Ok(())
        } else {
            self.round += 1;
            self.begin_round(ctx)
        }
    }
}

impl EventNode for AsyncDlNodeSm {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
        ctx.trace_round(self.round);
        match wake {
            Wake::Start => self.begin_round(ctx),
            Wake::Message(env) => {
                if !matches!(env.kind, MsgKind::Model)
                    || matches!(self.state, AsyncState::Done | AsyncState::Departed)
                {
                    return Ok(());
                }
                // Feed the quantile-deadline history (p<q> specs only),
                // but only while the collection window is open: during
                // Evaluating/Idling, `window_start_s` belongs to an
                // already-closed window, so offsets measured against it
                // would be inflated by eval time and balloon the
                // adaptive deadline.
                if matches!(self.state, AsyncState::Training | AsyncState::AwaitDeadline) {
                    self.record_offset((ctx.now_s - self.window_start_s).max(0.0));
                }
                // Late = already in flight when the last aggregating
                // deadline fired (the cut missed it).
                if env.sent_at_s < self.last_deadline_s {
                    match self.policy.late {
                        LatePolicy::Drop => {
                            self.stats.dropped_msgs += 1;
                            return Ok(());
                        }
                        LatePolicy::Buffer => self.stats.late_msgs += 1,
                    }
                }
                // Freshest model per neighbor wins (per-sender FIFO makes
                // later arrivals strictly newer).
                self.inbox.insert(env.src, (env.sent_at_s, env.payload));
                Ok(())
            }
            Wake::Timer(id) => {
                if self.deadline_timer != Some(id) {
                    return Ok(()); // stale timer from a superseded round
                }
                self.deadline_timer = None;
                match self.state {
                    AsyncState::Training => {
                        // Deadline fired mid-train: close the window now,
                        // aggregate the moment training completes.
                        self.last_deadline_s = ctx.now_s;
                        self.deadline_passed = true;
                        Ok(())
                    }
                    AsyncState::AwaitDeadline => {
                        self.last_deadline_s = ctx.now_s;
                        self.aggregate_and_advance(ctx)
                    }
                    AsyncState::Idling => {
                        self.round += 1;
                        self.begin_round(ctx)
                    }
                    _ => Ok(()),
                }
            }
            Wake::ComputeDone(out) => match out {
                ComputeOutput::Train { trainer, params, loss } => {
                    self.trainer = Some(trainer);
                    self.train_loss = loss;
                    let model = ParamVec::from_vec(params);
                    // One serialization, shared by every recipient —
                    // in a pooled buffer reused across rounds. Byzantine
                    // nodes swap in their attack model, exactly as in
                    // [`DlNodeSm`]; in async mode flood duplicates also
                    // overwrite (freshest-per-sender inbox), so the
                    // damage is wire bytes plus junk content.
                    let t = ctx.trace_begin();
                    let (payload, copies): (Payload, u32) = match self
                        .byz
                        .as_ref()
                        .and_then(|b| b.payload_model(self.id, self.round, model.as_slice()))
                    {
                        Some((attack, copies)) => {
                            let attack = ParamVec::from_vec(attack);
                            (
                                self.sharing.outgoing_pooled(
                                    &attack,
                                    self.round,
                                    &mut self.scratch,
                                )?,
                                copies,
                            )
                        }
                        None => (
                            self.sharing.outgoing_pooled(&model, self.round, &mut self.scratch)?,
                            1,
                        ),
                    };
                    ctx.note_serialized(payload.len());
                    ctx.trace_phase(TracePhase::Encode, t);
                    for &(nbr, _) in &self.neighbors {
                        for _ in 0..copies {
                            ctx.send(Envelope {
                                src: self.id,
                                dst: nbr,
                                round: self.round,
                                kind: MsgKind::Model,
                                sent_at_s: 0.0, // stamped by the scheduler
                                trace: 0,
                                payload: payload.clone(),
                            });
                        }
                    }
                    if self.parting_round() {
                        // Push the final update, then leave without
                        // pulling; disarm the pending deadline and give
                        // the parameter shard back to the store.
                        if let Some(id) = self.deadline_timer.take() {
                            ctx.cancel_timer(id);
                        }
                        self.params.put(model.into_vec());
                        self.params.release();
                        self.state = AsyncState::Departed;
                        ctx.depart();
                        return Ok(());
                    }
                    self.model = Some(model);
                    if self.deadline_passed {
                        // The window already closed while we trained.
                        self.aggregate_and_advance(ctx)
                    } else {
                        self.state = AsyncState::AwaitDeadline;
                        Ok(())
                    }
                }
                ComputeOutput::Eval { trainer, test_loss, test_acc } => {
                    self.trainer = Some(trainer);
                    let c = ctx.counters();
                    self.log.as_mut().expect("log taken mid-run").push(Record {
                        round: self.round,
                        emu_time_s: ctx.now_s,
                        real_time_s: self.wall.elapsed().as_secs_f64(),
                        train_loss: self.train_loss,
                        test_loss,
                        test_acc,
                        bytes_sent: c.bytes_sent,
                        bytes_recv: c.bytes_recv,
                        msgs_sent: c.msgs_sent,
                        bytes_serialized: c.bytes_serialized,
                        late_msgs: self.stats.late_msgs,
                        dropped_msgs: self.stats.dropped_msgs,
                        mean_staleness_s: self.stats.mean_staleness_s(),
                        poisoned_mass_admitted: self.defense.poisoned_mass,
                        rejected_contribs: self.defense.rejected,
                        isolation_rate: self.defense.isolation_rate(),
                    });
                    self.round += 1;
                    self.begin_round(ctx)
                }
                ComputeOutput::Value(_) => bail!("unexpected compute output"),
            },
        }
    }

    fn done(&self) -> bool {
        matches!(self.state, AsyncState::Done | AsyncState::Departed)
    }

    fn take_log(&mut self) -> Option<NodeLog> {
        self.log.take()
    }

    fn attach_telemetry(&mut self, sink: &Telemetry) {
        if let Some(log) = &mut self.log {
            log.set_sink(sink.clone());
        }
    }
}
