//! Minimal leveled logger (env_logger is unavailable offline).
//!
//! Thread-safe, writes to stderr with wall-clock-relative timestamps.
//! Level comes from `DECENTRA_LOG` (error|warn|info|debug|trace), default
//! `info`. Per-node log *files* are handled by `metrics`, not here.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: Lazy<Instant> = Lazy::new(Instant::now);

/// Initialize from the environment; idempotent.
pub fn init() {
    let lvl = std::env::var("DECENTRA_LOG")
        .map(|v| Level::from_str(&v))
        .unwrap_or(Level::Info);
    set_level(lvl);
    Lazy::force(&START);
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.elapsed();
    let line = format!(
        "[{:>8.3}s {} {}] {}\n",
        t.as_secs_f64(),
        level.tag(),
        target,
        msg
    );
    let _ = std::io::stderr().write_all(line.as_bytes());
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::from_str("ERROR"), Level::Error);
        assert_eq!(Level::from_str("debug"), Level::Debug);
        assert_eq!(Level::from_str("bogus"), Level::Info);
    }
}
