//! IEEE-754 binary16 (half precision) conversion.
//!
//! Used by the fp16 compressor in `compression` — the paper's compression
//! module packages "general-purpose compression algorithms for
//! floating-point lists"; halving the width is the cheapest of those.
//! Round-to-nearest-even on encode, exact on decode.

/// Convert f32 -> f16 bits (round-to-nearest-even, IEEE semantics
/// including subnormals, infinities, and NaN).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf stays inf; any NaN maps to the canonical quiet NaN.
        return if mant != 0 { sign | 0x7E00 } else { sign | 0x7C00 };
    }

    // Unbiased exponent, rebiased for f16 (bias 15 vs 127).
    let e16 = exp - 127 + 15;
    if e16 >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e16 <= 0 {
        // Subnormal or zero in f16.
        if e16 < -10 {
            return sign; // underflow to signed zero
        }
        // Add implicit leading 1, shift into subnormal position.
        let m = mant | 0x0080_0000;
        let shift = (14 - e16) as u32;
        let half = 1u32 << (shift - 1);
        let mut v = m >> shift;
        // Round to nearest even.
        let rem = m & ((1 << shift) - 1);
        if rem > half || (rem == half && (v & 1) == 1) {
            v += 1;
        }
        return sign | v as u16;
    }

    // Normal: take the top 10 mantissa bits with round-to-nearest-even.
    let mut v = ((e16 as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1FFF;
    if rem > 0x1000 || (rem == 0x1000 && (v & 1) == 1) {
        v += 1; // may carry into the exponent, which is exactly correct
    }
    sign | v as u16
}

/// Convert f16 bits -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // Subnormal: normalize.
            let mut e = 127 - 15 + 1;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x03FF) << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // inf / nan
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(x: f32) -> f32 {
        f16_bits_to_f32(f32_to_f16_bits(x))
    }

    #[test]
    fn exact_values() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0] {
            assert_eq!(roundtrip(v), v, "value {v}");
        }
    }

    #[test]
    fn signed_zero_preserved() {
        assert_eq!(roundtrip(-0.0).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn infinities_and_nan() {
        assert_eq!(roundtrip(f32::INFINITY), f32::INFINITY);
        assert_eq!(roundtrip(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(roundtrip(f32::NAN).is_nan());
    }

    #[test]
    fn overflow_saturates_to_inf() {
        assert_eq!(roundtrip(1.0e6), f32::INFINITY);
        assert_eq!(roundtrip(-1.0e6), f32::NEG_INFINITY);
    }

    #[test]
    fn tiny_values_flush_or_subnormal() {
        // Smallest f16 subnormal is ~5.96e-8.
        assert_eq!(roundtrip(1.0e-10), 0.0);
        let sub = 6.0e-8f32;
        let rt = roundtrip(sub);
        assert!(rt > 0.0 && (rt - sub).abs() / sub < 0.5);
    }

    #[test]
    fn relative_error_bounded_for_normals() {
        // f16 has 11 significant bits -> rel. error <= 2^-11.
        let mut x = 6.2e-5f32; // just above the smallest normal f16
        while x < 6.0e4 {
            let rt = roundtrip(x);
            let rel = ((rt - x) / x).abs();
            assert!(rel <= 4.9e-4, "x={x} rt={rt} rel={rel}");
            x *= 1.37;
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16;
        // RNE picks the even mantissa (1.0).
        let x = 1.0 + (2.0f32).powi(-11);
        assert_eq!(roundtrip(x), 1.0);
        // 1 + 3*2^-11 is halfway between the 1st and 2nd steps; RNE picks
        // the even (2nd) step.
        let y = 1.0 + 3.0 * (2.0f32).powi(-11);
        assert_eq!(roundtrip(y), 1.0 + 2.0 * (2.0f32).powi(-10));
    }

    #[test]
    fn exhaustive_decode_encode_identity() {
        // Every finite f16 must survive decode->encode exactly.
        for h in 0u16..=0xFFFF {
            let exp = (h >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/nan handled above
            }
            let f = f16_bits_to_f32(h);
            assert_eq!(f32_to_f16_bits(f), h, "bits {h:#06x}");
        }
    }
}
