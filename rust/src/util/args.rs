//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positionals, with
//! typed accessors and a generated usage string. This mirrors the role of
//! DecentralizePy's `utils` arg-parsing helpers.

use std::collections::BTreeMap;

/// Declarative option spec used for usage/help output.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum ArgError {
    #[error("missing value for --{0}")]
    MissingValue(String),
    #[error("missing required option --{0}")]
    MissingRequired(String),
    #[error("invalid value for --{0}: {1:?}")]
    Invalid(String, String),
}

impl Args {
    /// Parse raw tokens. `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        flag_names: &[&str],
    ) -> Result<Args, ArgError> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    args.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| ArgError::MissingValue(body.to_string()))?;
                    args.opts.insert(body.to_string(), v);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args, ArgError> {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn require(&self, name: &str) -> Result<&str, ArgError> {
        self.get(name)
            .ok_or_else(|| ArgError::MissingRequired(name.to_string()))
    }

    pub fn get_parse<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|_| ArgError::Invalid(name.to_string(), s.to_string())),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional (conventionally the subcommand).
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

/// Render a usage block from option specs.
pub fn usage(program: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut out = format!("{program} — {about}\n\nOptions:\n");
    for s in specs {
        let head = if s.is_flag {
            format!("  --{}", s.name)
        } else {
            format!("  --{} <v>", s.name)
        };
        out.push_str(&format!("{head:<28}{}", s.help));
        if let Some(d) = s.default {
            out.push_str(&format!(" [default: {d}]"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str], flags: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn key_value_styles() {
        let a = parse(&["--nodes", "16", "--rounds=40"], &[]);
        assert_eq!(a.get("nodes"), Some("16"));
        assert_eq!(a.get("rounds"), Some("40"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["run", "--verbose", "--lr", "0.05", "extra"], &["verbose"]);
        assert_eq!(a.command(), Some("run"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.positional(), &["run".to_string(), "extra".to_string()]);
    }

    #[test]
    fn typed_parsing() {
        let a = parse(&["--n", "42"], &[]);
        assert_eq!(a.get_parse("n", 0usize).unwrap(), 42);
        assert_eq!(a.get_parse("missing", 7i32).unwrap(), 7);
        assert!(a.get_parse::<f64>("n", 0.0).is_ok());
    }

    #[test]
    fn invalid_value_errors() {
        let a = parse(&["--n", "abc"], &[]);
        assert!(matches!(a.get_parse::<usize>("n", 0), Err(ArgError::Invalid(..))));
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(vec!["--x".to_string()], &[]);
        assert!(matches!(r, Err(ArgError::MissingValue(_))));
    }

    #[test]
    fn require_works() {
        let a = parse(&["--cfg", "f.json"], &[]);
        assert_eq!(a.require("cfg").unwrap(), "f.json");
        assert!(a.require("nope").is_err());
    }

    #[test]
    fn usage_renders() {
        let u = usage(
            "decentra",
            "decentralized learning",
            &[OptSpec { name: "nodes", help: "node count", default: Some("16"), is_flag: false }],
        );
        assert!(u.contains("--nodes"));
        assert!(u.contains("default: 16"));
    }
}
