//! Cross-cutting utilities: JSON, CLI args, logging, stats, f16, misc.
//!
//! Mirrors DecentralizePy's `utils` module (dict manipulation, argument
//! parsing) plus the pieces this offline environment must provide itself
//! (JSON codec, logger, bench-grade stats).

pub mod args;
pub mod f16;
pub mod json;
pub mod logger;
pub mod stats;

use std::time::{Duration, Instant};

/// Simple scope timer for coarse phase measurements.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Format a byte count with binary units ("1.5 MiB").
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration compactly ("1.25s", "310ms").
pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.0}ms", s * 1e3)
    } else {
        format!("{:.0}µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(12), "12 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_duration_units() {
        assert_eq!(human_duration(Duration::from_millis(310)), "310ms");
        assert_eq!(human_duration(Duration::from_secs_f64(1.25)), "1.25s");
        assert_eq!(human_duration(Duration::from_micros(42)), "42µs");
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
