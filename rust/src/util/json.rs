//! Minimal JSON value model, parser, and writer.
//!
//! serde is not available in this offline environment, so the framework
//! carries its own small JSON implementation. It is used for the artifact
//! manifest, experiment configs, per-node metric logs (JSONL), and result
//! aggregation. Supports the full JSON grammar; numbers are kept as f64
//! (with an i64 fast path preserved through `as_i64`).

use std::collections::BTreeMap;


/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e18 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index access; returns `Json::Null` out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Convenience constructors.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Serialize compactly (no whitespace).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null like most tolerant writers.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e18 {
        out.push_str(&(n as i64).to_string());
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Handle UTF-16 surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad \\u escape"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.peek().ok_or_else(|| self.err("bad hex"))?;
            self.pos += 1;
            v = v * 16
                + match b {
                    b'0'..=b'9' => (b - b'0') as u32,
                    b'a'..=b'f' => (b - b'a' + 10) as u32,
                    b'A'..=b'F' => (b - b'A' + 10) as u32,
                    _ => return Err(self.err("bad hex digit")),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(0).as_i64(), Some(1));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ é 😀"));
    }

    #[test]
    fn roundtrip_dump_parse() {
        let v = Json::obj(vec![
            ("name", Json::str("node-1")),
            ("loss", Json::num(0.125)),
            ("ok", Json::Bool(true)),
            ("tags", Json::arr(vec![Json::num(1), Json::str("a")])),
            ("none", Json::Null),
        ]);
        let text = v.dump();
        assert_eq!(parse(&text).unwrap(), v);
        let pretty = v.pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integer_precision_preserved() {
        let v = parse("1234567890123").unwrap();
        assert_eq!(v.as_i64(), Some(1234567890123));
        assert_eq!(v.dump(), "1234567890123");
    }

    #[test]
    fn missing_key_is_null() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        assert!(v.get("zzz").is_null());
        assert!(v.idx(0).is_null());
    }
}
