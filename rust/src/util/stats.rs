//! Summary statistics used for experiment reporting.
//!
//! The paper reports the mean over 5 seeds with a 95% confidence interval;
//! [`MeanCi`] implements exactly that (normal-approximation CI, which is
//! what matplotlib/seaborn-style error bands use at these sample counts).

/// Mean / variance / extrema accumulator (Welford's online algorithm).
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Half-width of the 95% confidence interval of the mean.
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        1.96 * self.std() / (self.n as f64).sqrt()
    }
}

/// Mean with a 95% CI, the unit the figures report per point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MeanCi {
    pub mean: f64,
    pub ci95: f64,
    pub n: u64,
}

/// Aggregate a slice of per-seed (or per-node) values into mean ± CI.
pub fn mean_ci(values: &[f64]) -> MeanCi {
    let mut r = Running::new();
    for &v in values {
        r.push(v);
    }
    MeanCi { mean: r.mean(), ci95: r.ci95(), n: r.count() }
}

/// Percentile over a copy of the data (linear interpolation, like numpy).
pub fn percentile(values: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q), "percentile out of range");
    if values.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median convenience wrapper.
pub fn median(values: &[f64]) -> f64 {
    percentile(values, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        let data = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for d in data {
            r.push(d);
        }
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
        assert_eq!(r.count(), 8);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = mean_ci(&[1.0, 2.0, 3.0]);
        let many: Vec<f64> = (0..300).map(|i| (i % 3) as f64 + 1.0).collect();
        let b = mean_ci(&many);
        assert!(b.ci95 < a.ci95);
        assert!((a.mean - 2.0).abs() < 1e-12);
        assert!((b.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn single_sample_has_zero_ci() {
        let m = mean_ci(&[5.0]);
        assert_eq!(m.mean, 5.0);
        assert_eq!(m.ci95, 0.0);
    }

    #[test]
    fn percentiles() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((median(&v) - 2.5).abs() < 1e-12);
        assert!((percentile(&v, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn percentile_range_checked() {
        percentile(&[1.0], 101.0);
    }
}
