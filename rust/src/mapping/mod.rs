//! Node ↔ machine mapping (the paper's *Mapping* module): associates
//! global node ids with (machine, process) slots so the same experiment
//! config runs in-process, on one cluster, or across WAN hosts.

use std::net::SocketAddr;

use anyhow::{bail, Context, Result};

/// Assignment of `nodes` global ranks onto `machines` hosts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mapping {
    nodes: usize,
    machines: usize,
    /// machine -> number of node processes on it.
    per_machine: Vec<usize>,
}

impl Mapping {
    /// Linear mapping: node `i` lives on machine `i / ceil(n/m)`.
    /// Mirrors DecentralizePy's `Linear` mapping.
    pub fn linear(nodes: usize, machines: usize) -> Mapping {
        assert!(machines > 0 && nodes > 0);
        let per = nodes.div_ceil(machines);
        let mut per_machine = vec![0usize; machines];
        for i in 0..nodes {
            per_machine[(i / per).min(machines - 1)] += 1;
        }
        Mapping { nodes, machines, per_machine }
    }

    /// Explicit per-machine process counts.
    pub fn explicit(per_machine: Vec<usize>) -> Mapping {
        let nodes = per_machine.iter().sum();
        Mapping { nodes, machines: per_machine.len(), per_machine }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn machines(&self) -> usize {
        self.machines
    }

    /// Global rank -> (machine id, local process rank).
    pub fn locate(&self, rank: usize) -> (usize, usize) {
        assert!(rank < self.nodes, "rank out of range");
        let mut offset = 0usize;
        for (m, &cnt) in self.per_machine.iter().enumerate() {
            if rank < offset + cnt {
                return (m, rank - offset);
            }
            offset += cnt;
        }
        unreachable!("mapping invariant violated");
    }

    /// (machine, local rank) -> global rank.
    pub fn global_rank(&self, machine: usize, local: usize) -> usize {
        assert!(machine < self.machines);
        assert!(local < self.per_machine[machine], "local rank out of range");
        self.per_machine[..machine].iter().sum::<usize>() + local
    }

    /// Ranks hosted on `machine`.
    pub fn ranks_on(&self, machine: usize) -> std::ops::Range<usize> {
        let start: usize = self.per_machine[..machine].iter().sum();
        start..start + self.per_machine[machine]
    }

    /// Build the per-node socket address table from per-machine base
    /// addresses: node with local rank `l` on machine `m` listens on
    /// `hosts[m]` with port `base_port(m) + l`.
    pub fn address_table(&self, hosts: &[SocketAddr]) -> Result<Vec<SocketAddr>> {
        if hosts.len() != self.machines {
            bail!("{} hosts for {} machines", hosts.len(), self.machines);
        }
        let mut out = Vec::with_capacity(self.nodes);
        for rank in 0..self.nodes {
            let (m, local) = self.locate(rank);
            let mut addr = hosts[m];
            let port = addr
                .port()
                .checked_add(local as u16)
                .context("port overflow in address table")?;
            addr.set_port(port);
            out.push(addr);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_even_split() {
        let m = Mapping::linear(16, 4);
        assert_eq!(m.nodes(), 16);
        assert_eq!(m.locate(0), (0, 0));
        assert_eq!(m.locate(3), (0, 3));
        assert_eq!(m.locate(4), (1, 0));
        assert_eq!(m.locate(15), (3, 3));
    }

    #[test]
    fn linear_uneven_split() {
        let m = Mapping::linear(10, 3); // ceil(10/3)=4 -> 4,4,2
        assert_eq!(m.locate(9), (2, 1));
        assert_eq!(m.ranks_on(0), 0..4);
        assert_eq!(m.ranks_on(2), 8..10);
    }

    #[test]
    fn roundtrip_locate_global() {
        let m = Mapping::explicit(vec![3, 1, 5]);
        for rank in 0..m.nodes() {
            let (mach, local) = m.locate(rank);
            assert_eq!(m.global_rank(mach, local), rank);
        }
    }

    #[test]
    fn address_table_ports() {
        let m = Mapping::explicit(vec![2, 2]);
        let hosts: Vec<SocketAddr> =
            vec!["10.0.0.1:9000".parse().unwrap(), "10.0.0.2:9100".parse().unwrap()];
        let table = m.address_table(&hosts).unwrap();
        assert_eq!(table[0], "10.0.0.1:9000".parse().unwrap());
        assert_eq!(table[1], "10.0.0.1:9001".parse().unwrap());
        assert_eq!(table[3], "10.0.0.2:9101".parse().unwrap());
    }

    #[test]
    fn address_table_host_count_checked() {
        let m = Mapping::linear(4, 2);
        assert!(m.address_table(&["1.2.3.4:1".parse().unwrap()]).is_err());
    }

    #[test]
    #[should_panic]
    fn locate_out_of_range() {
        Mapping::linear(4, 2).locate(4);
    }
}
