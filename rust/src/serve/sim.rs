//! Artifact-free synthetic driver for the serve daemon.
//!
//! The real [`crate::coordinator::run_experiment`] path needs lowered
//! HLO artifacts on disk; the daemon's smoke tests, the API load bench,
//! and CI all want a run that exercises the *control plane* — scheduler,
//! sharing, cancellation, telemetry — without them. [`run_sim`] is that
//! run: the same D-PSGD round structure on the same virtual-time
//! [`Scheduler`], but with "training" replaced by a deterministic pull
//! toward a seeded per-node target vector (a stand-in for non-IID local
//! objectives). Everything observable from the outside — round records,
//! telemetry events, cancellation semantics, aggregated series — flows
//! through exactly the machinery a real run uses.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::communication::shaper::NetworkModel;
use crate::communication::{Envelope, MsgKind};
use crate::config::ExperimentConfig;
use crate::coordinator::{RunHooks, RunResult};
use crate::graph::{from_spec, metropolis_hastings};
use crate::kernels::Scratch;
use crate::metrics::{aggregate, NodeLog, Record, Telemetry, TelemetryEvent};
use crate::model::ParamVec;
use crate::rng::{mix_seed, Xoshiro256pp};
use crate::scheduler::{EventNode, NodeCtx, Scheduler, Wake};
use crate::sharing::{self, Received, Sharing};
use crate::store::Payload;
use crate::trace::Phase as TracePhase;
use crate::util::Timer;

/// Parameter dimension of the synthetic model.
pub const SIM_DIM: usize = 1024;

/// Virtual seconds one local "training" step takes.
const SIM_STEP_S: f64 = 0.01;

enum Phase {
    /// Local step in progress: waiting on the step timer.
    Training,
    /// Broadcast staged: waiting for this round's neighbor models.
    Gathering,
    Done,
}

/// Synthetic D-PSGD state machine: same round skeleton as
/// [`crate::scheduler::DlNodeSm`] (train, broadcast, gather, aggregate,
/// eval) with the pool compute replaced by an inline update plus a
/// virtual-time step timer.
struct SimNodeSm {
    id: usize,
    rounds: u64,
    eval_every: u64,
    self_weight: f64,
    neighbors: Vec<(usize, f64)>,
    model: ParamVec,
    /// This node's local objective (shared target + per-node offset).
    target: Arc<[f32]>,
    sharing: Box<dyn Sharing>,
    scratch: Scratch,
    pending: HashMap<(u64, usize), Payload>,
    round: u64,
    phase: Phase,
    train_loss: f64,
    wall: Timer,
    log: Option<NodeLog>,
}

fn mse(a: &[f32], b: &[f32]) -> f64 {
    let sum: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| ((*x - *y) as f64) * ((*x - *y) as f64))
        .sum();
    sum / a.len().max(1) as f64
}

impl SimNodeSm {
    /// Start a round: inline "training" (pull the model toward the local
    /// target, pre-step distance is the train loss), then arm the step
    /// timer that advances virtual time.
    fn begin_round(&mut self, ctx: &mut NodeCtx) {
        let t = ctx.trace_begin();
        self.train_loss = mse(self.model.as_slice(), &self.target);
        for (m, t) in self.model.as_mut_slice().iter_mut().zip(self.target.iter()) {
            *m = 0.9 * *m + 0.1 * *t;
        }
        ctx.trace_phase(TracePhase::Train, t);
        self.phase = Phase::Training;
        ctx.set_timer(SIM_STEP_S);
    }

    /// Serialize once, send the shared payload to every neighbor.
    fn broadcast(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        let t = ctx.trace_begin();
        let payload = self.sharing.outgoing_pooled(&self.model, self.round, &mut self.scratch)?;
        ctx.note_serialized(payload.len());
        ctx.trace_phase(TracePhase::Encode, t);
        for &(nbr, _) in &self.neighbors {
            ctx.send(Envelope {
                src: self.id,
                dst: nbr,
                round: self.round,
                kind: MsgKind::Model,
                sent_at_s: 0.0,
                trace: 0,
                payload: payload.clone(),
            });
        }
        self.phase = Phase::Gathering;
        Ok(())
    }

    /// Aggregate and finish the round once every neighbor's model for
    /// the current round has arrived; otherwise keep waiting.
    fn try_aggregate(&mut self, ctx: &mut NodeCtx) -> Result<()> {
        let round = self.round;
        let all_in = self.neighbors.iter().all(|&(n, _)| self.pending.contains_key(&(round, n)));
        if !all_in {
            return Ok(());
        }
        let t = ctx.trace_begin();
        let msgs: Vec<(usize, f64, Payload)> = self
            .neighbors
            .iter()
            .map(|&(n, w)| (n, w, self.pending.remove(&(round, n)).unwrap()))
            .collect();
        let received: Vec<Received> = msgs
            .iter()
            .map(|(src, w, payload)| Received {
                src: *src,
                weight: *w,
                payload: payload.as_slice(),
            })
            .collect();
        self.sharing.aggregate_with(
            &mut self.model,
            self.self_weight,
            &received,
            &mut self.scratch,
        )?;
        ctx.trace_phase(TracePhase::Aggregate, t);
        if (round + 1) % self.eval_every == 0 || round + 1 == self.rounds {
            let test_loss = mse(self.model.as_slice(), &self.target);
            let test_acc = 1.0 / (1.0 + test_loss);
            let c = ctx.counters();
            let record = Record {
                round,
                emu_time_s: ctx.now_s,
                real_time_s: self.wall.elapsed().as_secs_f64(),
                train_loss: self.train_loss,
                test_loss,
                test_acc,
                bytes_sent: c.bytes_sent,
                bytes_recv: c.bytes_recv,
                msgs_sent: c.msgs_sent,
                bytes_serialized: c.bytes_serialized,
                late_msgs: 0,
                dropped_msgs: 0,
                mean_staleness_s: 0.0,
                poisoned_mass_admitted: 0.0,
                rejected_contribs: 0,
                isolation_rate: 0.0,
            };
            if let Some(log) = &mut self.log {
                log.push(record);
            }
        }
        self.round += 1;
        if self.round == self.rounds {
            self.phase = Phase::Done;
        } else {
            self.begin_round(ctx);
        }
        Ok(())
    }
}

impl EventNode for SimNodeSm {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
        ctx.trace_round(self.round);
        match wake {
            Wake::Start => self.begin_round(ctx),
            Wake::Timer(_) => {
                if matches!(self.phase, Phase::Training) {
                    self.broadcast(ctx)?;
                    self.try_aggregate(ctx)?;
                }
            }
            Wake::Message(env) => {
                if matches!(env.kind, MsgKind::Model) && env.round >= self.round {
                    self.pending.insert((env.round, env.src), env.payload);
                }
                if matches!(self.phase, Phase::Gathering) {
                    self.try_aggregate(ctx)?;
                }
            }
            Wake::ComputeDone(_) => bail!("sim nodes never submit pool jobs"),
        }
        Ok(())
    }

    fn done(&self) -> bool {
        matches!(self.phase, Phase::Done)
    }

    fn take_log(&mut self) -> Option<NodeLog> {
        self.log.take()
    }

    fn attach_telemetry(&mut self, sink: &Telemetry) {
        if let Some(log) = &mut self.log {
            log.set_sink(sink.clone());
        }
    }
}

/// Axes the sim driver does not model; reject them eagerly so a daemon
/// submission fails at POST time, not mid-run.
pub(crate) fn check_sim_support(cfg: &ExperimentConfig) -> Result<()> {
    if cfg.mode != "dl" {
        bail!("sim driver supports mode \"dl\" only (got {:?})", cfg.mode);
    }
    if cfg.runner != "scheduler" {
        bail!("sim driver requires runner \"scheduler\" (got {:?})", cfg.runner);
    }
    if cfg.secure {
        bail!("sim driver does not model secure aggregation");
    }
    if cfg.dynamic {
        bail!("sim driver supports static topologies only");
    }
    if !cfg.byzantine.is_empty() {
        bail!("sim driver does not model byzantine adversaries");
    }
    if !cfg.churn_trace.is_empty() || cfg.churn > 0.0 {
        bail!("sim driver does not model churn");
    }
    if cfg.step_time != "uniform" {
        bail!("sim driver supports step_time \"uniform\" only");
    }
    if !matches!(cfg.link_model.as_str(), "" | "uniform") {
        bail!("sim driver supports link_model \"uniform\" only");
    }
    Ok(())
}

/// Run the synthetic experiment described by `cfg` — no artifacts
/// needed. Honors the same [`RunHooks`] contract as
/// [`crate::coordinator::run_experiment_with`]: the telemetry sink (when
/// present) sees `run_started`, per-round, and `run_finished` events and
/// is closed on every exit path; the cancel flag stops the run at a
/// round boundary.
pub fn run_sim(cfg: &ExperimentConfig, hooks: &RunHooks) -> Result<RunResult> {
    let result = run_sim_inner(cfg, hooks);
    if let Some(sink) = &hooks.telemetry {
        if let Ok(r) = &result {
            sink.emit(TelemetryEvent::RunFinished { cancelled: r.cancelled, wall_s: r.wall_s });
        }
        sink.close();
    }
    result
}

fn run_sim_inner(cfg: &ExperimentConfig, hooks: &RunHooks) -> Result<RunResult> {
    cfg.validate()?;
    check_sim_support(cfg)?;
    let wall = Timer::start();

    // Same topology stream as the real coordinator, so a sim run and a
    // real run of one config share a graph.
    let mut topo_rng = Xoshiro256pp::new(mix_seed(&[cfg.seed, 0x7090]));
    let graph = from_spec(&cfg.topology, cfg.nodes, &mut topo_rng)?;
    let weights = metropolis_hastings(&graph);

    // Shared target (the "true model") and common init.
    let mut target_rng = Xoshiro256pp::new(mix_seed(&[cfg.seed, 0x51A0]));
    let target: Vec<f32> = (0..SIM_DIM).map(|_| target_rng.next_f32() * 2.0 - 1.0).collect();
    let mut init_rng = Xoshiro256pp::new(mix_seed(&[cfg.seed, 0x1217]));
    let init = ParamVec::random(SIM_DIM, 0.5, &mut init_rng);

    let network = match cfg.network.as_str() {
        "lan" => Some(NetworkModel::lan()),
        "wan" => Some(NetworkModel::wan()),
        _ => None,
    };
    let workers = if cfg.workers > 0 {
        cfg.workers
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    };
    let mut sched = Scheduler::new(network, workers);
    sched.set_control(hooks.control.clone());
    if let Some(tr) = &hooks.trace {
        sched.set_tracer(tr.clone());
    }
    if let Some(sink) = &hooks.telemetry {
        sched.set_telemetry(sink.clone());
        sink.emit(TelemetryEvent::RunStarted { nodes: cfg.nodes, rounds: cfg.rounds });
    }

    for id in 0..cfg.nodes {
        // Per-node objective: the shared target plus a small seeded
        // offset (the sim's stand-in for non-IID local data).
        let mut node_rng = Xoshiro256pp::new(mix_seed(&[cfg.seed, id as u64, 0x0FF5]));
        let local: Arc<[f32]> = target
            .iter()
            .map(|t| t + (node_rng.next_f32() - 0.5) * 0.2)
            .collect::<Vec<f32>>()
            .into();
        let mut sharing =
            sharing::from_spec(&cfg.sharing, SIM_DIM, mix_seed(&[cfg.seed, id as u64]))?;
        sharing.set_init(&init);
        sched.add_node(Box::new(SimNodeSm {
            id,
            rounds: cfg.rounds,
            eval_every: cfg.eval_every,
            self_weight: weights.self_weight(id),
            neighbors: weights.neighbor_weights(id).collect(),
            model: init.clone(),
            target: local,
            sharing,
            scratch: Scratch::new(),
            pending: HashMap::new(),
            round: 0,
            phase: Phase::Training,
            train_loss: 0.0,
            wall: Timer::start(),
            log: Some(NodeLog::new(id)),
        }));
    }

    sched.run()?;
    let cancelled = sched.was_cancelled();
    let mut logs = sched.take_logs();
    logs.sort_by_key(|l| l.node);
    let series = aggregate(&logs);
    Ok(RunResult {
        config: cfg.clone(),
        logs,
        series,
        wall_s: wall.elapsed().as_secs_f64(),
        param_count: SIM_DIM,
        store: None,
        cancelled,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::RunControl;

    fn sim_cfg(nodes: usize, rounds: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "simtest".into();
        cfg.nodes = nodes;
        cfg.rounds = rounds;
        cfg.eval_every = 2;
        cfg.topology = "ring".into();
        cfg.network = "none".into();
        cfg.workers = 2;
        // train_total only matters for the artifact path, but validate()
        // still checks it against the node count.
        cfg.train_total = nodes.max(2048);
        cfg
    }

    #[test]
    fn sim_run_is_deterministic_and_converges() {
        let cfg = sim_cfg(8, 6);
        let a = run_sim(&cfg, &RunHooks::default()).unwrap();
        let b = run_sim(&cfg, &RunHooks::default()).unwrap();
        assert_eq!(a.logs.len(), 8);
        for (la, lb) in a.logs.iter().zip(b.logs.iter()) {
            assert_eq!(la.records, lb.records);
        }
        // Eval rounds: 1, 3, 5 (eval_every = 2, last round 5 coincides).
        let rounds: Vec<u64> = a.logs[0].records.iter().map(|r| r.round).collect();
        assert_eq!(rounds, vec![1, 3, 5]);
        // The consensus pull toward the target must reduce the loss.
        let first = a.series.first().unwrap().test_loss.mean;
        let last = a.series.last().unwrap().test_loss.mean;
        assert!(last < first, "test loss {first} -> {last}");
        assert!(!a.cancelled);
    }

    #[test]
    fn sim_round_events_mirror_saved_records() {
        let cfg = sim_cfg(4, 4);
        let sink = Telemetry::new(1024);
        let hooks =
            RunHooks { control: RunControl::new(), telemetry: Some(sink.clone()), trace: None };
        let result = run_sim(&cfg, &hooks).unwrap();
        assert!(sink.is_closed());
        let (events, _) = sink.events_since(0);
        let mut streamed: Vec<(usize, Record)> = events
            .into_iter()
            .filter_map(|(_, e)| match e {
                TelemetryEvent::Round { node, record } => Some((node, record)),
                _ => None,
            })
            .collect();
        streamed.sort_by_key(|(node, r)| (*node, r.round));
        let mut saved: Vec<(usize, Record)> = Vec::new();
        for log in &result.logs {
            for r in &log.records {
                saved.push((log.node, r.clone()));
            }
        }
        assert_eq!(streamed, saved);
    }

    #[test]
    fn pre_cancelled_sim_run_stops_with_empty_logs() {
        let cfg = sim_cfg(8, 1000);
        let hooks = RunHooks::default();
        hooks.control.cancel();
        let result = run_sim(&cfg, &hooks).unwrap();
        assert!(result.cancelled);
        assert!(result.logs.iter().all(|l| l.records.is_empty()));
    }

    #[test]
    fn unsupported_axes_are_rejected() {
        let mut cfg = sim_cfg(4, 2);
        cfg.mode = "async_dl".into();
        assert!(run_sim(&cfg, &RunHooks::default()).is_err());
        let mut cfg = sim_cfg(4, 2);
        cfg.secure = true;
        assert!(run_sim(&cfg, &RunHooks::default()).is_err());
        let mut cfg = sim_cfg(4, 2);
        cfg.dynamic = true;
        assert!(run_sim(&cfg, &RunHooks::default()).is_err());
    }
}
