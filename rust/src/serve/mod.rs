//! `decentra serve`: an HTTP control plane for experiment runs.
//!
//! A hand-rolled HTTP/1.1 daemon ([`http`]) over
//! [`std::net::TcpListener`] — no new dependencies — exposing:
//!
//! * `POST /runs` — submit a config (validated with the existing
//!   [`ExperimentConfig`] machinery) into a bounded run queue. Body is
//!   either a bare config object or `{"driver": "sim" | "engine",
//!   "config": {...}}`; the `sim` driver ([`run_sim`]) needs no
//!   artifacts, the `engine` driver starts a
//!   [`crate::runtime::EngineHandle`] from the config's
//!   `artifacts_dir` and runs the real experiment.
//! * `GET /runs`, `GET /runs/:id` — queue/run status.
//! * `DELETE /runs/:id` — cooperative cancellation through the run's
//!   [`RunControl`]; a running fleet stops at a round boundary.
//! * `GET /runs/:id/events` — per-round [`TelemetryEvent`]s streamed as
//!   Server-Sent Events, resumable with `?from=<seq>`.
//! * `GET /runs/:id/trace` — the run's span recording as Chrome
//!   `trace.json` when the config enables tracing ([`crate::trace`]).
//! * `GET /metrics` — Prometheus text over the daemon's [`Registry`].
//! * `GET /healthz`, `POST /shutdown` — liveness and clean exit.
//!
//! Runs execute **one at a time** on a single executor thread; the
//! queue (bounded, `429` when full) decouples submission from
//! execution. Every run owns a [`Telemetry`] ring, so status polls and
//! SSE consumers never contend with the fleet's hot path beyond one
//! short-lived mutex.

pub mod http;
pub mod sim;

pub use sim::{run_sim, SIM_DIM};

use std::collections::{BTreeMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::{run_experiment_with, RunControl, RunHooks};
use crate::metrics::{Registry, Telemetry, TelemetryEvent};
use crate::runtime::EngineHandle;
use crate::trace::{TraceMode, TraceRecorder};
use crate::util::json::{parse, Json};
use crate::util::Timer;

use http::{read_request, Request, Response};

/// How SSE writers poll the telemetry ring between keepalives.
const SSE_POLL: Duration = Duration::from_millis(250);

/// Idle keep-alive connections are dropped after this.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Buckets for the per-round staleness and duration histograms
/// (virtual seconds; rounds run at emulated speed, not wall speed).
const ROUND_BUCKETS: [f64; 10] = [0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 60.0];

/// Daemon configuration (the `decentra serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address (`--addr`); port 0 picks a free port.
    pub addr: String,
    /// Max queued (not yet running) submissions (`--queue-cap`).
    pub queue_cap: usize,
    /// Telemetry ring capacity per run, in events (`--ring-cap`).
    pub ring_cap: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions { addr: "127.0.0.1:7070".into(), queue_cap: 16, ring_cap: 65_536 }
    }
}

/// Which execution path a submission takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Driver {
    /// Artifact-free synthetic run ([`run_sim`]).
    Sim,
    /// Real experiment through [`run_experiment_with`]; loads the
    /// config's artifacts at execution time.
    Engine,
}

impl Driver {
    fn as_str(self) -> &'static str {
        match self {
            Driver::Sim => "sim",
            Driver::Engine => "engine",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl Phase {
    fn as_str(self) -> &'static str {
        match self {
            Phase::Queued => "queued",
            Phase::Running => "running",
            Phase::Done => "done",
            Phase::Failed => "failed",
            Phase::Cancelled => "cancelled",
        }
    }

    fn is_terminal(self) -> bool {
        matches!(self, Phase::Done | Phase::Failed | Phase::Cancelled)
    }
}

/// Mutable run status, updated by the executor and DELETE handler.
struct RunState {
    phase: Phase,
    error: Option<String>,
    wall_s: Option<f64>,
    final_accuracy: Option<f64>,
    results_dir: Option<String>,
}

/// One submitted run: immutable identity + config, live control
/// handles, and the mutable status.
struct Run {
    id: u64,
    driver: Driver,
    cfg: ExperimentConfig,
    control: RunControl,
    telemetry: Telemetry,
    /// Span recorder, present when the config's `trace` key is not
    /// `off`. Serves `GET /runs/:id/trace` after (or during) the run.
    trace: Option<TraceRecorder>,
    state: Mutex<RunState>,
}

impl Run {
    fn phase(&self) -> Phase {
        self.state.lock().unwrap().phase
    }

    fn set_phase(&self, phase: Phase) {
        self.state.lock().unwrap().phase = phase;
    }

    fn status_json(&self) -> Json {
        let st = self.state.lock().unwrap();
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            ("status", Json::str(st.phase.as_str())),
            ("driver", Json::str(self.driver.as_str())),
            ("name", Json::str(self.cfg.name.clone())),
            ("nodes", Json::num(self.cfg.nodes as f64)),
            ("rounds", Json::num(self.cfg.rounds as f64)),
            ("rounds_streamed", Json::num(self.telemetry.rounds_emitted() as f64)),
            ("dropped_events", Json::num(self.telemetry.dropped_events() as f64)),
        ];
        if let Some(err) = &st.error {
            fields.push(("error", Json::str(err.clone())));
        }
        if let Some(wall_s) = st.wall_s {
            fields.push(("wall_s", Json::num(wall_s)));
        }
        if let Some(acc) = st.final_accuracy {
            fields.push(("final_accuracy", Json::num(acc)));
        }
        if let Some(dir) = &st.results_dir {
            fields.push(("results_dir", Json::str(dir.clone())));
        }
        Json::obj(fields)
    }
}

struct RunTable {
    next_id: u64,
    runs: BTreeMap<u64, Arc<Run>>,
    queue: VecDeque<u64>,
    active: Option<u64>,
}

/// State shared between the accept loop, per-connection handlers, and
/// the executor thread.
struct Shared {
    table: Mutex<RunTable>,
    /// Signals the executor: new queue entry or shutdown.
    work: Condvar,
    shutdown: AtomicBool,
    registry: Registry,
    queue_cap: usize,
    ring_cap: usize,
    addr: SocketAddr,
}

/// The serve daemon. [`bind`](Daemon::bind), then [`run`](Daemon::run)
/// until `POST /shutdown`.
pub struct Daemon {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Daemon {
    pub fn bind(opts: &ServeOptions) -> Result<Daemon> {
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding serve daemon to {}", opts.addr))?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            table: Mutex::new(RunTable {
                next_id: 1,
                runs: BTreeMap::new(),
                queue: VecDeque::new(),
                active: None,
            }),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
            registry: Registry::new(),
            queue_cap: opts.queue_cap.max(1),
            ring_cap: opts.ring_cap,
            addr,
        });
        Ok(Daemon { listener, shared })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Serve until shutdown: accept loop here, one executor thread for
    /// the run queue, one short-lived thread per connection.
    pub fn run(self) -> Result<()> {
        let exec_shared = Arc::clone(&self.shared);
        let executor = std::thread::Builder::new()
            .name("serve-executor".into())
            .spawn(move || executor_loop(&exec_shared))
            .context("spawning serve executor")?;
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let shared = Arc::clone(&self.shared);
            let _ = std::thread::Builder::new()
                .name("serve-conn".into())
                .spawn(move || handle_connection(&shared, stream));
        }
        // Wake the executor so it observes the shutdown flag.
        self.shared.work.notify_all();
        let _ = executor.join();
        Ok(())
    }
}

/// Pop queue entries and execute them one at a time.
fn executor_loop(shared: &Arc<Shared>) {
    loop {
        let run = {
            let mut table = shared.table.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(id) = table.queue.pop_front() {
                    break table.runs.get(&id).cloned();
                }
                table = shared.work.wait(table).unwrap();
            }
        };
        let Some(run) = run else { continue };
        // A DELETE may have cancelled the run while it sat in the queue.
        if run.phase() != Phase::Queued {
            continue;
        }
        run.set_phase(Phase::Running);
        shared.table.lock().unwrap().active = Some(run.id);
        let hooks = RunHooks {
            control: run.control.clone(),
            telemetry: Some(run.telemetry.clone()),
            trace: run.trace.clone(),
        };
        // Fold per-round statistics into the daemon registry as the run
        // streams them, without touching the fleet's hot path.
        let tap = {
            let shared = Arc::clone(shared);
            let telemetry = run.telemetry.clone();
            std::thread::Builder::new()
                .name("serve-tap".into())
                .spawn(move || round_stats_tap(&shared.registry, &telemetry))
                .ok()
        };
        let result = match run.driver {
            Driver::Sim => sim::run_sim(&run.cfg, &hooks),
            Driver::Engine => EngineHandle::start(&run.cfg.artifacts_dir, &[&run.cfg.model])
                .and_then(|engine| run_experiment_with(&run.cfg, &engine, &hooks)),
        };
        // The run paths close the sink themselves; this covers early
        // failures (e.g. missing artifacts) so SSE readers never hang.
        run.telemetry.close();
        if let Some(tap) = tap {
            let _ = tap.join();
        }
        if let Some(tr) = &run.trace {
            tr.observe_phases(&shared.registry);
        }
        let outcome = result.and_then(|res| {
            let dir = res.save()?;
            Ok((res, dir))
        });
        {
            let mut st = run.state.lock().unwrap();
            match outcome {
                Ok((res, dir)) => {
                    st.phase = if res.cancelled { Phase::Cancelled } else { Phase::Done };
                    st.wall_s = Some(res.wall_s);
                    st.final_accuracy = Some(res.final_accuracy());
                    st.results_dir = Some(dir.display().to_string());
                }
                Err(e) => {
                    st.phase = Phase::Failed;
                    st.error = Some(format!("{e:#}"));
                }
            }
            let metric = match st.phase {
                Phase::Done => "decentra_runs_completed_total",
                Phase::Cancelled => "decentra_runs_cancelled_total",
                _ => "decentra_runs_failed_total",
            };
            shared.registry.inc_counter(metric, 1.0);
        }
        shared.table.lock().unwrap().active = None;
    }
}

/// Consume a run's telemetry ring and fold per-round statistics into
/// the daemon [`Registry`]: staleness from each `Round` record, and the
/// emulated duration of every finished round from per-node
/// `emu_time_s` deltas. Runs on its own thread until the ring closes.
fn round_stats_tap(registry: &Registry, telemetry: &Telemetry) {
    let mut cursor = 0;
    // Last (round, emu_time_s) seen per node, for duration deltas.
    let mut last: BTreeMap<usize, (u64, f64)> = BTreeMap::new();
    loop {
        let (batch, next, closed) = telemetry.wait_since(cursor, SSE_POLL);
        cursor = next;
        for (_, event) in &batch {
            let TelemetryEvent::Round { node, record } = event else { continue };
            registry.observe_with(
                "decentra_staleness_seconds",
                "",
                &ROUND_BUCKETS,
                record.mean_staleness_s,
            );
            let prev = last.insert(*node, (record.round, record.emu_time_s));
            // Eval cadence can skip rounds: spread the emulated-time
            // delta over every round it covers.
            let (delta, rounds) = match prev {
                Some((r0, t0)) => (record.emu_time_s - t0, record.round.saturating_sub(r0)),
                None => (record.emu_time_s, record.round + 1),
            };
            if rounds > 0 && delta.is_finite() && delta >= 0.0 {
                let per_round = delta / rounds as f64;
                registry.observe_with(
                    "decentra_round_duration_seconds",
                    "",
                    &ROUND_BUCKETS,
                    per_round,
                );
            }
        }
        if closed && batch.is_empty() {
            return;
        }
    }
}

/// Serve requests on one connection until the peer closes (or an SSE
/// stream takes the connection over).
fn handle_connection(shared: &Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    loop {
        let req = match read_request(&mut stream) {
            Ok(Some(req)) => req,
            _ => return,
        };
        let close = req
            .headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false);
        let timer = Timer::start();
        shared.registry.inc_counter("decentra_http_requests_total", 1.0);
        // SSE takes over the whole connection and ends by closing it.
        if req.method == "GET" {
            if let Some(run) = events_target(shared, &req) {
                match parse_cursor(&req) {
                    Ok(from) => {
                        let _ = stream_events(&mut stream, &run, from);
                    }
                    Err(resp) => {
                        let _ = resp.write(&mut stream, false);
                    }
                }
                shared
                    .registry
                    .observe("decentra_http_request_seconds", timer.elapsed().as_secs_f64());
                return;
            }
        }
        let resp = route(shared, &req);
        shared
            .registry
            .observe("decentra_http_request_seconds", timer.elapsed().as_secs_f64());
        if resp.write(&mut stream, !close).is_err() || close {
            return;
        }
    }
}

/// The run behind `GET /runs/:id/events`, if that is what `req` is.
fn events_target(shared: &Arc<Shared>, req: &Request) -> Option<Arc<Run>> {
    let seg = req.segments();
    if seg.len() == 3 && seg[0] == "runs" && seg[2] == "events" {
        let id: u64 = seg[1].parse().ok()?;
        return shared.table.lock().unwrap().runs.get(&id).cloned();
    }
    None
}

/// The `?from=` resume cursor for SSE. Absent means 0; anything
/// non-numeric is a client error rather than a silent restart.
fn parse_cursor(req: &Request) -> Result<u64, Response> {
    match req.query.get("from") {
        None => Ok(0),
        Some(v) => v
            .parse()
            .map_err(|_| Response::json(400, err_json("from must be an integer"))),
    }
}

fn route(shared: &Arc<Shared>, req: &Request) -> Response {
    let seg = req.segments();
    match (req.method.as_str(), seg.as_slice()) {
        ("GET", ["healthz"]) => Response::text(200, "ok\n"),
        ("GET", ["metrics"]) => render_metrics(shared),
        ("POST", ["runs"]) => submit_run(shared, &req.body),
        ("GET", ["runs"]) => list_runs(shared),
        ("GET", ["runs", id]) => with_run(shared, id, |run| {
            Response::json(200, run.status_json().dump())
        }),
        ("DELETE", ["runs", id]) => with_run(shared, id, cancel_run),
        ("GET", ["runs", id, "trace"]) => with_run(shared, id, trace_response),
        ("GET", ["runs", _, "events"]) => {
            // events_target said no: the id did not parse or exist.
            Response::json(404, err_json("no such run"))
        }
        ("POST", ["shutdown"]) => shutdown(shared),
        (_, ["healthz" | "metrics" | "shutdown"]) | (_, ["runs", ..]) => {
            Response::json(405, err_json("method not allowed"))
        }
        _ => Response::json(404, err_json("no such endpoint")),
    }
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).dump()
}

fn with_run(shared: &Arc<Shared>, id: &str, f: impl FnOnce(&Arc<Run>) -> Response) -> Response {
    let Ok(id) = id.parse::<u64>() else {
        return Response::json(404, err_json("run ids are integers"));
    };
    let run = shared.table.lock().unwrap().runs.get(&id).cloned();
    match run {
        Some(run) => f(&run),
        None => Response::json(404, err_json("no such run")),
    }
}

/// `POST /runs`: parse, validate, enqueue.
fn submit_run(shared: &Arc<Shared>, body: &[u8]) -> Response {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return Response::json(400, err_json("body is not UTF-8")),
    };
    let v = match parse(text) {
        Ok(v) => v,
        Err(e) => return Response::json(400, err_json(&format!("invalid JSON: {e}"))),
    };
    // Either a bare config or an envelope naming the driver.
    let (driver_name, cfg_json) = if v.get("config").is_null() {
        ("sim".to_string(), &v)
    } else {
        (v.get("driver").as_str().unwrap_or("sim").to_string(), v.get("config"))
    };
    let driver = match driver_name.as_str() {
        "sim" => Driver::Sim,
        "engine" => Driver::Engine,
        other => {
            let msg = format!("unknown driver {other:?} (expected sim | engine)");
            return Response::json(400, err_json(&msg));
        }
    };
    let cfg = match ExperimentConfig::from_json(cfg_json) {
        Ok(cfg) => cfg,
        Err(e) => return Response::json(400, err_json(&format!("{e:#}"))),
    };
    if driver == Driver::Sim {
        if let Err(e) = sim::check_sim_support(&cfg) {
            return Response::json(400, err_json(&format!("{e:#}")));
        }
    }
    // `validate` already vetted the spec; building the recorder here
    // keeps the 400 path alive if that ever loosens.
    let trace = match TraceMode::parse(&cfg.trace) {
        Ok(TraceMode::Off) => None,
        Ok(mode) => Some(TraceRecorder::new(mode)),
        Err(e) => return Response::json(400, err_json(&format!("{e:#}"))),
    };
    let mut table = shared.table.lock().unwrap();
    if table.queue.len() >= shared.queue_cap {
        return Response::json(429, err_json("run queue is full"));
    }
    let id = table.next_id;
    table.next_id += 1;
    let run = Arc::new(Run {
        id,
        driver,
        cfg,
        control: RunControl::new(),
        telemetry: Telemetry::new(shared.ring_cap),
        trace,
        state: Mutex::new(RunState {
            phase: Phase::Queued,
            error: None,
            wall_s: None,
            final_accuracy: None,
            results_dir: None,
        }),
    });
    table.runs.insert(id, run);
    table.queue.push_back(id);
    drop(table);
    shared.registry.inc_counter("decentra_runs_submitted_total", 1.0);
    shared.work.notify_all();
    let body = Json::obj(vec![("id", Json::num(id as f64)), ("status", Json::str("queued"))]);
    Response::json(201, body.dump())
}

fn list_runs(shared: &Arc<Shared>) -> Response {
    let table = shared.table.lock().unwrap();
    let runs: Vec<Json> = table.runs.values().map(|r| r.status_json()).collect();
    Response::json(200, Json::obj(vec![("runs", Json::Arr(runs))]).dump())
}

/// `DELETE /runs/:id`: queued runs cancel immediately, running runs get
/// their [`RunControl`] flag and stop at the next round boundary,
/// finished runs are a conflict.
/// `GET /runs/:id/trace`: the run's span recording as Chrome
/// `trace.json`. Available while the run is still going (a partial
/// snapshot) and after it ends; 404 when the config left tracing off.
fn trace_response(run: &Arc<Run>) -> Response {
    match &run.trace {
        Some(tr) => Response::json(200, tr.snapshot().to_chrome_json()),
        None => Response::json(404, err_json("tracing disabled for this run")),
    }
}

fn cancel_run(run: &Arc<Run>) -> Response {
    let mut st = run.state.lock().unwrap();
    match st.phase {
        Phase::Queued => {
            st.phase = Phase::Cancelled;
            drop(st);
            // Nothing will ever run: close the ring so SSE readers end.
            run.telemetry.close();
            Response::json(200, run.status_json().dump())
        }
        Phase::Running => {
            drop(st);
            run.control.cancel();
            let body = Json::obj(vec![
                ("id", Json::num(run.id as f64)),
                ("status", Json::str("running")),
                ("cancel_requested", Json::Bool(true)),
            ]);
            Response::json(200, body.dump())
        }
        phase => {
            debug_assert!(phase.is_terminal());
            drop(st);
            Response::json(409, err_json("run already finished"))
        }
    }
}

fn render_metrics(shared: &Arc<Shared>) -> Response {
    {
        let table = shared.table.lock().unwrap();
        shared.registry.set_gauge("decentra_runs_queued", table.queue.len() as f64);
        let active = if table.active.is_some() { 1.0 } else { 0.0 };
        shared.registry.set_gauge("decentra_run_active", active);
        let runs = table.runs.values();
        let dropped: u64 = runs.clone().map(|r| r.telemetry.dropped_events()).sum();
        let buffered: u64 = runs.map(|r| r.telemetry.buffered_events()).sum();
        shared.registry.set_gauge("decentra_telemetry_dropped_events", dropped as f64);
        shared.registry.set_gauge("decentra_telemetry_buffered_events", buffered as f64);
    }
    Response::text(200, shared.registry.render())
}

fn shutdown(shared: &Arc<Shared>) -> Response {
    // Stop the active run (if any) and unblock the executor. The flag
    // is set under the table lock: the executor checks it under the
    // same lock before waiting, so the notify below cannot be lost.
    let active = {
        let table = shared.table.lock().unwrap();
        shared.shutdown.store(true, Ordering::SeqCst);
        table.active.and_then(|id| table.runs.get(&id).cloned())
    };
    if let Some(run) = active {
        run.control.cancel();
    }
    shared.work.notify_all();
    // Nudge the accept loop so it observes the flag.
    let _ = TcpStream::connect(shared.addr);
    Response::json(200, Json::obj(vec![("status", Json::str("shutting down"))]).dump())
}

/// Stream `run`'s telemetry ring as Server-Sent Events, starting at
/// sequence `from`. Frames carry the ring sequence as the SSE `id`, so
/// a dropped client reconnects with `?from=<last id + 1>`. Ends with an
/// `end` event once the ring is closed and drained.
fn stream_events(stream: &mut TcpStream, run: &Arc<Run>, from: u64) -> Result<()> {
    use std::io::Write;
    let head = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
                Cache-Control: no-cache\r\nConnection: close\r\n\r\n";
    stream.write_all(head.as_bytes())?;
    stream.flush()?;
    let mut cursor = from;
    loop {
        let (batch, next, closed) = run.telemetry.wait_since(cursor, SSE_POLL);
        cursor = next;
        if batch.is_empty() && !closed {
            // Comment frame: keeps half-open connections detectable.
            stream.write_all(b": keepalive\n\n")?;
            stream.flush()?;
            continue;
        }
        for (seq, event) in &batch {
            let data = event.to_json().dump();
            let frame = format!("id: {seq}\nevent: {}\ndata: {data}\n\n", event.kind());
            stream.write_all(frame.as_bytes())?;
        }
        stream.flush()?;
        if closed && batch.is_empty() {
            stream.write_all(b"event: end\ndata: {}\n\n")?;
            stream.flush()?;
            return Ok(());
        }
    }
}
