//! Minimal HTTP/1.1 framing for the serve daemon.
//!
//! Hand-rolled over [`std::net::TcpStream`] — the same no-new-deps
//! discipline as the TCP transport in [`crate::communication`]. Scope is
//! exactly what the daemon's API needs: request-line + headers + an
//! optional `Content-Length` body on the way in; status + headers + body
//! (or a streaming body the caller writes itself) on the way out. No
//! chunked encoding, no TLS, no HTTP/2.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Result};

/// Reject header blocks larger than this (a defensive cap, not a limit
/// any legitimate client hits).
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Reject bodies larger than this (configs are a few KiB).
const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path with the query string stripped (e.g. `/runs/3/events`).
    pub path: String,
    /// Decoded `?k=v&k2=v2` query parameters (no percent-decoding —
    /// the API's values are all numeric).
    pub query: BTreeMap<String, String>,
    /// Header names lowercased.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Path split into non-empty `/`-separated segments.
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
}

/// Read one request off the stream. `Ok(None)` means the peer closed
/// cleanly before sending anything (the idle keep-alive case).
pub fn read_request(stream: &mut TcpStream) -> Result<Option<Request>> {
    // Accumulate until the blank line that ends the header block.
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    let header_end = loop {
        if let Some(pos) = find_header_end(&head) {
            break pos;
        }
        if head.len() > MAX_HEAD_BYTES {
            bail!("request header block exceeds {MAX_HEAD_BYTES} bytes");
        }
        let n = stream.read(&mut buf)?;
        if n == 0 {
            if head.is_empty() {
                return Ok(None);
            }
            bail!("connection closed mid-request");
        }
        head.extend_from_slice(&buf[..n]);
    };
    let (header_bytes, rest) = head.split_at(header_end);
    let rest = &rest[4..]; // skip the \r\n\r\n itself
    let text = std::str::from_utf8(header_bytes)?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("").to_string();
    if method.is_empty() || target.is_empty() {
        bail!("malformed request line {request_line:?}");
    }
    let mut headers = BTreeMap::new();
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), parse_query(q)),
        None => (target, BTreeMap::new()),
    };
    let content_length: usize = match headers.get("content-length") {
        Some(v) => v.parse()?,
        None => 0,
    };
    if content_length > MAX_BODY_BYTES {
        bail!("request body exceeds {MAX_BODY_BYTES} bytes");
    }
    let mut body = rest.to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&buf[..n]);
    }
    body.truncate(content_length);
    Ok(Some(Request { method, path, query, headers, body }))
}

fn find_header_end(bytes: &[u8]) -> Option<usize> {
    bytes.windows(4).position(|w| w == b"\r\n\r\n")
}

fn parse_query(q: &str) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    for pair in q.split('&') {
        if pair.is_empty() {
            continue;
        }
        match pair.split_once('=') {
            Some((k, v)) => out.insert(k.to_string(), v.to_string()),
            None => out.insert(pair.to_string(), String::new()),
        };
    }
    out
}

/// One response, written whole (streaming endpoints write their own
/// headers and frames instead).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response { status, content_type: "application/json", body: body.into_bytes() }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        let body = body.into().into_bytes();
        Response { status, content_type: "text/plain; charset=utf-8", body }
    }

    /// Serialize onto the stream. `keep_alive` controls the
    /// `Connection` header (the daemon serves one request per
    /// connection unless the client asked to keep it open).
    pub fn write(&self, stream: &mut TcpStream, keep_alive: bool) -> Result<()> {
        let reason = reason_phrase(self.status);
        let connection = if keep_alive { "keep-alive" } else { "close" };
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            self.status,
            reason,
            self.content_type,
            self.body.len(),
            connection
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()?;
        Ok(())
    }
}

pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn roundtrip(raw: &[u8]) -> Option<Request> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&raw).unwrap();
        });
        let (mut stream, _) = listener.accept().unwrap();
        let req = read_request(&mut stream).unwrap();
        writer.join().unwrap();
        req
    }

    #[test]
    fn parses_request_line_headers_query_and_body() {
        let raw = b"POST /runs?from=3&verbose HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let req = roundtrip(raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/runs");
        assert_eq!(req.query.get("from").map(String::as_str), Some("3"));
        assert_eq!(req.query.get("verbose").map(String::as_str), Some(""));
        assert_eq!(req.headers.get("host").map(String::as_str), Some("x"));
        assert_eq!(req.body, b"abcd");
        assert_eq!(req.segments(), vec!["runs"]);
    }

    #[test]
    fn clean_eof_is_none_and_get_has_no_body() {
        assert!(roundtrip(b"").is_none());
        let req = roundtrip(b"GET /runs/7/events HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert_eq!(req.segments(), vec!["runs", "7", "events"]);
    }

    #[test]
    fn response_writes_framed_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reader = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        });
        let (mut stream, _) = listener.accept().unwrap();
        Response::json(201, "{\"id\":1}".into()).write(&mut stream, false).unwrap();
        drop(stream);
        let got = reader.join().unwrap();
        assert!(got.starts_with("HTTP/1.1 201 Created\r\n"), "{got}");
        assert!(got.contains("Content-Length: 8\r\n"), "{got}");
        assert!(got.ends_with("{\"id\":1}"), "{got}");
    }
}
