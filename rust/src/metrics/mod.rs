//! Metrics: per-node JSONL logs and cross-node aggregation.
//!
//! Matching the paper's design, "each node locally writes logs and
//! results in JSON files; to compute aggregate statistics we collect and
//! process the results in a single machine at the end" (§2.2). A
//! [`NodeLog`] accumulates one record per evaluation round; the
//! [`aggregate`] functions turn a set of node logs into the mean ± 95% CI
//! series the figures plot.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};
use crate::util::stats::{mean_ci, MeanCi};

pub mod telemetry;

pub use telemetry::{Registry, Telemetry, TelemetryEvent};

/// One evaluation record for one node.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub round: u64,
    /// Emulated wall-clock seconds since training start.
    pub emu_time_s: f64,
    /// Real wall-clock seconds since training start.
    pub real_time_s: f64,
    pub train_loss: f64,
    pub test_loss: f64,
    pub test_acc: f64,
    /// Cumulative wire bytes sent by this node.
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    pub msgs_sent: u64,
    /// Cumulative payload bytes this node actually serialized: each
    /// built payload counts once, however many recipients the zero-copy
    /// broadcast shares it with (`bytes_sent` stays per-recipient wire
    /// bytes; see [`crate::communication::counters`]).
    pub bytes_serialized: u64,
    /// Async gossip: cumulative messages that missed a deadline but were
    /// buffered for the next round (0 for synchronous nodes).
    pub late_msgs: u64,
    /// Async gossip: cumulative messages dropped for missing a deadline.
    pub dropped_msgs: u64,
    /// Async gossip: mean virtual age (seconds) of every neighbor model
    /// aggregated so far.
    pub mean_staleness_s: f64,
    /// Byzantine scenarios: cumulative mixing weight of Byzantine
    /// contributions the aggregation *admitted* (0 with no adversaries
    /// or a perfect defense).
    pub poisoned_mass_admitted: f64,
    /// Byzantine scenarios: cumulative contributions (any sender) the
    /// robust aggregation rejected.
    pub rejected_contribs: u64,
    /// Byzantine scenarios: fraction of Byzantine contributions the
    /// defense rejected so far (0 when nothing Byzantine arrived).
    pub isolation_rate: f64,
}

impl Record {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round", Json::num(self.round as f64)),
            ("emu_time_s", Json::num(self.emu_time_s)),
            ("real_time_s", Json::num(self.real_time_s)),
            ("train_loss", Json::num(self.train_loss)),
            ("test_loss", Json::num(self.test_loss)),
            ("test_acc", Json::num(self.test_acc)),
            ("bytes_sent", Json::num(self.bytes_sent as f64)),
            ("bytes_recv", Json::num(self.bytes_recv as f64)),
            ("msgs_sent", Json::num(self.msgs_sent as f64)),
            ("bytes_serialized", Json::num(self.bytes_serialized as f64)),
            ("late_msgs", Json::num(self.late_msgs as f64)),
            ("dropped_msgs", Json::num(self.dropped_msgs as f64)),
            ("mean_staleness_s", Json::num(self.mean_staleness_s)),
            ("poisoned_mass_admitted", Json::num(self.poisoned_mass_admitted)),
            ("rejected_contribs", Json::num(self.rejected_contribs as f64)),
            ("isolation_rate", Json::num(self.isolation_rate)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Record> {
        let f = |k: &str| -> Result<f64> {
            v.get(k)
                .as_f64()
                .with_context(|| format!("record missing field {k}"))
        };
        // Fields added after the seed format (async gossip, the shared
        // parameter store) default to 0 so older logs still load.
        let opt = |k: &str| -> f64 { v.get(k).as_f64().unwrap_or(0.0) };
        Ok(Record {
            round: f("round")? as u64,
            emu_time_s: f("emu_time_s")?,
            real_time_s: f("real_time_s")?,
            train_loss: f("train_loss")?,
            test_loss: f("test_loss")?,
            test_acc: f("test_acc")?,
            bytes_sent: f("bytes_sent")? as u64,
            bytes_recv: f("bytes_recv")? as u64,
            msgs_sent: f("msgs_sent")? as u64,
            bytes_serialized: opt("bytes_serialized") as u64,
            late_msgs: opt("late_msgs") as u64,
            dropped_msgs: opt("dropped_msgs") as u64,
            mean_staleness_s: opt("mean_staleness_s"),
            poisoned_mass_admitted: opt("poisoned_mass_admitted"),
            rejected_contribs: opt("rejected_contribs") as u64,
            isolation_rate: opt("isolation_rate"),
        })
    }
}

/// Per-node log: node id + records in round order.
///
/// Optionally mirrors every pushed [`Record`] into a [`Telemetry`] sink
/// ([`set_sink`](NodeLog::set_sink)) so live consumers see rounds as
/// they complete; the sink never changes what is stored or saved.
#[derive(Debug, Clone, Default)]
pub struct NodeLog {
    pub node: usize,
    pub records: Vec<Record>,
    sink: Option<Telemetry>,
}

impl NodeLog {
    pub fn new(node: usize) -> NodeLog {
        NodeLog { node, records: Vec::new(), sink: None }
    }

    /// Mirror future pushes into `sink` as [`TelemetryEvent::Round`]s.
    pub fn set_sink(&mut self, sink: Telemetry) {
        self.sink = Some(sink);
    }

    pub fn push(&mut self, r: Record) {
        if let Some(sink) = &self.sink {
            sink.emit(TelemetryEvent::Round { node: self.node, record: r.clone() });
        }
        self.records.push(r);
    }

    /// Serialize as JSONL: one header line then one record per line.
    pub fn to_jsonl(&self) -> String {
        let mut out = Json::obj(vec![("node", Json::num(self.node as f64))]).dump();
        out.push('\n');
        for r in &self.records {
            out.push_str(&r.to_json().dump());
            out.push('\n');
        }
        out
    }

    pub fn from_jsonl(text: &str) -> Result<NodeLog> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = parse(lines.next().context("empty node log")?)?;
        let node = header
            .get("node")
            .as_usize()
            .context("node log header missing node id")?;
        let mut log = NodeLog::new(node);
        for line in lines {
            log.push(Record::from_json(&parse(line)?)?);
        }
        Ok(log)
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("node_{:04}.jsonl", self.node));
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(self.to_jsonl().as_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<NodeLog> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        NodeLog::from_jsonl(&text)
    }

    /// Load every `node_*.jsonl` in a directory.
    pub fn load_dir(dir: &Path) -> Result<Vec<NodeLog>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name.starts_with("node_") && name.ends_with(".jsonl") {
                out.push(NodeLog::load(&path)?);
            }
        }
        out.sort_by_key(|l| l.node);
        Ok(out)
    }
}

/// A point in an aggregated series.
#[derive(Debug, Clone, PartialEq)]
pub struct SeriesPoint {
    pub round: u64,
    /// Mean cumulative bytes sent per node at this round.
    pub bytes_sent: MeanCi,
    pub emu_time_s: MeanCi,
    pub real_time_s: MeanCi,
    pub test_acc: MeanCi,
    pub test_loss: MeanCi,
    pub train_loss: MeanCi,
    /// Mean per-node fraction of Byzantine contributions rejected.
    pub isolation_rate: MeanCi,
    /// Mean per-node cumulative admitted Byzantine mixing weight.
    pub poisoned_mass_admitted: MeanCi,
}

/// Aggregate across nodes, grouped by **round number**: every round
/// that *any* log evaluated becomes one [`SeriesPoint`] with mean ± CI
/// over the nodes that logged it (the CI's `n` records how many).
/// Nodes that crash or depart early simply stop contributing, and a
/// node that skipped an eval (offline session) is absent from just
/// that round's point — neither truncates nor skews the survivors'
/// series. With identical logs (no churn) this degenerates to
/// averaging over the whole fleet, exactly as before.
pub fn aggregate(logs: &[NodeLog]) -> Vec<SeriesPoint> {
    let mut rounds: Vec<u64> = logs
        .iter()
        .flat_map(|l| l.records.iter().map(|r| r.round))
        .collect();
    rounds.sort_unstable();
    rounds.dedup();
    let mut out = Vec::with_capacity(rounds.len());
    for round in rounds {
        let present: Vec<&Record> = logs
            .iter()
            .filter_map(|l| l.records.iter().find(|r| r.round == round))
            .collect();
        let collect = |f: &dyn Fn(&Record) -> f64| -> Vec<f64> {
            present.iter().map(|r| f(r)).collect()
        };
        out.push(SeriesPoint {
            round,
            bytes_sent: mean_ci(&collect(&|r| r.bytes_sent as f64)),
            emu_time_s: mean_ci(&collect(&|r| r.emu_time_s)),
            real_time_s: mean_ci(&collect(&|r| r.real_time_s)),
            test_acc: mean_ci(&collect(&|r| r.test_acc)),
            test_loss: mean_ci(&collect(&|r| r.test_loss)),
            train_loss: mean_ci(&collect(&|r| r.train_loss)),
            isolation_rate: mean_ci(&collect(&|r| r.isolation_rate)),
            poisoned_mass_admitted: mean_ci(&collect(&|r| r.poisoned_mass_admitted)),
        });
    }
    out
}

/// Render an aggregated series as aligned text columns (what the figure
/// harnesses print) — round, acc, loss, time, bytes.
pub fn render_series(name: &str, series: &[SeriesPoint]) -> String {
    let mut out = format!(
        "# {name}\n# {:>6} {:>10} {:>10} {:>12} {:>12} {:>14}\n",
        "round", "acc", "acc_ci95", "loss", "emu_time_s", "bytes_sent"
    );
    for p in series {
        out.push_str(&format!(
            "  {:>6} {:>10.4} {:>10.4} {:>12.4} {:>12.3} {:>14.0}\n",
            p.round, p.test_acc.mean, p.test_acc.ci95, p.test_loss.mean,
            p.emu_time_s.mean, p.bytes_sent.mean
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: u64, acc: f64, bytes: u64) -> Record {
        Record {
            round,
            emu_time_s: round as f64 * 0.5,
            real_time_s: round as f64 * 0.1,
            train_loss: 2.0 / (round + 1) as f64,
            test_loss: 2.1 / (round + 1) as f64,
            test_acc: acc,
            bytes_sent: bytes,
            bytes_recv: bytes,
            msgs_sent: round * 5,
            bytes_serialized: bytes / 2,
            late_msgs: round,
            dropped_msgs: 1,
            mean_staleness_s: 0.25,
            poisoned_mass_admitted: 0.125,
            rejected_contribs: round,
            isolation_rate: 0.75,
        }
    }

    #[test]
    fn record_without_async_fields_still_loads() {
        let mut j = rec(2, 0.5, 10).to_json();
        // Simulate a pre-async, pre-store log line by dropping new keys.
        if let Json::Obj(ref mut obj) = j {
            obj.remove("late_msgs");
            obj.remove("dropped_msgs");
            obj.remove("mean_staleness_s");
            obj.remove("bytes_serialized");
            obj.remove("poisoned_mass_admitted");
            obj.remove("rejected_contribs");
            obj.remove("isolation_rate");
        }
        let r = Record::from_json(&j).unwrap();
        assert_eq!(r.late_msgs, 0);
        assert_eq!(r.dropped_msgs, 0);
        assert_eq!(r.mean_staleness_s, 0.0);
        assert_eq!(r.bytes_serialized, 0);
        assert_eq!(r.poisoned_mass_admitted, 0.0);
        assert_eq!(r.rejected_contribs, 0);
        assert_eq!(r.isolation_rate, 0.0);
    }

    #[test]
    fn record_json_roundtrip() {
        let r = rec(3, 0.42, 1000);
        let j = r.to_json();
        assert_eq!(Record::from_json(&j).unwrap(), r);
    }

    #[test]
    fn jsonl_roundtrip() {
        let mut log = NodeLog::new(7);
        log.push(rec(0, 0.1, 100));
        log.push(rec(1, 0.2, 200));
        let text = log.to_jsonl();
        let back = NodeLog::from_jsonl(&text).unwrap();
        assert_eq!(back.node, 7);
        assert_eq!(back.records, log.records);
    }

    #[test]
    fn save_load_dir() {
        let dir = std::env::temp_dir().join("decentra_metrics_test");
        let _ = std::fs::remove_dir_all(&dir);
        for node in 0..3 {
            let mut log = NodeLog::new(node);
            log.push(rec(0, 0.1 * node as f64, 50));
            log.save(&dir).unwrap();
        }
        let logs = NodeLog::load_dir(&dir).unwrap();
        assert_eq!(logs.len(), 3);
        assert_eq!(logs[2].node, 2);
    }

    #[test]
    fn aggregate_means_and_survivor_series() {
        let mut a = NodeLog::new(0);
        let mut b = NodeLog::new(1);
        a.push(rec(0, 0.2, 100));
        a.push(rec(1, 0.4, 200));
        b.push(rec(0, 0.4, 300));
        // b stops after its first eval (crash/departure): round 0
        // averages both nodes, round 1 is the survivor alone.
        let series = aggregate(&[a, b]);
        assert_eq!(series.len(), 2);
        assert!((series[0].test_acc.mean - 0.3).abs() < 1e-12);
        assert!((series[0].bytes_sent.mean - 200.0).abs() < 1e-12);
        assert_eq!(series[0].test_acc.n, 2);
        assert!((series[1].test_acc.mean - 0.4).abs() < 1e-12);
        assert_eq!(series[1].test_acc.n, 1);
    }

    #[test]
    fn aggregate_survivor_series_includes_defense_fields() {
        // Three nodes; node 2 crashes after round 0. The defense-metric
        // columns (isolation_rate, poisoned_mass_admitted) must average
        // over exactly the survivors, with the CI's n saying how many.
        let mut a = NodeLog::new(0);
        let mut b = NodeLog::new(1);
        let mut c = NodeLog::new(2);
        for (log, iso, mass) in [(&mut a, 0.5, 0.1), (&mut b, 1.0, 0.3), (&mut c, 0.0, 0.8)] {
            let mut r = rec(0, 0.2, 100);
            r.isolation_rate = iso;
            r.poisoned_mass_admitted = mass;
            log.push(r);
        }
        for (log, iso, mass) in [(&mut a, 0.6, 0.2), (&mut b, 0.8, 0.4)] {
            let mut r = rec(1, 0.3, 200);
            r.isolation_rate = iso;
            r.poisoned_mass_admitted = mass;
            log.push(r);
        }
        let series = aggregate(&[a, b, c]);
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].isolation_rate.n, 3);
        assert!((series[0].isolation_rate.mean - 0.5).abs() < 1e-12);
        assert!((series[0].poisoned_mass_admitted.mean - 0.4).abs() < 1e-12);
        // Round 1: only the two survivors contribute.
        assert_eq!(series[1].isolation_rate.n, 2);
        assert_eq!(series[1].poisoned_mass_admitted.n, 2);
        assert!((series[1].isolation_rate.mean - 0.7).abs() < 1e-12);
        assert!((series[1].poisoned_mass_admitted.mean - 0.3).abs() < 1e-12);
    }

    #[test]
    fn push_mirrors_into_telemetry_sink() {
        let t = Telemetry::new(8);
        let mut log = NodeLog::new(4);
        log.set_sink(t.clone());
        let r = rec(0, 0.5, 100);
        log.push(r.clone());
        let (batch, _) = t.events_since(0);
        assert_eq!(batch.len(), 1);
        match &batch[0].1 {
            TelemetryEvent::Round { node, record } => {
                assert_eq!(*node, 4);
                assert_eq!(record, &r);
            }
            other => panic!("unexpected event {other:?}"),
        }
        // The sink is live-mirroring only: the log still stores records.
        assert_eq!(log.records.len(), 1);
    }

    #[test]
    fn aggregate_empty() {
        assert!(aggregate(&[]).is_empty());
    }

    #[test]
    fn render_contains_data() {
        let mut a = NodeLog::new(0);
        a.push(rec(0, 0.5, 123));
        let text = render_series("demo", &aggregate(&[a]));
        assert!(text.contains("demo"));
        assert!(text.contains("0.5"));
        assert!(text.contains("123"));
    }

    #[test]
    fn from_jsonl_rejects_garbage() {
        assert!(NodeLog::from_jsonl("").is_err());
        assert!(NodeLog::from_jsonl("{\"x\":1}\n").is_err());
        assert!(NodeLog::from_jsonl("{\"node\":0}\nnot json\n").is_err());
    }
}
