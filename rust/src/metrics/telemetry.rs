//! Live telemetry: the streaming side of the metrics subsystem.
//!
//! Saved `node_*.jsonl` logs only exist after a run finishes; the
//! `decentra serve` control plane ([`crate::serve`]) needs the same
//! round-granularity data *while* the run executes. Two pieces provide
//! it:
//!
//! * [`Telemetry`] — a lock-light bounded ring buffer of
//!   [`TelemetryEvent`]s. Producers (the node state machines, via their
//!   [`crate::metrics::NodeLog`] sink) append under one short mutex
//!   hold; consumers read by **cursor** (a monotone sequence number), so
//!   any number of SSE streams can follow the same run without
//!   back-pressure on the fleet — a slow consumer misses evicted events
//!   (counted in [`Telemetry::dropped_events`]) instead of stalling the
//!   scheduler.
//! * [`Registry`] — a small Prometheus-text counter/gauge/histogram
//!   registry backing the daemon's `GET /metrics` endpoint.
//!
//! The round event carries the exact [`Record`] the node pushes into its
//! log, so a streamed round and the `node_*.jsonl` line written at save
//! time serialize bit-identically (pinned by `rust/tests/serve_api.rs`).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::store::StoreStats;
use crate::util::json::Json;

use super::Record;

/// One live event in a run's telemetry stream.
#[derive(Debug, Clone, PartialEq)]
pub enum TelemetryEvent {
    /// A runner started executing the fleet.
    RunStarted { nodes: usize, rounds: u64 },
    /// One node finished an evaluation round. `record` is exactly what
    /// the node appended to its [`crate::metrics::NodeLog`] — the same
    /// struct later serialized into `node_*.jsonl` — so consumers see
    /// round rate (event cadence), virtual vs. real clock skew
    /// (`emu_time_s` vs `real_time_s`), and the staleness / defense
    /// metrics without waiting for the run to end.
    Round { node: usize, record: Record },
    /// A [`StoreStats`] accounting snapshot (`phase`: `start` | `end`),
    /// labeled with the store kind (`shared` | `paged`).
    Store { phase: String, kind: String, stats: StoreStats },
    /// The run reached quiescence (or its cancel flag).
    RunFinished { cancelled: bool, wall_s: f64 },
}

impl TelemetryEvent {
    /// Stable event-type tag (the SSE `event:` field).
    pub fn kind(&self) -> &'static str {
        match self {
            TelemetryEvent::RunStarted { .. } => "run_started",
            TelemetryEvent::Round { .. } => "round",
            TelemetryEvent::Store { .. } => "store",
            TelemetryEvent::RunFinished { .. } => "run_finished",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            TelemetryEvent::RunStarted { nodes, rounds } => Json::obj(vec![
                ("nodes", Json::num(*nodes as f64)),
                ("rounds", Json::num(*rounds as f64)),
            ]),
            // The record is embedded unmodified: `data.record` dumps to
            // the identical bytes as the saved node_*.jsonl line.
            TelemetryEvent::Round { node, record } => Json::obj(vec![
                ("node", Json::num(*node as f64)),
                ("record", record.to_json()),
            ]),
            TelemetryEvent::Store { phase, kind, stats } => {
                let mut j = stats.to_json();
                if let Json::Obj(ref mut obj) = j {
                    obj.insert("phase".into(), Json::str(phase.as_str()));
                    obj.insert("kind".into(), Json::str(kind.as_str()));
                }
                j
            }
            TelemetryEvent::RunFinished { cancelled, wall_s } => Json::obj(vec![
                ("cancelled", Json::Bool(*cancelled)),
                ("wall_s", Json::num(*wall_s)),
            ]),
        }
    }
}

#[derive(Debug)]
struct Ring {
    events: VecDeque<(u64, TelemetryEvent)>,
    next_seq: u64,
    closed: bool,
}

#[derive(Debug)]
struct TelemetryInner {
    cap: usize,
    ring: Mutex<Ring>,
    cond: Condvar,
    rounds: AtomicU64,
    dropped: AtomicU64,
}

/// Lock-light ring buffer of [`TelemetryEvent`]s for one run. Cheap to
/// clone (handle); producers and consumers share the same ring.
#[derive(Debug, Clone)]
pub struct Telemetry {
    inner: Arc<TelemetryInner>,
}

impl Default for Telemetry {
    fn default() -> Telemetry {
        Telemetry::new(65_536)
    }
}

impl Telemetry {
    /// A ring holding at most `cap` events; the oldest are evicted (and
    /// counted as dropped) when producers outpace the slowest consumer.
    pub fn new(cap: usize) -> Telemetry {
        Telemetry {
            inner: Arc::new(TelemetryInner {
                cap: cap.max(1),
                ring: Mutex::new(Ring { events: VecDeque::new(), next_seq: 0, closed: false }),
                cond: Condvar::new(),
                rounds: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
            }),
        }
    }

    /// Append one event (no-op after [`close`](Telemetry::close)).
    pub fn emit(&self, event: TelemetryEvent) {
        let is_round = matches!(event, TelemetryEvent::Round { .. });
        let mut ring = self.inner.ring.lock().unwrap();
        if ring.closed {
            return;
        }
        if is_round {
            self.inner.rounds.fetch_add(1, Ordering::Relaxed);
        }
        if ring.events.len() == self.inner.cap {
            ring.events.pop_front();
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
        let seq = ring.next_seq;
        ring.next_seq += 1;
        ring.events.push_back((seq, event));
        drop(ring);
        self.inner.cond.notify_all();
    }

    /// Copy out every buffered event with sequence >= `cursor`; returns
    /// the batch and the cursor to pass next time. Non-blocking.
    pub fn events_since(&self, cursor: u64) -> (Vec<(u64, TelemetryEvent)>, u64) {
        let ring = self.inner.ring.lock().unwrap();
        let batch: Vec<(u64, TelemetryEvent)> = ring
            .events
            .iter()
            .filter(|(seq, _)| *seq >= cursor)
            .cloned()
            .collect();
        let next = batch.last().map_or(cursor, |(seq, _)| seq + 1);
        (batch, next)
    }

    /// Like [`events_since`](Telemetry::events_since), but blocks up to
    /// `timeout` for something new. The final `bool` is the closed flag:
    /// an empty batch with `closed = true` means the stream is over.
    pub fn wait_since(
        &self,
        cursor: u64,
        timeout: Duration,
    ) -> (Vec<(u64, TelemetryEvent)>, u64, bool) {
        let guard = self.inner.ring.lock().unwrap();
        let (ring, _) = self
            .inner
            .cond
            .wait_timeout_while(guard, timeout, |r| !r.closed && r.next_seq <= cursor)
            .unwrap();
        let batch: Vec<(u64, TelemetryEvent)> = ring
            .events
            .iter()
            .filter(|(seq, _)| *seq >= cursor)
            .cloned()
            .collect();
        let next = batch.last().map_or(cursor, |(seq, _)| seq + 1);
        (batch, next, ring.closed)
    }

    /// Mark the stream finished: consumers drain what is buffered and
    /// stop waiting. Idempotent; later emits are dropped.
    pub fn close(&self) {
        let mut ring = self.inner.ring.lock().unwrap();
        ring.closed = true;
        drop(ring);
        self.inner.cond.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.ring.lock().unwrap().closed
    }

    /// Total `Round` events emitted (monotone; unaffected by eviction).
    pub fn rounds_emitted(&self) -> u64 {
        self.inner.rounds.load(Ordering::Relaxed)
    }

    /// Events evicted from the ring before every consumer saw them.
    pub fn dropped_events(&self) -> u64 {
        self.inner.dropped.load(Ordering::Relaxed)
    }

    /// Sequence number the next emitted event will get (== events ever
    /// emitted).
    pub fn next_seq(&self) -> u64 {
        self.inner.ring.lock().unwrap().next_seq
    }

    /// Events currently buffered in the ring (the live queue depth,
    /// bounded by the capacity).
    pub fn buffered_events(&self) -> u64 {
        self.inner.ring.lock().unwrap().events.len() as u64
    }
}

/// Default latency buckets for [`Registry::observe`] (seconds).
const LATENCY_BUCKETS: [f64; 12] =
    [1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.5, 1.0];

#[derive(Debug, Clone)]
enum Metric {
    Counter(f64),
    Gauge(f64),
    Histogram { buckets: Vec<f64>, counts: Vec<u64>, sum: f64, count: u64 },
}

/// Minimal counter/gauge/histogram registry rendering the Prometheus
/// text exposition format (`GET /metrics`). Metric names are used as-is;
/// callers keep them to `[a-zA-Z_][a-zA-Z0-9_]*`.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    metrics: Arc<Mutex<BTreeMap<String, Metric>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Add `by` to a (monotone) counter, creating it at 0 first.
    pub fn inc_counter(&self, name: &str, by: f64) {
        let mut m = self.metrics.lock().unwrap();
        match m.entry(name.to_string()).or_insert(Metric::Counter(0.0)) {
            Metric::Counter(v) => *v += by,
            _ => debug_assert!(false, "metric {name} is not a counter"),
        }
    }

    /// Set a gauge to `v`, creating it if absent.
    pub fn set_gauge(&self, name: &str, v: f64) {
        let mut m = self.metrics.lock().unwrap();
        match m.entry(name.to_string()).or_insert(Metric::Gauge(0.0)) {
            Metric::Gauge(g) => *g = v,
            _ => debug_assert!(false, "metric {name} is not a gauge"),
        }
    }

    /// Observe one sample into a histogram (created on first use with
    /// the default latency buckets).
    pub fn observe(&self, name: &str, v: f64) {
        self.observe_with(name, "", &LATENCY_BUCKETS, v);
    }

    /// Observe one sample into a labeled histogram with explicit
    /// buckets (created on first use). `labels` is the inner label list
    /// without braces (e.g. `phase="train"`); empty means unlabeled.
    /// Every series of one family must use the same buckets.
    pub fn observe_with(&self, name: &str, labels: &str, buckets: &[f64], v: f64) {
        let key = if labels.is_empty() {
            name.to_string()
        } else {
            format!("{name}{{{labels}}}")
        };
        let mut m = self.metrics.lock().unwrap();
        let metric = m.entry(key).or_insert(Metric::Histogram {
            buckets: buckets.to_vec(),
            counts: vec![0; buckets.len()],
            sum: 0.0,
            count: 0,
        });
        match metric {
            Metric::Histogram { buckets, counts, sum, count } => {
                for (le, c) in buckets.iter().zip(counts.iter_mut()) {
                    if v <= *le {
                        *c += 1;
                    }
                }
                *sum += v;
                *count += 1;
            }
            _ => debug_assert!(false, "metric {name} is not a histogram"),
        }
    }

    /// Render every metric in the Prometheus text format.
    pub fn render(&self) -> String {
        let m = self.metrics.lock().unwrap();
        let mut out = String::new();
        // Labeled histogram series are keyed `family{labels}`; the
        // BTreeMap keeps one family's series adjacent, so one TYPE line
        // per family suffices.
        let mut last_family: Option<String> = None;
        for (key, metric) in m.iter() {
            match metric {
                Metric::Counter(v) => {
                    out.push_str(&format!("# TYPE {key} counter\n{key} {v}\n"));
                }
                Metric::Gauge(v) => {
                    out.push_str(&format!("# TYPE {key} gauge\n{key} {v}\n"));
                }
                Metric::Histogram { buckets, counts, sum, count } => {
                    let (family, labels) = match key.split_once('{') {
                        Some((f, rest)) => (f, rest.trim_end_matches('}')),
                        None => (key.as_str(), ""),
                    };
                    if last_family.as_deref() != Some(family) {
                        out.push_str(&format!("# TYPE {family} histogram\n"));
                        last_family = Some(family.to_string());
                    }
                    let sep = if labels.is_empty() { "" } else { "," };
                    for (le, c) in buckets.iter().zip(counts.iter()) {
                        out.push_str(&format!(
                            "{family}_bucket{{{labels}{sep}le=\"{le}\"}} {c}\n"
                        ));
                    }
                    out.push_str(&format!(
                        "{family}_bucket{{{labels}{sep}le=\"+Inf\"}} {count}\n"
                    ));
                    if labels.is_empty() {
                        out.push_str(&format!("{family}_sum {sum}\n{family}_count {count}\n"));
                    } else {
                        out.push_str(&format!("{family}_sum{{{labels}}} {sum}\n"));
                        out.push_str(&format!("{family}_count{{{labels}}} {count}\n"));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_ev(node: usize, round: u64) -> TelemetryEvent {
        TelemetryEvent::Round {
            node,
            record: Record {
                round,
                emu_time_s: 1.0,
                real_time_s: 0.5,
                train_loss: 0.1,
                test_loss: 0.2,
                test_acc: 0.9,
                bytes_sent: 10,
                bytes_recv: 10,
                msgs_sent: 6,
                bytes_serialized: 5,
                late_msgs: 0,
                dropped_msgs: 0,
                mean_staleness_s: 0.0,
                poisoned_mass_admitted: 0.0,
                rejected_contribs: 0,
                isolation_rate: 0.0,
            },
        }
    }

    #[test]
    fn cursor_reads_are_incremental() {
        let t = Telemetry::new(16);
        t.emit(round_ev(0, 0));
        t.emit(round_ev(1, 0));
        let (batch, next) = t.events_since(0);
        assert_eq!(batch.len(), 2);
        assert_eq!(next, 2);
        let (batch, next) = t.events_since(next);
        assert!(batch.is_empty());
        assert_eq!(next, 2);
        t.emit(round_ev(2, 0));
        let (batch, _) = t.events_since(next);
        assert_eq!(batch.len(), 1);
        assert_eq!(t.rounds_emitted(), 3);
    }

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let t = Telemetry::new(2);
        for i in 0..5 {
            t.emit(round_ev(i, 0));
        }
        assert_eq!(t.dropped_events(), 3);
        let (batch, _) = t.events_since(0);
        assert_eq!(batch.len(), 2);
        // The survivors are the newest, with their original sequences.
        assert_eq!(batch[0].0, 3);
        assert_eq!(batch[1].0, 4);
    }

    #[test]
    fn wait_since_wakes_on_emit_and_on_close() {
        let t = Telemetry::new(8);
        let t2 = t.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            t2.emit(round_ev(0, 0));
            std::thread::sleep(Duration::from_millis(20));
            t2.close();
        });
        let (batch, next, closed) = t.wait_since(0, Duration::from_secs(5));
        assert_eq!(batch.len(), 1);
        assert!(!closed);
        let (batch, _, closed) = t.wait_since(next, Duration::from_secs(5));
        assert!(batch.is_empty());
        assert!(closed);
        h.join().unwrap();
    }

    #[test]
    fn closed_ring_drops_emits() {
        let t = Telemetry::new(8);
        t.close();
        t.emit(round_ev(0, 0));
        let (batch, _) = t.events_since(0);
        assert!(batch.is_empty());
        assert!(t.is_closed());
    }

    #[test]
    fn round_event_json_embeds_record_verbatim() {
        let ev = round_ev(3, 7);
        assert_eq!(ev.kind(), "round");
        let want = match &ev {
            TelemetryEvent::Round { record, .. } => record.to_json().dump(),
            _ => unreachable!(),
        };
        assert_eq!(ev.to_json().get("record").dump(), want);
        assert_eq!(ev.to_json().get("node").as_usize(), Some(3));
    }

    #[test]
    fn buffered_events_tracks_ring_depth() {
        let t = Telemetry::new(2);
        assert_eq!(t.buffered_events(), 0);
        t.emit(round_ev(0, 0));
        assert_eq!(t.buffered_events(), 1);
        for i in 0..5 {
            t.emit(round_ev(i, 0));
        }
        // Bounded by the capacity even after evictions.
        assert_eq!(t.buffered_events(), 2);
        assert_eq!(t.dropped_events(), 4);
    }

    #[test]
    fn labeled_histograms_share_one_type_line() {
        let r = Registry::new();
        let buckets = [0.1, 1.0];
        r.observe_with("phase_seconds", "phase=\"train\"", &buckets, 0.05);
        r.observe_with("phase_seconds", "phase=\"train\"", &buckets, 0.5);
        r.observe_with("phase_seconds", "phase=\"aggregate\"", &buckets, 2.0);
        let text = r.render();
        assert_eq!(text.matches("# TYPE phase_seconds histogram").count(), 1);
        assert!(text.contains("phase_seconds_bucket{phase=\"train\",le=\"0.1\"} 1"));
        assert!(text.contains("phase_seconds_bucket{phase=\"train\",le=\"+Inf\"} 2"));
        assert!(text.contains("phase_seconds_bucket{phase=\"aggregate\",le=\"1\"} 0"));
        assert!(text.contains("phase_seconds_count{phase=\"train\"} 2"));
        assert!(text.contains("phase_seconds_sum{phase=\"aggregate\"} 2"));
    }

    #[test]
    fn registry_renders_prometheus_text() {
        let r = Registry::new();
        r.inc_counter("requests_total", 1.0);
        r.inc_counter("requests_total", 2.0);
        r.set_gauge("queued", 4.0);
        r.observe("latency_seconds", 0.002);
        r.observe("latency_seconds", 0.2);
        let text = r.render();
        assert!(text.contains("# TYPE requests_total counter"));
        assert!(text.contains("requests_total 3"));
        assert!(text.contains("queued 4"));
        assert!(text.contains("latency_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("latency_seconds_count 2"));
    }
}
