//! Secure-aggregation DL node (paper §3.4).
//!
//! Same D-PSGD loop as [`super::DlNode`] but every outgoing model is
//! masked per receiver with pairwise-cancellable masks ([`crate::secure`]).
//! Requires full (dense) sharing — masks must cover every coordinate —
//! and a static topology (the 48-node setting the paper evaluates).
//!
//! Wire overhead beyond D-PSGD, all counted by the transport:
//! * one 32-byte master-secret exchange per node pair at round 0 (the
//!   stand-in for a DH key agreement), and
//! * one 16-byte per-(pair, receiver) seed advertisement per round
//!   (the "shared seeds" metadata of the paper, ~3% extra bytes).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::communication::{shaper::EmuClock, shaper::NetworkModel, Envelope, MsgKind, Transport};
use crate::compression::{FloatCodec, RawF32};
use crate::dataset::Dataset;
use crate::graph::{Graph, MixingWeights};
use crate::kernels::{self, Scratch};
use crate::metrics::{NodeLog, Record, Telemetry};
use crate::secure::Masker;
use crate::store::{ParamSlot, Payload};
use crate::training::Trainer;
use crate::util::Timer;

pub struct SecureDlNode {
    pub id: usize,
    pub rounds: u64,
    pub eval_every: u64,
    pub transport: Box<dyn Transport>,
    pub trainer: Trainer,
    /// Private vector or shared-store CoW handle (`param_store` config).
    pub params: ParamSlot,
    /// Full static topology (every node knows the graph; the coordinator
    /// distributes it, standing in for the receiver-announces-senders
    /// metadata round of the real protocol).
    pub graph: Arc<Graph>,
    pub weights: Arc<MixingWeights>,
    pub masker: Masker,
    pub test: Arc<Dataset>,
    pub network: Option<NetworkModel>,
    pub step_time_s: f64,
    pub eval_time_s: f64,
    /// Live sink mirroring every completed eval round (`None` = none).
    pub telemetry: Option<Telemetry>,
}

impl SecureDlNode {
    pub fn run(mut self) -> Result<NodeLog> {
        let mut log = NodeLog::new(self.id);
        if let Some(sink) = &self.telemetry {
            log.set_sink(sink.clone());
        }
        let mut clock = EmuClock::new();
        let wall = Timer::start();
        let neighbors: Vec<usize> = self.graph.neighbors_vec(self.id);
        let mut pending: HashMap<(u64, usize), Payload> = HashMap::new();
        // Reusable f64 accumulator for the masked fold (warm after
        // round 0; no per-round allocation).
        let mut scratch = Scratch::new();

        // Round-0 key agreement.
        for env in key_agreement_envelopes(self.id, self.masker_seed(), &self.graph, &neighbors) {
            self.transport.note_serialized(env.payload.len());
            self.transport.send(env)?;
        }

        for round in 0..self.rounds {
            // 1. Local training.
            let (mut params, train_loss) = self.trainer.train_round(self.params.take())?;

            let bytes_before = self.transport.counters().bytes_sent;

            // 2. Per-receiver masking + send. Masked payloads are
            //    per-receiver distinct buffers, so serialization is
            //    counted per envelope (nothing to share).
            for env in secure_round_envelopes(
                self.id,
                round,
                &params,
                &self.graph,
                &self.weights,
                &self.masker,
            ) {
                self.transport.note_serialized(env.payload.len());
                self.transport.send(env)?;
            }
            let sent_this_round = self.transport.counters().bytes_sent - bytes_before;

            // 3. Receive masked models from all neighbors and aggregate:
            //    x <- w_self x + sum_i w_i x~_i  (masks cancel pairwise),
            //    fused straight from payload bytes into the reusable f64
            //    accumulator, in neighbor order as before.
            kernels::widen_scale(
                &mut scratch.doubles,
                &params,
                self.weights.self_weight(self.id),
            );
            for &nbr in &neighbors {
                let payload = self.await_model(round, nbr, &mut pending)?;
                let w = self.weights.weight(self.id, nbr);
                kernels::decode_le_axpy_widen(&mut scratch.doubles, w, &payload)?;
            }
            kernels::narrow(&mut params, &scratch.doubles);
            self.params.put(params);

            // 4. Emulated clock.
            if let Some(net) = self.network {
                clock.advance(self.step_time_s * self.trainer.local_steps() as f64);
                clock.advance(net.round_upload_time(sent_this_round));
            }

            // 5. Evaluation (borrow the params out, no copy).
            if (round + 1) % self.eval_every == 0 || round + 1 == self.rounds {
                let params = self.params.take();
                let (test_loss, test_acc) = self.trainer.evaluate(&params, &self.test)?;
                self.params.put(params);
                if self.network.is_some() {
                    clock.advance(self.eval_time_s);
                }
                let c = self.transport.counters();
                log.push(Record {
                    round,
                    emu_time_s: clock.now(),
                    real_time_s: wall.elapsed().as_secs_f64(),
                    train_loss,
                    test_loss,
                    test_acc,
                    bytes_sent: c.bytes_sent,
                    bytes_recv: c.bytes_recv,
                    msgs_sent: c.msgs_sent,
                    bytes_serialized: c.bytes_serialized,
                    late_msgs: 0,
                    dropped_msgs: 0,
                    mean_staleness_s: 0.0,
                    poisoned_mass_admitted: 0.0,
                    rejected_contribs: 0,
                    isolation_rate: 0.0,
                });
            }
        }
        Ok(log)
    }

    fn masker_seed(&self) -> u64 {
        // The masker carries the experiment seed; reuse it for master
        // secrets so both pair members derive identically.
        self.masker.experiment_seed()
    }

    fn await_model(
        &mut self,
        round: u64,
        src: usize,
        pending: &mut HashMap<(u64, usize), Payload>,
    ) -> Result<Payload> {
        if let Some(p) = pending.remove(&(round, src)) {
            return Ok(p);
        }
        loop {
            let env = self
                .transport
                .recv()?
                .with_context(|| format!("transport closed waiting for {src}@{round}"))?;
            match env.kind {
                MsgKind::Model if env.round == round && env.src == src => {
                    return Ok(env.payload)
                }
                MsgKind::Model if env.round >= round => {
                    pending.insert((env.round, env.src), env.payload);
                }
                // Seed/key messages carry no state we need (both sides
                // derive deterministically); they exist for byte
                // accounting. Model messages from stale rounds are
                // dropped.
                _ => continue,
            }
        }
    }
}

/// Nodes that can co-occur with `id` in some receiver's sender set.
pub(crate) fn two_hop_peers(graph: &Graph, id: usize, neighbors: &[usize]) -> Vec<usize> {
    let mut out = std::collections::BTreeSet::new();
    for &r in neighbors {
        for n in graph.neighbors(r) {
            if n != id {
                out.insert(n);
            }
        }
    }
    out.into_iter().collect()
}

/// Round-0 key agreement: one 32-byte message to every higher-id node we
/// share a receiver with (here: anyone within 2 hops). Shared by the
/// threaded [`SecureDlNode`] and the scheduler's `SecureDlNodeSm`.
pub(crate) fn key_agreement_envelopes(
    id: usize,
    seed: u64,
    graph: &Graph,
    neighbors: &[usize],
) -> Vec<Envelope> {
    let mut out = Vec::new();
    for peer in two_hop_peers(graph, id, neighbors) {
        if peer > id {
            let master = crate::secure::master_secret(seed, id, peer);
            out.push(Envelope {
                src: id,
                dst: peer,
                round: 0,
                kind: MsgKind::SecureSeed,
                sent_at_s: 0.0,
                trace: 0,
                payload: master.to_vec().into(),
            });
        }
    }
    out
}

/// One round's outgoing traffic for a secure node: per-receiver seed
/// advertisements plus the masked model. Each receiver r gets
/// `x_i + (1/w_ri) * sum of pair masks over r's sender set`; the
/// 16-byte per-(pair, receiver) seed advertisements to higher-id
/// co-senders are the metadata the paper attributes the ~3% overhead to.
pub(crate) fn secure_round_envelopes(
    id: usize,
    round: u64,
    params: &[f32],
    graph: &Graph,
    weights: &MixingWeights,
    masker: &Masker,
) -> Vec<Envelope> {
    let codec = RawF32;
    let dim = params.len();
    let seed = masker.experiment_seed();
    let mut out = Vec::new();
    for r in graph.neighbors_vec(id) {
        let co_senders: Vec<usize> = graph.neighbors_vec(r);
        let w_ri = weights.weight(r, id);
        debug_assert!(w_ri > 0.0);
        for &peer in &co_senders {
            if peer > id {
                let master = crate::secure::master_secret(seed, id, peer);
                let round_seed = crate::secure::round_seed(&master, r, round);
                out.push(Envelope {
                    src: id,
                    dst: peer,
                    round,
                    kind: MsgKind::SecureSeed,
                    sent_at_s: 0.0,
                    trace: 0,
                    payload: round_seed.to_vec().into(),
                });
            }
        }
        let mask = masker.mask_for(r, round, &co_senders, (1.0 / w_ri) as f32, dim);
        let mut masked = params.to_vec();
        for (m, k) in masked.iter_mut().zip(mask.iter()) {
            *m += k;
        }
        out.push(Envelope {
            src: id,
            dst: r,
            round,
            kind: MsgKind::Model,
            sent_at_s: 0.0,
            trace: 0,
            payload: codec.encode(&masked).into(),
        });
    }
    out
}
