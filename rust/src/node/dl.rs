//! The DL client node: the paper's Fig 2 training loop as a long-running
//! process — train locally, exchange models with the current neighbors,
//! aggregate, periodically evaluate.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::communication::{shaper::EmuClock, shaper::NetworkModel, Envelope, MsgKind, Transport};
use crate::dataset::Dataset;
use crate::kernels::Scratch;
use crate::metrics::{NodeLog, Record, Telemetry};
use crate::model::ParamVec;
use crate::scenario::ByzantineRoster;
use crate::sharing::{DefenseStats, Received, Sharing};
use crate::store::{ParamSlot, Payload};
use crate::training::Trainer;
use crate::util::Timer;

use super::proto::{decode_neighbors, encode_control, Control, NeighborAssignment};

/// Static or sampler-driven topology view for one node.
pub enum TopologyView {
    /// Fixed neighbor row: (self weight, [(neighbor, weight)]).
    Static { self_weight: f64, neighbors: Vec<(usize, f64)> },
    /// Ask the peer sampler (at `sampler_rank`) every round.
    Dynamic { sampler_rank: usize },
}

/// Everything a DL node needs to run.
pub struct DlNode {
    pub id: usize,
    pub rounds: u64,
    pub eval_every: u64,
    pub transport: Box<dyn Transport>,
    pub trainer: Trainer,
    pub sharing: Box<dyn Sharing>,
    /// Private vector or shared-store CoW handle (`param_store` config).
    pub params: ParamSlot,
    pub topology: TopologyView,
    pub test: Arc<Dataset>,
    /// Byzantine attack roster (`None` = every node honest). This node
    /// consults only its own entry; the roster is shared fleet-wide so
    /// defense metrics can label senders.
    pub byz: Option<Arc<ByzantineRoster>>,
    /// WAN model for the emulated clock (None = skip emu accounting).
    pub network: Option<NetworkModel>,
    /// Calibrated seconds per local training step (for the emu clock).
    pub step_time_s: f64,
    /// Eval time estimate per full test pass (emu clock).
    pub eval_time_s: f64,
    /// Live sink mirroring every completed eval round (`None` = none).
    pub telemetry: Option<Telemetry>,
}

impl DlNode {
    /// Run the D-PSGD loop; returns this node's metric log.
    pub fn run(mut self) -> Result<NodeLog> {
        let mut log = NodeLog::new(self.id);
        if let Some(sink) = &self.telemetry {
            log.set_sink(sink.clone());
        }
        let mut clock = EmuClock::new();
        let wall = Timer::start();
        // Model messages that arrived early (neighbors running ahead).
        let mut pending: HashMap<(u64, usize), Payload> = HashMap::new();
        // Per-node arena: hot-path buffers warm up in round 0 and are
        // reused for the rest of the run.
        let mut scratch = Scratch::new();
        let mut defense = DefenseStats::default();

        for round in 0..self.rounds {
            // 1. Current topology row.
            let assign = self.neighbor_row(round, &mut pending)?;

            // 2. Local training (first take materializes the CoW shard
            //    in shared-store mode).
            let (new_params, train_loss) = self.trainer.train_round(self.params.take())?;
            let model = ParamVec::from_vec(new_params);

            // 3. Share with neighbors: serialize once, every envelope
            //    shares the same payload buffer (pooled across rounds).
            //    A Byzantine node swaps in its attack model here — its
            //    *own* params keep the honest training result, so the
            //    attack is sustained round after round. Flood attacks
            //    amplify by sending `copies` duplicates per neighbor
            //    (receivers keep one per (round, sender); the rest is
            //    wire-byte damage).
            let (payload, copies): (Payload, u32) = match self
                .byz
                .as_ref()
                .and_then(|b| b.payload_model(self.id, round, model.as_slice()))
            {
                Some((attack, copies)) => {
                    let attack = ParamVec::from_vec(attack);
                    (self.sharing.outgoing_pooled(&attack, round, &mut scratch)?, copies)
                }
                None => (self.sharing.outgoing_pooled(&model, round, &mut scratch)?, 1),
            };
            self.transport.note_serialized(payload.len());
            let bytes_before = self.transport.counters().bytes_sent;
            for &(nbr, _) in &assign.neighbors {
                for _ in 0..copies {
                    self.transport.send(Envelope {
                        src: self.id,
                        dst: nbr,
                        round,
                        kind: MsgKind::Model,
                        sent_at_s: 0.0,
                        trace: 0,
                        payload: payload.clone(),
                    })?;
                }
            }
            let sent_this_round = self.transport.counters().bytes_sent - bytes_before;

            // 4. Collect this round's models from all current neighbors.
            let mut msgs: Vec<(usize, Payload)> = Vec::with_capacity(assign.neighbors.len());
            for &(nbr, _) in &assign.neighbors {
                let payload = self.await_model(round, nbr, &mut pending)?;
                msgs.push((nbr, payload));
            }

            // 5. Aggregate.
            let mut model = model;
            {
                let received: Vec<Received> = msgs
                    .iter()
                    .map(|(src, payload)| Received {
                        src: *src,
                        weight: weight_of(&assign, *src),
                        payload: payload.as_slice(),
                    })
                    .collect();
                self.sharing
                    .aggregate_with(&mut model, assign.self_weight, &received, &mut scratch)?;
                // Defense accounting: how much adversarial mass did the
                // aggregation admit, how much did it isolate?
                if let Some(roster) = &self.byz {
                    let report = self.sharing.defense_report();
                    for (i, r) in received.iter().enumerate() {
                        let admitted = report
                            .map_or(1.0, |rep| rep.admitted.get(i).copied().unwrap_or(1.0));
                        defense.observe(roster.is_byzantine(r.src), r.weight, admitted);
                    }
                }
            }
            self.params.put(model.into_vec());

            // 6. Emulated clock: local compute + uplink transfer.
            if let Some(net) = self.network {
                clock.advance(self.step_time_s * self.trainer.local_steps() as f64);
                clock.advance(net.round_upload_time(sent_this_round));
            }

            // 7. Periodic evaluation (borrow the params out, no copy).
            if (round + 1) % self.eval_every == 0 || round + 1 == self.rounds {
                let params = self.params.take();
                let (test_loss, test_acc) = self.trainer.evaluate(&params, &self.test)?;
                self.params.put(params);
                if self.network.is_some() {
                    clock.advance(self.eval_time_s);
                }
                let c = self.transport.counters();
                log.push(Record {
                    round,
                    emu_time_s: clock.now(),
                    real_time_s: wall.elapsed().as_secs_f64(),
                    train_loss,
                    test_loss,
                    test_acc,
                    bytes_sent: c.bytes_sent,
                    bytes_recv: c.bytes_recv,
                    msgs_sent: c.msgs_sent,
                    bytes_serialized: c.bytes_serialized,
                    late_msgs: 0,
                    dropped_msgs: 0,
                    mean_staleness_s: 0.0,
                    poisoned_mass_admitted: defense.poisoned_mass,
                    rejected_contribs: defense.rejected,
                    isolation_rate: defense.isolation_rate(),
                });
            }
        }
        Ok(log)
    }

    /// Resolve the neighbor row for `round`.
    fn neighbor_row(
        &mut self,
        round: u64,
        pending: &mut HashMap<(u64, usize), Payload>,
    ) -> Result<NeighborAssignment> {
        match &self.topology {
            TopologyView::Static { self_weight, neighbors } => Ok(NeighborAssignment {
                round,
                self_weight: *self_weight,
                neighbors: neighbors.clone(),
            }),
            TopologyView::Dynamic { sampler_rank } => {
                let sampler = *sampler_rank;
                self.transport.send(Envelope {
                    src: self.id,
                    dst: sampler,
                    round,
                    kind: MsgKind::Control,
                    sent_at_s: 0.0,
                    trace: 0,
                    payload: encode_control(&Control::Ready { round }).into(),
                })?;
                loop {
                    let env = self
                        .transport
                        .recv()?
                        .context("transport closed while waiting for peer sampler")?;
                    match env.kind {
                        MsgKind::Neighbors => {
                            let a = decode_neighbors(&env.payload)?;
                            if a.round != round {
                                bail!(
                                    "sampler sent round {} while waiting for {round}",
                                    a.round
                                );
                            }
                            return Ok(a);
                        }
                        MsgKind::Model => {
                            pending.insert((env.round, env.src), env.payload);
                        }
                        other => bail!("unexpected {other:?} while waiting for sampler"),
                    }
                }
            }
        }
    }

    /// Wait for the Model message of (round, src), buffering strays.
    fn await_model(
        &mut self,
        round: u64,
        src: usize,
        pending: &mut HashMap<(u64, usize), Payload>,
    ) -> Result<Payload> {
        if let Some(p) = pending.remove(&(round, src)) {
            return Ok(p);
        }
        loop {
            let env = self
                .transport
                .recv()?
                .with_context(|| format!("transport closed waiting for model {src}@{round}"))?;
            match env.kind {
                MsgKind::Model => {
                    if env.round == round && env.src == src {
                        return Ok(env.payload);
                    }
                    if env.round < round {
                        // A stale duplicate — drop (can only happen after
                        // a dynamic-topology change mid-flight).
                        continue;
                    }
                    pending.insert((env.round, env.src), env.payload);
                }
                MsgKind::Control => continue, // stop arrives after our last round
                other => bail!("unexpected {other:?} while collecting models"),
            }
        }
    }
}

/// Look up a neighbor's weight in an assignment.
fn weight_of(a: &NeighborAssignment, src: usize) -> f64 {
    a.neighbors
        .iter()
        .find(|(n, _)| *n == src)
        .map(|(_, w)| *w)
        .unwrap_or(0.0)
}
