//! Decentralized gossip-based peer sampling (Jelasity et al., TOCS 2007)
//! — the paper's named future-work item ("decentralized peer sampling
//! [16]"), provided as a first-class module.
//!
//! Each node keeps a small **partial view**: a set of (peer, age)
//! descriptors. Every round it picks the *oldest* peer, sends it half of
//! its view (plus its own fresh descriptor), receives the symmetric
//! half-view back, and merges keeping the freshest descriptor per peer.
//! The stream of view samples converges to (near-)uniform random peers —
//! which is exactly what a dynamic d-regular topology needs, without the
//! centralized sampler.
//!
//! This module implements the protocol state machine over plain payloads
//! (so it is transport-agnostic and unit-testable without threads); the
//! driver exchanges the `ViewMessage`s through any [`crate::communication::Transport`].

use crate::rng::Xoshiro256pp;

/// A peer descriptor: node id + age in rounds (0 = freshest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Descriptor {
    pub peer: usize,
    pub age: u32,
}

/// Exchanged half-view.
#[derive(Debug, Clone, PartialEq)]
pub struct ViewMessage {
    pub from: usize,
    pub descriptors: Vec<Descriptor>,
    /// True for the initiating push (the receiver must reply).
    pub is_push: bool,
}

/// Peer-sampling service state for one node.
#[derive(Debug)]
pub struct GossipView {
    pub node: usize,
    /// Maximum view size (the classic "c" parameter).
    pub capacity: usize,
    view: Vec<Descriptor>,
    rng: Xoshiro256pp,
}

impl GossipView {
    /// Bootstrap from any non-empty seed set (e.g. ring neighbors).
    pub fn new(node: usize, capacity: usize, seeds: &[usize], seed: u64) -> GossipView {
        assert!(capacity >= 2, "view capacity must be >= 2");
        let view = seeds
            .iter()
            .filter(|&&p| p != node)
            .take(capacity)
            .map(|&peer| Descriptor { peer, age: 0 })
            .collect();
        GossipView { node, capacity, view, rng: Xoshiro256pp::new(seed) }
    }

    pub fn view(&self) -> &[Descriptor] {
        &self.view
    }

    /// Pick the gossip partner for this round: the oldest descriptor
    /// (ties broken randomly). Returns `None` on an empty view.
    pub fn select_partner(&mut self) -> Option<usize> {
        if self.view.is_empty() {
            return None;
        }
        let max_age = self.view.iter().map(|d| d.age).max().unwrap();
        let oldest: Vec<usize> = self
            .view
            .iter()
            .filter(|d| d.age == max_age)
            .map(|d| d.peer)
            .collect();
        Some(oldest[self.rng.range(0, oldest.len())])
    }

    /// Build the half-view to send to `partner` (push or reply).
    pub fn make_message(&mut self, partner: usize, is_push: bool) -> ViewMessage {
        // Own fresh descriptor first, then a random half of the view
        // excluding the partner itself.
        let mut pool: Vec<Descriptor> =
            self.view.iter().copied().filter(|d| d.peer != partner).collect();
        self.rng.shuffle(&mut pool);
        pool.truncate(self.capacity / 2);
        let mut descriptors = vec![Descriptor { peer: self.node, age: 0 }];
        descriptors.extend(pool);
        ViewMessage { from: self.node, descriptors, is_push }
    }

    /// Merge a received half-view; keeps the freshest descriptor per peer
    /// and trims back to capacity by dropping the oldest.
    pub fn merge(&mut self, msg: &ViewMessage) {
        for d in &msg.descriptors {
            if d.peer == self.node {
                continue;
            }
            match self.view.iter_mut().find(|v| v.peer == d.peer) {
                Some(existing) => existing.age = existing.age.min(d.age),
                None => self.view.push(*d),
            }
        }
        // Trim: drop oldest first (random among ties).
        while self.view.len() > self.capacity {
            let max_age = self.view.iter().map(|d| d.age).max().unwrap();
            let idx_candidates: Vec<usize> = self
                .view
                .iter()
                .enumerate()
                .filter(|(_, d)| d.age == max_age)
                .map(|(i, _)| i)
                .collect();
            let kill = idx_candidates[self.rng.range(0, idx_candidates.len())];
            self.view.swap_remove(kill);
        }
    }

    /// Advance the round: age every descriptor.
    pub fn tick(&mut self) {
        for d in self.view.iter_mut() {
            d.age = d.age.saturating_add(1);
        }
    }

    /// Sample `k` distinct peers from the current view (what the DL node
    /// uses as its dynamic neighbor set).
    pub fn sample_neighbors(&mut self, k: usize) -> Vec<usize> {
        let mut peers: Vec<usize> = self.view.iter().map(|d| d.peer).collect();
        self.rng.shuffle(&mut peers);
        peers.truncate(k);
        peers
    }
}

/// Drive a full in-memory gossip network for `rounds` (used by tests and
/// by the ablation bench; the threaded deployment exchanges the same
/// messages over a real transport).
pub fn simulate_rounds(views: &mut [GossipView], rounds: usize) {
    for _ in 0..rounds {
        for i in 0..views.len() {
            let Some(partner) = views[i].select_partner() else { continue };
            let push = views[i].make_message(partner, true);
            let reply = views[partner].make_message(views[i].node, false);
            views[partner].merge(&push);
            views[i].merge(&reply);
        }
        for v in views.iter_mut() {
            v.tick();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn network(n: usize, capacity: usize) -> Vec<GossipView> {
        // Bootstrap from a ring: each node knows its 2 ring neighbors.
        (0..n)
            .map(|i| {
                GossipView::new(
                    i,
                    capacity,
                    &[(i + 1) % n, (i + n - 1) % n],
                    1000 + i as u64,
                )
            })
            .collect()
    }

    #[test]
    fn views_stay_within_capacity_and_exclude_self() {
        let mut views = network(20, 6);
        simulate_rounds(&mut views, 30);
        for v in &views {
            assert!(v.view().len() <= 6);
            assert!(v.view().iter().all(|d| d.peer != v.node));
            // No duplicate peers.
            let set: std::collections::HashSet<_> =
                v.view().iter().map(|d| d.peer).collect();
            assert_eq!(set.len(), v.view().len());
        }
    }

    #[test]
    fn views_fill_to_capacity() {
        let mut views = network(30, 8);
        simulate_rounds(&mut views, 20);
        for v in &views {
            assert_eq!(v.view().len(), 8, "node {}", v.node);
        }
    }

    #[test]
    fn view_reach_spreads_beyond_bootstrap() {
        // After gossip, views must contain peers far from the original
        // ring positions (the service mixes the whole network).
        let n = 40;
        let mut views = network(n, 8);
        simulate_rounds(&mut views, 30);
        let mut far = 0usize;
        for v in &views {
            for d in v.view() {
                let dist =
                    (v.node as i64 - d.peer as i64).rem_euclid(n as i64).min(
                        (d.peer as i64 - v.node as i64).rem_euclid(n as i64),
                    );
                if dist > 5 {
                    far += 1;
                }
            }
        }
        assert!(far > n, "only {far} long-range descriptors");
    }

    #[test]
    fn indegree_roughly_balanced() {
        // Uniform sampling => in-degree (appearances in others' views)
        // concentrates around capacity.
        let n = 40;
        let cap = 8;
        let mut views = network(n, cap);
        simulate_rounds(&mut views, 50);
        let mut indeg = vec![0usize; n];
        for v in &views {
            for d in v.view() {
                indeg[d.peer] += 1;
            }
        }
        let max = *indeg.iter().max().unwrap();
        let min = *indeg.iter().min().unwrap();
        assert!(min >= 1, "some node vanished: {indeg:?}");
        assert!(max <= cap * 4, "hotspot: {indeg:?}");
    }

    #[test]
    fn sample_neighbors_distinct_and_from_view() {
        let mut views = network(20, 8);
        simulate_rounds(&mut views, 20);
        let v = &mut views[3];
        let members: std::collections::HashSet<usize> =
            v.view().iter().map(|d| d.peer).collect();
        let sample = v.sample_neighbors(5);
        assert_eq!(sample.len(), 5);
        let set: std::collections::HashSet<_> = sample.iter().collect();
        assert_eq!(set.len(), 5);
        assert!(sample.iter().all(|p| members.contains(p)));
    }

    #[test]
    fn empty_view_yields_no_partner() {
        let mut v = GossipView::new(0, 4, &[], 1);
        assert_eq!(v.select_partner(), None);
        assert!(v.sample_neighbors(3).is_empty());
    }

    #[test]
    fn merge_prefers_fresh_descriptors() {
        let mut v = GossipView::new(0, 4, &[1], 1);
        v.tick();
        v.tick();
        assert_eq!(v.view()[0].age, 2);
        v.merge(&ViewMessage {
            from: 1,
            descriptors: vec![Descriptor { peer: 1, age: 0 }],
            is_push: true,
        });
        assert_eq!(v.view()[0].age, 0);
    }
}
