//! Node implementations (the paper's *Node* module): "an object of a
//! sub-class of the node module is instantiated when a DL process
//! starts … from being a DL client to a FL server or a centralized peer
//! sampler" (§2.2).
//!
//! * [`DlNode`] — the D-PSGD client (paper Fig 2 loop).
//! * [`SecureDlNode`] — DL client with pairwise-mask secure aggregation.
//! * [`PeerSampler`] — centralized per-round topology service.
//! * [`FlServer`] / [`FlClient`] / [`ParameterServer`] — FL emulation.
//! * [`async_dl`] — asynchronous-gossip policies (virtual deadlines,
//!   staleness weighting, late-delivery handling) consumed by the
//!   scheduler's `AsyncDlNodeSm`.

pub mod async_dl;
mod dl;
mod fl;
mod gossip_sampler;
mod peer_sampler;
pub mod proto;
mod secure_dl;

pub use async_dl::{AsyncPolicy, DeadlineSpec, LatePolicy, StalenessPolicy};
pub use dl::{DlNode, TopologyView};
pub use gossip_sampler::{simulate_rounds as gossip_simulate, Descriptor, GossipView, ViewMessage};
pub use fl::{FlClient, FlServer, ParameterServer};
pub use peer_sampler::PeerSampler;
pub use secure_dl::SecureDlNode;

// Round-logic helpers shared with the virtual-time scheduler's state
// machines (crate::scheduler).
pub(crate) use peer_sampler::draw_round;
pub(crate) use secure_dl::{key_agreement_envelopes, secure_round_envelopes};
