//! Payload encodings for control-plane messages (neighbor lists, round
//! barriers, secure-agg seed exchange). Data-plane model payloads are
//! owned by the sharing module.

use anyhow::{bail, Result};

/// Per-round neighbor assignment sent by the peer sampler: the node's
/// neighbor ids with their Metropolis-Hastings weights, plus the node's
/// self-weight.
#[derive(Debug, Clone, PartialEq)]
pub struct NeighborAssignment {
    pub round: u64,
    pub self_weight: f64,
    /// (neighbor id, mixing weight)
    pub neighbors: Vec<(usize, f64)>,
}

pub fn encode_neighbors(a: &NeighborAssignment) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + a.neighbors.len() * 12);
    out.extend_from_slice(&a.round.to_le_bytes());
    out.extend_from_slice(&(a.self_weight as f32).to_le_bytes());
    out.extend_from_slice(&(a.neighbors.len() as u32).to_le_bytes());
    for &(id, w) in &a.neighbors {
        out.extend_from_slice(&(id as u32).to_le_bytes());
        out.extend_from_slice(&(w as f32).to_le_bytes());
    }
    out
}

pub fn decode_neighbors(bytes: &[u8]) -> Result<NeighborAssignment> {
    if bytes.len() < 16 {
        bail!("neighbor assignment too short");
    }
    let round = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let self_weight = f32::from_le_bytes(bytes[8..12].try_into().unwrap()) as f64;
    let count = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    if bytes.len() != 16 + count * 8 {
        bail!("neighbor assignment length mismatch");
    }
    let mut neighbors = Vec::with_capacity(count);
    for i in 0..count {
        let off = 16 + i * 8;
        let id = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        let w = f32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap()) as f64;
        neighbors.push((id, w));
    }
    Ok(NeighborAssignment { round, self_weight, neighbors })
}

/// Control messages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Control {
    /// Node is ready for `round` (peer-sampler barrier).
    Ready { round: u64 },
    /// Orderly stop.
    Stop,
}

pub fn encode_control(c: &Control) -> Vec<u8> {
    match c {
        Control::Ready { round } => {
            let mut out = vec![0u8];
            out.extend_from_slice(&round.to_le_bytes());
            out
        }
        Control::Stop => vec![1u8],
    }
}

pub fn decode_control(bytes: &[u8]) -> Result<Control> {
    match bytes.first() {
        Some(0) if bytes.len() == 9 => Ok(Control::Ready {
            round: u64::from_le_bytes(bytes[1..9].try_into().unwrap()),
        }),
        Some(1) if bytes.len() == 1 => Ok(Control::Stop),
        _ => bail!("bad control payload"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbors_roundtrip() {
        let a = NeighborAssignment {
            round: 17,
            self_weight: 0.25,
            neighbors: vec![(3, 0.25), (9, 0.5)],
        };
        let back = decode_neighbors(&encode_neighbors(&a)).unwrap();
        assert_eq!(back.round, 17);
        assert!((back.self_weight - 0.25).abs() < 1e-6);
        assert_eq!(back.neighbors.len(), 2);
        assert_eq!(back.neighbors[1].0, 9);
    }

    #[test]
    fn neighbors_empty() {
        let a = NeighborAssignment { round: 0, self_weight: 1.0, neighbors: vec![] };
        assert_eq!(decode_neighbors(&encode_neighbors(&a)).unwrap().neighbors.len(), 0);
    }

    #[test]
    fn neighbors_rejects_truncation() {
        let a = NeighborAssignment {
            round: 1,
            self_weight: 0.5,
            neighbors: vec![(1, 0.5)],
        };
        let enc = encode_neighbors(&a);
        assert!(decode_neighbors(&enc[..enc.len() - 1]).is_err());
        assert!(decode_neighbors(&[1, 2]).is_err());
    }

    #[test]
    fn control_roundtrip() {
        for c in [Control::Ready { round: 42 }, Control::Stop] {
            assert_eq!(decode_control(&encode_control(&c)).unwrap(), c);
        }
        assert!(decode_control(&[9]).is_err());
        assert!(decode_control(&[0, 1]).is_err());
    }
}
