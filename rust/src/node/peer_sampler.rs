//! Centralized peer sampler: instantiates a fresh topology every round
//! and tells each node who its neighbors are (paper §3.2, "any dynamic
//! graph can be realized within the peer sampler").
//!
//! The sampler occupies an extra transport rank (`nodes`). Nodes send
//! `Control::Ready{round}`; once all `nodes` are ready the sampler draws
//! a new graph from the configured spec, computes Metropolis-Hastings
//! weights, and replies with each node's `NeighborAssignment`. This
//! doubles as the round barrier for dynamic experiments.
//!
//! Availability is pluggable ([`Availability`]): either the original
//! per-round i.i.d. Bernoulli draw, or a replayable
//! [`crate::scenario::ChurnTrace`]. Either way, unavailable nodes
//! receive an empty assignment for the round — they keep training
//! locally but skip the exchange — and the round's topology is drawn
//! over the active set only.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::communication::{Envelope, MsgKind, Transport};
use crate::graph::{from_spec, metropolis_hastings};
use crate::rng::{mix_seed, Xoshiro256pp};
use crate::scenario::Availability;

use super::proto::{decode_control, encode_neighbors, Control, NeighborAssignment};

pub struct PeerSampler {
    pub rank: usize,
    pub nodes: usize,
    pub rounds: u64,
    /// Topology spec re-sampled every round (e.g. "regular:5").
    pub spec: String,
    pub seed: u64,
    /// Per-round availability model (FedScale-style client churn, a
    /// paper future-work item): Bernoulli unavailability or a replayable
    /// churn trace.
    pub avail: Availability,
    pub transport: Box<dyn Transport>,
}

impl PeerSampler {
    /// Serve all rounds, then exit.
    pub fn run(self) -> Result<()> {
        let mut early: HashMap<u64, usize> = HashMap::new();
        for round in 0..self.rounds {
            // Barrier: collect `nodes` ready messages for this round.
            let mut ready = early.remove(&round).unwrap_or(0);
            while ready < self.nodes {
                let env = self
                    .transport
                    .recv()?
                    .context("transport closed while sampling")?;
                if env.kind != MsgKind::Control {
                    bail!("peer sampler got unexpected {:?}", env.kind);
                }
                match decode_control(&env.payload)? {
                    Control::Ready { round: r } if r == round => ready += 1,
                    Control::Ready { round: r } if r > round => {
                        *early.entry(r).or_insert(0) += 1;
                    }
                    Control::Ready { .. } => {} // stale; ignore
                    Control::Stop => return Ok(()),
                }
            }
            for (node, assign) in
                draw_round(&self.spec, self.seed, &self.avail, self.nodes, round)?
                    .into_iter()
                    .enumerate()
            {
                self.transport.send(Envelope {
                    src: self.rank,
                    dst: node,
                    round,
                    kind: MsgKind::Neighbors,
                    sent_at_s: 0.0,
                    trace: 0,
                    payload: encode_neighbors(&assign).into(),
                })?;
            }
        }
        Ok(())
    }
}

/// Draw one round's topology for every node: availability (Bernoulli or
/// churn trace), parity fix-up for d-regular specs, fresh graph +
/// Metropolis-Hastings weights over the active set. Deterministic in
/// `(seed, round)` (a trace makes it replayable outright); shared by the
/// threaded [`PeerSampler`] and the scheduler's `SamplerSm`. Inactive
/// nodes get an empty assignment (train locally, skip the exchange).
pub(crate) fn draw_round(
    spec: &str,
    seed: u64,
    avail: &Availability,
    nodes: usize,
    round: u64,
) -> Result<Vec<NeighborAssignment>> {
    // Availability draw for this round (the Bernoulli arm consumes rng
    // draws in node order, exactly as the pre-trace implementation did).
    let mut rng = Xoshiro256pp::new(mix_seed(&[seed, 0x70_70, round]));
    let mut active: Vec<usize> = (0..nodes)
        .filter(|&node| match avail {
            Availability::Bernoulli(p) => *p <= 0.0 || rng.next_f64() >= *p,
            Availability::Trace(trace) => trace.active(node, round),
        })
        .collect();
    // A d-regular draw needs |active| * d even and d < |active|; mark one
    // more node unavailable when the parity is wrong (random victim to
    // avoid bias).
    if let Some(d) = regular_degree(spec) {
        if active.len() > d && (active.len() * d) % 2 == 1 {
            let victim = rng.range(0, active.len());
            active.remove(victim);
        }
    }
    // Fresh topology + weights over the active set (global node ids are
    // relabeled onto 0..active.len() for the generator).
    let assignments = sample_over_active(spec, &active, &mut rng)?;
    Ok((0..nodes)
        .map(|node| {
            let a = assignments.get(&node).cloned().unwrap_or(NeighborAssignment {
                round,
                self_weight: 1.0,
                neighbors: Vec::new(),
            });
            NeighborAssignment { round, ..a }
        })
        .collect())
}

/// Draw the round's topology over `active` and compute per-node rows.
fn sample_over_active(
    spec: &str,
    active: &[usize],
    rng: &mut Xoshiro256pp,
) -> Result<HashMap<usize, NeighborAssignment>> {
    let m = active.len();
    let mut out = HashMap::new();
    if m < 2 {
        return Ok(out);
    }
    // Degrade the spec gracefully when the active set is too small for
    // it (e.g. regular:5 with 4 actives -> fully connected).
    let g = if matches!(regular_degree(spec), Some(d) if d >= m) {
        crate::graph::fully_connected(m)
    } else {
        match from_spec(spec, m, rng) {
            Ok(g) => g,
            Err(_) => crate::graph::fully_connected(m),
        }
    };
    let w = metropolis_hastings(&g);
    for (local, &global) in active.iter().enumerate() {
        out.insert(
            global,
            NeighborAssignment {
                round: 0, // caller overwrites
                self_weight: w.self_weight(local),
                neighbors: w
                    .neighbor_weights(local)
                    .map(|(n, wt)| (active[n], wt))
                    .collect(),
            },
        );
    }
    Ok(out)
}

/// Extract `d` from a `regular:<d>` spec.
fn regular_degree(spec: &str) -> Option<usize> {
    spec.strip_prefix("regular:")?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::communication::inproc::InprocHub;
    use crate::node::proto::{decode_neighbors, encode_control};

    #[test]
    fn sampler_serves_rounds_and_barriers() {
        let nodes = 4usize;
        let rounds = 3u64;
        let hub = InprocHub::new(nodes + 1);
        let sampler = PeerSampler {
            rank: nodes,
            nodes,
            rounds,
            spec: "regular:3".into(),
            seed: 7,
            avail: Availability::always(),
            transport: Box::new(hub.endpoint(nodes)),
        };
        let h = std::thread::spawn(move || sampler.run().unwrap());
        let mut assignments: Vec<Vec<NeighborAssignment>> = vec![Vec::new(); nodes];
        for round in 0..rounds {
            for id in 0..nodes {
                hub.endpoint(id)
                    .send(Envelope {
                        src: id,
                        dst: nodes,
                        round,
                        kind: MsgKind::Control,
                        sent_at_s: 0.0,
                        trace: 0,
                        payload: encode_control(&Control::Ready { round }).into(),
                    })
                    .unwrap();
            }
            for id in 0..nodes {
                let env = hub.endpoint(id).recv().unwrap().unwrap();
                assert_eq!(env.kind, MsgKind::Neighbors);
                let a = decode_neighbors(&env.payload).unwrap();
                assert_eq!(a.round, round);
                // 3-regular on 4 nodes = complete graph; weights 1/4.
                assert_eq!(a.neighbors.len(), 3);
                let total: f64 =
                    a.self_weight + a.neighbors.iter().map(|(_, w)| w).sum::<f64>();
                assert!((total - 1.0).abs() < 1e-9);
                assignments[id].push(a);
            }
        }
        h.join().unwrap();
        // Assignments are symmetric: if j is i's neighbor, i is j's.
        for round in 0..rounds as usize {
            for i in 0..nodes {
                for &(j, _) in &assignments[i][round].neighbors {
                    assert!(assignments[j][round]
                        .neighbors
                        .iter()
                        .any(|&(n, _)| n == i));
                }
            }
        }
    }

    #[test]
    fn dynamic_graphs_change_between_rounds() {
        let nodes = 10usize;
        let hub = InprocHub::new(nodes + 1);
        let sampler = PeerSampler {
            rank: nodes,
            nodes,
            rounds: 2,
            spec: "regular:3".into(),
            seed: 3,
            avail: Availability::always(),
            transport: Box::new(hub.endpoint(nodes)),
        };
        let h = std::thread::spawn(move || sampler.run().unwrap());
        let mut per_round: Vec<Vec<Vec<usize>>> = Vec::new();
        for round in 0..2u64 {
            for id in 0..nodes {
                hub.endpoint(id)
                    .send(Envelope {
                        src: id,
                        dst: nodes,
                        round,
                        kind: MsgKind::Control,
                        sent_at_s: 0.0,
                        trace: 0,
                        payload: encode_control(&Control::Ready { round }).into(),
                    })
                    .unwrap();
            }
            let mut rows = Vec::new();
            for id in 0..nodes {
                let env = hub.endpoint(id).recv().unwrap().unwrap();
                let a = decode_neighbors(&env.payload).unwrap();
                rows.push(a.neighbors.iter().map(|&(n, _)| n).collect::<Vec<_>>());
            }
            per_round.push(rows);
        }
        h.join().unwrap();
        assert_ne!(per_round[0], per_round[1]);
    }

    #[test]
    fn stop_terminates_early() {
        let hub = InprocHub::new(3);
        let sampler = PeerSampler {
            rank: 2,
            nodes: 2,
            rounds: 100,
            spec: "ring".into(),
            seed: 1,
            avail: Availability::always(),
            transport: Box::new(hub.endpoint(2)),
        };
        let h = std::thread::spawn(move || sampler.run());
        hub.endpoint(0)
            .send(Envelope {
                src: 0,
                dst: 2,
                round: 0,
                kind: MsgKind::Control,
                sent_at_s: 0.0,
                trace: 0,
                payload: encode_control(&Control::Stop).into(),
            })
            .unwrap();
        assert!(h.join().unwrap().is_ok());
    }


    #[test]
    fn churn_excludes_inactive_nodes() {
        let nodes = 12usize;
        let hub = InprocHub::new(nodes + 1);
        let sampler = PeerSampler {
            rank: nodes,
            nodes,
            rounds: 4,
            spec: "regular:3".into(),
            seed: 11,
            avail: Availability::Bernoulli(0.4),
            transport: Box::new(hub.endpoint(nodes)),
        };
        let h = std::thread::spawn(move || sampler.run().unwrap());
        let mut saw_inactive = false;
        for round in 0..4u64 {
            for id in 0..nodes {
                hub.endpoint(id)
                    .send(Envelope {
                        src: id,
                        dst: nodes,
                        round,
                        kind: MsgKind::Control,
                        sent_at_s: 0.0,
                        trace: 0,
                        payload: encode_control(&Control::Ready { round }).into(),
                    })
                    .unwrap();
            }
            let mut rows = Vec::new();
            for id in 0..nodes {
                let env = hub.endpoint(id).recv().unwrap().unwrap();
                let a = decode_neighbors(&env.payload).unwrap();
                assert_eq!(a.round, round);
                rows.push(a);
            }
            let inactive: std::collections::HashSet<usize> = rows
                .iter()
                .enumerate()
                .filter(|(_, a)| a.neighbors.is_empty())
                .map(|(i, _)| i)
                .collect();
            saw_inactive |= !inactive.is_empty();
            // No active node lists an inactive node as neighbor, and
            // weights still sum to 1 for everyone.
            for (i, a) in rows.iter().enumerate() {
                let total: f64 =
                    a.self_weight + a.neighbors.iter().map(|(_, w)| w).sum::<f64>();
                assert!((total - 1.0).abs() < 1e-9, "node {i}");
                for &(n, _) in &a.neighbors {
                    assert!(!inactive.contains(&n), "round {round}: {i} -> {n}");
                }
            }
        }
        h.join().unwrap();
        assert!(saw_inactive, "40% churn never produced an inactive node");
    }

    #[test]
    fn churn_trace_drives_active_set() {
        use crate::scenario::ChurnTrace;
        use std::sync::Arc;
        // Node 2 departs after round 1; node 3 sits out round 1 only.
        let dir = std::env::temp_dir().join("decentra_sampler_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.txt");
        std::fs::write(&path, "2 0 2\n3 0 1\n3 2 -\n").unwrap();
        let trace = Arc::new(ChurnTrace::from_file(path.to_str().unwrap(), 6).unwrap());
        let avail = Availability::Trace(trace);
        for round in 0..4u64 {
            let rows = draw_round("regular:2", 5, &avail, 6, round).unwrap();
            let inactive: Vec<usize> = rows
                .iter()
                .enumerate()
                .filter(|(_, a)| a.neighbors.is_empty())
                .map(|(i, _)| i)
                .collect();
            match round {
                0 => assert!(inactive.is_empty(), "round 0: {inactive:?}"),
                1 => assert_eq!(inactive, vec![3]),
                // Node 2 has departed; 3 is back.
                _ => assert_eq!(inactive, vec![2]),
            }
            // Replayable: the same round draws the same rows.
            assert_eq!(rows, draw_round("regular:2", 5, &avail, 6, round).unwrap());
            // No active node lists an inactive one.
            for (i, a) in rows.iter().enumerate() {
                for &(n, _) in &a.neighbors {
                    assert!(!inactive.contains(&n), "round {round}: {i} -> {n}");
                }
            }
        }
    }
}
