//! Federated-learning emulation: the paper's Fig 1 point that a Node can
//! be specialized into an FL server (and clients). FedAvg with
//! configurable client participation; participation 1.0 gives the
//! classic synchronous parameter-server shape ([`ParameterServer`]).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::communication::{Envelope, MsgKind, Payload, Transport};
use crate::compression::{FloatCodec, RawF32};
use crate::dataset::Dataset;
use crate::metrics::{NodeLog, Record};
use crate::rng::{mix_seed, Xoshiro256pp};
use crate::training::Trainer;
use crate::util::Timer;

use super::proto::{encode_control, Control};

/// FedAvg server occupying transport rank `rank`.
pub struct FlServer {
    pub rank: usize,
    pub clients: usize,
    pub rounds: u64,
    pub eval_every: u64,
    /// Fraction of clients sampled per round (1.0 = all).
    pub participation: f64,
    pub seed: u64,
    pub transport: Box<dyn Transport>,
    pub params: Vec<f32>,
    /// Server-side evaluation.
    pub trainer: Trainer,
    pub test: Arc<Dataset>,
}

/// Synchronous parameter server = FedAvg with full participation.
pub type ParameterServer = FlServer;

impl FlServer {
    pub fn run(mut self) -> Result<NodeLog> {
        let codec = RawF32;
        let mut log = NodeLog::new(self.rank);
        let wall = Timer::start();
        let dim = self.params.len();
        let mut rng = Xoshiro256pp::new(mix_seed(&[self.seed, 0xF1]));
        let m = ((self.clients as f64 * self.participation).round() as usize)
            .clamp(1, self.clients);

        for round in 0..self.rounds {
            // Sample cohort and broadcast the global model: serialized
            // once, shared by every cohort member's envelope.
            let cohort = rng.sample_indices(self.clients, m);
            let payload: Payload = codec.encode(&self.params).into();
            self.transport.note_serialized(payload.len());
            for &c in &cohort {
                self.transport.send(Envelope {
                    src: self.rank,
                    dst: c,
                    round,
                    kind: MsgKind::FlBroadcast,
                    sent_at_s: 0.0,
                    trace: 0,
                    payload: payload.clone(),
                })?;
            }
            // Collect updates; FedAvg = uniform average over the cohort.
            let mut acc = vec![0.0f64; dim];
            let mut got: HashMap<usize, bool> = HashMap::new();
            while got.len() < cohort.len() {
                let env = self
                    .transport
                    .recv()?
                    .context("transport closed collecting FL updates")?;
                match env.kind {
                    MsgKind::FlUpdate if env.round == round => {
                        if got.insert(env.src, true).is_some() {
                            bail!("duplicate update from client {}", env.src);
                        }
                        let vals = codec.decode(&env.payload, dim)?;
                        for (a, v) in acc.iter_mut().zip(vals.iter()) {
                            *a += *v as f64;
                        }
                    }
                    MsgKind::FlUpdate => {} // stale round; drop
                    other => bail!("server got unexpected {other:?}"),
                }
            }
            for (p, a) in self.params.iter_mut().zip(acc.iter()) {
                *p = (*a / cohort.len() as f64) as f32;
            }

            if (round + 1) % self.eval_every == 0 || round + 1 == self.rounds {
                let (test_loss, test_acc) = self.trainer.evaluate(&self.params, &self.test)?;
                let c = self.transport.counters();
                log.push(Record {
                    round,
                    emu_time_s: 0.0,
                    real_time_s: wall.elapsed().as_secs_f64(),
                    train_loss: f64::NAN,
                    test_loss,
                    test_acc,
                    bytes_sent: c.bytes_sent,
                    bytes_recv: c.bytes_recv,
                    msgs_sent: c.msgs_sent,
                    bytes_serialized: c.bytes_serialized,
                    late_msgs: 0,
                    dropped_msgs: 0,
                    mean_staleness_s: 0.0,
                    poisoned_mass_admitted: 0.0,
                    rejected_contribs: 0,
                    isolation_rate: 0.0,
                });
            }
        }
        // Orderly stop for all clients.
        for c in 0..self.clients {
            self.transport.send(Envelope {
                src: self.rank,
                dst: c,
                round: self.rounds,
                kind: MsgKind::Control,
                sent_at_s: 0.0,
                trace: 0,
                payload: encode_control(&Control::Stop).into(),
            })?;
        }
        Ok(log)
    }
}

/// FL client: waits for broadcasts, trains locally, returns the update.
pub struct FlClient {
    pub id: usize,
    pub server_rank: usize,
    pub transport: Box<dyn Transport>,
    pub trainer: Trainer,
}

impl FlClient {
    pub fn run(mut self) -> Result<()> {
        let codec = RawF32;
        loop {
            let env = self
                .transport
                .recv()?
                .context("transport closed in FL client")?;
            match env.kind {
                MsgKind::FlBroadcast => {
                    let params = codec.decode(&env.payload, env.payload.len() / 4)?;
                    let (new_params, _loss) = self.trainer.train_round(params)?;
                    let payload: Payload = codec.encode(&new_params).into();
                    self.transport.note_serialized(payload.len());
                    self.transport.send(Envelope {
                        src: self.id,
                        dst: self.server_rank,
                        round: env.round,
                        kind: MsgKind::FlUpdate,
                        sent_at_s: 0.0,
                        trace: 0,
                        payload,
                    })?;
                }
                MsgKind::Control => return Ok(()),
                other => bail!("FL client got unexpected {other:?}"),
            }
        }
    }
}
