//! Asynchronous gossip policies: virtual deadlines, staleness-aware
//! aggregation weights, and late-delivery handling.
//!
//! Synchronous D-PSGD barriers every round: a node cannot aggregate
//! until *all* of its neighbors' models for that round have arrived, so
//! one straggler or one dead peer paces (or deadlocks) its whole
//! neighborhood. The asynchronous variant (AD-PSGD-style) drops the
//! completeness requirement: each node trains continuously, broadcasts,
//! and at a per-round **virtual deadline** aggregates whatever neighbor
//! models have arrived, weighting each by its **staleness** (the virtual
//! age carried in the envelope's `sent_at_s` stamp).
//!
//! This module holds the pure policy types shared by the scheduler's
//! [`AsyncDlNodeSm`](crate::scheduler::AsyncDlNodeSm) state machine, the
//! config validation, and the CLI:
//!
//! * [`DeadlineSpec`] — when a round's collection window closes:
//!   `fixed:<seconds>` | `p<q>` (quantile-adaptive over observed
//!   neighbor arrival offsets) | `factor:<f>` (multiple of the node's
//!   own per-round compute time).
//! * [`StalenessPolicy`] — how much weight an aged model retains:
//!   `none` | `linear:<tau>` | `poly:<alpha>`.
//! * [`LatePolicy`] — what happens to a message that was already in
//!   flight when the deadline fired: `buffer` it for the next round or
//!   `drop` it. Either way it is counted per node.

use anyhow::{bail, Context, Result};

/// When a node's per-round collection window closes, in virtual time
/// measured from the round's start.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DeadlineSpec {
    /// A fixed window of `seconds` per round.
    Fixed(f64),
    /// Adaptive: the `q`-quantile (0 < q < 1) of the recently observed
    /// neighbor-model arrival offsets (the state machine keeps a bounded
    /// rolling window) — the node waits just long enough to catch
    /// roughly a `q` fraction of its neighbors' updates. Until enough
    /// history exists the window falls back to twice the node's own
    /// round compute time.
    Quantile(f64),
    /// `f` times the node's own per-round compute time.
    Factor(f64),
}

/// Observations needed before a [`DeadlineSpec::Quantile`] window trusts
/// its history instead of the compute-time fallback.
const QUANTILE_WARMUP: usize = 4;

impl DeadlineSpec {
    /// Parse `fixed:<seconds>` | `p<q>` | `factor:<f>`.
    pub fn from_spec(spec: &str) -> Result<DeadlineSpec> {
        if let Some(s) = spec.strip_prefix("fixed:") {
            let secs: f64 = s.parse().with_context(|| format!("bad deadline seconds {s:?}"))?;
            if !(secs > 0.0) {
                bail!("fixed deadline must be > 0 seconds (got {secs})");
            }
            return Ok(DeadlineSpec::Fixed(secs));
        }
        if let Some(q) = spec.strip_prefix('p') {
            let q: u32 = q.parse().with_context(|| format!("bad deadline quantile {spec:?}"))?;
            if !(1..=99).contains(&q) {
                bail!("deadline quantile must be p1..p99 (got p{q})");
            }
            return Ok(DeadlineSpec::Quantile(q as f64 / 100.0));
        }
        if let Some(f) = spec.strip_prefix("factor:") {
            let f: f64 = f.parse().with_context(|| format!("bad deadline factor {f:?}"))?;
            if !(f > 0.0) {
                bail!("deadline factor must be > 0 (got {f})");
            }
            return Ok(DeadlineSpec::Factor(f));
        }
        bail!("unknown deadline spec {spec:?} (expected fixed:<seconds> | p<q> | factor:<f>)")
    }

    /// Check spec syntax only.
    pub fn validate_spec(spec: &str) -> Result<()> {
        DeadlineSpec::from_spec(spec).map(|_| ())
    }

    /// The collection window for the next round. `round_compute_s` is
    /// the node's own per-round training time; `history` the arrival
    /// offsets (arrival time − window start) observed so far, in
    /// arrival order.
    pub fn window_s(&self, round_compute_s: f64, history: &[f64]) -> f64 {
        let floor = 1e-9; // never a zero-length window
        match *self {
            DeadlineSpec::Fixed(s) => s.max(floor),
            DeadlineSpec::Factor(f) => (f * round_compute_s).max(floor),
            DeadlineSpec::Quantile(q) => {
                if history.len() < QUANTILE_WARMUP {
                    return (2.0 * round_compute_s).max(floor);
                }
                let mut sorted = history.to_vec();
                sorted.sort_by(f64::total_cmp);
                let rank = ((sorted.len() as f64) * q).ceil() as usize;
                sorted[rank.clamp(1, sorted.len()) - 1].max(floor)
            }
        }
    }
}

/// Multiplier applied to a neighbor's mixing weight as a function of its
/// model's virtual age at aggregation time. Weight shed by aging models
/// folds back into the receiver's self-weight, keeping the mixing row
/// stochastic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StalenessPolicy {
    /// Age-blind: every arrived model keeps its full weight.
    None,
    /// Linear decay to zero at age `tau`: `max(0, 1 - age/tau)`.
    Linear(f64),
    /// Polynomial decay `(1 + age)^-alpha` (never reaches zero).
    Poly(f64),
}

impl StalenessPolicy {
    /// Parse `none` | `linear:<tau>` | `poly:<alpha>`.
    pub fn from_spec(spec: &str) -> Result<StalenessPolicy> {
        if spec.is_empty() || spec == "none" {
            return Ok(StalenessPolicy::None);
        }
        if let Some(t) = spec.strip_prefix("linear:") {
            let tau: f64 = t.parse().with_context(|| format!("bad staleness tau {t:?}"))?;
            if !(tau > 0.0) {
                bail!("linear staleness tau must be > 0 seconds (got {tau})");
            }
            return Ok(StalenessPolicy::Linear(tau));
        }
        if let Some(a) = spec.strip_prefix("poly:") {
            let alpha: f64 = a.parse().with_context(|| format!("bad staleness alpha {a:?}"))?;
            if !(alpha >= 0.0) {
                bail!("poly staleness alpha must be >= 0 (got {alpha})");
            }
            return Ok(StalenessPolicy::Poly(alpha));
        }
        bail!("unknown staleness spec {spec:?} (expected none | linear:<tau> | poly:<alpha>)")
    }

    /// Check spec syntax only.
    pub fn validate_spec(spec: &str) -> Result<()> {
        StalenessPolicy::from_spec(spec).map(|_| ())
    }

    /// Weight multiplier in [0, 1] for a model `age_s` old.
    pub fn factor(&self, age_s: f64) -> f64 {
        let age = age_s.max(0.0);
        match *self {
            StalenessPolicy::None => 1.0,
            StalenessPolicy::Linear(tau) => (1.0 - age / tau).max(0.0),
            StalenessPolicy::Poly(alpha) => (1.0 + age).powf(-alpha),
        }
    }
}

/// What to do with a message that was already in flight when the
/// receiver's deadline fired (its `sent_at_s` predates the last
/// deadline): hold it for the next aggregation, or discard it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LatePolicy {
    /// Keep it; the next deadline aggregates it with its (larger) age.
    Buffer,
    /// Discard it and count it.
    Drop,
}

impl LatePolicy {
    /// Parse `buffer` | `drop`.
    pub fn from_spec(spec: &str) -> Result<LatePolicy> {
        match spec {
            "" | "buffer" => Ok(LatePolicy::Buffer),
            "drop" => Ok(LatePolicy::Drop),
            other => bail!("unknown late policy {other:?} (expected buffer | drop)"),
        }
    }

    /// Check spec syntax only.
    pub fn validate_spec(spec: &str) -> Result<()> {
        LatePolicy::from_spec(spec).map(|_| ())
    }
}

/// The full async-gossip policy bundle a node runs under.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AsyncPolicy {
    pub deadline: DeadlineSpec,
    pub staleness: StalenessPolicy,
    pub late: LatePolicy,
}

impl AsyncPolicy {
    /// Build from the three config specs.
    pub fn from_specs(deadline: &str, staleness: &str, late: &str) -> Result<AsyncPolicy> {
        Ok(AsyncPolicy {
            deadline: DeadlineSpec::from_spec(deadline)?,
            staleness: StalenessPolicy::from_spec(staleness)?,
            late: LatePolicy::from_spec(late)?,
        })
    }
}

/// Per-node async-gossip counters surfaced through the metric log.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AsyncStats {
    /// Messages that missed their deadline but were kept for the next
    /// round ([`LatePolicy::Buffer`]).
    pub late_msgs: u64,
    /// Messages discarded for missing their deadline
    /// ([`LatePolicy::Drop`]).
    pub dropped_msgs: u64,
    /// Sum of virtual ages over all models aggregated so far.
    pub staleness_sum_s: f64,
    /// Number of models aggregated so far.
    pub aggregated: u64,
}

impl AsyncStats {
    /// Mean virtual age of every model aggregated so far (0 if none).
    pub fn mean_staleness_s(&self) -> f64 {
        if self.aggregated == 0 {
            0.0
        } else {
            self.staleness_sum_s / self.aggregated as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_spec_parsing() {
        assert_eq!(DeadlineSpec::from_spec("fixed:0.5").unwrap(), DeadlineSpec::Fixed(0.5));
        assert_eq!(DeadlineSpec::from_spec("p90").unwrap(), DeadlineSpec::Quantile(0.9));
        assert_eq!(DeadlineSpec::from_spec("factor:2").unwrap(), DeadlineSpec::Factor(2.0));
        for bad in ["", "fixed:0", "fixed:-1", "p0", "p100", "px", "factor:0", "soon"] {
            assert!(DeadlineSpec::validate_spec(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn fixed_and_factor_windows() {
        assert_eq!(DeadlineSpec::Fixed(0.5).window_s(0.1, &[]), 0.5);
        assert!((DeadlineSpec::Factor(3.0).window_s(0.1, &[]) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn quantile_window_warms_up_then_adapts() {
        let d = DeadlineSpec::Quantile(0.5);
        // Too little history: fall back to 2x compute.
        assert!((d.window_s(0.1, &[0.9]) - 0.2).abs() < 1e-12);
        // With history, the median of the observed offsets.
        let hist = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6];
        let w = d.window_s(0.1, &hist);
        assert!((w - 0.3).abs() < 1e-12, "median window {w}");
        // p90 over ten offsets picks the 9th smallest.
        let hist10: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
        let w = DeadlineSpec::Quantile(0.9).window_s(0.1, &hist10);
        assert!((w - 0.9).abs() < 1e-12, "p90 window {w}");
    }

    #[test]
    fn staleness_factors() {
        assert_eq!(StalenessPolicy::None.factor(1e9), 1.0);
        let lin = StalenessPolicy::Linear(2.0);
        assert!((lin.factor(0.0) - 1.0).abs() < 1e-12);
        assert!((lin.factor(1.0) - 0.5).abs() < 1e-12);
        assert_eq!(lin.factor(5.0), 0.0);
        let poly = StalenessPolicy::Poly(1.0);
        assert!((poly.factor(0.0) - 1.0).abs() < 1e-12);
        assert!((poly.factor(1.0) - 0.5).abs() < 1e-12);
        assert!(poly.factor(100.0) > 0.0);
    }

    #[test]
    fn staleness_spec_parsing() {
        assert_eq!(StalenessPolicy::from_spec("none").unwrap(), StalenessPolicy::None);
        assert_eq!(StalenessPolicy::from_spec("linear:3").unwrap(), StalenessPolicy::Linear(3.0));
        assert_eq!(StalenessPolicy::from_spec("poly:0.5").unwrap(), StalenessPolicy::Poly(0.5));
        for bad in ["linear:0", "linear:-2", "poly:-1", "exp:2"] {
            assert!(StalenessPolicy::validate_spec(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn late_policy_parsing() {
        assert_eq!(LatePolicy::from_spec("buffer").unwrap(), LatePolicy::Buffer);
        assert_eq!(LatePolicy::from_spec("").unwrap(), LatePolicy::Buffer);
        assert_eq!(LatePolicy::from_spec("drop").unwrap(), LatePolicy::Drop);
        assert!(LatePolicy::from_spec("queue").is_err());
    }

    #[test]
    fn stats_mean_staleness() {
        let mut s = AsyncStats::default();
        assert_eq!(s.mean_staleness_s(), 0.0);
        s.staleness_sum_s = 3.0;
        s.aggregated = 2;
        assert!((s.mean_staleness_s() - 1.5).abs() < 1e-12);
    }
}
