//! Secure aggregation via pairwise cancellable masks (Bonawitz et al.
//! 2017, adapted to DL per Vujasinovic 2023 — the paper's §3.4).
//!
//! For a receiver `r`, every unordered pair `{i, j}` of `r`'s neighbors
//! expands the same pseudo-random mask from a shared per-(pair, receiver,
//! round) seed; `i` adds it, `j` subtracts it. Because the receiver
//! multiplies each sender's model by its (public) Metropolis–Hastings
//! weight `w_ri`, sender `i` pre-scales its masks by `1 / w_ri`:
//!
//! ```text
//! i sends   x_i + (1/w_ri) Σ_j ±PRG(seed_ijr)
//! r computes Σ_i w_ri x̃_i = Σ_i w_ri x_i  (+ masks that cancel pairwise)
//! ```
//!
//! so `r` learns only the weighted aggregate, never an individual model.
//! Masks and parameters are f32, so the cancellation leaves rounding
//! residue — exactly the precision loss the paper measures as a ~3%
//! accuracy drop on CIFAR-10.
//!
//! Key material: each unordered node pair holds a 32-byte master secret
//! (exchanged once over the wire at round 0 and counted as overhead —
//! standing in for a Diffie–Hellman agreement); per-round seeds derive
//! via HMAC-SHA256(master, receiver ‖ round), and masks expand with
//! AES-128-CTR.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes128;
use hmac::{Hmac, Mac};
use sha2::Sha256;

use crate::rng::{mix_seed, Xoshiro256pp};

type HmacSha256 = Hmac<Sha256>;

/// 32-byte pairwise master secret.
pub type MasterSecret = [u8; 32];

/// Generate the master secret node `lo` creates for pair (lo, hi).
/// Deterministic per (experiment seed, pair) so tests can replay it; the
/// wire exchange is what the byte accounting measures.
pub fn master_secret(experiment_seed: u64, lo: usize, hi: usize) -> MasterSecret {
    let mut rng = Xoshiro256pp::new(mix_seed(&[
        experiment_seed,
        0x5EC0_5EC0,
        lo as u64,
        hi as u64,
    ]));
    let mut out = [0u8; 32];
    for chunk in out.chunks_mut(8) {
        chunk.copy_from_slice(&rng.next_u64().to_le_bytes());
    }
    out
}

/// Derive the per-(pair, receiver, round) mask seed.
pub fn round_seed(master: &MasterSecret, receiver: usize, round: u64) -> [u8; 16] {
    let mut mac = <HmacSha256 as Mac>::new_from_slice(master).expect("hmac key");
    mac.update(&(receiver as u64).to_le_bytes());
    mac.update(&round.to_le_bytes());
    let digest = mac.finalize().into_bytes();
    let mut seed = [0u8; 16];
    seed.copy_from_slice(&digest[..16]);
    seed
}

/// Expand a seed into `len` pseudo-random f32 in [-scale, scale) with
/// AES-128-CTR (4 floats per block).
pub fn expand_mask(seed: &[u8; 16], len: usize, scale: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; len];
    expand_mask_into(seed, scale, &mut out, false);
    out
}

/// In-place variant: `acc[i] += ±mask[i]` without allocating the mask
/// (`subtract` flips the sign). Counter blocks are encrypted eight at a
/// time (`encrypt_blocks`), which lets the software AES backend pipeline
/// rounds across blocks — the §Perf optimization for the secure hot path.
pub fn expand_mask_into(seed: &[u8; 16], scale: f32, acc: &mut [f32], subtract: bool) {
    use aes::cipher::generic_array::GenericArray;

    let cipher = Aes128::new_from_slice(seed).expect("aes key");
    const LANES: usize = 8; // blocks per encrypt_blocks call
    let mut blocks = [GenericArray::from([0u8; 16]); LANES];
    let mut counter = 0u128;
    let sign = if subtract { -scale } else { scale };
    let mut i = 0usize;
    while i < acc.len() {
        for b in blocks.iter_mut() {
            b.copy_from_slice(&counter.to_le_bytes());
            counter += 1;
        }
        cipher.encrypt_blocks(&mut blocks);
        'outer: for b in &blocks {
            for word in b.chunks_exact(4) {
                if i == acc.len() {
                    break 'outer;
                }
                let u = u32::from_le_bytes([word[0], word[1], word[2], word[3]]);
                // Map to [-1, 1) with 24 bits of uniformity, then scale.
                let f = (u >> 8) as f32 * (1.0 / (1u32 << 23) as f32) - 1.0;
                acc[i] += sign * f;
                i += 1;
            }
        }
    }
}

/// Masking engine owned by one secure node.
pub struct Masker {
    pub node: usize,
    pub mask_scale: f32,
    experiment_seed: u64,
}

impl Masker {
    pub fn new(node: usize, experiment_seed: u64, mask_scale: f32) -> Masker {
        Masker { node, mask_scale, experiment_seed }
    }

    pub fn experiment_seed(&self) -> u64 {
        self.experiment_seed
    }

    /// Build the summed mask this node must add to the model it sends to
    /// `receiver` in `round`. `co_senders` is the receiver's neighbor set
    /// (excluding the receiver itself); `inv_weight` is `1 / w_{receiver,
    /// self}` (public MH weight).
    pub fn mask_for(
        &self,
        receiver: usize,
        round: u64,
        co_senders: &[usize],
        inv_weight: f32,
        dim: usize,
    ) -> Vec<f32> {
        let mut total = vec![0.0f32; dim];
        for &peer in co_senders {
            if peer == self.node {
                continue;
            }
            let (lo, hi) = (self.node.min(peer), self.node.max(peer));
            let master = master_secret(self.experiment_seed, lo, hi);
            let seed = round_seed(&master, receiver, round);
            // Lower id adds, higher id subtracts: the pair cancels.
            // Accumulated in place (no per-pair mask allocation).
            expand_mask_into(&seed, self.mask_scale, &mut total, self.node != lo);
        }
        for t in total.iter_mut() {
            *t *= inv_weight;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_deterministic_and_distinct() {
        let m = master_secret(1, 0, 1);
        let s1 = round_seed(&m, 2, 10);
        let s2 = round_seed(&m, 2, 10);
        let s3 = round_seed(&m, 2, 11);
        let s4 = round_seed(&m, 3, 10);
        assert_eq!(s1, s2);
        assert_ne!(s1, s3);
        assert_ne!(s1, s4);
        let e1 = expand_mask(&s1, 100, 1.0);
        let e2 = expand_mask(&s1, 100, 1.0);
        assert_eq!(e1, e2);
    }

    #[test]
    fn expand_mask_range_and_moments() {
        let seed = [7u8; 16];
        let mask = expand_mask(&seed, 50_000, 2.0);
        assert!(mask.iter().all(|&x| (-2.0..2.0).contains(&x)));
        let mean: f64 = mask.iter().map(|&x| x as f64).sum::<f64>() / mask.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        // Variance of U(-2,2) = 4/3.
        let var: f64 =
            mask.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / mask.len() as f64;
        assert!((var - 4.0 / 3.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn pair_masks_cancel_exactly_unscaled() {
        // The raw pair masks are bit-identical, so +m + (-m) == 0 exactly.
        let a = Masker::new(0, 42, 4.0);
        let b = Masker::new(1, 42, 4.0);
        let co = vec![0usize, 1];
        let ma = a.mask_for(9, 3, &co, 1.0, 256);
        let mb = b.mask_for(9, 3, &co, 1.0, 256);
        for i in 0..256 {
            assert_eq!(ma[i] + mb[i], 0.0, "coord {i}");
        }
    }

    #[test]
    fn weighted_aggregate_recovers_sum() {
        // 3 senders with distinct weights; masks scaled by 1/w cancel in
        // the weighted sum up to f32 rounding.
        let dim = 512;
        let seed = 7u64;
        let weights = [0.25f32, 0.35, 0.20]; // receiver's weights per sender
        let senders = [0usize, 1, 2];
        let models: Vec<Vec<f32>> = (0..3)
            .map(|s| {
                let mut rng = Xoshiro256pp::new(100 + s as u64);
                (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect()
            })
            .collect();
        let receiver = 5usize;
        let round = 2u64;
        let mut agg = vec![0.0f32; dim];
        for (si, &s) in senders.iter().enumerate() {
            let masker = Masker::new(s, seed, 2.0);
            let mask = masker.mask_for(receiver, round, &senders, 1.0 / weights[si], dim);
            for i in 0..dim {
                agg[i] += weights[si] * (models[si][i] + mask[i]);
            }
        }
        for i in 0..dim {
            let want: f32 = (0..3).map(|s| weights[s] * models[s][i]).sum();
            assert!(
                (agg[i] - want).abs() < 1e-3,
                "coord {i}: {} vs {want}",
                agg[i]
            );
        }
    }

    #[test]
    fn masked_model_hides_plaintext() {
        // With masks active, the sent vector is far from the true model.
        let dim = 1000;
        let masker = Masker::new(0, 1, 8.0);
        let mask = masker.mask_for(2, 0, &[0, 1, 3], 1.0, dim);
        let l2: f64 = mask.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(l2 > 100.0, "mask energy too low: {l2}");
    }

    #[test]
    fn single_sender_has_no_mask() {
        // With no co-sender there is no pair — and no privacy, which the
        // protocol surfaces by sending the model unmasked (degree-1
        // receivers are a known secure-agg limitation).
        let masker = Masker::new(4, 1, 8.0);
        let mask = masker.mask_for(2, 0, &[4], 1.0, 64);
        assert!(mask.iter().all(|&x| x == 0.0));
    }
}
