//! Runtime: PJRT execution of the AOT-compiled L2/L1 artifacts.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`, wrapped in a channel-served engine
//! thread ([`EngineHandle`]) because the `xla` crate types are not
//! `Send`. See `/opt/xla-example/load_hlo/` for the original pattern.

mod engine;
mod manifest;

pub use engine::{EngineHandle, Outputs};
pub use manifest::{ArgSpec, DType, EntryMeta, Manifest, ModelMeta};
