//! PJRT engine: loads AOT artifacts and executes them for the node layer.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (neither `Send` nor
//! `Sync`), so the engine owns the client and all compiled executables on
//! **one dedicated service thread**; node threads talk to it through a
//! cloneable [`EngineHandle`] over an mpsc channel. On this single-core
//! testbed XLA execution is serial anyway, so funneling compute through
//! one thread costs nothing and keeps the hot path allocation-free apart
//! from the literal buffers themselves.
//!
//! Multi-step rounds go through [`EngineHandle::train_chain`], which
//! batches a whole local round into one request so the channel round-trip
//! is paid once per round, not once per step — the request-batching the
//! virtual-time scheduler relies on at 1000+ nodes.
//!
//! HLO **text** is the interchange format (not serialized protos): see
//! `python/compile/aot.py` and /opt/xla-example/README.md.
//!
//! The `xla` crate is an optional dependency (feature `xla`). Without it
//! the crate still builds: [`EngineHandle::start`] reports a clear error
//! and everything artifact-independent (graphs, sharing, transports, the
//! scheduler) works normally.

use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::manifest::{DType, EntryMeta, Manifest};

/// A request processed by the engine thread.
enum Request {
    Execute {
        model: String,
        entry: &'static str,
        f32_args: Vec<Vec<f32>>,
        i32_args: Vec<Vec<i32>>,
        /// Argument order as dtype tags ('f' pulls the next f32 arg, 'i'
        /// the next i32 arg) — mirrors the manifest arg order.
        order: Vec<DType>,
        reply: mpsc::Sender<Result<Outputs>>,
    },
    /// A whole local round: `batches.len()` chained train steps executed
    /// without crossing the channel between steps. Returns the final
    /// params and the per-step losses.
    TrainChain {
        model: String,
        params: Vec<f32>,
        lr: f32,
        batches: Vec<(Vec<f32>, Vec<i32>)>,
        order: Vec<DType>,
        reply: mpsc::Sender<Result<(Vec<f32>, Vec<f32>)>>,
    },
    Shutdown,
}

/// Raw outputs of an entry point, in manifest order.
#[derive(Debug, Clone, Default)]
pub struct Outputs {
    pub f32s: Vec<Vec<f32>>,
    pub i32s: Vec<Vec<i32>>,
    /// Dtype per output, aligned with the manifest `outs`.
    pub order: Vec<DType>,
}

impl Outputs {
    /// The n-th output interpreted as f32 data.
    pub fn f32_out(&self, n: usize) -> &[f32] {
        let mut fi = 0;
        for (i, d) in self.order.iter().enumerate() {
            if i == n {
                assert_eq!(*d, DType::F32, "output {n} is not f32");
                return &self.f32s[fi];
            }
            if *d == DType::F32 {
                fi += 1;
            }
        }
        panic!("output index {n} out of range");
    }

    pub fn i32_out(&self, n: usize) -> &[i32] {
        let mut ii = 0;
        for (i, d) in self.order.iter().enumerate() {
            if i == n {
                assert_eq!(*d, DType::I32, "output {n} is not i32");
                return &self.i32s[ii];
            }
            if *d == DType::I32 {
                ii += 1;
            }
        }
        panic!("output index {n} out of range")
    }
}

/// Cloneable, `Send` handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
    manifest: Arc<Manifest>,
}

impl EngineHandle {
    /// Start the engine thread, loading and compiling the given models'
    /// artifacts eagerly (all four entry points each).
    pub fn start(artifacts_dir: &Path, models: &[&str]) -> Result<EngineHandle> {
        let manifest = Arc::new(Manifest::load(artifacts_dir)?);
        for m in models {
            manifest.model(m)?; // validate before spawning
        }
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread_manifest = Arc::clone(&manifest);
        let model_names: Vec<String> = models.iter().map(|s| s.to_string()).collect();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || backend::engine_main(thread_manifest, model_names, rx, ready_tx))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .context("engine thread died during startup")??;
        Ok(EngineHandle { tx, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Look up an entry's metadata, for argument validation.
    fn entry_meta(&self, model: &str, entry: &str) -> Result<EntryMeta> {
        let meta = self.manifest.model(model)?;
        meta.entries
            .get(entry)
            .cloned()
            .with_context(|| format!("entry {entry:?} missing for model {model:?}"))
    }

    fn execute(
        &self,
        model: &str,
        entry: &'static str,
        f32_args: Vec<Vec<f32>>,
        i32_args: Vec<Vec<i32>>,
    ) -> Result<Outputs> {
        let em = self.entry_meta(model, entry)?;
        // Validate argument shapes against the manifest before crossing
        // the channel: failures surface at the call site.
        let order: Vec<DType> = em.args.iter().map(|a| a.dtype).collect();
        let (mut fi, mut ii) = (0usize, 0usize);
        for a in &em.args {
            match a.dtype {
                DType::F32 => {
                    let got = f32_args
                        .get(fi)
                        .with_context(|| format!("missing f32 arg {}", a.name))?;
                    if got.len() != a.element_count() {
                        bail!(
                            "arg {} expects {} elements, got {}",
                            a.name,
                            a.element_count(),
                            got.len()
                        );
                    }
                    fi += 1;
                }
                DType::I32 => {
                    let got = i32_args
                        .get(ii)
                        .with_context(|| format!("missing i32 arg {}", a.name))?;
                    if got.len() != a.element_count() {
                        bail!(
                            "arg {} expects {} elements, got {}",
                            a.name,
                            a.element_count(),
                            got.len()
                        );
                    }
                    ii += 1;
                }
            }
        }
        if fi != f32_args.len() || ii != i32_args.len() {
            bail!("extra arguments supplied to {model}/{entry}");
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::Execute {
                model: model.to_string(),
                entry,
                f32_args,
                i32_args,
                order,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("engine thread is gone"))?;
        reply_rx.recv().context("engine thread dropped the reply")?
    }

    /// One local SGD step: returns (new_params, loss).
    pub fn train_step(
        &self,
        model: &str,
        params: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let out = self.execute(model, "train", vec![params, x, vec![lr]], vec![y])?;
        let new_params = out.f32_out(0).to_vec();
        let loss = out.f32_out(1)[0];
        Ok((new_params, loss))
    }

    /// Chain `batches.len()` SGD steps in ONE engine request: params flow
    /// step-to-step inside the engine thread, so the per-step channel
    /// round-trip (and reply allocation) is amortized over the round.
    /// Bit-identical to calling [`train_step`] in a loop.
    ///
    /// [`train_step`]: EngineHandle::train_step
    pub fn train_chain(
        &self,
        model: &str,
        params: Vec<f32>,
        batches: Vec<(Vec<f32>, Vec<i32>)>,
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if batches.is_empty() {
            return Ok((params, Vec::new()));
        }
        let em = self.entry_meta(model, "train")?;
        let order: Vec<DType> = em.args.iter().map(|a| a.dtype).collect();
        let (mut fexp, mut iexp) = (Vec::new(), Vec::new());
        for a in &em.args {
            match a.dtype {
                DType::F32 => fexp.push(a.element_count()),
                DType::I32 => iexp.push(a.element_count()),
            }
        }
        // train's signature is (params, x, lr | y) in some manifest order.
        if fexp.len() != 3 || iexp.len() != 1 {
            bail!("{model}/train has an unexpected signature");
        }
        if params.len() != fexp[0] {
            bail!("params expect {} elements, got {}", fexp[0], params.len());
        }
        for (x, y) in &batches {
            if x.len() != fexp[1] {
                bail!("batch features expect {} elements, got {}", fexp[1], x.len());
            }
            if y.len() != iexp[0] {
                bail!("batch labels expect {} elements, got {}", iexp[0], y.len());
            }
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::TrainChain {
                model: model.to_string(),
                params,
                lr,
                batches,
                order,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("engine thread is gone"))?;
        reply_rx.recv().context("engine thread dropped the reply")?
    }

    /// Evaluate one fixed-size batch: returns (sum_loss, correct_count).
    pub fn eval_batch(
        &self,
        model: &str,
        params: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
    ) -> Result<(f32, i32)> {
        let out = self.execute(model, "eval", vec![params, x], vec![y])?;
        Ok((out.f32_out(0)[0], out.i32_out(1)[0]))
    }

    /// Weighted aggregation of up to `agg_k` stacked models via the L1
    /// Pallas kernel artifact: returns the mixed parameter vector.
    pub fn aggregate(
        &self,
        model: &str,
        stack: Vec<f32>,
        weights: Vec<f32>,
    ) -> Result<Vec<f32>> {
        let out = self.execute(model, "agg", vec![stack, weights], vec![])?;
        Ok(out.f32_out(0).to_vec())
    }

    /// Threshold sparsification with error feedback via the L1 kernel:
    /// returns (sent, new_residual).
    pub fn sparsify(
        &self,
        model: &str,
        values: Vec<f32>,
        residual: Vec<f32>,
        threshold: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let out = self.execute(
            model,
            "sparsify",
            vec![values, residual, vec![threshold]],
            vec![],
        )?;
        Ok((out.f32_out(0).to_vec(), out.f32_out(1).to_vec()))
    }

    /// Stop the engine thread (idempotent; outstanding requests finish
    /// first because the channel is FIFO).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

#[cfg(feature = "xla")]
mod backend {
    //! Real PJRT execution (feature `xla`).

    use std::collections::BTreeMap;

    use super::*;

    struct Compiled {
        exe: xla::PjRtLoadedExecutable,
        meta: EntryMeta,
    }

    pub(super) fn engine_main(
        manifest: Arc<Manifest>,
        models: Vec<String>,
        rx: mpsc::Receiver<Request>,
        ready: mpsc::Sender<Result<()>>,
    ) {
        let setup = (|| -> Result<(xla::PjRtClient, BTreeMap<(String, String), Compiled>)> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let mut table = BTreeMap::new();
            for model in &models {
                let meta = manifest.model(model)?;
                for (tag, em) in &meta.entries {
                    let proto = xla::HloModuleProto::from_text_file(&em.file)
                        .with_context(|| format!("parsing {}", em.file.display()))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client
                        .compile(&comp)
                        .with_context(|| format!("compiling {}", em.file.display()))?;
                    table.insert(
                        (model.clone(), tag.clone()),
                        Compiled { exe, meta: em.clone() },
                    );
                }
            }
            Ok((client, table))
        })();
        let table = match setup {
            Ok((_client, table)) => {
                let _ = ready.send(Ok(()));
                table
            }
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        };
        while let Ok(req) = rx.recv() {
            match req {
                Request::Shutdown => break,
                Request::Execute { model, entry, f32_args, i32_args, order, reply } => {
                    let result = run_one(&table, &model, entry, f32_args, i32_args, order);
                    let _ = reply.send(result);
                }
                Request::TrainChain { model, mut params, lr, batches, order, reply } => {
                    let result = (|| -> Result<(Vec<f32>, Vec<f32>)> {
                        let mut losses = Vec::with_capacity(batches.len());
                        for (x, y) in batches {
                            let out = run_one(
                                &table,
                                &model,
                                "train",
                                vec![std::mem::take(&mut params), x, vec![lr]],
                                vec![y],
                                order.clone(),
                            )?;
                            params = out.f32_out(0).to_vec();
                            losses.push(out.f32_out(1)[0]);
                        }
                        Ok((params, losses))
                    })();
                    let _ = reply.send(result);
                }
            }
        }
    }

    fn run_one(
        table: &BTreeMap<(String, String), Compiled>,
        model: &str,
        entry: &str,
        f32_args: Vec<Vec<f32>>,
        i32_args: Vec<Vec<i32>>,
        order: Vec<DType>,
    ) -> Result<Outputs> {
        let compiled = table
            .get(&(model.to_string(), entry.to_string()))
            .with_context(|| format!("{model}/{entry} not compiled"))?;
        // Build literals in manifest order.
        let (mut fi, mut ii) = (0usize, 0usize);
        let mut literals = Vec::with_capacity(order.len());
        for (pos, d) in order.iter().enumerate() {
            let spec = &compiled.meta.args[pos];
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match d {
                DType::F32 => {
                    let lit = xla::Literal::vec1(&f32_args[fi]);
                    fi += 1;
                    lit.reshape(&dims)?
                }
                DType::I32 => {
                    let lit = xla::Literal::vec1(&i32_args[ii]);
                    ii += 1;
                    lit.reshape(&dims)?
                }
            };
            literals.push(lit);
        }
        let result = compiled.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple, even for
        // one output.
        let parts = tuple.to_tuple()?;
        if parts.len() != compiled.meta.outs.len() {
            bail!(
                "{model}/{entry}: expected {} outputs, got {}",
                compiled.meta.outs.len(),
                parts.len()
            );
        }
        let mut out = Outputs::default();
        for (lit, spec) in parts.into_iter().zip(compiled.meta.outs.iter()) {
            out.order.push(spec.dtype);
            match spec.dtype {
                DType::F32 => out.f32s.push(lit.to_vec::<f32>()?),
                DType::I32 => out.i32s.push(lit.to_vec::<i32>()?),
            }
        }
        Ok(out)
    }
}

#[cfg(not(feature = "xla"))]
mod backend {
    //! Stub backend: the `xla` crate is not compiled in. Startup fails
    //! with a clear message; artifact-gated tests skip long before this.

    use super::*;

    pub(super) fn engine_main(
        _manifest: Arc<Manifest>,
        _models: Vec<String>,
        rx: mpsc::Receiver<Request>,
        ready: mpsc::Sender<Result<()>>,
    ) {
        let _ = ready.send(Err(anyhow::anyhow!(
            "built without the `xla` feature: PJRT execution is unavailable \
             (rebuild with `cargo build --features xla`)"
        )));
        drop(rx);
    }
}
