//! PJRT engine: loads AOT artifacts and executes them for the node layer.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (neither `Send` nor
//! `Sync`), so the engine owns the client and all compiled executables on
//! **one dedicated service thread**; node threads talk to it through a
//! cloneable [`EngineHandle`] over an mpsc channel. On this single-core
//! testbed XLA execution is serial anyway, so funneling compute through
//! one thread costs nothing and keeps the hot path allocation-free apart
//! from the literal buffers themselves.
//!
//! HLO **text** is the interchange format (not serialized protos): see
//! `python/compile/aot.py` and /opt/xla-example/README.md.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::manifest::{DType, EntryMeta, Manifest};

/// A request processed by the engine thread.
enum Request {
    Execute {
        model: String,
        entry: &'static str,
        f32_args: Vec<Vec<f32>>,
        i32_args: Vec<Vec<i32>>,
        /// Argument order as dtype tags ('f' pulls the next f32 arg, 'i'
        /// the next i32 arg) — mirrors the manifest arg order.
        order: Vec<DType>,
        reply: mpsc::Sender<Result<Outputs>>,
    },
    Shutdown,
}

/// Raw outputs of an entry point, in manifest order.
#[derive(Debug, Clone, Default)]
pub struct Outputs {
    pub f32s: Vec<Vec<f32>>,
    pub i32s: Vec<Vec<i32>>,
    /// Dtype per output, aligned with the manifest `outs`.
    pub order: Vec<DType>,
}

impl Outputs {
    /// The n-th output interpreted as f32 data.
    pub fn f32_out(&self, n: usize) -> &[f32] {
        let mut fi = 0;
        for (i, d) in self.order.iter().enumerate() {
            if i == n {
                assert_eq!(*d, DType::F32, "output {n} is not f32");
                return &self.f32s[fi];
            }
            if *d == DType::F32 {
                fi += 1;
            }
        }
        panic!("output index {n} out of range");
    }

    pub fn i32_out(&self, n: usize) -> &[i32] {
        let mut ii = 0;
        for (i, d) in self.order.iter().enumerate() {
            if i == n {
                assert_eq!(*d, DType::I32, "output {n} is not i32");
                return &self.i32s[ii];
            }
            if *d == DType::I32 {
                ii += 1;
            }
        }
        panic!("output index {n} out of range");
    }
}

/// Cloneable, `Send` handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
    manifest: Arc<Manifest>,
}

impl EngineHandle {
    /// Start the engine thread, loading and compiling the given models'
    /// artifacts eagerly (all four entry points each).
    pub fn start(artifacts_dir: &Path, models: &[&str]) -> Result<EngineHandle> {
        let manifest = Arc::new(Manifest::load(artifacts_dir)?);
        for m in models {
            manifest.model(m)?; // validate before spawning
        }
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread_manifest = Arc::clone(&manifest);
        let model_names: Vec<String> = models.iter().map(|s| s.to_string()).collect();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || engine_main(thread_manifest, model_names, rx, ready_tx))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .context("engine thread died during startup")??;
        Ok(EngineHandle { tx, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn execute(
        &self,
        model: &str,
        entry: &'static str,
        f32_args: Vec<Vec<f32>>,
        i32_args: Vec<Vec<i32>>,
    ) -> Result<Outputs> {
        let meta = self.manifest.model(model)?;
        let em = meta
            .entries
            .get(entry)
            .with_context(|| format!("entry {entry:?} missing for model {model:?}"))?;
        // Validate argument shapes against the manifest before crossing
        // the channel: failures surface at the call site.
        let order: Vec<DType> = em.args.iter().map(|a| a.dtype).collect();
        let (mut fi, mut ii) = (0usize, 0usize);
        for a in &em.args {
            match a.dtype {
                DType::F32 => {
                    let got = f32_args
                        .get(fi)
                        .with_context(|| format!("missing f32 arg {}", a.name))?;
                    if got.len() != a.element_count() {
                        bail!(
                            "arg {} expects {} elements, got {}",
                            a.name,
                            a.element_count(),
                            got.len()
                        );
                    }
                    fi += 1;
                }
                DType::I32 => {
                    let got = i32_args
                        .get(ii)
                        .with_context(|| format!("missing i32 arg {}", a.name))?;
                    if got.len() != a.element_count() {
                        bail!(
                            "arg {} expects {} elements, got {}",
                            a.name,
                            a.element_count(),
                            got.len()
                        );
                    }
                    ii += 1;
                }
            }
        }
        if fi != f32_args.len() || ii != i32_args.len() {
            bail!("extra arguments supplied to {model}/{entry}");
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::Execute {
                model: model.to_string(),
                entry,
                f32_args,
                i32_args,
                order,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("engine thread is gone"))?;
        reply_rx.recv().context("engine thread dropped the reply")?
    }

    /// One local SGD step: returns (new_params, loss).
    pub fn train_step(
        &self,
        model: &str,
        params: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let out = self.execute(model, "train", vec![params, x, vec![lr]], vec![y])?;
        let new_params = out.f32_out(0).to_vec();
        let loss = out.f32_out(1)[0];
        Ok((new_params, loss))
    }

    /// Evaluate one fixed-size batch: returns (sum_loss, correct_count).
    pub fn eval_batch(
        &self,
        model: &str,
        params: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
    ) -> Result<(f32, i32)> {
        let out = self.execute(model, "eval", vec![params, x], vec![y])?;
        Ok((out.f32_out(0)[0], out.i32_out(1)[0]))
    }

    /// Weighted aggregation of up to `agg_k` stacked models via the L1
    /// Pallas kernel artifact: returns the mixed parameter vector.
    pub fn aggregate(
        &self,
        model: &str,
        stack: Vec<f32>,
        weights: Vec<f32>,
    ) -> Result<Vec<f32>> {
        let out = self.execute(model, "agg", vec![stack, weights], vec![])?;
        Ok(out.f32_out(0).to_vec())
    }

    /// Threshold sparsification with error feedback via the L1 kernel:
    /// returns (sent, new_residual).
    pub fn sparsify(
        &self,
        model: &str,
        values: Vec<f32>,
        residual: Vec<f32>,
        threshold: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let out = self.execute(
            model,
            "sparsify",
            vec![values, residual, vec![threshold]],
            vec![],
        )?;
        Ok((out.f32_out(0).to_vec(), out.f32_out(1).to_vec()))
    }

    /// Stop the engine thread (idempotent; outstanding requests finish
    /// first because the channel is FIFO).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    meta: EntryMeta,
}

fn engine_main(
    manifest: Arc<Manifest>,
    models: Vec<String>,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
) {
    let setup = (|| -> Result<(xla::PjRtClient, BTreeMap<(String, String), Compiled>)> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut table = BTreeMap::new();
        for model in &models {
            let meta = manifest.model(model)?;
            for (tag, em) in &meta.entries {
                let proto = xla::HloModuleProto::from_text_file(&em.file)
                    .with_context(|| format!("parsing {}", em.file.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling {}", em.file.display()))?;
                table.insert(
                    (model.clone(), tag.clone()),
                    Compiled { exe, meta: em.clone() },
                );
            }
        }
        Ok((client, table))
    })();
    let table = match setup {
        Ok((_client, table)) => {
            let _ = ready.send(Ok(()));
            table
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Execute { model, entry, f32_args, i32_args, order, reply } => {
                let result = run_one(&table, &model, entry, f32_args, i32_args, order);
                let _ = reply.send(result);
            }
        }
    }
}

fn run_one(
    table: &BTreeMap<(String, String), Compiled>,
    model: &str,
    entry: &str,
    f32_args: Vec<Vec<f32>>,
    i32_args: Vec<Vec<i32>>,
    order: Vec<DType>,
) -> Result<Outputs> {
    let compiled = table
        .get(&(model.to_string(), entry.to_string()))
        .with_context(|| format!("{model}/{entry} not compiled"))?;
    // Build literals in manifest order.
    let (mut fi, mut ii) = (0usize, 0usize);
    let mut literals = Vec::with_capacity(order.len());
    for (pos, d) in order.iter().enumerate() {
        let spec = &compiled.meta.args[pos];
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match d {
            DType::F32 => {
                let lit = xla::Literal::vec1(&f32_args[fi]);
                fi += 1;
                lit.reshape(&dims)?
            }
            DType::I32 => {
                let lit = xla::Literal::vec1(&i32_args[ii]);
                ii += 1;
                lit.reshape(&dims)?
            }
        };
        literals.push(lit);
    }
    let result = compiled.exe.execute::<xla::Literal>(&literals)?;
    let tuple = result[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True: always a tuple, even for one
    // output.
    let parts = tuple.to_tuple()?;
    if parts.len() != compiled.meta.outs.len() {
        bail!(
            "{model}/{entry}: expected {} outputs, got {}",
            compiled.meta.outs.len(),
            parts.len()
        );
    }
    let mut out = Outputs::default();
    for (lit, spec) in parts.into_iter().zip(compiled.meta.outs.iter()) {
        out.order.push(spec.dtype);
        match spec.dtype {
            DType::F32 => out.f32s.push(lit.to_vec::<f32>()?),
            DType::I32 => out.i32s.push(lit.to_vec::<i32>()?),
        }
    }
    Ok(out)
}
