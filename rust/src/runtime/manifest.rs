//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Json};

/// Element type of an argument/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn from_str(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => bail!("unknown dtype {other:?}"),
        })
    }
}

/// One argument or output of an entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<ArgSpec> {
        let name = v.get("name").as_str().context("arg missing name")?.to_string();
        let shape = v
            .get("shape")
            .as_arr()
            .context("arg missing shape")?
            .iter()
            .map(|d| d.as_usize().context("bad shape dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::from_str(v.get("dtype").as_str().unwrap_or("f32"))?;
        Ok(ArgSpec { name, shape, dtype })
    }
}

/// One lowered entry point (train / eval / agg / sparsify).
#[derive(Debug, Clone, PartialEq)]
pub struct EntryMeta {
    pub file: PathBuf,
    pub args: Vec<ArgSpec>,
    pub outs: Vec<ArgSpec>,
}

/// Per-model metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    pub name: String,
    pub param_count: usize,
    /// (h, w, c)
    pub input_shape: (usize, usize, usize),
    pub num_classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub agg_k: usize,
    /// Raw little-endian f32 file with the common initial parameters
    /// (shared by every node; absent in older manifests).
    pub init_file: Option<PathBuf>,
    pub entries: BTreeMap<String, EntryMeta>,
}

impl ModelMeta {
    /// Load the common initial parameter vector.
    pub fn load_init(&self) -> Result<Vec<f32>> {
        let path = self
            .init_file
            .as_ref()
            .context("manifest has no init_file (re-run `make artifacts`)")?;
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != self.param_count * 4 {
            bail!(
                "init file {} has {} bytes, expected {}",
                path.display(),
                bytes.len(),
                self.param_count * 4
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub image: usize,
    pub models: BTreeMap<String, ModelMeta>,
    /// Directory the artifact files live in.
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} (run `make artifacts` to build the AOT artifacts)",
                path.display()
            )
        })?;
        let v = parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&v, dir)
    }

    pub fn from_json(v: &Json, dir: &Path) -> Result<Manifest> {
        if v.get("format").as_i64() != Some(1) {
            bail!("unsupported manifest format {:?}", v.get("format"));
        }
        let image = v.get("image").as_usize().context("manifest missing image")?;
        let mut models = BTreeMap::new();
        let obj = v.get("models").as_obj().context("manifest missing models")?;
        for (name, m) in obj {
            let shape = m
                .get("input_shape")
                .as_arr()
                .context("model missing input_shape")?;
            if shape.len() != 3 {
                bail!("input_shape must be rank 3");
            }
            let mut entries = BTreeMap::new();
            let eobj = m.get("entries").as_obj().context("model missing entries")?;
            for (tag, e) in eobj {
                let file = dir.join(e.get("file").as_str().context("entry missing file")?);
                let args = e
                    .get("args")
                    .as_arr()
                    .context("entry missing args")?
                    .iter()
                    .map(ArgSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let outs = e
                    .get("outs")
                    .as_arr()
                    .context("entry missing outs")?
                    .iter()
                    .map(ArgSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                entries.insert(tag.clone(), EntryMeta { file, args, outs });
            }
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    param_count: m
                        .get("param_count")
                        .as_usize()
                        .context("model missing param_count")?,
                    input_shape: (
                        shape[0].as_usize().context("dim")?,
                        shape[1].as_usize().context("dim")?,
                        shape[2].as_usize().context("dim")?,
                    ),
                    num_classes: m
                        .get("num_classes")
                        .as_usize()
                        .context("model missing num_classes")?,
                    train_batch: m
                        .get("train_batch")
                        .as_usize()
                        .context("model missing train_batch")?,
                    eval_batch: m
                        .get("eval_batch")
                        .as_usize()
                        .context("model missing eval_batch")?,
                    agg_k: m.get("agg_k").as_usize().context("model missing agg_k")?,
                    init_file: m.get("init_file").as_str().map(|f| dir.join(f)),
                    entries,
                },
            );
        }
        Ok(Manifest { image, models, dir: dir.to_path_buf() })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "image": 16,
      "models": {
        "mlp": {
          "param_count": 100,
          "input_shape": [4, 4, 3],
          "num_classes": 10,
          "train_batch": 8,
          "eval_batch": 32,
          "agg_k": 16,
          "entries": {
            "train": {
              "file": "mlp_train.hlo.txt",
              "args": [
                {"name": "params", "shape": [100], "dtype": "f32"},
                {"name": "x", "shape": [8, 4, 4, 3], "dtype": "f32"},
                {"name": "y", "shape": [8], "dtype": "i32"},
                {"name": "lr", "shape": [1], "dtype": "f32"}
              ],
              "outs": [
                {"name": "params", "shape": [100], "dtype": "f32"},
                {"name": "loss", "shape": [], "dtype": "f32"}
              ]
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let v = parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.image, 16);
        let mlp = m.model("mlp").unwrap();
        assert_eq!(mlp.param_count, 100);
        assert_eq!(mlp.input_shape, (4, 4, 3));
        let train = &mlp.entries["train"];
        assert_eq!(train.args.len(), 4);
        assert_eq!(train.args[1].element_count(), 8 * 4 * 4 * 3);
        assert_eq!(train.args[2].dtype, DType::I32);
        assert_eq!(train.outs[1].shape.len(), 0);
        assert!(train.file.ends_with("mlp_train.hlo.txt"));
    }

    #[test]
    fn missing_model_errors() {
        let v = parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp")).unwrap();
        assert!(m.model("cnn").is_err());
    }

    #[test]
    fn rejects_bad_format() {
        let v = parse(r#"{"format": 2, "image": 8, "models": {}}"#).unwrap();
        assert!(Manifest::from_json(&v, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"i32\"", "\"f64\"");
        let v = parse(&bad).unwrap();
        assert!(Manifest::from_json(&v, Path::new("/tmp")).is_err());
    }
}
