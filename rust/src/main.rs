//! `decentra` — the DecentralizeRs command-line driver.
//!
//! Subcommands:
//! * `run`     — run an experiment from a JSON config (in-process emulation)
//! * `node`    — run ONE node over TCP (multi-process / multi-machine mode)
//! * `graph`   — generate / inspect topology files
//! * `report`  — aggregate a results directory into a series table
//! * `fl`      — run the FL-server emulation (Fig 1's specialized node)
//! * `serve`   — HTTP daemon: submit / watch / cancel runs over a REST+SSE API

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use decentralize_rs::config::ExperimentConfig;
use decentralize_rs::coordinator::{run_experiment_with, RunHooks};
use decentralize_rs::graph;
use decentralize_rs::metrics::{aggregate, render_series, NodeLog};
use decentralize_rs::rng::Xoshiro256pp;
use decentralize_rs::runtime::EngineHandle;
use decentralize_rs::trace::{TraceMode, TraceRecorder};
use decentralize_rs::util::args::{usage, Args, OptSpec};
use decentralize_rs::util::logger;
use decentralize_rs::{log_info, util};

const FLAGS: &[&str] = &["save", "dynamic", "secure", "info", "help"];

fn main() {
    logger::init();
    let args = match Args::from_env(FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.command() {
        Some("run") => cmd_run(&args),
        Some("node") => cmd_node(&args),
        Some("graph") => cmd_graph(&args),
        Some("report") => cmd_report(&args),
        Some("fl") => cmd_fl(&args),
        Some("serve") => cmd_serve(&args),
        _ => {
            print_usage();
            return;
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Value-taking option row for the usage table.
fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> OptSpec {
    OptSpec { name, help, default, is_flag: false }
}

/// Boolean flag row for the usage table.
fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, default: None, is_flag: true }
}

fn print_usage() {
    println!(
        "{}",
        usage(
            "decentra",
            "decentralized learning framework (DecentralizePy reproduction)",
            &[
                opt("config", "experiment config JSON (run/node)", None),
                opt("nodes", "override node count", None),
                opt("rounds", "override round count", None),
                opt("topology", "override topology spec", None),
                opt("sharing", "override sharing spec", None),
                opt("seed", "override seed", None),
                flag("dynamic", "re-sample the topology every round (peer sampler)"),
                flag("secure", "wrap sharing in pairwise-mask secure aggregation"),
                opt("mode", "round model: dl (synchronous) | async_dl (deadline gossip)", Some("dl")),
                opt("deadline", "async deadline: fixed:<s> | p<q> | factor:<f>", Some("factor:2")),
                opt("staleness", "async staleness: none | linear:<tau> | poly:<alpha>", Some("none")),
                opt("late", "async late-delivery policy: buffer | drop", Some("buffer")),
                opt("runner", "in-process runner: scheduler | threads (run mode)", Some("scheduler")),
                opt("workers", "scheduler worker threads (0 = cores)", Some("0")),
                opt("fold", "neighbor fold plan: serial | tree:<width> (deterministic at any worker count)", Some("serial")),
                opt("param-store", "model-state ownership: owned | shared (CoW shards + zero-copy broadcast) | paged (per-page CoW + interning)", Some("owned")),
                opt("page-size", "elements per CoW page (paged store only)", Some("1024")),
                opt("trace", "span tracing: off | sample:<rate> | full (run mode)", Some("off")),
                opt("trace-out", "trace + folded output path (run mode)", Some("trace.json")),
                opt("scenario", "scenario overlay JSON: step_time/link_model/churn_trace/network/churn", None),
                opt("step-time-trace", "per-node compute: uniform | stragglers:<f>:<x> | lognormal:<s> | trace:<path>", Some("uniform")),
                opt("link-model", "per-link delays: uniform | geo:<clusters> | matrix:<path>", Some("uniform")),
                opt("churn-trace", "availability: trace:<path> | sessions:<on>:<off> | departures:<frac> | crashes:<frac>:<horizon_s>", None),
                opt("byzantine", "adversaries: byzantine:<frac>:flood[:<factor>] | byzantine:<frac>:poison[:<scale>] | byzantine:<frac>:collude:<k>", None),
                opt("participation", "client participation fraction (fl mode)", Some("0.5")),
                opt("artifacts", "artifacts directory", Some("artifacts")),
                flag("save", "persist logs under results/"),
                opt("rank", "this node's rank (node mode)", None),
                opt("peers", "peers file: one host:port per rank (node mode)", None),
                opt("out", "output file (graph mode)", None),
                flag("info", "print graph statistics (graph mode)"),
                opt("dir", "results dir (report mode)", None),
                opt("addr", "listen address (serve mode)", Some("127.0.0.1:7070")),
                opt("queue-cap", "max queued runs before 429 (serve mode)", Some("16")),
                opt("ring-cap", "telemetry ring capacity per run (serve mode)", Some("65536")),
            ],
        )
    );
    println!("subcommands: run | node | graph | report | fl | serve");
}

/// Apply common CLI overrides onto a loaded config.
fn apply_overrides(cfg: &mut ExperimentConfig, args: &Args) -> Result<()> {
    if let Some(n) = args.get("nodes") {
        cfg.nodes = n.parse().context("--nodes")?;
    }
    if let Some(r) = args.get("rounds") {
        cfg.rounds = r.parse().context("--rounds")?;
    }
    if let Some(t) = args.get("topology") {
        cfg.topology = t.to_string();
    }
    if let Some(s) = args.get("sharing") {
        cfg.sharing = s.to_string();
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = s.parse().context("--seed")?;
    }
    if args.flag("dynamic") {
        cfg.dynamic = true;
    }
    if args.flag("secure") {
        cfg.secure = true;
    }
    if let Some(m) = args.get("mode") {
        cfg.mode = m.to_string();
    }
    if let Some(d) = args.get("deadline") {
        cfg.deadline = d.to_string();
    }
    if let Some(s) = args.get("staleness") {
        cfg.staleness = s.to_string();
    }
    if let Some(l) = args.get("late") {
        cfg.late = l.to_string();
    }
    if let Some(r) = args.get("runner") {
        cfg.runner = r.to_string();
    }
    if let Some(w) = args.get("workers") {
        cfg.workers = w.parse().context("--workers")?;
    }
    if let Some(f) = args.get("fold") {
        cfg.fold = f.to_string();
    }
    if let Some(p) = args.get("param-store") {
        cfg.param_store = p.to_string();
    }
    if let Some(p) = args.get("page-size") {
        cfg.page_size = p.parse().context("--page-size")?;
    }
    if let Some(t) = args.get("trace") {
        cfg.trace = t.to_string();
    }
    if let Some(s) = args.get("step-time-trace") {
        cfg.step_time = s.to_string();
    }
    if let Some(s) = args.get("link-model") {
        cfg.link_model = s.to_string();
    }
    if let Some(s) = args.get("churn-trace") {
        cfg.churn_trace = s.to_string();
    }
    if let Some(s) = args.get("byzantine") {
        cfg.byzantine = s.to_string();
    }
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(a);
    }
    cfg.validate()
}

/// Merge a scenario overlay file onto the config: a JSON object with
/// any of `step_time`, `link_model`, `churn_trace`, `byzantine`,
/// `network`, `churn`.
/// Individual flags (`--step-time-trace`, …) still win over the file.
/// Unknown keys and wrong-typed values are hard errors — a silently
/// ignored scenario axis would fake baseline results as scenario runs.
fn apply_scenario_file(cfg: &mut ExperimentConfig, path: &Path) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading scenario {}", path.display()))?;
    let v = decentralize_rs::util::json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let obj = v.as_obj().context("scenario file must be a JSON object")?;
    for (k, val) in obj {
        let want_str = || {
            val.as_str().map(str::to_string).with_context(|| {
                format!("scenario key {k:?} in {} must be a string", path.display())
            })
        };
        match k.as_str() {
            "step_time" => cfg.step_time = want_str()?,
            "link_model" => cfg.link_model = want_str()?,
            "churn_trace" => cfg.churn_trace = want_str()?,
            "byzantine" => cfg.byzantine = want_str()?,
            "network" => cfg.network = want_str()?,
            "churn" => {
                cfg.churn = val.as_f64().with_context(|| {
                    format!("scenario key \"churn\" in {} must be a number", path.display())
                })?;
            }
            other => bail!(
                "unknown scenario key {other:?} in {} \
                 (expected step_time | link_model | churn_trace | byzantine | network | churn)",
                path.display()
            ),
        }
    }
    Ok(())
}

/// Modes that bypass the in-process scheduler cannot honor the scenario
/// axes (or churn, or async gossip); reject them instead of silently
/// running a baseline.
fn reject_scenario_axes(cfg: &ExperimentConfig, mode: &str) -> Result<()> {
    if !matches!(cfg.step_time.as_str(), "" | "uniform")
        || !matches!(cfg.link_model.as_str(), "" | "uniform")
        || !cfg.churn_trace.is_empty()
        || !cfg.byzantine.is_empty()
        || cfg.churn > 0.0
    {
        bail!(
            "{mode} mode does not support scenario axes \
             (step_time / link_model / churn_trace / byzantine / churn); use `decentra run`"
        );
    }
    if cfg.mode != "dl" {
        bail!("{mode} mode supports only mode \"dl\" (async gossip needs the scheduler; use `decentra run`)");
    }
    Ok(())
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(path) = args.get("scenario") {
        apply_scenario_file(&mut cfg, Path::new(path))?;
    }
    apply_overrides(&mut cfg, args)?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    log_info!("run", "experiment {:?}: {} nodes, {} rounds, topology {}, sharing {}{}{} [{} runner]",
        cfg.name, cfg.nodes, cfg.rounds, cfg.topology, cfg.sharing,
        if cfg.secure { " + secure-agg" } else { "" },
        if cfg.mode == "async_dl" { " + async gossip" } else { "" },
        cfg.runner);
    let engine = EngineHandle::start(&cfg.artifacts_dir, &[cfg.model.as_str()])?;
    // `validate` vetted the spec, so parse cannot fail here.
    let trace = match TraceMode::parse(&cfg.trace)? {
        TraceMode::Off => None,
        mode => Some(TraceRecorder::new(mode)),
    };
    let hooks = RunHooks { trace: trace.clone(), ..RunHooks::default() };
    let result = run_experiment_with(&cfg, &engine, &hooks)?;
    print!("{}", render_series(&cfg.name, &result.series));
    println!(
        "final: acc {:.4}  bytes/node {}  emu {:.1}s  wall {:.1}s",
        result.final_accuracy(),
        util::human_bytes(result.final_bytes_per_node() as u64),
        result.final_emu_time(),
        result.wall_s
    );
    if let Some(report) = &result.store {
        println!(
            "store: peak param bytes {} (shared base {}), {}/{} shards materialized",
            util::human_bytes(report.at_end.peak_resident_bytes),
            util::human_bytes(report.at_end.shared_bytes),
            report.at_end.materialized_total,
            report.at_end.nodes,
        );
        if report.at_end.page_size > 0 {
            println!(
                "store: paged ({} elems/page), {} divergent pages live ({})",
                report.at_end.page_size,
                report.at_end.live_pages,
                util::human_bytes(report.at_end.page_bytes),
            );
        }
    }
    if let Some(tr) = &trace {
        let out = PathBuf::from(args.get_or("trace-out", "trace.json"));
        let snap = tr.snapshot();
        std::fs::write(&out, snap.to_chrome_json())
            .with_context(|| format!("writing {}", out.display()))?;
        let folded = out.with_extension("folded");
        std::fs::write(&folded, snap.to_folded())
            .with_context(|| format!("writing {}", folded.display()))?;
        log_info!(
            "run",
            "trace: {} spans ({} dropped) -> {} and {}",
            snap.spans.len(),
            snap.dropped_spans,
            out.display(),
            folded.display()
        );
    }
    if args.flag("save") {
        let dir = result.save()?;
        log_info!("run", "results saved to {}", dir.display());
    }
    engine.shutdown();
    Ok(())
}

/// Multi-process mode: run one DL node over TCP. Every process loads the
/// same config and derives the same dataset partition / topology / init
/// deterministically from the shared seed — only the rank differs.
fn cmd_node(args: &Args) -> Result<()> {
    use decentralize_rs::communication::tcp::TcpTransport;
    use decentralize_rs::dataset::{DataLoader, Partition};
    use decentralize_rs::node::{DlNode, TopologyView};
    use decentralize_rs::rng::mix_seed;
    use decentralize_rs::training::Trainer;
    use std::net::SocketAddr;
    use std::sync::Arc;

    let cfg = load_config(args)?;
    if cfg.dynamic {
        bail!("node mode supports static topologies (run the sampler in-process instead)");
    }
    reject_scenario_axes(&cfg, "node")?;
    let rank: usize = args.require("rank")?.parse().context("--rank")?;
    let peers_file = args.require("peers")?;
    let peers: Vec<SocketAddr> = std::fs::read_to_string(peers_file)
        .with_context(|| format!("reading {peers_file}"))?
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
        .map(|l| l.trim().parse().with_context(|| format!("bad peer addr {l:?}")))
        .collect::<Result<_>>()?;
    if peers.len() != cfg.nodes {
        bail!("peers file has {} entries for {} nodes", peers.len(), cfg.nodes);
    }
    if rank >= cfg.nodes {
        bail!("rank {rank} out of range");
    }

    let engine = EngineHandle::start(&cfg.artifacts_dir, &[cfg.model.as_str()])?;
    let meta = engine.manifest().model(&cfg.model)?.clone();
    let (train, test) = decentralize_rs::coordinator::build_dataset(&cfg, meta.eval_batch);
    let mut part_rng = Xoshiro256pp::new(mix_seed(&[cfg.seed, 0x9A27]));
    let shards =
        Partition::from_spec(&cfg.partition)?.split(&train.labels, cfg.nodes, &mut part_rng);
    let mut topo_rng = Xoshiro256pp::new(mix_seed(&[cfg.seed, 0x7090]));
    let g = graph::from_spec(&cfg.topology, cfg.nodes, &mut topo_rng)?;
    let w = graph::metropolis_hastings(&g);

    let transport = TcpTransport::bind(rank, peers[rank], peers.clone())?;
    log_info!("node", "rank {rank} listening on {}", transport.local_addr());
    // No startup barrier needed: first sends retry with backoff until
    // the remote listener is up (see TcpTransport::connect_with_retry).

    let loader = DataLoader::new(
        train.subset(&shards[rank]),
        meta.train_batch,
        mix_seed(&[cfg.seed, 0xDA7A, rank as u64]),
    );
    let node = DlNode {
        id: rank,
        rounds: cfg.rounds,
        eval_every: cfg.eval_every,
        transport: Box::new(Arc::clone(&transport)),
        trainer: Trainer::new(engine.clone(), &cfg.model, loader, cfg.lr, cfg.local_steps)?,
        sharing: {
            let mut s = decentralize_rs::sharing::from_spec(
                &cfg.sharing,
                meta.param_count,
                mix_seed(&[cfg.seed, rank as u64]),
            )?;
            s.set_fold(decentralize_rs::kernels::fold::FoldCtx {
                spec: decentralize_rs::kernels::fold::FoldSpec::parse(&cfg.fold)?,
                workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            });
            s
        },
        // One node per process: a shared store has nothing to share, so
        // TCP node mode always owns its parameters.
        params: decentralize_rs::store::ParamSlot::owned(meta.load_init()?),
        topology: TopologyView::Static {
            self_weight: w.self_weight(rank),
            neighbors: w.neighbor_weights(rank).collect(),
        },
        test: Arc::new(test),
        // reject_scenario_axes above guarantees no byzantine spec here.
        byz: None,
        network: None,
        step_time_s: 0.0,
        eval_time_s: 0.0,
        telemetry: None,
    };
    let log = node.run()?;
    let dir = cfg.results_dir.join(&cfg.name);
    log.save(&dir)?;
    log_info!("node", "rank {rank} done; log in {}", dir.display());
    transport.shutdown();
    engine.shutdown();
    Ok(())
}

fn cmd_graph(args: &Args) -> Result<()> {
    let spec = args.get_or("topology", "regular:5").to_string();
    let n: usize = args.get_parse("nodes", 16usize)?;
    let seed: u64 = args.get_parse("seed", 1u64)?;
    let mut rng = Xoshiro256pp::new(seed);
    let g = graph::from_spec(&spec, n, &mut rng)?;
    if args.flag("info") {
        let (dmin, dmean, dmax) = graph::degree_stats(&g);
        println!("topology {spec} on {n} nodes");
        println!("  edges      {}", g.edge_count());
        println!("  degree     min {dmin} / mean {dmean:.2} / max {dmax}");
        println!("  connected  {}", graph::is_connected(&g));
        if let Some(d) = graph::diameter(&g) {
            println!("  diameter   {d}");
        }
        println!("  spectral gap {:.4}", graph::spectral_gap(&g, 200));
    }
    if let Some(out) = args.get("out") {
        let path = Path::new(out);
        if out.ends_with(".adj") {
            graph::save_adjacency_list(&g, path)?;
        } else {
            graph::save_edge_list(&g, path)?;
        }
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.require("dir")?);
    let logs = NodeLog::load_dir(&dir)?;
    if logs.is_empty() {
        bail!("no node logs in {}", dir.display());
    }
    let series = aggregate(&logs);
    print!("{}", render_series(&dir.display().to_string(), &series));
    Ok(())
}

/// FL emulation demo: one server + N clients over the in-process hub.
fn cmd_fl(args: &Args) -> Result<()> {
    use decentralize_rs::communication::inproc::InprocHub;
    use decentralize_rs::dataset::{DataLoader, Partition};
    use decentralize_rs::node::{FlClient, FlServer};
    use decentralize_rs::rng::mix_seed;
    use decentralize_rs::training::Trainer;
    use std::sync::Arc;

    let mut cfg = load_config(args)?;
    cfg.name = "fl_emulation".into();
    reject_scenario_axes(&cfg, "fl")?;
    let participation: f64 = args.get_parse("participation", 0.5f64)?;
    let engine = EngineHandle::start(&cfg.artifacts_dir, &[cfg.model.as_str()])?;
    let meta = engine.manifest().model(&cfg.model)?.clone();
    let (train, test) = decentralize_rs::coordinator::build_dataset(&cfg, meta.eval_batch);
    let mut part_rng = Xoshiro256pp::new(mix_seed(&[cfg.seed, 0x9A27]));
    let shards =
        Partition::from_spec(&cfg.partition)?.split(&train.labels, cfg.nodes, &mut part_rng);
    let hub = InprocHub::new(cfg.nodes + 1);
    let test = Arc::new(test);

    let mut log = None;
    std::thread::scope(|scope| -> Result<()> {
        let server = FlServer {
            rank: cfg.nodes,
            clients: cfg.nodes,
            rounds: cfg.rounds,
            eval_every: cfg.eval_every,
            participation,
            seed: cfg.seed,
            transport: Box::new(hub.endpoint(cfg.nodes)),
            params: meta.load_init()?,
            trainer: Trainer::new(
                engine.clone(),
                &cfg.model,
                DataLoader::new(train.subset(&shards[0]), meta.train_batch, 0),
                cfg.lr,
                cfg.local_steps,
            )?,
            test: Arc::clone(&test),
        };
        let sh = scope.spawn(move || server.run());
        let mut clients = Vec::new();
        for id in 0..cfg.nodes {
            let client = FlClient {
                id,
                server_rank: cfg.nodes,
                transport: Box::new(hub.endpoint(id)),
                trainer: Trainer::new(
                    engine.clone(),
                    &cfg.model,
                    DataLoader::new(
                        train.subset(&shards[id]),
                        meta.train_batch,
                        mix_seed(&[cfg.seed, id as u64]),
                    ),
                    cfg.lr,
                    cfg.local_steps,
                )?,
            };
            clients.push(scope.spawn(move || client.run()));
        }
        log = Some(sh.join().map_err(|_| anyhow::anyhow!("server panicked"))??);
        for c in clients {
            c.join().map_err(|_| anyhow::anyhow!("client panicked"))??;
        }
        Ok(())
    })?;
    hub.shutdown();
    let log = log.unwrap();
    let series = aggregate(&[log]);
    print!("{}", render_series("fl_emulation", &series));
    engine.shutdown();
    Ok(())
}

/// Observability daemon: a REST + SSE API for submitting, watching, and
/// cancelling experiment runs (see [`decentralize_rs::serve`]).
fn cmd_serve(args: &Args) -> Result<()> {
    use decentralize_rs::serve::{Daemon, ServeOptions};

    let defaults = ServeOptions::default();
    let opts = ServeOptions {
        addr: args.get_or("addr", &defaults.addr).to_string(),
        queue_cap: args.get_parse("queue-cap", defaults.queue_cap)?,
        ring_cap: args.get_parse("ring-cap", defaults.ring_cap)?,
    };
    let daemon = Daemon::bind(&opts)?;
    log_info!("serve", "listening on http://{}", daemon.local_addr());
    daemon.run()
}
