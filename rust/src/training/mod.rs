//! Local training (the paper's *Training* module): per-round local SGD
//! steps and test-set evaluation, executed through the PJRT engine.

use anyhow::{bail, Result};

use crate::dataset::{DataLoader, Dataset};
use crate::runtime::EngineHandle;

/// Local trainer owned by one node.
pub struct Trainer {
    engine: EngineHandle,
    model: String,
    loader: DataLoader,
    lr: f32,
    local_steps: u32,
}

impl Trainer {
    pub fn new(
        engine: EngineHandle,
        model: &str,
        loader: DataLoader,
        lr: f32,
        local_steps: u32,
    ) -> Result<Trainer> {
        let meta = engine.manifest().model(model)?;
        if loader.batch_size() != meta.train_batch {
            bail!(
                "loader batch {} != lowered train batch {}",
                loader.batch_size(),
                meta.train_batch
            );
        }
        Ok(Trainer {
            engine,
            model: model.to_string(),
            loader,
            lr,
            local_steps,
        })
    }

    pub fn model_name(&self) -> &str {
        &self.model
    }

    pub fn local_steps(&self) -> u32 {
        self.local_steps
    }

    /// Run `local_steps` SGD steps; returns (new_params, mean train loss).
    ///
    /// The whole round is submitted as ONE chained engine request
    /// ([`EngineHandle::train_chain`]): batches are drawn up front and
    /// parameters flow step-to-step inside the engine thread, so the
    /// channel round-trip is paid once per round. Arithmetic is identical
    /// to per-step submission.
    pub fn train_round(&mut self, params: Vec<f32>) -> Result<(Vec<f32>, f64)> {
        let batches: Vec<(Vec<f32>, Vec<i32>)> = (0..self.local_steps)
            .map(|_| {
                let batch = self.loader.next_batch();
                (batch.features, batch.labels)
            })
            .collect();
        let (params, losses) = self.engine.train_chain(&self.model, params, batches, self.lr)?;
        let total: f64 = losses.iter().map(|&l| l as f64).sum();
        Ok((params, total / self.local_steps as f64))
    }

    /// Exact test-set metrics: returns (mean loss, accuracy).
    ///
    /// The eval executable has a fixed batch shape; the caller must supply
    /// a test set whose size is a multiple of the lowered eval batch (the
    /// coordinator rounds `test_total` up to guarantee this).
    pub fn evaluate(&self, params: &[f32], test: &Dataset) -> Result<(f64, f64)> {
        let meta = self.engine.manifest().model(&self.model)?;
        let b = meta.eval_batch;
        if test.len() % b != 0 {
            bail!("test set size {} not a multiple of eval batch {b}", test.len());
        }
        let mut sum_loss = 0.0f64;
        let mut correct = 0i64;
        for (batch, valid) in DataLoader::eval_batches(test, b) {
            debug_assert_eq!(valid, b);
            let (l, c) = self.engine.eval_batch(
                &self.model,
                params.to_vec(),
                batch.features,
                batch.labels,
            )?;
            sum_loss += l as f64;
            correct += c as i64;
        }
        let n = test.len() as f64;
        Ok((sum_loss / n, correct as f64 / n))
    }
}

#[cfg(test)]
mod tests {
    // Trainer is exercised end-to-end in rust/tests/dl_integration.rs
    // (it needs compiled artifacts); unit-level input validation only.
    use super::*;
    use crate::dataset::SyntheticSpec;

    #[test]
    fn batch_mismatch_rejected() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = match EngineHandle::start(&dir, &["mlp"]) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("skipping: engine unavailable ({e:#})");
                return;
            }
        };
        let (train, _) = crate::dataset::generate(&SyntheticSpec::cifar10s(16, 64, 32, 1));
        let bad = DataLoader::new(train, 3, 0); // lowered batch is 8
        assert!(Trainer::new(engine.clone(), "mlp", bad, 0.05, 1).is_err());
        engine.shutdown();
    }
}
