//! Figure 4 bench: sparsification (random sampling, Choco-SGD, TopK) vs
//! full sharing at a 10% budget, reduced scale. Full-resolution harness:
//! `cargo run --release --example sparsification`.

mod fig_common;

use fig_common::{bench_config, engine_or_skip, run_variant};

fn main() {
    println!("== fig4: sparsification vs full sharing (10% budget) ==");
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };

    let mut full = bench_config("fig4/full");
    full.rounds = 16;
    let mut rand = full.clone();
    rand.name = "fig4/random".into();
    rand.sharing = "subsample:0.1".into();
    let mut choco = full.clone();
    choco.name = "fig4/choco".into();
    choco.sharing = "choco:0.1:0.6".into();
    let mut topk = full.clone();
    topk.name = "fig4/topk".into();
    topk.sharing = "topk:0.1".into();

    let r_full = run_variant(&full, &engine);
    let r_rand = run_variant(&rand, &engine);
    let r_choco = run_variant(&choco, &engine);
    let r_topk = run_variant(&topk, &engine);

    let budget_ok = r_rand.final_bytes_per_node() < r_full.final_bytes_per_node() * 0.2
        && r_choco.final_bytes_per_node() < r_full.final_bytes_per_node() * 0.2
        && r_topk.final_bytes_per_node() < r_full.final_bytes_per_node() * 0.2;
    println!("shape: sparsifiers honor ~10x byte budget  : {budget_ok}");
    println!(
        "shape: full-sharing accuracy lead at equal rounds: {:.4} vs best sparsifier {:.4}",
        r_full.final_accuracy(),
        r_rand
            .final_accuracy()
            .max(r_choco.final_accuracy())
            .max(r_topk.final_accuracy())
    );
    println!("== fig4 done ==");
}
