//! Figure 6 bench: scalability — N vs 4N nodes over the same total
//! dataset, degree 5 vs 9, reduced scale. Full-resolution harness:
//! `cargo run --release --example scalability`.

mod fig_common;

use fig_common::{bench_config, engine_or_skip, run_variant};

fn main() {
    println!("== fig6: scalability (fixed dataset, 4x nodes, degree 5 vs 9) ==");
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };

    let small_n = 10usize;
    let large_n = 40usize;

    let mut s5 = bench_config("fig6/small_5reg");
    s5.nodes = small_n;
    s5.topology = "regular:5".into();
    s5.train_total = 1280;
    let mut l5 = s5.clone();
    l5.name = "fig6/large_5reg".into();
    l5.nodes = large_n;
    let mut l9 = l5.clone();
    l9.name = "fig6/large_9reg".into();
    l9.topology = "regular:9".into();

    let r_s5 = run_variant(&s5, &engine);
    let r_l5 = run_variant(&l5, &engine);
    let r_l9 = run_variant(&l9, &engine);

    println!(
        "shape: 5-regular {}n vs {}n accuracy: {:.4} vs {:.4} (paper: ~equal)",
        small_n,
        large_n,
        r_s5.final_accuracy(),
        r_l5.final_accuracy()
    );
    println!(
        "shape: degree 9 vs 5 at {}n: {:+.1} accuracy points (paper: +5.8)",
        large_n,
        (r_l9.final_accuracy() - r_l5.final_accuracy()) * 100.0
    );
    println!("== fig6 done ==");
}
