//! Figure 6 bench: scalability — N vs 4N nodes over the same total
//! dataset, degree 5 vs 9, reduced scale, plus the virtual-time
//! scheduler sweep (the paper's 1000+-node emulation on a bounded
//! worker pool). The sweep runs `param_store = "owned"` to 1024 nodes
//! (the historical ceiling: per-node parameter buffers) and
//! `param_store = "shared"` to 4096, recording peak parameter bytes per
//! point from the store report; the whole trajectory is written to
//! `BENCH_fig6.json`. Full-resolution harness:
//! `cargo run --release --example scalability`.

mod fig_common;

use decentralize_rs::coordinator::RunResult;
use decentralize_rs::util::json::Json;
use fig_common::{bench_config, engine_or_skip, run_variant};

/// Peak parameter bytes for one run: the store report in shared mode,
/// the analytic per-node-copy floor (nodes × params × 4) in owned mode.
fn peak_param_bytes(r: &RunResult, nodes: usize) -> (u64, u64) {
    match &r.store {
        Some(report) => (
            report.at_start.resident_bytes + report.at_start.shared_bytes,
            report.at_end.peak_resident_bytes + report.at_end.shared_bytes,
        ),
        None => {
            let owned = (nodes * r.param_count * 4) as u64;
            (owned, owned)
        }
    }
}

fn main() {
    println!("== fig6: scalability (fixed dataset, 4x nodes, degree 5 vs 9) ==");
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };

    let small_n = 10usize;
    let large_n = 40usize;

    let mut s5 = bench_config("fig6/small_5reg");
    s5.nodes = small_n;
    s5.topology = "regular:5".into();
    s5.train_total = 1280;
    let mut l5 = s5.clone();
    l5.name = "fig6/large_5reg".into();
    l5.nodes = large_n;
    let mut l9 = l5.clone();
    l9.name = "fig6/large_9reg".into();
    l9.topology = "regular:9".into();

    let r_s5 = run_variant(&s5, &engine);
    let r_l5 = run_variant(&l5, &engine);
    let r_l9 = run_variant(&l9, &engine);

    println!(
        "shape: 5-regular {}n vs {}n accuracy: {:.4} vs {:.4} (paper: ~equal)",
        small_n,
        large_n,
        r_s5.final_accuracy(),
        r_l5.final_accuracy()
    );
    println!(
        "shape: degree 9 vs 5 at {}n: {:+.1} accuracy points (paper: +5.8)",
        large_n,
        (r_l9.final_accuracy() - r_l5.final_accuracy()) * 100.0
    );

    // Virtual-time scheduler sweep: wall-clock and parameter memory vs
    // node count on a bounded worker pool. Owned mode stops at the old
    // 1024 ceiling; the shared store carries the sweep to 4096 (its
    // startup cost is one base snapshot regardless of fleet size, and
    // broadcasts serialize once per round instead of once per neighbor).
    println!("-- scheduler sweep: regular:6, 3 rounds, owned ≤1024 vs shared ≤4096 --");
    let sweep: &[(usize, &str)] = &[
        (128, "owned"),
        (256, "owned"),
        (512, "owned"),
        (1024, "owned"),
        (128, "shared"),
        (256, "shared"),
        (512, "shared"),
        (1024, "shared"),
        (2048, "shared"),
        (4096, "shared"),
    ];
    let mut rows: Vec<Json> = Vec::new();
    for &(n, store_mode) in sweep {
        let mut cfg = bench_config(&format!("fig6/sched_{n}_{store_mode}"));
        cfg.runner = "scheduler".into();
        cfg.param_store = store_mode.into();
        cfg.nodes = n;
        cfg.rounds = 3;
        cfg.eval_every = 3;
        cfg.topology = "regular:6".into();
        cfg.train_total = n * 8; // one train batch per node per step
        cfg.test_total = 64;
        cfg.local_steps = 1;
        let r = run_variant(&cfg, &engine);
        let (start_bytes, peak_bytes) = peak_param_bytes(&r, n);
        println!(
            "scale {n:>5} nodes [{store_mode:>6}]: wall {:>7.2}s  emu {:>8.1}s  acc {:.4}  \
             param bytes start {:>12} peak {:>12}",
            r.wall_s,
            r.final_emu_time(),
            r.final_accuracy(),
            start_bytes,
            peak_bytes,
        );
        rows.push(Json::obj(vec![
            ("figure", Json::str("fig6")),
            ("nodes", Json::num(n as f64)),
            ("param_store", Json::str(store_mode)),
            ("rounds", Json::num(cfg.rounds as f64)),
            ("wall_s", Json::num(r.wall_s)),
            ("emu_time_s", Json::num(r.final_emu_time())),
            ("test_acc", Json::num(r.final_accuracy())),
            ("param_count", Json::num(r.param_count as f64)),
            ("param_bytes_start", Json::num(start_bytes as f64)),
            ("param_bytes_peak", Json::num(peak_bytes as f64)),
        ]));
    }
    let artifact = Json::Arr(rows).pretty();
    match std::fs::write("BENCH_fig6.json", &artifact) {
        Ok(()) => println!("trajectory written to BENCH_fig6.json"),
        Err(e) => println!("(could not write BENCH_fig6.json: {e})"),
    }
    println!("== fig6 done ==");
}
