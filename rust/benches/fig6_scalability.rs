//! Figure 6 bench: scalability — N vs 4N nodes over the same total
//! dataset, degree 5 vs 9, reduced scale, plus the virtual-time
//! scheduler sweep to 1024 nodes (the paper's 1000+-node emulation on a
//! bounded worker pool). Full-resolution harness:
//! `cargo run --release --example scalability`.

mod fig_common;

use fig_common::{bench_config, engine_or_skip, run_variant};

fn main() {
    println!("== fig6: scalability (fixed dataset, 4x nodes, degree 5 vs 9) ==");
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };

    let small_n = 10usize;
    let large_n = 40usize;

    let mut s5 = bench_config("fig6/small_5reg");
    s5.nodes = small_n;
    s5.topology = "regular:5".into();
    s5.train_total = 1280;
    let mut l5 = s5.clone();
    l5.name = "fig6/large_5reg".into();
    l5.nodes = large_n;
    let mut l9 = l5.clone();
    l9.name = "fig6/large_9reg".into();
    l9.topology = "regular:9".into();

    let r_s5 = run_variant(&s5, &engine);
    let r_l5 = run_variant(&l5, &engine);
    let r_l9 = run_variant(&l9, &engine);

    println!(
        "shape: 5-regular {}n vs {}n accuracy: {:.4} vs {:.4} (paper: ~equal)",
        small_n,
        large_n,
        r_s5.final_accuracy(),
        r_l5.final_accuracy()
    );
    println!(
        "shape: degree 9 vs 5 at {}n: {:+.1} accuracy points (paper: +5.8)",
        large_n,
        (r_l9.final_accuracy() - r_l5.final_accuracy()) * 100.0
    );

    // Virtual-time scheduler sweep: wall-clock vs node count with a
    // bounded worker pool (workers ~ cores, not threads = nodes). The
    // thread-per-node runner cannot reach the top of this range.
    println!("-- scheduler sweep: 128..1024 nodes, regular:6, 3 rounds --");
    for &n in &[128usize, 256, 512, 1024] {
        let mut cfg = bench_config(&format!("fig6/sched_{n}"));
        cfg.runner = "scheduler".into();
        cfg.nodes = n;
        cfg.rounds = 3;
        cfg.eval_every = 3;
        cfg.topology = "regular:6".into();
        cfg.train_total = n * 8; // one train batch per node per step
        cfg.test_total = 64;
        cfg.local_steps = 1;
        let r = run_variant(&cfg, &engine);
        println!(
            "scale {n:>5} nodes: wall {:>7.2}s  emu {:>8.1}s  acc {:.4}",
            r.wall_s,
            r.final_emu_time(),
            r.final_accuracy()
        );
    }
    println!("== fig6 done ==");
}
