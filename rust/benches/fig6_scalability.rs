//! Figure 6 bench: scalability — N vs 4N nodes over the same total
//! dataset, degree 5 vs 9, reduced scale, plus the virtual-time
//! scheduler sweep (the paper's 1000+-node emulation on a bounded
//! worker pool). Two sweeps feed `BENCH_fig6.json`:
//!
//! * **Memory tier sweep** (artifact-free, always runs): a ring-gossip
//!   fleet over the shared [`ParamStore`] at 8192 → 102400 nodes, with
//!   a sparse writer cohort (`nodes / 16`). The unpaged shared store is
//!   charged a whole shard per writer; the paged store
//!   (`--param-store paged`) only the pages a writer actually dirties,
//!   which is what carries the sweep to the 100k tier. Points past the
//!   wall-clock budget are recorded as not-completed instead of
//!   stalling `cargo bench`.
//! * **Engine sweep** (needs artifacts): real training runs,
//!   `param_store = "owned"` to 1024 nodes (the historical ceiling),
//!   shared/paged to 4096, recording peak parameter bytes per point
//!   from the store report.
//!
//! Full-resolution harness: `cargo run --release --example scalability`.

mod fig_common;

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;
use decentralize_rs::communication::{Envelope, MsgKind, Payload};
use decentralize_rs::coordinator::RunResult;
use decentralize_rs::scheduler::{EventNode, NodeCtx, Scheduler, Wake};
use decentralize_rs::store::{ParamSlot, ParamStore};
use decentralize_rs::util::json::Json;
use fig_common::{bench_config, engine_or_skip, run_variant};

/// Memory-sweep model: 4096 f32 = 16 KiB per shard.
const DIM: usize = 4096;
/// Paged-mode page size: 1024 f32 = 4 KiB pages (4 pages per shard).
const PAGE: usize = 1024;
const MEM_ROUNDS: u64 = 3;
/// Wall-clock budget for the whole memory sweep; later points are
/// recorded with `completed: false` once it is spent.
const MEM_BUDGET_S: f64 = 120.0;

/// Ring-gossip node for the memory sweep (mirrors the CI memory smoke):
/// writers nudge one coordinate per round — with an id-distinct value,
/// so no two writers ever produce byte-identical pages and the paged
/// store's interning cannot collapse the cohort — then every node
/// broadcasts one small shared payload to both ring neighbors.
struct MemNode {
    id: usize,
    fleet: usize,
    params: ParamSlot,
    writer: bool,
    round: u64,
    /// Per-round arrival counts (a neighbor may run one round ahead).
    arrived: HashMap<u64, usize>,
}

impl MemNode {
    fn do_round(&mut self, ctx: &mut NodeCtx) {
        if self.writer {
            let mut v = self.params.take();
            // Id-distinct write: every writer's dirty page is unique.
            v[self.id % DIM] += 1.0 + self.id as f32;
            self.params.put(v);
        }
        let payload: Payload = vec![self.round as u8; 64].into();
        ctx.note_serialized(payload.len());
        for dst in [
            (self.id + 1) % self.fleet,
            (self.id + self.fleet - 1) % self.fleet,
        ] {
            ctx.send(Envelope {
                src: self.id,
                dst,
                round: self.round,
                kind: MsgKind::Model,
                sent_at_s: 0.0,
                trace: 0,
                payload: payload.clone(),
            });
        }
    }

    fn advance_if_ready(&mut self, ctx: &mut NodeCtx) {
        while self.round < MEM_ROUNDS
            && self.arrived.get(&self.round).copied().unwrap_or(0) >= 2
        {
            self.arrived.remove(&self.round);
            self.round += 1;
            if self.round < MEM_ROUNDS {
                self.do_round(ctx);
            }
        }
    }
}

impl EventNode for MemNode {
    fn on_event(&mut self, ctx: &mut NodeCtx, wake: Wake) -> Result<()> {
        match wake {
            Wake::Start => {
                self.do_round(ctx);
                Ok(())
            }
            Wake::Message(env) => {
                if env.round >= self.round {
                    *self.arrived.entry(env.round).or_insert(0) += 1;
                }
                self.advance_if_ready(ctx);
                Ok(())
            }
            _ => Ok(()),
        }
    }

    fn done(&self) -> bool {
        self.round >= MEM_ROUNDS
    }
}

/// Analytic peak floor for one memory-sweep point, page-granular: a
/// writer that dirties a single element still pays a whole page (or a
/// whole shard when unpaged), plus one transient assembled shard and
/// the shared base.
fn mem_peak_floor(writers: usize, paged: bool) -> u64 {
    let shard_bytes = (DIM * 4) as u64;
    let unit = if paged { (PAGE * 4) as u64 } else { shard_bytes };
    writers as u64 * unit + shard_bytes + shard_bytes
}

/// Run one memory-sweep point and return its JSON row.
fn mem_point(n: usize, paged: bool, budget_left: bool) -> Json {
    let shard_bytes = (DIM * 4) as u64;
    let writers = n / 16;
    let mode = if paged { "paged" } else { "shared" };
    let floor = mem_peak_floor(writers, paged);
    if !budget_left {
        println!(
            "mem   {n:>6} nodes [{mode:>6}]: skipped (wall budget spent); \
             analytic peak floor {floor}"
        );
        return Json::obj(vec![
            ("figure", Json::str("fig6")),
            ("kind", Json::str("memory_sweep")),
            ("nodes", Json::num(n as f64)),
            ("param_store", Json::str(mode)),
            ("page_size", Json::num(if paged { PAGE as f64 } else { 0.0 })),
            ("rounds", Json::num(MEM_ROUNDS as f64)),
            ("writers", Json::num(writers as f64)),
            ("wall_s", Json::Null),
            ("param_bytes_start", Json::num(shard_bytes as f64)),
            ("param_bytes_peak", Json::num(floor as f64)),
            ("live_pages", Json::Null),
            ("live_shards", Json::Null),
            ("completed", Json::Bool(false)),
            ("provenance", Json::str("computed")),
        ]);
    }

    let store = if paged {
        ParamStore::from_vec_paged(vec![0.5; DIM], PAGE)
    } else {
        ParamStore::from_vec(vec![0.5; DIM])
    };
    let mut sched = Scheduler::new(None, 4);
    for id in 0..n {
        sched.add_node(Box::new(MemNode {
            id,
            fleet: n,
            params: ParamSlot::stored(store.register()),
            writer: id < writers,
            round: 0,
            arrived: HashMap::new(),
        }));
    }
    let wall = Instant::now();
    sched.run().expect("memory sweep fleet");
    let wall_s = wall.elapsed().as_secs_f64();
    let stats = store.stats();
    let peak = stats.peak_resident_bytes + stats.shared_bytes;
    println!(
        "mem   {n:>6} nodes [{mode:>6}]: wall {wall_s:>6.2}s  peak param bytes {peak:>12}  \
         (floor {floor})  {}/{} shards materialized, {} divergent pages live",
        stats.live_shards, stats.nodes, stats.live_pages,
    );
    assert!(
        peak <= floor,
        "memory sweep peak {peak} exceeds page-granular analytic floor {floor} \
         ({n} nodes, {mode})"
    );
    Json::obj(vec![
        ("figure", Json::str("fig6")),
        ("kind", Json::str("memory_sweep")),
        ("nodes", Json::num(n as f64)),
        ("param_store", Json::str(mode)),
        ("page_size", Json::num(if paged { PAGE as f64 } else { 0.0 })),
        ("rounds", Json::num(MEM_ROUNDS as f64)),
        ("writers", Json::num(writers as f64)),
        ("wall_s", Json::num(wall_s)),
        ("param_bytes_start", Json::num(stats.shared_bytes as f64)),
        ("param_bytes_peak", Json::num(peak as f64)),
        ("live_pages", Json::num(stats.live_pages as f64)),
        ("live_shards", Json::num(stats.live_shards as f64)),
        ("completed", Json::Bool(true)),
        ("provenance", Json::str("measured")),
    ])
}

fn write_rows(rows: &[Json]) {
    let artifact = Json::Arr(rows.to_vec()).pretty();
    match std::fs::write("BENCH_fig6.json", &artifact) {
        Ok(()) => println!("trajectory written to BENCH_fig6.json"),
        Err(e) => println!("(could not write BENCH_fig6.json: {e})"),
    }
}

/// Peak parameter bytes for one engine run: the store report in
/// shared/paged mode, the analytic per-node-copy floor
/// (nodes × params × 4) in owned mode.
fn peak_param_bytes(r: &RunResult, nodes: usize) -> (u64, u64) {
    match &r.store {
        Some(report) => (
            report.at_start.resident_bytes + report.at_start.shared_bytes,
            report.at_end.peak_resident_bytes + report.at_end.shared_bytes,
        ),
        None => {
            let owned = (nodes * r.param_count * 4) as u64;
            (owned, owned)
        }
    }
}

fn main() {
    // Memory tier sweep first: artifact-free, so it runs (and the JSON
    // gets written) even where the PJRT engine is unavailable.
    println!("== fig6: memory tier sweep (ring gossip, writers = nodes/16) ==");
    let sweep_start = Instant::now();
    let mem_sweep: &[(usize, bool)] = &[
        (8192, false),
        (8192, true),
        (16384, true),
        (32768, true),
        (65536, true),
        (102400, true),
    ];
    let mut rows: Vec<Json> = Vec::new();
    for &(n, paged) in mem_sweep {
        let budget_left = sweep_start.elapsed().as_secs_f64() < MEM_BUDGET_S;
        rows.push(mem_point(n, paged, budget_left));
    }
    write_rows(&rows);

    println!("== fig6: scalability (fixed dataset, 4x nodes, degree 5 vs 9) ==");
    let Some(engine) = engine_or_skip(&["mlp"]) else { return };

    let small_n = 10usize;
    let large_n = 40usize;

    let mut s5 = bench_config("fig6/small_5reg");
    s5.nodes = small_n;
    s5.topology = "regular:5".into();
    s5.train_total = 1280;
    let mut l5 = s5.clone();
    l5.name = "fig6/large_5reg".into();
    l5.nodes = large_n;
    let mut l9 = l5.clone();
    l9.name = "fig6/large_9reg".into();
    l9.topology = "regular:9".into();

    let r_s5 = run_variant(&s5, &engine);
    let r_l5 = run_variant(&l5, &engine);
    let r_l9 = run_variant(&l9, &engine);

    println!(
        "shape: 5-regular {}n vs {}n accuracy: {:.4} vs {:.4} (paper: ~equal)",
        small_n,
        large_n,
        r_s5.final_accuracy(),
        r_l5.final_accuracy()
    );
    println!(
        "shape: degree 9 vs 5 at {}n: {:+.1} accuracy points (paper: +5.8)",
        large_n,
        (r_l9.final_accuracy() - r_l5.final_accuracy()) * 100.0
    );

    // Virtual-time scheduler sweep: wall-clock and parameter memory vs
    // node count on a bounded worker pool. Owned mode stops at the old
    // 1024 ceiling; the shared store carries the sweep to 4096 (its
    // startup cost is one base snapshot regardless of fleet size, and
    // broadcasts serialize once per round instead of once per neighbor);
    // paged points pin the page-granular accounting under real training,
    // where every node diverges and the two modes meet.
    println!("-- scheduler sweep: regular:6, 3 rounds, owned ≤1024 vs shared/paged ≤4096 --");
    let sweep: &[(usize, &str)] = &[
        (128, "owned"),
        (256, "owned"),
        (512, "owned"),
        (1024, "owned"),
        (128, "shared"),
        (256, "shared"),
        (512, "shared"),
        (1024, "shared"),
        (2048, "shared"),
        (4096, "shared"),
        (1024, "paged"),
        (4096, "paged"),
    ];
    for &(n, store_mode) in sweep {
        let mut cfg = bench_config(&format!("fig6/sched_{n}_{store_mode}"));
        cfg.runner = "scheduler".into();
        cfg.param_store = store_mode.into();
        cfg.nodes = n;
        cfg.rounds = 3;
        cfg.eval_every = 3;
        cfg.topology = "regular:6".into();
        cfg.train_total = n * 8; // one train batch per node per step
        cfg.test_total = 64;
        cfg.local_steps = 1;
        let r = run_variant(&cfg, &engine);
        let (start_bytes, peak_bytes) = peak_param_bytes(&r, n);
        let (live_shards, live_pages) = match &r.store {
            Some(report) => (report.at_end.live_shards, report.at_end.live_pages),
            None => (0, 0),
        };
        println!(
            "scale {n:>5} nodes [{store_mode:>6}]: wall {:>7.2}s  emu {:>8.1}s  acc {:.4}  \
             param bytes start {:>12} peak {:>12}  shards {live_shards} pages {live_pages}",
            r.wall_s,
            r.final_emu_time(),
            r.final_accuracy(),
            start_bytes,
            peak_bytes,
        );
        rows.push(Json::obj(vec![
            ("figure", Json::str("fig6")),
            ("kind", Json::str("engine_sweep")),
            ("nodes", Json::num(n as f64)),
            ("param_store", Json::str(store_mode)),
            ("rounds", Json::num(cfg.rounds as f64)),
            ("wall_s", Json::num(r.wall_s)),
            ("emu_time_s", Json::num(r.final_emu_time())),
            ("test_acc", Json::num(r.final_accuracy())),
            ("param_count", Json::num(r.param_count as f64)),
            ("param_bytes_start", Json::num(start_bytes as f64)),
            ("param_bytes_peak", Json::num(peak_bytes as f64)),
        ]));
    }
    write_rows(&rows);
    println!("== fig6 done ==");
}
